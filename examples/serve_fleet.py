"""Serve a GRU wave through the fault-tolerant fleet — and survive a
scripted replica kill mid-load.

The fleet is one call: build a FleetRouter over N ServeEngine replicas,
``generate(requests)``, read ``request.out`` — exactly the single-engine
surface. Here replica0 is killed while it holds in-flight requests and
restored later; the router detects the death by heartbeat timeout,
retries the lost requests on the survivor (token streams are unchanged —
greedy decode is deterministic, retries restart from scratch), and the
restored replica re-enters the rotation warm. Everything runs in virtual
time (ManualClock): deterministic, zero sleeps.

    PYTHONPATH=src python examples/serve_fleet.py
"""
import jax
import numpy as np

from repro.configs.base import GRUConfig, get_smoke_config
from repro.core.params import init_params
from repro.distributed.fault_tolerance import ManualClock
from repro.models import api as mapi
from repro.serve.engine import Request
from repro.serve.fleet import (FaultEvent, FaultInjector, FleetConfig,
                               FleetRouter)


def main():
    cfg = get_smoke_config("gru-jet").replace(
        gru=GRUConfig(input_dim=5, hidden_dim=16, num_classes=5,
                      seq_len=32, num_layers=2))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)

    rng = np.random.default_rng(7)
    reqs = [Request(prompt=rng.normal(size=(4 + i % 3, cfg.gru.input_dim))
                    .astype(np.float32), max_new_tokens=8)
            for i in range(8)]

    # kill replica0 at t=0.05 (mid-wave), bring it back at t=0.30
    injector = FaultInjector([
        FaultEvent(t=0.05, kind="kill", replica="replica0"),
        FaultEvent(t=0.30, kind="restore", replica="replica0"),
    ])
    router = FleetRouter(
        cfg, params, replicas=2, max_batch=2, clock=ManualClock(),
        config=FleetConfig(heartbeat_timeout_s=0.05, tick_s=0.01),
        injector=injector)

    done = router.generate(reqs)          # the whole fleet behind one call
    for i, r in enumerate(done):
        print(f"req{i}: {r.out}")
    s = router.stats()
    assert s["completed"] == s["submitted"] == len(reqs), s
    assert s["failed"] == 0 and s["kills"] == 1 and s["restores"] == 1
    print(f"\nsurvived: completed={s['completed']}/{s['submitted']} "
          f"retries={s['retries']} kills={s['kills']} "
          f"restores={s['restores']} "
          f"(replica0 restarts={s['replicas']['replica0']['restarts']})")


if __name__ == "__main__":
    main()
