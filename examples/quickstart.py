"""Quickstart: the paper's GRU in 60 seconds.

Builds the jet-tagging GRU (H=20, X=5), runs the three structural matvec
modes, shows they agree with the dense oracle, and measures the
latency-critical single-step serve path.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GRUConfig
from repro.configs.gru_jet import CONFIG
from repro.core import gru
from repro.core.latency import gru_step_model, gru_tile_cost
from repro.core.params import init_params


def main():
    cfg = CONFIG.gru
    params = init_params(gru.gru_classifier_specs(cfg), jax.random.key(0))
    xs = jax.random.normal(jax.random.key(1), (1, cfg.seq_len, cfg.input_dim))

    print(f"paper model: GRU H={cfg.hidden_dim} X={cfg.input_dim} "
          f"(AIE tile cost model: {gru_tile_cost(cfg.hidden_dim)} tiles)")

    h0 = jnp.zeros((1, cfg.hidden_dim))
    ref, _ = gru.gru_reference(params["cell"], h0, xs)
    for mode in ("rowwise", "cascade", "dense"):
        c = GRUConfig(cfg.input_dim, cfg.hidden_dim, matvec_mode=mode)
        h, _ = gru.gru_sequence(params["cell"], h0, xs, cfg=c)
        err = float(jnp.abs(h - ref).max())
        print(f"  {mode:8s} max|err| vs oracle = {err:.2e}")

    logits = gru.gru_classify(params, xs, cfg=cfg)
    print(f"jet-tagging logits: {np.asarray(logits)[0].round(3)}")

    # latency path: one recurrent step, batch 1 (the paper's measurement)
    step = jax.jit(lambda p, h, x: gru.gru_step(p, h, x=x, cfg=cfg))
    x1 = xs[:, 0]
    h = step(params["cell"], h0, x1)
    h.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(500):
        h = step(params["cell"], h, x1)
    h.block_until_ready()
    us = (time.perf_counter() - t0) / 500 * 1e6
    model = gru_step_model(cfg.hidden_dim, cfg.input_dim)
    print(f"serve step: {us:.1f} us/step on this host; "
          f"analytic v5e model: {model.total_s*1e9:.0f} ns/step "
          f"(dominated by per-dispatch overhead — the gru_sequence Pallas "
          f"kernel amortizes it across all T steps, the TPU analogue of the "
          f"paper's free-running kernels; paper: 163-197 ns at H=28/32)")


if __name__ == "__main__":
    main()
