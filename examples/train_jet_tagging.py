"""End-to-end driver: train the paper's jet-tagging GRU for a few hundred
steps with checkpointing, then serve it and report per-step latency.

    PYTHONPATH=src python examples/train_jet_tagging.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.train import main as train_main


def main():
    with tempfile.TemporaryDirectory() as ck:
        state = train_main([
            "--arch", "gru-jet", "--steps", "300", "--batch", "64",
            "--lr", "3e-3", "--checkpoint-dir", ck,
            "--checkpoint-every", "100", "--log-every", "50",
        ])
        # resume from the checkpoint to prove restart works end to end
        print("--- simulated restart ---")
        train_main([
            "--arch", "gru-jet", "--steps", "320", "--batch", "64",
            "--lr", "3e-3", "--checkpoint-dir", ck, "--resume",
            "--log-every", "10",
        ])

    # serve the trained model
    from repro.configs.gru_jet import CONFIG
    from repro.core import gru
    from repro.data.pipeline import SyntheticStream
    from repro.configs.base import ShapeConfig
    stream = SyntheticStream(CONFIG, ShapeConfig("t", CONFIG.gru.seq_len,
                                                 256, "train"))
    batch = stream.batch_at(10_001)
    logits = gru.gru_classify(state["params"], jnp.asarray(batch["features"]),
                              cfg=CONFIG.gru)
    acc = float((np.asarray(logits).argmax(-1) == batch["labels"]).mean())
    print(f"held-out accuracy after training: {acc:.3f}")
    assert acc > 0.5, "training did not learn the teacher"


if __name__ == "__main__":
    main()
