"""Serve a small LM with batched requests through the engine (the paper's
latency-measurement methodology: consecutive step-to-step intervals).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main as serve_main


def main():
    serve_main(["--arch", "qwen3-0.6b", "--smoke", "--requests", "4",
                "--prompt-len", "12", "--max-new", "24"])


if __name__ == "__main__":
    main()
