"""Concurrent clients on the serving fleet — the asyncio front-end.

Eight client coroutines each ``await client.submit(...)`` and stream
their tokens with ``async for``, over the same fault-tolerant
FleetRouter as ``examples/serve_fleet.py`` — including the scripted
replica kill/restore. One client disconnects mid-stream (its task is
cancelled), which propagates into ``FleetRouter.cancel``: the request
leaves its wave lane and the other seven clients finish unharmed, with
token streams bitwise-equal to the synchronous fleet path. Everything
runs in virtual time (ManualClock): deterministic, zero sleeps — the
asserts make this the CI async smoke.

    PYTHONPATH=src python examples/serve_async.py
"""
import asyncio

import jax
import numpy as np

from repro.configs.base import GRUConfig, get_smoke_config
from repro.core.params import init_params
from repro.distributed.fault_tolerance import ManualClock
from repro.models import api as mapi
from repro.serve.async_frontend import AsyncFleetClient
from repro.serve.engine import Request
from repro.serve.fleet import (FaultEvent, FaultInjector, FleetConfig,
                               FleetRouter)

N_CLIENTS = 8


def _build():
    cfg = get_smoke_config("gru-jet").replace(
        gru=GRUConfig(input_dim=5, hidden_dim=16, num_classes=5,
                      seq_len=32, num_layers=2))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    return cfg, params


def _requests(cfg):
    rng = np.random.default_rng(7)
    return [Request(prompt=rng.normal(size=(4 + i % 3, cfg.gru.input_dim))
                    .astype(np.float32), max_new_tokens=8)
            for i in range(N_CLIENTS)]


def _router(cfg, params):
    # same scripted fault as the sync example: kill replica0 mid-wave,
    # restore it while the fleet is still serving
    injector = FaultInjector([
        FaultEvent(t=0.05, kind="kill", replica="replica0"),
        FaultEvent(t=0.15, kind="restore", replica="replica0")])
    return FleetRouter(
        cfg, params, replicas=2, max_batch=2, clock=ManualClock(),
        config=FleetConfig(heartbeat_timeout_s=0.05, tick_s=0.01),
        injector=injector)


async def serve(cfg, params, reqs):
    """N concurrent client coroutines; client 0 disconnects mid-stream."""
    streamed = [None] * len(reqs)

    async def client_coro(client, i, req, first_token):
        handle = await client.submit(req)
        toks = []
        async for tok in handle:
            toks.append(tok)
            first_token.set()
        streamed[i] = toks

    router = _router(cfg, params)
    async with AsyncFleetClient(router) as client:
        first_token = asyncio.Event()
        victim = asyncio.create_task(
            client_coro(client, 0, reqs[0], first_token))
        others = [asyncio.create_task(
            client_coro(client, i, reqs[i], first_token))
            for i in range(1, len(reqs))]
        await first_token.wait()             # someone is mid-stream
        victim.cancel()                      # client 0 hangs up
        await asyncio.gather(victim, *others, return_exceptions=True)
    return router, streamed


def main():
    cfg, params = _build()
    reqs = _requests(cfg)
    router, streamed = asyncio.run(serve(cfg, params, reqs))

    # the synchronous path on the same seeds: streams must match bitwise
    sync_reqs = _requests(cfg)
    _router(cfg, params).generate(sync_reqs)

    s = router.stats()
    survivors = list(range(1, N_CLIENTS))
    for i in survivors:
        print(f"client{i}: {streamed[i]}")
        assert reqs[i].done and streamed[i] == reqs[i].out
        assert streamed[i] == sync_reqs[i].out, "async != sync stream"
    # the disconnect propagated without stalling anyone
    assert s["cancelled"] == 1 and not reqs[0].done
    assert router.tickets[0].status == "cancelled"
    assert router.tickets[0].flights == []
    # 100% of still-connected admitted requests completed under faults
    assert s["completed"] == len(survivors) and s["failed"] == 0
    assert s["kills"] == 1 and s["restores"] == 1
    print(f"\nasync fleet: {N_CLIENTS} concurrent clients, "
          f"completed={s['completed']} cancelled={s['cancelled']} "
          f"(mid-stream disconnect) retries={s['retries']} "
          f"kills={s['kills']} restores={s['restores']}; "
          f"streams bitwise-equal to the synchronous path")


if __name__ == "__main__":
    main()
