"""Fault-tolerant training demo: inject two node failures mid-run; the
supervisor shrinks the mesh, restores the last committed checkpoint and
finishes — the loss trajectory keeps descending across restarts.

    PYTHONPATH=src python examples/elastic_training.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ShapeConfig, TrainConfig, get_smoke_config
from repro.data.pipeline import SyntheticStream
from repro.distributed.fault_tolerance import ElasticMeshManager, Supervisor
from repro.distributed.sharding import ShardCtx
from repro.train import trainer


def main():
    cfg = get_smoke_config("qwen3-0.6b")
    tcfg = TrainConfig(learning_rate=2e-3, warmup_steps=5, total_steps=60)
    stream = SyntheticStream(cfg, ShapeConfig("t", 32, 8, "train"))

    with tempfile.TemporaryDirectory() as ckdir:
        mgr = CheckpointManager(ckdir, keep=2)
        mesh_mgr = ElasticMeshManager(total_devices=8, model_parallel=2)

        def build(mesh_shape):
            print(f"[supervisor] (re)building for mesh shape {mesh_shape}")
            step_jit = jax.jit(trainer.make_train_step(cfg, tcfg, ShardCtx()),
                               donate_argnums=(0,))

            def step_fn(state, step):
                batch = {k: jnp.asarray(v)
                         for k, v in stream.batch_at(step).items()}
                state, metrics = step_jit(state, batch)
                return state, {"loss": float(metrics["loss"])}

            state = trainer.init_state(cfg, tcfg)

            def save_fn(state, step):
                mgr.save(state, step)

            def restore_fn(like):
                step = mgr.latest_step() or 0
                st = mgr.restore(like, step=step) if step else like
                print(f"[supervisor] restored checkpoint at step {step}")
                return st, step
            return step_fn, state, save_fn, restore_fn

        sup = Supervisor(mesh_mgr, build, checkpoint_every=10)
        state, step, history = sup.run(40, inject={13: [0], 27: [1]})
        losses = [m["loss"] for _, m in history]
        print(f"completed {step} steps with {sup.restarts} restarts; "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
        assert step == 40 and sup.restarts == 2
        assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
