"""Fleet serving under load: throughput vs per-request tail latency, with
and without injected replica faults, depth-aware vs static routing (A/B).

The paper's constraint is a per-request latency bound; a single engine
meets it per kernel, the fleet (``repro.serve.fleet``) must keep meeting
it while replicas crash and recover. This benchmark drives a FleetRouter
with an open-loop arrival process under the REAL clock:

* **Arrivals** — seeded Poisson process (exponential inter-arrival gaps)
  at ``--rate`` requests/s.
* **Prompts** — heavy-tailed lengths (clipped lognormal), so the prefill
  bucket mix is realistic and depth routing has something to exploit.
* **Faults** (``faults=True`` arms) — a deterministic schedule placed at
  fractions of the arrival horizon: replica0 is killed at 25% and
  restored at 60%; replica1 runs a slow window (recorded-signal
  inflation — the fleet is single-process under a real clock, see the
  fleet module docstring) from 20% to 50% so the straggler/hedging path
  exercises too.

Four runs share one request seed: {depth, static} x {no-fault, faults};
``--autotune`` adds two more arms, ``tuned_{nofault,faults}`` — depth
routing plus a per-replica :class:`AutoTuner` warmed on a replay of the
same workload (tune on yesterday's traffic, serve today's), the fleet
A/B the CI gate judges (``tuned e2e p99 <= 1.1x static`` and zero
drops). Tuned runs snapshot/restore the process-global CostModel so
online recalibration in one arm never leaks into the next, and the full
decision records land in ``BENCH_autotune_decisions.json``.

Every run reports throughput, e2e p50/p99 (admit->finish, including
fleet queueing, retries and hedging — the honest per-request numbers)
and the full fault accounting. CI asserts the faulted runs drop nothing:
``completed == admitted`` and ``failed == 0`` with ``kills >= 1``.

``--horizon SECONDS`` sizes the workload from the arrival process
(``n = rate x horizon``) instead of a raw count; ``--saturation``
sweeps offered-load multipliers and emits an offered-load vs e2e-p99
curve per arm (where the tuned arm peels away from static as the fleet
saturates).

    PYTHONPATH=src python benchmarks/serve_fleet.py [--smoke] [--autotune]

Emits BENCH_serve_fleet.json. CSV: name,value,notes
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.configs.base import GRUConfig, get_smoke_config
from repro.core import runtime
from repro.core.params import init_params
from repro.models import api as mapi
from repro.serve.engine import Request, bucket_len
from repro.serve.fleet import (FaultEvent, FaultInjector, FleetConfig,
                               FleetRejected, FleetRouter)


def _setup(hidden: int, layers: int):
    cfg = get_smoke_config("gru-jet").replace(
        gru=GRUConfig(input_dim=5, hidden_dim=hidden, num_classes=5,
                      seq_len=64, num_layers=layers))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    return cfg, params


def _workload(cfg, n: int, rate: float, seed: int, max_prompt: int,
              max_new: int):
    """Seeded open-loop workload: Poisson arrival offsets + heavy-tail
    (lognormal, clipped) prompt lengths. Same seed -> same requests, so
    the A/B arms serve identical traffic."""
    rng = np.random.default_rng(seed)
    t_arr = np.cumsum(rng.exponential(1.0 / rate, n))
    lens = np.clip(np.rint(np.exp(rng.normal(1.5, 0.8, n))),
                   2, max_prompt).astype(int)
    X = cfg.gru.input_dim
    reqs = [Request(prompt=rng.normal(size=(int(L), X)).astype(np.float32),
                    max_new_tokens=max_new)
            for L in lens]
    return t_arr, reqs


def _prewarm(router: FleetRouter, cfg, lens) -> None:
    """Compile every replica's prefill buckets + decode jit out-of-band
    (direct engine calls — no router counters touched), so measured
    queue waits are service, not trace time, and slow first steps don't
    trip the heartbeat/straggler detectors spuriously."""
    bucket_min = router.replicas[0].engine.bucket_min
    buckets = sorted({bucket_len(int(L), bucket_min) for L in lens})
    X = cfg.gru.input_dim
    for rep in router.replicas:
        warm = [Request(prompt=np.zeros((b, X), np.float32),
                        max_new_tokens=1) for b in buckets]
        rep.engine.generate(warm)


def _tune_warmup(router: FleetRouter, reqs) -> None:
    """Replay the workload through each replica's engine directly (no
    router counters): the tuners observe the real prompt-length
    distribution and real step timings, retune at the drain boundary,
    and a second pass compiles the tuned bucket ladder — so the measured
    run starts with yesterday's-traffic tuning applied and pays no
    mid-run ladder compiles."""
    for rep in router.replicas:
        for p in range(2):
            clones = [Request(prompt=r.prompt,
                              max_new_tokens=(r.max_new_tokens if p == 0
                                              else 1))
                      for r in reqs]
            rep.engine.generate(clones)


def _fault_schedule(horizon_s: float, t0: float):
    """Kill/restore + slow window at fixed fractions of the arrival
    horizon, shifted to absolute clock time ``t0``."""
    rel = [FaultEvent(t=0.25 * horizon_s, kind="kill", replica="replica0"),
           FaultEvent(t=0.60 * horizon_s, kind="restore", replica="replica0"),
           FaultEvent(t=0.20 * horizon_s, kind="slow", replica="replica1",
                      factor=5.0),
           FaultEvent(t=0.50 * horizon_s, kind="slow", replica="replica1",
                      factor=1.0)]
    return FaultInjector([dataclasses.replace(e, t=t0 + e.t) for e in rel])


def run_once(cfg, params, *, routing: str, faults: bool, n: int, rate: float,
             seed: int, replicas: int, max_batch: int, max_prompt: int,
             max_new: int, label: str, csv: bool = True,
             autotune: bool = False,
             wall_limit_s: float = 300.0) -> dict:
    t_arr, reqs = _workload(cfg, n, rate, seed, max_prompt, max_new)
    horizon = float(t_arr[-1])
    config = FleetConfig(
        routing=routing,
        queue_limit=n + 8,               # open-loop: never shed own traffic
        retry_budget=5,                  # headroom over the injected kill
        # real clock: a tick is one decode step per replica; the timeout
        # must dominate any single step or a busy replica reads as dead
        heartbeat_timeout_s=max(1.0, 0.15 * horizon),
        backoff_base_s=0.05,
        straggler_factor=4.0)
    # online recalibration mutates the PROCESS-GLOBAL CostModel; restore
    # the pre-run model afterwards so one arm's folds never leak into the
    # next arm's dispatch (each run_once is a self-contained experiment)
    model_snap = runtime.cost_model()
    try:
        router = FleetRouter(cfg, params, replicas=replicas,
                             max_batch=max_batch, config=config,
                             autotune=autotune)
        _prewarm(router, cfg, [len(r.prompt) for r in reqs])
        if autotune:
            _tune_warmup(router, reqs)
        t0 = router.clock.now()
        if faults:
            router.injector = _fault_schedule(horizon, t0)
        admitted, arrival_shed, i = 0, 0, 0
        while i < n or any(t.outstanding for t in router.tickets):
            now = router.clock.now() - t0
            if now > wall_limit_s:
                raise RuntimeError(f"{label}: fleet run exceeded "
                                   f"{wall_limit_s}s wall limit")
            while i < n and t_arr[i] <= now:
                try:
                    router.submit(reqs[i])
                    admitted += 1
                except FleetRejected:
                    arrival_shed += 1
                i += 1
            router.tick()
        dur = router.clock.now() - t0
        s = router.stats()
        if s["completed"] == 0:
            # an arm that served nothing has NaN percentiles (never a
            # fake-perfect 0.0) — and NaN fails every <= comparison, so
            # the p99 gates would silently become vacuous. Die loudly.
            raise RuntimeError(
                f"{label}: arm completed 0 requests (admitted={admitted}, "
                f"arrival_shed={arrival_shed}, failed={s['failed']}) — "
                f"empty arms have no percentiles and cannot be gated")
        row = {"label": label, "routing": routing, "faults": faults,
               "autotune": autotune,
               "arrivals": n, "admitted": admitted,
               "arrival_shed": arrival_shed,
               "completed": s["completed"], "failed": s["failed"],
               "shed": s["shed"], "retries": s["retries"],
               "hedges": s["hedges"],
               "hedges_cancelled": s["hedges_cancelled"],
               "kills": s["kills"], "restores": s["restores"],
               "duration_s": round(dur, 4),
               "throughput_rps": round(s["completed"] / max(dur, 1e-9), 2),
               "e2e_p50_s": round(s["e2e_p50_s"], 5),
               "e2e_p99_s": round(s["e2e_p99_s"], 5),
               "queue_wait_p50_s": round(s["queue_wait_p50_s"], 5),
               "queue_wait_p99_s": round(s["queue_wait_p99_s"], 5),
               "replicas": {name: {k: v[k] for k in
                                   ("alive", "restarts", "steps",
                                    "requests", "wave_size",
                                    "bucket_ladder", "retunes")}
                            for name, v in s["replicas"].items()}}
        if autotune:
            # compact per-run counts on the row; the FULL decision records
            # (with justifying measurements) go to the decisions artifact
            full = {rep.name: rep.engine.latency_stats()["autotune"]
                    for rep in router.replicas}
            row["autotune_summary"] = {
                name: {"retunes": at.get("retunes", 0),
                       "decisions": len(at.get("decisions", ())),
                       "wave_size": at["wave_size"],
                       "bucket_ladder": at["bucket_ladder"]}
                for name, at in full.items()}
            row["_decisions_full"] = {
                name: at.get("decisions", []) for name, at in full.items()}
    finally:
        if autotune:
            runtime.set_cost_model(model_snap)
    if csv:
        print(f"fleet_{label},{row['throughput_rps']:.2f},"
              f"rps;e2e_p99={row['e2e_p99_s'] * 1e3:.1f}ms;"
              f"completed={row['completed']}/{row['admitted']};"
              f"retries={row['retries']};hedges={row['hedges']};"
              f"kills={row['kills']}")
    return row


def run(n: int = 120, rate: float = 20.0, hidden: int = 32, layers: int = 2,
        replicas: int = 2, max_batch: int = 4, max_prompt: int = 32,
        max_new: int = 8, seed: int = 0, autotune: bool = False,
        saturation: tuple = (),
        json_path: str = "BENCH_serve_fleet.json",
        decisions_path: str = "BENCH_autotune_decisions.json",
        csv: bool = True) -> dict:
    cfg, params = _setup(hidden, layers)
    runs, decisions = [], []
    arms = [(routing, faults, False)
            for routing in ("depth", "static") for faults in (False, True)]
    if autotune:
        # the tuned arms ride depth routing: tuned-vs-static isolates what
        # the AUTOTUNER buys on top of the better routing baseline
        arms += [("depth", False, True), ("depth", True, True)]
    for routing, faults, tuned in arms:
        label = (f"{'tuned' if tuned else routing}_"
                 f"{'faults' if faults else 'nofault'}")
        row = run_once(
            cfg, params, routing=routing, faults=faults, n=n, rate=rate,
            seed=seed, replicas=replicas, max_batch=max_batch,
            max_prompt=max_prompt, max_new=max_new, label=label,
            autotune=tuned, csv=csv)
        full = row.pop("_decisions_full", None)
        if full is not None:
            decisions.append({"label": label, "replicas": full})
        runs.append(row)
    summary = {}
    by = {r["label"]: r for r in runs}
    if by["depth_nofault"]["e2e_p99_s"] > 0:
        summary["static_over_depth_p99"] = round(
            by["static_nofault"]["e2e_p99_s"]
            / by["depth_nofault"]["e2e_p99_s"], 3)
    if autotune and by["static_nofault"]["e2e_p99_s"] > 0:
        # the CI gate's A/B: the feedback loop must never LOSE to the
        # static configuration it replaced (<= 1.1x static e2e p99)
        summary["tuned_over_static_p99"] = round(
            by["tuned_nofault"]["e2e_p99_s"]
            / by["static_nofault"]["e2e_p99_s"], 3)
        summary["tuned_retunes"] = sum(
            v["retunes"] for v in by["tuned_nofault"]["autotune_summary"]
            .values())
    for label, r in by.items():
        if r["faults"]:
            summary[f"{label}_zero_drops"] = bool(
                r["failed"] == 0 and r["completed"] == r["admitted"])
    # saturation sweep: same workload shape at scaled offered load, per
    # arm — where the curves peel apart is the fleet's capacity knee
    sat_rows = []
    sat_arms = ["depth", "static"] + (["tuned"] if autotune else [])
    for mult in saturation:
        n_sat = max(16, n // 2)          # shorter runs: the sweep is a
        for arm in sat_arms:             # curve, not a precision estimate
            r = run_once(
                cfg, params, routing="depth" if arm == "tuned" else arm,
                faults=False, n=n_sat, rate=rate * mult, seed=seed,
                replicas=replicas, max_batch=max_batch,
                max_prompt=max_prompt, max_new=max_new,
                label=f"sat_{arm}_x{mult:g}", autotune=(arm == "tuned"),
                csv=False)
            r.pop("_decisions_full", None)
            sat_rows.append({"offered_rps": round(rate * mult, 3),
                             "arm": arm, "arrivals": n_sat,
                             "completed": r["completed"],
                             "throughput_rps": r["throughput_rps"],
                             "e2e_p99_s": r["e2e_p99_s"]})
            if csv:
                print(f"fleet_sat_{arm}_x{mult:g},"
                      f"{r['e2e_p99_s'] * 1e3:.1f},"
                      f"e2e_p99_ms@offered={rate * mult:g}rps")
    out = {"bench": "serve_fleet", "backend": jax.default_backend(),
           "replicas": replicas, "rate_rps": rate, "autotune": autotune,
           "runs": runs, "summary": summary}
    if sat_rows:
        out["saturation"] = sat_rows
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
    if autotune:
        with open(decisions_path, "w") as f:
            json.dump({"bench": "autotune_decisions",
                       "rate_rps": rate, "replicas": replicas,
                       "runs": decisions}, f, indent=2)
    if csv:
        for k, v in summary.items():
            print(f"fleet_{k},{float(v) if not isinstance(v, bool) else int(v)},summary")
        print(f"fleet_artifact,0.00,{json_path}")
        if autotune:
            print(f"fleet_autotune_artifact,0.00,{decisions_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced load for CI (still emits the artifact and "
                         "runs the faulted arms)")
    ap.add_argument("--n", type=int, default=None, help="total arrivals")
    ap.add_argument("--rate", type=float, default=None, help="arrivals/s")
    ap.add_argument("--horizon", type=float, default=None,
                    help="arrival horizon in seconds; sizes the workload "
                         "as n = rate x horizon (overrides --n)")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", action="store_true",
                    help="add the tuned_{nofault,faults} arms (per-replica "
                         "AutoTuner warmed on a workload replay) and emit "
                         "BENCH_autotune_decisions.json")
    ap.add_argument("--saturation", default=None,
                    help="comma-separated offered-load multipliers for the "
                         "saturation sweep (default: 0.5,1,2 for full "
                         "runs, off for --smoke; pass '' to disable)")
    ap.add_argument("--json", default="BENCH_serve_fleet.json")
    ap.add_argument("--decisions-json",
                    default="BENCH_autotune_decisions.json")
    args = ap.parse_args()
    if args.saturation is None:
        sat = () if args.smoke else (0.5, 1.0, 2.0)
    else:
        sat = tuple(float(m) for m in args.saturation.split(",") if m)
    rate = args.rate or (6.0 if args.smoke else 20.0)
    n = args.n or (24 if args.smoke else 120)
    if args.horizon is not None:
        n = max(1, int(round(rate * args.horizon)))
    if args.smoke:
        run(n=n, rate=rate, hidden=16, layers=1,
            replicas=args.replicas, max_prompt=16, max_new=4,
            seed=args.seed, autotune=args.autotune, saturation=sat,
            json_path=args.json, decisions_path=args.decisions_json)
    else:
        run(n=n, rate=rate,
            replicas=args.replicas, seed=args.seed,
            autotune=args.autotune, saturation=sat,
            json_path=args.json, decisions_path=args.decisions_json)
