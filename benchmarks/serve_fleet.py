"""Fleet serving under load: throughput vs per-request tail latency, with
and without injected replica faults, depth-aware vs static routing (A/B).

The paper's constraint is a per-request latency bound; a single engine
meets it per kernel, the fleet (``repro.serve.fleet``) must keep meeting
it while replicas crash and recover. This benchmark drives a FleetRouter
with an open-loop arrival process under the REAL clock:

* **Arrivals** — seeded Poisson process (exponential inter-arrival gaps)
  at ``--rate`` requests/s.
* **Prompts** — heavy-tailed lengths (clipped lognormal), so the prefill
  bucket mix is realistic and depth routing has something to exploit.
* **Faults** (``faults=True`` arms) — a deterministic schedule placed at
  fractions of the arrival horizon: replica0 is killed at 25% and
  restored at 60%; replica1 runs a slow window (recorded-signal
  inflation — the fleet is single-process under a real clock, see the
  fleet module docstring) from 20% to 50% so the straggler/hedging path
  exercises too.

Four runs share one request seed: {depth, static} x {no-fault, faults}.
Every run reports throughput, e2e p50/p99 (admit->finish, including
fleet queueing, retries and hedging — the honest per-request numbers)
and the full fault accounting. CI asserts the faulted runs drop nothing:
``completed == admitted`` and ``failed == 0`` with ``kills >= 1``.

    PYTHONPATH=src python benchmarks/serve_fleet.py [--smoke]

Emits BENCH_serve_fleet.json. CSV: name,value,notes
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.configs.base import GRUConfig, get_smoke_config
from repro.core.params import init_params
from repro.models import api as mapi
from repro.serve.engine import Request, bucket_len
from repro.serve.fleet import (FaultEvent, FaultInjector, FleetConfig,
                               FleetRejected, FleetRouter)


def _setup(hidden: int, layers: int):
    cfg = get_smoke_config("gru-jet").replace(
        gru=GRUConfig(input_dim=5, hidden_dim=hidden, num_classes=5,
                      seq_len=64, num_layers=layers))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    return cfg, params


def _workload(cfg, n: int, rate: float, seed: int, max_prompt: int,
              max_new: int):
    """Seeded open-loop workload: Poisson arrival offsets + heavy-tail
    (lognormal, clipped) prompt lengths. Same seed -> same requests, so
    the A/B arms serve identical traffic."""
    rng = np.random.default_rng(seed)
    t_arr = np.cumsum(rng.exponential(1.0 / rate, n))
    lens = np.clip(np.rint(np.exp(rng.normal(1.5, 0.8, n))),
                   2, max_prompt).astype(int)
    X = cfg.gru.input_dim
    reqs = [Request(prompt=rng.normal(size=(int(L), X)).astype(np.float32),
                    max_new_tokens=max_new)
            for L in lens]
    return t_arr, reqs


def _prewarm(router: FleetRouter, cfg, lens) -> None:
    """Compile every replica's prefill buckets + decode jit out-of-band
    (direct engine calls — no router counters touched), so measured
    queue waits are service, not trace time, and slow first steps don't
    trip the heartbeat/straggler detectors spuriously."""
    bucket_min = router.replicas[0].engine.bucket_min
    buckets = sorted({bucket_len(int(L), bucket_min) for L in lens})
    X = cfg.gru.input_dim
    for rep in router.replicas:
        warm = [Request(prompt=np.zeros((b, X), np.float32),
                        max_new_tokens=1) for b in buckets]
        rep.engine.generate(warm)


def _fault_schedule(horizon_s: float, t0: float):
    """Kill/restore + slow window at fixed fractions of the arrival
    horizon, shifted to absolute clock time ``t0``."""
    rel = [FaultEvent(t=0.25 * horizon_s, kind="kill", replica="replica0"),
           FaultEvent(t=0.60 * horizon_s, kind="restore", replica="replica0"),
           FaultEvent(t=0.20 * horizon_s, kind="slow", replica="replica1",
                      factor=5.0),
           FaultEvent(t=0.50 * horizon_s, kind="slow", replica="replica1",
                      factor=1.0)]
    return FaultInjector([dataclasses.replace(e, t=t0 + e.t) for e in rel])


def run_once(cfg, params, *, routing: str, faults: bool, n: int, rate: float,
             seed: int, replicas: int, max_batch: int, max_prompt: int,
             max_new: int, label: str, csv: bool = True,
             wall_limit_s: float = 300.0) -> dict:
    t_arr, reqs = _workload(cfg, n, rate, seed, max_prompt, max_new)
    horizon = float(t_arr[-1])
    config = FleetConfig(
        routing=routing,
        queue_limit=n + 8,               # open-loop: never shed own traffic
        retry_budget=5,                  # headroom over the injected kill
        # real clock: a tick is one decode step per replica; the timeout
        # must dominate any single step or a busy replica reads as dead
        heartbeat_timeout_s=max(1.0, 0.15 * horizon),
        backoff_base_s=0.05,
        straggler_factor=4.0)
    router = FleetRouter(cfg, params, replicas=replicas, max_batch=max_batch,
                         config=config)
    _prewarm(router, cfg, [len(r.prompt) for r in reqs])
    t0 = router.clock.now()
    if faults:
        router.injector = _fault_schedule(horizon, t0)
    admitted, arrival_shed, i = 0, 0, 0
    while i < n or any(t.outstanding for t in router.tickets):
        now = router.clock.now() - t0
        if now > wall_limit_s:
            raise RuntimeError(f"{label}: fleet run exceeded "
                               f"{wall_limit_s}s wall limit")
        while i < n and t_arr[i] <= now:
            try:
                router.submit(reqs[i])
                admitted += 1
            except FleetRejected:
                arrival_shed += 1
            i += 1
        router.tick()
    dur = router.clock.now() - t0
    s = router.stats()
    row = {"label": label, "routing": routing, "faults": faults,
           "arrivals": n, "admitted": admitted,
           "arrival_shed": arrival_shed,
           "completed": s["completed"], "failed": s["failed"],
           "shed": s["shed"], "retries": s["retries"],
           "hedges": s["hedges"], "hedges_cancelled": s["hedges_cancelled"],
           "kills": s["kills"], "restores": s["restores"],
           "duration_s": round(dur, 4),
           "throughput_rps": round(s["completed"] / max(dur, 1e-9), 2),
           "e2e_p50_s": round(s["e2e_p50_s"], 5),
           "e2e_p99_s": round(s["e2e_p99_s"], 5),
           "queue_wait_p50_s": round(s["queue_wait_p50_s"], 5),
           "queue_wait_p99_s": round(s["queue_wait_p99_s"], 5),
           "replicas": {name: {k: v[k] for k in
                               ("alive", "restarts", "steps", "requests")}
                        for name, v in s["replicas"].items()}}
    if csv:
        print(f"fleet_{label},{row['throughput_rps']:.2f},"
              f"rps;e2e_p99={row['e2e_p99_s'] * 1e3:.1f}ms;"
              f"completed={row['completed']}/{row['admitted']};"
              f"retries={row['retries']};hedges={row['hedges']};"
              f"kills={row['kills']}")
    return row


def run(n: int = 120, rate: float = 20.0, hidden: int = 32, layers: int = 2,
        replicas: int = 2, max_batch: int = 4, max_prompt: int = 32,
        max_new: int = 8, seed: int = 0,
        json_path: str = "BENCH_serve_fleet.json", csv: bool = True) -> dict:
    cfg, params = _setup(hidden, layers)
    runs = []
    for routing in ("depth", "static"):
        for faults in (False, True):
            label = f"{routing}_{'faults' if faults else 'nofault'}"
            runs.append(run_once(
                cfg, params, routing=routing, faults=faults, n=n, rate=rate,
                seed=seed, replicas=replicas, max_batch=max_batch,
                max_prompt=max_prompt, max_new=max_new, label=label,
                csv=csv))
    summary = {}
    by = {r["label"]: r for r in runs}
    if by["depth_nofault"]["e2e_p99_s"] > 0:
        summary["static_over_depth_p99"] = round(
            by["static_nofault"]["e2e_p99_s"]
            / by["depth_nofault"]["e2e_p99_s"], 3)
    for label, r in by.items():
        if r["faults"]:
            summary[f"{label}_zero_drops"] = bool(
                r["failed"] == 0 and r["completed"] == r["admitted"])
    out = {"bench": "serve_fleet", "backend": jax.default_backend(),
           "replicas": replicas, "rate_rps": rate, "runs": runs,
           "summary": summary}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
    if csv:
        for k, v in summary.items():
            print(f"fleet_{k},{float(v) if not isinstance(v, bool) else int(v)},summary")
        print(f"fleet_artifact,0.00,{json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced load for CI (still emits the artifact and "
                         "runs the faulted arms)")
    ap.add_argument("--n", type=int, default=None, help="total arrivals")
    ap.add_argument("--rate", type=float, default=None, help="arrivals/s")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_serve_fleet.json")
    args = ap.parse_args()
    if args.smoke:
        run(n=args.n or 24, rate=args.rate or 6.0, hidden=16, layers=1,
            replicas=args.replicas, max_prompt=16, max_new=4,
            seed=args.seed, json_path=args.json)
    else:
        run(n=args.n or 120, rate=args.rate or 20.0,
            replicas=args.replicas, seed=args.seed, json_path=args.json)
