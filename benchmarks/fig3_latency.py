"""E1/E2 — the paper's Fig. 3: GRU forward-pass latency vs hidden size and
input size, Hybrid (fused aggregation) vs AIE (unfused).

Two measurements per point:

* measured   — wall-clock of the jitted single-step serve path on THIS host
  (CPU; relative behaviour, not v5e numbers),
* analytic   — the v5e latency model (repro.core.latency.gru_step_model),
  which reproduces the paper's two key findings:
  (1) fused/hybrid aggregation beats unfused as H grows,
  (2) decoupled W.x makes latency flat in X until the input GEMM dominates.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import GRUConfig
from repro.core import gru
from repro.core.latency import gru_step_model
from repro.core.params import init_params

HIDDEN = (20, 24, 28, 32)
INPUTS = (5, 8, 32, 128, 256)


def _measure_step(cfg: GRUConfig, iters: int = 300) -> float:
    params = init_params(gru.gru_cell_specs(cfg.input_dim, cfg.hidden_dim),
                         jax.random.key(0))
    h = jnp.zeros((1, cfg.hidden_dim))
    x = jnp.ones((1, cfg.input_dim))
    step = jax.jit(lambda p, h, x: gru.gru_step(p, h, x=x, cfg=cfg))
    out = step(params, h, x)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step(params, out, x)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv=True, iters: int = 300):
    rows = []
    for H in HIDDEN:
        for fused, label in ((True, "hybrid"), (False, "aie_unfused")):
            cfg = GRUConfig(input_dim=5, hidden_dim=H, fused_gates=fused)
            us = _measure_step(cfg, iters)
            model = gru_step_model(H, 5, fused_gates=fused)
            rows.append((f"fig3_h{H}_{label}", us,
                         f"v5e_model_ns={model.total_s*1e9:.1f}"))
    for X in INPUTS:
        for dec, label in ((True, "decoupled"), (False, "inline")):
            cfg = GRUConfig(input_dim=X, hidden_dim=32, decoupled_wx=dec)
            model = gru_step_model(32, X, decoupled_wx=dec)
            # measured path: decoupling shows up at the sequence level
            us = _measure_step(cfg, iters // 2)
            rows.append((f"fig3_x{X}_{label}", us,
                         f"v5e_model_ns={model.total_s*1e9:.1f}"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
    return rows


if __name__ == "__main__":
    run()
