"""Decode-step latency: fused persistent stack kernel vs layer-by-layer XLA
(plus the per-layer Pallas chain and, under a mesh, the sharded step).

The paper's figure of merit is the latency of ONE recurrent step. This
benchmark tracks it per PR for the serving implementations:

* ``xla``     — layer-by-layer structural modes (the paper's row-wise
  scheme by default), L separate dispatch chains per step.
* ``fused``   — ONE pallas_call advances the whole batch through all L
  layers (weights pinned in VMEM via constant index maps; interpret mode
  on CPU).
* ``chain``   — per-layer Pallas kernels (``--via runtime`` only; the
  hetero-capable backend, measured so the cost model can rank it).
* ``sharded`` — ONE persistent shard_map step over pre-sharded weights
  (``--mesh N``; requires N host devices, e.g. via XLA_FLAGS).
* ``fused_q8`` / ``chain_q8`` — the int8-weight-row twins (``--q8``,
  implied by ``--emit-costs``): exact-name pins, so they are measured
  regardless of the accuracy gate; every row carries a ``dtype`` column
  (``int8`` vs ``float32``) naming the served datapath.

``--via`` picks how the step is obtained:

* ``direct``  — the legacy entry point ``gru_stack_decode_step(impl=...)``
  (now an executor shim, kept for continuity of the series).
* ``runtime`` — ``repro.core.runtime.compile(cfg, ..., mode="decode")``:
  the compiled-executable path ServeEngine uses; each row then records
  WHICH backend the executable resolved (``backend`` field) and whether
  the choice came from measured calibration (``cost_source``), so the
  artifact documents the dispatch decision alongside the latency.

``--emit-costs`` additionally writes ``BENCH_backend_costs.json`` in the
schema ``repro.core.runtime.CostModel`` loads — the calibration artifact
that turns ``backend="auto"`` into measured per-shape dispatch. It forces
``--via runtime`` (cost entries are keyed by executor backend names) and
adds the ``chain`` impl so every single-host decode candidate is covered
(the CostModel only trusts calibrations that cover ALL legal candidates).
It also measures whole-SEQUENCE (prefill) latency per backend and emits
``op="sequence"`` rows next to the decode ones, so ``auto`` can pick the
prefill backend per shape too (``--seq-len`` sets the measured T).

``--family slstm`` measures the sLSTM cell family through the identical
sweep (xla + fused impls — the names its ``(slstm, ·)`` registry
namespace serves; forces ``--via runtime``). Every row in both artifacts
carries a ``family`` column, so one BENCH_backend_costs.json can hold
measured dispatch rows for several families side by side (the CostModel
keys on it; missing column = gru, pre-registry artifacts load unchanged).

``--mesh N`` extends both sweeps with the shard_map backends: the
``sharded`` decode step (``sharded_decode``), and — for sequences AND
decode — ``pallas_sharded``, the fused shard kernels inside the
shard_map.

Sweeps depth x batch and reports the per-step latency DISTRIBUTION
(p50/p99 — the paper's constraint is a tail bound, not an average), each
step timed individually with a device sync, all impls measured in
alternating rounds (shared-host drift bias). Emits BENCH_gru_decode.json.

    PYTHONPATH=src python benchmarks/decode_latency.py [--smoke] \
        [--via runtime] [--emit-costs] [--mesh N]

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GRUConfig
from repro.core import cells, gru, runtime
from repro.core.params import init_params

# impl label -> executor backend preference. ALL exact names: each impl
# pins one backend, so measurements are hermetic even when a stale
# calibration artifact sits in the cwd (a family pref like "pallas" would
# let measured costs from a previous run pick pallas_chain for the
# "fused" rows and drop pallas_fused from the emitted coverage). The
# "sharded" label pins the op-matching shard_map backend (sharded_decode
# for decode steps, sharded for sequences); pallas_sharded serves both.
_IMPL_PREF = {"xla": "xla", "fused": "pallas_fused", "chain": "pallas_chain",
              "sharded": "sharded_decode", "pallas_sharded": "pallas_sharded",
              "fused_q8": "pallas_fused_q8", "chain_q8": "pallas_chain_q8"}
_SEQ_IMPL_PREF = {"xla": "xla", "fused": "pallas_fused",
                  "chain": "pallas_chain", "sharded": "sharded",
                  "pallas_sharded": "pallas_sharded",
                  "fused_q8": "pallas_fused_q8", "chain_q8": "pallas_chain_q8"}
_MESH_IMPLS = ("sharded", "pallas_sharded")
_Q8_IMPLS = ("fused_q8", "chain_q8")

# impls each cell family registers backends for (``--family``): the sLSTM
# family serves xla + pallas_fused only (no chain/q8/sharded twins yet),
# and both its backend names resolve in the (slstm, ·) registry namespace
# under the same impl labels as GRU's.
_FAMILY_IMPLS = {
    "gru": tuple(_IMPL_PREF),
    "slstm": ("xla", "fused"),
}


def _family_params_state(cfg: GRUConfig, batch: int):
    """(raw params pytree, initial flat state) for ``cfg``'s cell family.
    The GRU path is kept byte-for-byte on its historical code path so the
    measured rows stay comparable across the artifact series."""
    if cells.cfg_family(cfg) == "gru":
        return (init_params(gru.gru_stack_specs(cfg), jax.random.key(0)),
                gru.stack_h0(cfg, batch))
    fam = cells.get_family(cfg.family)
    raw = init_params({"cells": fam.stack_specs(cfg)}, jax.random.key(0))
    return raw, fam.state0(cfg, batch)


def _make_step(cfg: GRUConfig, impl: str, batch: int, via: str = "direct",
               placement=None):
    """(jitted step fn, params, warm state, input, backend, cost_source)
    for one impl routed either through the legacy entry point or the
    compiled executable."""
    raw, hs = _family_params_state(cfg, batch)
    rcfg = dataclasses.replace(cfg, backend=_IMPL_PREF[impl])
    # serving prepares params once (ServeEngine via runtime.prepare);
    # measure the same placement-resident fast path here
    params = runtime.prepare(raw, rcfg, placement)
    x = jnp.ones((batch, cfg.input_dim))
    if via == "runtime":
        exe = runtime.compile(rcfg, batch=batch, placement=placement,
                              mode="decode")
        backend, src = exe.decode_backend, exe.cost_source
        f = jax.jit(lambda p, h, xv: exe.decode(p, h, xv))
    else:
        assert impl in ("xla", "fused"), \
            f"--via direct serves xla/fused only, not {impl!r}"
        assert cells.cfg_family(cfg) == "gru", \
            "--via direct is the legacy GRU entry point; other families " \
            "measure --via runtime"
        backend, src = impl, "n/a"
        params = {"cells": params.cells,
                  **({"stacked_cells": params.stacked}
                     if params.stacked is not None else {})}
        f = jax.jit(lambda p, h, xv: gru.gru_stack_decode_step(
            p, h, xv, cfg=cfg,
            impl="pallas" if impl == "fused" else impl))
    with warnings.catch_warnings():
        # the legacy shim warns at first TRACE, i.e. on this first call
        warnings.simplefilter("ignore", DeprecationWarning)
        out = f(params, hs, x)
    out[-1].block_until_ready()
    return f, params, out, x, backend, src


def _per_step_times(cfg: GRUConfig, batch: int, iters: int, via: str,
                    impls=("xla", "fused"), placement=None,
                    warmup: int = 10, rounds: int = 10):
    """Per-step latencies for ALL impls, measured in alternating rounds so
    machine-load drift (shared CI hosts) biases no implementation."""
    bench, backends, sources = {}, {}, {}
    for impl in impls:
        f, params, out, x, backend, src = _make_step(
            cfg, impl, batch, via,
            placement=placement if impl in _MESH_IMPLS else None)
        bench[impl] = (f, params, out, x)
        backends[impl] = backend
        sources[impl] = src
    ts = {impl: [] for impl in bench}
    for impl, (f, params, out, x) in bench.items():
        for _ in range(warmup):
            out = f(params, out, x)
        out[-1].block_until_ready()
        bench[impl] = (f, params, out, x)
    per_round = max(iters // rounds, 1)
    for _ in range(rounds):
        for impl, (f, params, out, x) in bench.items():
            for _ in range(per_round):
                t0 = time.perf_counter()
                out = f(params, out, x)
                out[-1].block_until_ready()
                ts[impl].append(time.perf_counter() - t0)
            bench[impl] = (f, params, out, x)
    return {impl: np.array(v) for impl, v in ts.items()}, backends, sources


def _make_seq(cfg: GRUConfig, impl: str, batch: int, seq_len: int,
              placement=None):
    """(jitted prefill fn, prepared params, h0s, xs, backend, cost_source)
    for one sequence impl, always via the compiled executable (sequence
    cost rows are keyed by executor backend names)."""
    raw, h0s = _family_params_state(cfg, batch)
    rcfg = dataclasses.replace(cfg, backend=_SEQ_IMPL_PREF[impl])
    params = runtime.prepare(raw, rcfg, placement)
    xs = jnp.ones((batch, seq_len, cfg.input_dim))
    exe = runtime.compile(rcfg, batch=batch, seq=seq_len,
                          placement=placement, mode="prefill")
    f = jax.jit(lambda p, h, x: exe.prefill(p, h, x))
    out = f(params, h0s, xs)
    out[-1].block_until_ready()
    return f, params, h0s, xs, exe.sequence_backend, exe.cost_source


def _per_seq_times(cfg: GRUConfig, batch: int, seq_len: int, iters: int,
                   impls=("xla", "fused"), placement=None, warmup: int = 3,
                   rounds: int = 5):
    """Whole-sequence (prefill) latencies for ALL impls, interleaved in
    alternating rounds like the decode sweep (same drift-bias rule)."""
    bench, backends, sources = {}, {}, {}
    for impl in impls:
        f, params, h0s, xs, backend, src = _make_seq(
            cfg, impl, batch, seq_len,
            placement=placement if impl in _MESH_IMPLS else None)
        bench[impl] = (f, params, h0s, xs)
        backends[impl] = backend
        sources[impl] = src
    ts = {impl: [] for impl in bench}
    for impl, (f, params, h0s, xs) in bench.items():
        for _ in range(warmup):
            f(params, h0s, xs)[-1].block_until_ready()
    per_round = max(iters // rounds, 1)
    for _ in range(rounds):
        for impl, (f, params, h0s, xs) in bench.items():
            for _ in range(per_round):
                t0 = time.perf_counter()
                f(params, h0s, xs)[-1].block_until_ready()
                ts[impl].append(time.perf_counter() - t0)
    return {impl: np.array(v) for impl, v in ts.items()}, backends, sources


def emit_costs(rows, json_path: str = "BENCH_backend_costs.json",
               csv: bool = True) -> dict:
    """Convert measured rows into the CostModel calibration artifact.

    Schema (``repro.core.runtime.CostModel.load``): one entry per
    (family, backend, op, depth, batch, hidden_dim) with the measured
    ``p50_us`` — ``op`` is ``"decode"`` or ``"sequence"`` (rows without an
    ``op`` field are decode rows from older sweeps; rows without a
    ``family`` column are GRU rows from pre-registry sweeps). Rows must
    come from ``--via runtime`` so ``backend`` holds executor backend
    names (the keys dispatch ranks by)."""
    seen, entries = set(), []
    for r in rows:
        if r.get("via") != "runtime":
            continue
        op = r.get("op", "decode")
        fam = r.get("family", "gru")
        key = (fam, r["backend"], op, r["depth"], r["batch"],
               r["hidden_dim"])
        if key in seen:
            continue
        seen.add(key)
        entries.append({"family": fam, "backend": r["backend"], "op": op,
                        "depth": r["depth"], "batch": r["batch"],
                        "hidden_dim": r["hidden_dim"],
                        "p50_us": r["p50_us"]})
    out = {"bench": "gru_backend_costs", "schema": 1,
           "device": jax.default_backend(), "entries": entries}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
    if csv:
        print(f"decode_costs_artifact,0.00,{json_path};"
              f"entries={len(entries)}")
    return out


def run(depths=(1, 2, 3), batches=(1, 8, 32), H=32, X: int = 5,
        iters: int = 300, json_path: str = "BENCH_gru_decode.json",
        csv: bool = True, via: str = "direct",
        impls=("xla", "fused"), mesh_axis: int = 0,
        costs_path: str = None, seq_len: int = 0, seq_iters: int = None,
        family: str = "gru"):
    """Depth x batch x hidden x impl sweep; emits the BENCH_gru_decode.json
    artifact (and, with ``costs_path``, the CostModel calibration).
    ``seq_len`` > 0 additionally measures whole-sequence prefill latency
    per impl at that T (``op="sequence"`` rows — the prefill half of the
    calibration). ``H`` may be one hidden size or a tuple — the q8 rows
    only become interesting at serving widths (the int8 working-set win is
    a bandwidth effect: B=1, H >= 256). ``family`` selects the cell family
    (``repro.core.cells``) every row measures and is recorded as a column
    in both artifacts; impls the family has no backend for are dropped."""
    allowed = _FAMILY_IMPLS[family]
    dropped = tuple(i for i in impls if i not in allowed)
    impls = tuple(i for i in impls if i in allowed)
    if dropped and csv:
        print(f"decode_family_drop,0.00,family={family};"
              f"no_backend_for={'/'.join(dropped)}")
    placement = None
    if mesh_axis:
        assert len(jax.devices()) >= mesh_axis, (
            f"--mesh {mesh_axis} needs {mesh_axis} devices; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={mesh_axis}")
        from repro.compat import make_mesh
        placement = runtime.Placement(mesh=make_mesh((mesh_axis,),
                                                     ("model",)))
        impls = tuple(impls) + _MESH_IMPLS
    hiddens = (H,) if isinstance(H, int) else tuple(H)
    rows = []
    for H in hiddens:
        for L in depths:
            for B in batches:
                _sweep_one(rows, L, B, H, X, iters, via, impls, placement,
                           seq_len, seq_iters, csv, family)
    summary = _summarize(rows, depths, batches, hiddens)
    out = {"bench": "gru_decode_step_latency", "family": family,
           "backend": jax.default_backend(), "via": via,
           "rows": rows, "summary": summary}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
    if csv:
        for k, v in summary.items():
            print(f"decode_{k},{v:.3f},speedup")
        print(f"decode_artifact,0.00,{json_path}")
    if costs_path:
        emit_costs(rows, costs_path, csv=csv)
    return out


def _sweep_one(rows, L, B, H, X, iters, via, impls, placement, seq_len,
               seq_iters, csv, family: str = "gru"):
    cfg = GRUConfig(input_dim=X, hidden_dim=H, num_layers=L, family=family)
    series, backends, sources = _per_step_times(
        cfg, B, iters, via, impls=impls, placement=placement)
    for impl, ts in series.items():
        row = {"op": "decode", "family": family,
               "depth": L, "batch": B, "impl": impl,
               "hidden_dim": H,
               "input_dim": X, "steps": len(ts),
               "via": via, "backend": backends[impl],
               "dtype": runtime.backend_dtype(backends[impl]),
               "cost_source": sources[impl],
               "p50_us": round(float(np.percentile(ts, 50)) * 1e6, 2),
               "p90_us": round(float(np.percentile(ts, 90)) * 1e6, 2),
               "p99_us": round(float(np.percentile(ts, 99)) * 1e6, 2),
               "mean_us": round(float(ts.mean()) * 1e6, 2)}
        rows.append(row)
        tag = "" if family == "gru" else f"{family}_"
        if csv:
            print(f"decode_{tag}L{L}_B{B}_H{H}_{impl},{row['p50_us']:.2f},"
                  f"p99={row['p99_us']:.2f}us;backend={row['backend']}")
    if seq_len:
        seq_impls = tuple(i for i in impls if i in _SEQ_IMPL_PREF)
        series, backends, sources = _per_seq_times(
            cfg, B, seq_len, seq_iters or max(iters // 4, 20),
            impls=seq_impls, placement=placement)
        for impl, ts in series.items():
            row = {"op": "sequence", "family": family,
                   "depth": L, "batch": B,
                   "impl": impl, "hidden_dim": H, "input_dim": X,
                   "seq_len": seq_len, "steps": len(ts),
                   "via": "runtime", "backend": backends[impl],
                   "dtype": runtime.backend_dtype(backends[impl]),
                   "cost_source": sources[impl],
                   "p50_us": round(float(np.percentile(ts, 50)) * 1e6, 2),
                   "p99_us": round(float(np.percentile(ts, 99)) * 1e6, 2),
                   "mean_us": round(float(ts.mean()) * 1e6, 2)}
            rows.append(row)
            tag = "" if family == "gru" else f"{family}_"
            if csv:
                print(f"seq_{tag}L{L}_B{B}_H{H}_T{seq_len}_{impl},"
                      f"{row['p50_us']:.2f},"
                      f"p99={row['p99_us']:.2f}us;"
                      f"backend={row['backend']}")


def _summarize(rows, depths, batches, hiddens):
    """Per-depth fused-vs-xla speedups (legacy keys, at the smallest swept
    hidden/batch) plus per-shape q8-vs-f32 speedups wherever both the f32
    and the int8 fused rows were measured."""
    summary = {}
    for L in depths:
        pair = {r["impl"]: r for r in rows
                if r.get("op", "decode") == "decode"
                and r["depth"] == L and r["batch"] == min(batches)
                and r["hidden_dim"] == min(hiddens)}
        if {"xla", "fused"} <= pair.keys():
            summary[f"p50_speedup_depth{L}"] = round(
                pair["xla"]["p50_us"] / max(pair["fused"]["p50_us"], 1e-9), 3)
    for H in hiddens:
        for L in depths:
            for B in batches:
                pair = {r["impl"]: r for r in rows
                        if r.get("op", "decode") == "decode"
                        and r["depth"] == L and r["batch"] == B
                        and r["hidden_dim"] == H}
                if {"fused", "fused_q8"} <= pair.keys():
                    summary[f"q8_p50_speedup_L{L}_B{B}_H{H}"] = round(
                        pair["fused"]["p50_us"]
                        / max(pair["fused_q8"]["p50_us"], 1e-9), 3)
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI (still emits the artifacts)")
    ap.add_argument("--via", choices=("direct", "runtime"), default="direct",
                    help="route steps through the legacy entry point or the "
                         "compiled executable (records the resolved backend "
                         "in the artifact)")
    ap.add_argument("--emit-costs", nargs="?", const="BENCH_backend_costs.json",
                    default=None, metavar="PATH",
                    help="also write the CostModel calibration artifact "
                         "(forces --via runtime and adds the chain impl so "
                         "every single-host decode candidate is covered)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="also measure the shard_map backends (the sharded "
                         "decode step and pallas_sharded sequence+decode) "
                         "on an N-device mesh (needs N host devices via "
                         "XLA_FLAGS)")
    ap.add_argument("--seq-len", type=int, default=0, metavar="T",
                    help="also measure whole-sequence prefill latency at "
                         "this T per impl (op=\"sequence\" rows; "
                         "--emit-costs defaults it to 16 so the "
                         "calibration covers prefill dispatch too)")
    ap.add_argument("--depths", type=int, nargs="+", default=None)
    ap.add_argument("--batches", type=int, nargs="+", default=None)
    ap.add_argument("--hidden", type=int, nargs="+", default=None,
                    metavar="H",
                    help="hidden sizes to sweep (default 32; the q8 rows "
                         "want serving widths too, e.g. --hidden 32 512)")
    ap.add_argument("--q8", action="store_true",
                    help="also measure the int8 backends (fused_q8 + "
                         "chain_q8 rows, exact-name pins — no accuracy "
                         "artifact needed to MEASURE them); --emit-costs "
                         "implies it so the calibration carries their "
                         "CostModel rows")
    ap.add_argument("--family", choices=sorted(_FAMILY_IMPLS),
                    default="gru",
                    help="cell family to measure (repro.core.cells "
                         "registry); slstm serves xla + fused only and "
                         "forces --via runtime; rows carry a family "
                         "column in both artifacts")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--json", default="BENCH_gru_decode.json")
    args = ap.parse_args()
    via = args.via
    impls = ("xla", "fused")
    if args.family != "gru":
        via = "runtime"                 # legacy direct path is GRU-only
    seq_len = args.seq_len
    if args.emit_costs:
        via = "runtime"                 # cost entries need backend names
        impls = ("xla", "fused", "chain")
        seq_len = seq_len or 16         # calibrate prefill dispatch too
    if args.q8 or args.emit_costs:
        via = "runtime"                 # q8 impls are executor-only
        impls = tuple(impls) + _Q8_IMPLS
    if args.mesh:
        via = "runtime"                 # the sharded impls are executor-only
    if args.smoke:
        run(depths=tuple(args.depths or (1, 3)),
            batches=tuple(args.batches or (1, 8)),
            H=tuple(args.hidden or (32,)),
            iters=args.iters or 120, json_path=args.json, via=via,
            impls=impls, mesh_axis=args.mesh, costs_path=args.emit_costs,
            seq_len=seq_len, family=args.family)
    else:
        run(depths=tuple(args.depths or (1, 2, 3)),
            batches=tuple(args.batches or (1, 8, 32)),
            H=tuple(args.hidden or (32,)),
            iters=args.iters or 300, json_path=args.json, via=via,
            impls=impls, mesh_axis=args.mesh, costs_path=args.emit_costs,
            seq_len=seq_len, family=args.family)
