"""Decode-step latency: fused persistent stack kernel vs layer-by-layer XLA.

The paper's figure of merit is the latency of ONE recurrent step. This
benchmark tracks it per PR for the two serving implementations:

* ``xla``   — layer-by-layer structural modes (the paper's row-wise scheme
  by default), L separate dispatch chains per step.
* ``fused`` — ONE pallas_call advances the whole batch through all L
  layers (weights pinned in VMEM via constant index maps; interpret mode
  on CPU).

``--via`` picks how the step is obtained:

* ``direct``  — the legacy entry point ``gru_stack_decode_step(impl=...)``
  (now an executor shim, kept for continuity of the series).
* ``runtime`` — ``repro.core.runtime.plan(cfg, mode="decode").decode``:
  the capability-dispatched executor path ServeEngine uses; each row then
  records WHICH backend the plan resolved (``backend`` field), so the
  artifact documents the dispatch decision alongside the latency.

Sweeps depth x batch and reports the per-step latency DISTRIBUTION
(p50/p99 — the paper's constraint is a tail bound, not an average), each
step timed individually with a device sync, both impls measured in
alternating rounds (shared-host drift bias). Emits BENCH_gru_decode.json.

    PYTHONPATH=src python benchmarks/decode_latency.py [--smoke] [--via runtime]

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.params import init_params


def _make_step(cfg: GRUConfig, impl: str, batch: int, via: str = "direct"):
    """(jitted step fn, params, warm state, input, backend name) for one
    impl routed either through the legacy entry point or the executor."""
    raw = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    rcfg = dataclasses.replace(cfg, backend=impl)
    # serving prepares params once (ServeEngine via runtime.prepare);
    # measure the same pre-stacked fast path here
    params = runtime.prepare(raw, rcfg)
    hs = gru.stack_h0(cfg, batch)
    x = jnp.ones((batch, cfg.input_dim))
    if via == "runtime":
        plan = runtime.plan(rcfg, batch=batch, mode="decode")
        backend = plan.decode_backend
        f = jax.jit(lambda p, h, xv: plan.decode(p, h, xv))
    else:
        backend = impl
        params = {"cells": params.cells,
                  **({"stacked_cells": params.stacked}
                     if params.stacked is not None else {})}
        f = jax.jit(lambda p, h, xv: gru.gru_stack_decode_step(
            p, h, xv, cfg=cfg, impl=impl))
    with warnings.catch_warnings():
        # the legacy shim warns at first TRACE, i.e. on this first call
        warnings.simplefilter("ignore", DeprecationWarning)
        out = f(params, hs, x)
    out[-1].block_until_ready()
    return f, params, out, x, backend


def _per_step_times(cfg: GRUConfig, batch: int, iters: int, via: str,
                    warmup: int = 10, rounds: int = 10):
    """Per-step latencies for BOTH impls, measured in alternating rounds so
    machine-load drift (shared CI hosts) biases neither implementation."""
    bench, backends = {}, {}
    for impl in ("xla", "fused"):
        f, params, out, x, backend = _make_step(
            cfg, "pallas" if impl == "fused" else "xla", batch, via)
        bench[impl] = (f, params, out, x)
        backends[impl] = backend
    ts = {impl: [] for impl in bench}
    for impl, (f, params, out, x) in bench.items():
        for _ in range(warmup):
            out = f(params, out, x)
        out[-1].block_until_ready()
        bench[impl] = (f, params, out, x)
    per_round = max(iters // rounds, 1)
    for _ in range(rounds):
        for impl, (f, params, out, x) in bench.items():
            for _ in range(per_round):
                t0 = time.perf_counter()
                out = f(params, out, x)
                out[-1].block_until_ready()
                ts[impl].append(time.perf_counter() - t0)
            bench[impl] = (f, params, out, x)
    return {impl: np.array(v) for impl, v in ts.items()}, backends


def run(depths=(1, 2, 3), batches=(1, 8, 32), H: int = 32, X: int = 5,
        iters: int = 300, json_path: str = "BENCH_gru_decode.json",
        csv: bool = True, via: str = "direct"):
    """Depth x batch x impl sweep; emits the BENCH_gru_decode.json artifact."""
    rows = []
    for L in depths:
        for B in batches:
            cfg = GRUConfig(input_dim=X, hidden_dim=H, num_layers=L)
            pair, backends = _per_step_times(cfg, B, iters, via)
            for impl, ts in pair.items():
                row = {"depth": L, "batch": B, "impl": impl, "hidden_dim": H,
                       "input_dim": X, "steps": len(ts),
                       "via": via, "backend": backends[impl],
                       "p50_us": round(float(np.percentile(ts, 50)) * 1e6, 2),
                       "p90_us": round(float(np.percentile(ts, 90)) * 1e6, 2),
                       "p99_us": round(float(np.percentile(ts, 99)) * 1e6, 2),
                       "mean_us": round(float(ts.mean()) * 1e6, 2)}
                rows.append(row)
                if csv:
                    print(f"decode_L{L}_B{B}_{impl},{row['p50_us']:.2f},"
                          f"p99={row['p99_us']:.2f}us;backend={row['backend']}")
    summary = {}
    for L in depths:
        pair = {r["impl"]: r for r in rows
                if r["depth"] == L and r["batch"] == min(batches)}
        if {"xla", "fused"} <= pair.keys():
            summary[f"p50_speedup_depth{L}"] = round(
                pair["xla"]["p50_us"] / max(pair["fused"]["p50_us"], 1e-9), 3)
    out = {"bench": "gru_decode_step_latency",
           "backend": jax.default_backend(), "via": via,
           "rows": rows, "summary": summary}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
    if csv:
        for k, v in summary.items():
            print(f"decode_{k},{v:.3f},fused_vs_xla")
        print(f"decode_artifact,0.00,{json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI (still emits the artifact)")
    ap.add_argument("--via", choices=("direct", "runtime"), default="direct",
                    help="route steps through the legacy entry point or the "
                         "capability-dispatched executor (records the "
                         "plan's backend choice in the artifact)")
    ap.add_argument("--depths", type=int, nargs="+", default=None)
    ap.add_argument("--batches", type=int, nargs="+", default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--json", default="BENCH_gru_decode.json")
    args = ap.parse_args()
    if args.smoke:
        run(depths=tuple(args.depths or (1, 3)),
            batches=tuple(args.batches or (1, 8)),
            iters=args.iters or 120, json_path=args.json, via=args.via)
    else:
        run(depths=tuple(args.depths or (1, 2, 3)),
            batches=tuple(args.batches or (1, 8, 32)),
            iters=args.iters or 300, json_path=args.json, via=args.via)
