"""E3 — the paper's Table 1: resource utilization vs hidden size.

AIE columns translate to TPU-native resources (DESIGN.md §2):
  tiles used          -> paper's 3*3*H+1 model (reported for reference) and
                         the Pallas grid cells of the fused-step kernel
  PL FF/LUT           -> VMEM working-set bytes per kernel block
  AIE AGGR TILE LAT   -> unfused (separate-aggregation) HLO op count vs the
                         fused epilogue's, from the lowered step

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.configs.base import GRUConfig
from repro.core import gru
from repro.core.latency import gru_tile_cost
from repro.core.params import init_params

HIDDEN = (20, 24, 28, 32)


def _hlo_op_count(cfg: GRUConfig) -> int:
    params = init_params(gru.gru_cell_specs(cfg.input_dim, cfg.hidden_dim),
                         jax.random.key(0))
    h = jax.ShapeDtypeStruct((1, cfg.hidden_dim), jnp.float32)
    x = jax.ShapeDtypeStruct((1, cfg.input_dim), jnp.float32)
    txt = (jax.jit(lambda p, h, x: gru.gru_step(p, h, x=x, cfg=cfg))
           .lower(params, h, x).compile().as_text())
    return len(re.findall(r"^\s+(?:ROOT )?%\S+ = ", txt, re.MULTILINE))


def run(csv=True):
    rows = []
    for H in HIDDEN:
        # paper's tile count and our kernel's VMEM footprint for one block
        tiles = gru_tile_cost(H)
        vmem = (H * 3 * H + 4 * 1 * 3 * H + 2 * 1 * H) * 4   # u + xp/b + h/h'
        fused_ops = _hlo_op_count(GRUConfig(5, H, fused_gates=True))
        unfused_ops = _hlo_op_count(GRUConfig(5, H, fused_gates=False))
        rows.append((f"table1_h{H}", 0.0,
                     f"aie_tiles={tiles};vmem_bytes={vmem};"
                     f"hlo_ops_fused={fused_ops};hlo_ops_unfused={unfused_ops}"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
    return rows


if __name__ == "__main__":
    run()
