"""E4 — row-wise vs cascade parallelization of the recurrent matvec.

Four views:
  (a) single-host wall-clock of the two STRUCTURAL modes (lax.map grid vs
      sequential-accumulation scan) at paper sizes and LM sizes,
  (b) the analytic v5e model across row_shards (the AIE-tiles -> TPU-chips
      translation of the paper's scaling argument),
  (c) collective bytes/ops parsed from the compiled shard_map programs on a
      4-device host mesh (subprocess; all-gather-only vs psum — Fig. 1b's
      aggregation study), including the beyond-paper v3 single-aggregation
      variant,
  (d) DEPTH SWEEP (``--num-layers 1 2 4``): per-step decode latency of a
      deep GRU stack per structural mode, written to BENCH_gru_depth.json —
      the paper's figure of merit extended to multi-layer stacks.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.latency import gru_step_model
from repro.core.params import init_params

_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.configs.base import GRUConfig
from repro.core import gru, rowparallel
from repro.core.params import init_params
from repro.launch.hloparse import analyze
H, X, B, T = 64, 16, 1, 8
mesh = jax.make_mesh((4,), ("model",))
params = init_params(gru.gru_cell_specs(X, H), jax.random.key(0))
h0 = jnp.zeros((B, H)); xs = jnp.ones((B, T, X))
for mode in ("rowwise", "cascade"):
    for variant in ("v1", "v3"):
        cfg = GRUConfig(input_dim=X, hidden_dim=H, matvec_mode=mode, variant=variant)
        f = jax.jit(lambda p, h, x: rowparallel.gru_sequence_sharded(p, h, x, mesh=mesh, cfg=cfg))
        a = analyze(f.lower(params, h0, xs).compile().as_text())
        kinds = ",".join(f"{k}:{int(v)}" for k, v in sorted(a.coll_counts.items()))
        print(f"E4SUB,{mode}_{variant},{a.total_coll_bytes:.0f},{kinds}")
"""


def _measure_seq(cfg: GRUConfig, H: int, X: int, T: int = 32,
                 iters: int = 50) -> float:
    params = init_params(gru.gru_cell_specs(X, H), jax.random.key(0))
    h0 = jnp.zeros((1, H))
    xs = jnp.ones((1, T, X))
    exe = runtime.compile(cfg, batch=1, seq=T, mode="sequence")
    f = jax.jit(lambda p, h, x: exe.sequence(p, (h,), x)[0][0])
    f((params,), h0, xs).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f((params,), h0, xs)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _measure_stack_decode(cfg: GRUConfig, iters: int = 200):
    """Per-step decode latency (us) of one compiled-executable pass through
    the stack, plus the backend the executable resolved."""
    params = runtime.prepare(
        init_params(gru.gru_stack_specs(cfg), jax.random.key(0)), cfg)
    hs = gru.stack_h0(cfg, 1)
    x = jnp.ones((1, cfg.input_dim))
    exe = runtime.compile(cfg, batch=1, mode="decode")
    f = jax.jit(lambda p, h, xv: exe.decode(p, h, xv))
    out = f(params, hs, x)
    out[-1].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(params, out, x)
    out[-1].block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6, exe.decode_backend


def run_depth_sweep(layers=(1, 2, 4), H: int = 32, X: int = 5,
                    json_path: str = "BENCH_gru_depth.json", csv=True):
    """Decode-latency depth sweep; emits the BENCH_gru_depth.json artifact."""
    results = []
    for L in layers:
        for mode in ("rowwise", "cascade", "dense"):
            cfg = GRUConfig(input_dim=X, hidden_dim=H, num_layers=L,
                            matvec_mode=mode)
            us, backend = _measure_stack_decode(cfg)
            results.append({"num_layers": L, "mode": mode, "hidden_dim": H,
                            "input_dim": X, "backend": backend,
                            "decode_step_us": round(us, 2)})
            if csv:
                print(f"e4_depth_L{L}_{mode},{us:.2f},stack_decode_step;"
                      f"backend={backend}")
    with open(json_path, "w") as f:
        json.dump({"bench": "gru_depth_decode_latency", "rows": results}, f,
                  indent=2)
    if csv:
        print(f"e4_depth_artifact,0.00,{json_path}")
    return results


def run(csv=True):
    rows = []
    for H, X in ((32, 5), (256, 64)):
        for mode in ("rowwise", "cascade", "dense"):
            cfg = GRUConfig(input_dim=X, hidden_dim=H, matvec_mode=mode)
            us = _measure_seq(cfg, H, X)
            rows.append((f"e4_seq_h{H}_{mode}", us, "structural_wall_clock"))
    for shards in (1, 4, 16):
        m = gru_step_model(1024, 256, row_shards=shards, dtype_bytes=2)
        rows.append((f"e4_model_shards{shards}", 0.0,
                     f"v5e_step_ns={m.total_s*1e9:.1f};"
                     f"coll_ns={m.collective_s*1e9:.1f}"))
    # (c) compiled collective study
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    try:
        out = subprocess.run([sys.executable, "-c", _SUB], env=env, text=True,
                             capture_output=True, timeout=420)
        for line in out.stdout.splitlines():
            if line.startswith("E4SUB,"):
                _, name, cbytes, kinds = line.split(",", 3)
                rows.append((f"e4_coll_{name}", 0.0,
                             f"coll_bytes={cbytes};{kinds}"))
        if out.returncode != 0:
            rows.append(("e4_coll_error", 0.0, out.stderr[-200:].replace("\n", " ")))
    except subprocess.TimeoutExpired:
        rows.append(("e4_coll_timeout", 0.0, "subprocess timeout"))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-layers", type=int, nargs="+", default=None,
                    help="run ONLY the depth sweep at these stack depths")
    ap.add_argument("--depth-json", default="BENCH_gru_depth.json")
    args = ap.parse_args()
    if args.num_layers:
        run_depth_sweep(tuple(args.num_layers), json_path=args.depth_json)
    else:
        run()
        run_depth_sweep(json_path=args.depth_json)
