# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV. E1/E2 = Fig. 3 (latency vs H and X), E3 = Table 1 (resources),
# E4 = rowwise-vs-cascade aggregation study (+ the deep-stack depth sweep,
# artifact: BENCH_gru_depth.json).
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import fig3_latency, rowwise_vs_cascade, table1_resources
    print("name,us_per_call,derived")
    fig3_latency.run(csv=True, iters=120)
    table1_resources.run(csv=True)
    rowwise_vs_cascade.run(csv=True)
    rowwise_vs_cascade.run_depth_sweep(csv=True)


if __name__ == "__main__":
    main()
