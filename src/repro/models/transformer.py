"""Decoder-only transformer LM: dense and MoE blocks, GQA attention.

Compile-time discipline for the 40-cell dry-run: layers are stacked and
scanned (``lax.scan`` over a (L, ...) param tree) with per-block remat, so
the HLO is one block body regardless of depth. The loss fuses unembedding
with a chunked, rematerialized cross-entropy so full (B,S,V) logits are
never materialized (vocab 256k x 1M tokens would otherwise dominate HBM).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.params import Spec, stack_specs
from repro.distributed.sharding import ShardCtx, constrain
from repro.models import attention as attn_mod
from repro.models import layers, moe as moe_mod
from repro.models.layers import cdtype


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def block_specs(cfg: ModelConfig) -> dict:
    s = {
        "ln1": layers.norm_specs(cfg.d_model, cfg.norm),
        "attn": attn_mod.attn_specs(cfg),
    }
    if not cfg.parallel_block:
        s["ln2"] = layers.norm_specs(cfg.d_model, cfg.norm)
    if cfg.moe is not None:
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp, cfg.mlp_bias)
    return s


def lm_specs(cfg: ModelConfig) -> dict:
    s = {
        "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),
        "blocks": stack_specs(block_specs(cfg), cfg.num_layers),
        "final_norm": layers.norm_specs(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = Spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                            init="fan_in")
    return s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def block_apply(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                *, ctx: ShardCtx, collect_kv: bool = False):
    """One transformer block. Returns (x, aux, kv-or-None)."""
    h = layers.norm_apply(p["ln1"], x, cfg.norm)
    a, kv = attn_mod.attention(p["attn"], cfg, h, ctx=ctx,
                               window=cfg.sliding_window, positions=positions)
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        if cfg.moe is not None:
            m, aux = moe_mod.moe_apply(p["moe"], cfg, h, ctx=ctx)
        else:
            m = layers.mlp_apply(p["mlp"], h, cfg.mlp)
        x = x + a + m
    else:
        x = x + a
        h2 = layers.norm_apply(p["ln2"], x, cfg.norm)
        if cfg.moe is not None:
            m, aux = moe_mod.moe_apply(p["moe"], cfg, h2, ctx=ctx)
        else:
            m = layers.mlp_apply(p["mlp"], h2, cfg.mlp)
        x = x + m
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)
    return x, aux, (kv if collect_kv else None)


def hidden_states(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
                  ctx: ShardCtx, collect_kv: bool = False,
                  inputs_embeds: Optional[jax.Array] = None):
    """tokens (B,S) -> (h (B,S,D), aux, stacked kv or None)."""
    B, S = tokens.shape
    x = (inputs_embeds if inputs_embeds is not None
         else layers.embed_apply(params["embed"], tokens, cdtype(cfg)))
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(carry, p_layer):
        x = carry
        fn = block_apply
        if cfg.remat:
            fn = jax.checkpoint(
                functools.partial(block_apply, cfg=cfg, ctx=ctx,
                                  collect_kv=collect_kv),
                prevent_cse=False, static_argnums=())
            x2, aux, kv = fn(p_layer, x=x, positions=positions)
        else:
            x2, aux, kv = fn(p_layer, cfg, x, positions, ctx=ctx,
                             collect_kv=collect_kv)
        return x2, (aux, kv)

    if cfg.scan_layers:
        x, (auxes, kvs) = jax.lax.scan(body, x, params["blocks"])
        aux = auxes.sum()
    else:
        aux = jnp.zeros((), jnp.float32)
        kv_list = []
        L = cfg.num_layers
        for i in range(L):
            p_layer = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x, (a, kv) = body(x, p_layer)
            aux = aux + a
            kv_list.append(kv)
        kvs = (jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *kv_list)
               if collect_kv else None)
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    return x, aux, kvs


def _unembed_table(params: dict, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"], True
    return params["lm_head"], False


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            ctx: ShardCtx = ShardCtx()) -> jax.Array:
    """Full logits (B,S,V) — smoke tests / small vocabs only."""
    h, _, _ = hidden_states(params, cfg, tokens, ctx=ctx)
    table, tied = _unembed_table(params, cfg)
    return layers.unembed_apply(table, h, tied)


# ---------------------------------------------------------------------------
# loss (fused chunked CE — never materializes (B,S,V))
# ---------------------------------------------------------------------------

def _pick_chunk(S: int, target: int = 512) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def chunked_ce(h: jax.Array, table: jax.Array, targets: jax.Array,
               mask: Optional[jax.Array], tied: bool, chunk: int = 512):
    """Mean CE from final hidden states; logits per seq-chunk, rematerialized."""
    B, S, D = h.shape
    c = _pick_chunk(S, chunk)
    n = S // c
    hc = h.reshape(B, n, c, D)
    tc = targets.reshape(B, n, c)
    mc = (mask.reshape(B, n, c).astype(jnp.float32) if mask is not None
          else jnp.ones((B, n, c), jnp.float32))

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, blk):
        nll_sum, m_sum = carry
        hb, tb, mb = blk                                   # (B,c,D),(B,c),(B,c)
        w = table.astype(hb.dtype)
        logits = (hb @ w.T if tied else hb @ w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mb
        return (nll_sum + nll.sum(), m_sum + mb.sum()), None

    (nll_sum, m_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(tc, 1, 0), jnp.moveaxis(mc, 1, 0)))
    return nll_sum / jnp.maximum(m_sum, 1.0)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()):
    """batch: {tokens (B,S), targets (B,S), mask optional} -> (loss, metrics)."""
    h, aux, _ = hidden_states(params, cfg, batch["tokens"], ctx=ctx)
    table, tied = _unembed_table(params, cfg)
    ce = chunked_ce(h, table, batch["targets"], batch.get("mask"), tied)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    return {
        "layers": attn_mod.init_cache_specs(cfg, batch, capacity,
                                            layers_axis=cfg.num_layers),
        "pos": Spec((), (), init="zeros", dtype="int32"),
    }


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    from repro.core.params import init_params
    c = init_params(cache_specs(cfg, batch, capacity), jax.random.key(0))
    # empty slots are marked -1; pos=-1 so the first decode writes position 0
    c["layers"]["slot_pos"] = c["layers"]["slot_pos"] - 1
    c["pos"] = c["pos"] - 1
    return c


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            ctx: ShardCtx = ShardCtx(), inputs_embeds: Optional[jax.Array] = None,
            headroom: int = 64):
    """tokens (B,S) -> (last-token logits (B,V), filled cache).

    ``headroom`` empty slots are appended so decode steps never wrap onto
    the prompt (full-attention semantics)."""
    B, S = tokens.shape
    h, _, kvs = hidden_states(params, cfg, tokens, ctx=ctx, collect_kv=True,
                              inputs_embeds=inputs_embeds)
    table, tied = _unembed_table(params, cfg)
    logits = layers.unembed_apply(table, h[:, -1], tied)
    k, v = kvs                                             # (L,B,S,Hkv,hd)
    pad = ((0, 0), (0, 0), (0, 0), (0, headroom), (0, 0))
    slot = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                            jnp.full((headroom,), -1, jnp.int32)])
    cache = {
        "layers": {
            "k": jnp.pad(jnp.moveaxis(k, 2, 3), pad),      # (L,B,Hkv,S+hr,hd)
            "v": jnp.pad(jnp.moveaxis(v, 2, 3), pad),
            "slot_pos": jnp.broadcast_to(slot[None], (cfg.num_layers, S + headroom)),
        },
        "pos": jnp.array(S - 1, jnp.int32),
    }
    return logits, cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jax.Array, *, ctx: ShardCtx = ShardCtx()):
    """One decode step. tokens (B,) -> (logits (B,V), updated cache).

    Layers are UNROLLED up to 48 deep (§Perf H3): scanning layers at decode
    makes XLA materialize the stacked cache ``ys`` with a full-buffer copy
    per layer (copy-insertion on the in-loop DUS), ~L x the intrinsic cache
    traffic. Unrolled, each layer's ring-buffer update aliases in place and
    the step reads params+cache exactly once. Beyond 48 layers (94-layer
    MoE) compile time of the unrolled graph outweighs the win and the scan
    path is kept (trade-off recorded in EXPERIMENTS §Perf H3)."""
    B = tokens.shape[0]
    pos = cache["pos"] + 1
    x = layers.embed_apply(params["embed"], tokens[:, None], cdtype(cfg))
    if cfg.num_layers > 48:
        return _decode_step_scanned(params, cfg, cache, x, pos, ctx)

    layer_cache = dict(cache["layers"])
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    C = layer_cache["k"].shape[3]
    slot = (pos % C).astype(jnp.int32)
    for i in range(cfg.num_layers):
        p_layer = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
        h = layers.norm_apply(p_layer["ln1"], x, cfg.norm)
        q, k_new, v_new = attn_mod._project_qkv(p_layer["attn"], cfg, h,
                                                positions)
        # slice this layer's slab, ring-write the token, write the slab back
        # at a STATIC layer index (keeps SPMD from replicating the cache)
        k_l = jax.lax.dynamic_index_in_dim(layer_cache["k"], i, 0, False)
        v_l = jax.lax.dynamic_index_in_dim(layer_cache["v"], i, 0, False)
        sp_l = jax.lax.dynamic_index_in_dim(layer_cache["slot_pos"], i, 0, False)
        k_l = jax.lax.dynamic_update_slice(
            k_l, jnp.moveaxis(k_new, 1, 2).astype(k_l.dtype), (0, 0, slot, 0))
        v_l = jax.lax.dynamic_update_slice(
            v_l, jnp.moveaxis(v_new, 1, 2).astype(v_l.dtype), (0, 0, slot, 0))
        sp_l = jax.lax.dynamic_update_slice(
            sp_l, pos[None].astype(jnp.int32), (slot,))
        layer_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["k"], k_l[None], i, 0)
        layer_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["v"], v_l[None], i, 0)
        layer_cache["slot_pos"] = jax.lax.dynamic_update_slice_in_dim(
            layer_cache["slot_pos"], sp_l[None], i, 0)
        a = attn_mod.decode_attend(p_layer["attn"], cfg, q[:, 0], k_l, v_l,
                                   sp_l, pos, window=cfg.sliding_window)
        if cfg.parallel_block:
            if cfg.moe is not None:
                m, _ = moe_mod.moe_apply(p_layer["moe"], cfg, h, ctx=ctx)
            else:
                m = layers.mlp_apply(p_layer["mlp"], h, cfg.mlp)
            x = x + a + m
        else:
            x = x + a
            h2 = layers.norm_apply(p_layer["ln2"], x, cfg.norm)
            if cfg.moe is not None:
                m, _ = moe_mod.moe_apply(p_layer["moe"], cfg, h2, ctx=ctx)
            else:
                m = layers.mlp_apply(p_layer["mlp"], h2, cfg.mlp)
            x = x + m
    new_layer_cache = layer_cache
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    table, tied = _unembed_table(params, cfg)
    logits = layers.unembed_apply(table, x[:, 0], tied)
    return logits, {"layers": new_layer_cache, "pos": pos}


def _decode_step_scanned(params, cfg, cache, x, pos, ctx):
    """Scan-over-layers decode (deep stacks where unrolling is too costly
    to compile; pays the per-layer cache copy — see §Perf H3)."""
    def body(carry, inp):
        x = carry
        p_layer, cache_l = inp
        h = layers.norm_apply(p_layer["ln1"], x, cfg.norm)
        a, new_cache = attn_mod.decode_attention(
            p_layer["attn"], cfg, h, cache_l, pos, ctx=ctx,
            window=cfg.sliding_window)
        if cfg.parallel_block:
            if cfg.moe is not None:
                m, _ = moe_mod.moe_apply(p_layer["moe"], cfg, h, ctx=ctx)
            else:
                m = layers.mlp_apply(p_layer["mlp"], h, cfg.mlp)
            x = x + a + m
        else:
            x = x + a
            h2 = layers.norm_apply(p_layer["ln2"], x, cfg.norm)
            if cfg.moe is not None:
                m, _ = moe_mod.moe_apply(p_layer["moe"], cfg, h2, ctx=ctx)
            else:
                m = layers.mlp_apply(p_layer["mlp"], h2, cfg.mlp)
            x = x + m
        return x, new_cache

    x, new_layer_cache = jax.lax.scan(body, x,
                                      (params["blocks"], cache["layers"]))
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    table, tied = _unembed_table(params, cfg)
    logits = layers.unembed_apply(table, x[:, 0], tied)
    return logits, {"layers": new_layer_cache, "pos": pos}
