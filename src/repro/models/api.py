"""Uniform model API: one namespace per family + abstract input builders.

Everything downstream (trainer, serving engine, dry-run, benchmarks) talks
to models exclusively through this module, so adding an architecture is:
write the module, register it here, add a config.
"""
from __future__ import annotations

from dataclasses import dataclass
from types import SimpleNamespace
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.params import Spec
from repro.core import cells as cell_families
from repro.models import gru_lm, hymba, llava, slstm_lm, transformer, whisper, xlstm


def _transformer_api():
    return SimpleNamespace(
        specs=transformer.lm_specs,
        loss_fn=lambda p, cfg, batch, ctx: transformer.loss_fn(p, cfg, batch, ctx=ctx),
        forward=lambda p, cfg, batch, ctx: transformer.forward(p, cfg, batch["tokens"], ctx=ctx),
        prefill=lambda p, cfg, batch, ctx: transformer.prefill(p, cfg, batch["tokens"], ctx=ctx),
        decode_step=lambda p, cfg, cache, tok, ctx: transformer.decode_step(p, cfg, cache, tok, ctx=ctx),
        cache_specs=transformer.cache_specs,
        init_cache=transformer.init_cache,
    )


def _llava_api():
    return SimpleNamespace(
        specs=llava.lm_specs,
        loss_fn=lambda p, cfg, batch, ctx: llava.loss_fn(p, cfg, batch, ctx=ctx),
        forward=lambda p, cfg, batch, ctx: llava.forward(p, cfg, batch, ctx=ctx),
        prefill=lambda p, cfg, batch, ctx: llava.prefill(p, cfg, batch, ctx=ctx),
        decode_step=lambda p, cfg, cache, tok, ctx: llava.decode_step(p, cfg, cache, tok, ctx=ctx),
        cache_specs=llava.cache_specs,
        init_cache=llava.init_cache,
    )


def _whisper_api():
    return SimpleNamespace(
        specs=whisper.lm_specs,
        loss_fn=lambda p, cfg, batch, ctx: whisper.loss_fn(p, cfg, batch, ctx=ctx),
        forward=lambda p, cfg, batch, ctx: whisper.forward(p, cfg, batch, ctx=ctx),
        prefill=lambda p, cfg, batch, ctx: whisper.prefill(p, cfg, batch, ctx=ctx),
        decode_step=lambda p, cfg, cache, tok, ctx: whisper.decode_step(p, cfg, cache, tok, ctx=ctx),
        cache_specs=whisper.cache_specs,
        init_cache=whisper.init_cache,
    )


def _xlstm_api():
    return SimpleNamespace(
        specs=xlstm.lm_specs,
        loss_fn=lambda p, cfg, batch, ctx: xlstm.loss_fn(p, cfg, batch, ctx=ctx),
        forward=lambda p, cfg, batch, ctx: xlstm.forward(p, cfg, batch["tokens"], ctx=ctx),
        prefill=lambda p, cfg, batch, ctx: xlstm.prefill(p, cfg, batch["tokens"], ctx=ctx),
        decode_step=lambda p, cfg, cache, tok, ctx: xlstm.decode_step(p, cfg, cache, tok, ctx=ctx),
        cache_specs=xlstm.cache_specs,
        init_cache=xlstm.init_cache,
    )


def _hymba_api():
    return SimpleNamespace(
        specs=hymba.lm_specs,
        loss_fn=lambda p, cfg, batch, ctx: hymba.loss_fn(p, cfg, batch, ctx=ctx),
        forward=lambda p, cfg, batch, ctx: hymba.forward(p, cfg, batch["tokens"], ctx=ctx),
        prefill=lambda p, cfg, batch, ctx: hymba.prefill(p, cfg, batch["tokens"], ctx=ctx),
        decode_step=lambda p, cfg, cache, tok, ctx: hymba.decode_step(p, cfg, cache, tok, ctx=ctx),
        cache_specs=hymba.cache_specs,
        init_cache=hymba.init_cache,
    )


def _gru_api():
    return SimpleNamespace(
        specs=gru_lm.lm_specs,
        prepare_params=gru_lm.prepare_params,      # one-time serving prep
        executable=gru_lm.serve_executable,        # compiled-plan introspection
        loss_fn=lambda p, cfg, batch, ctx: gru_lm.loss_fn(p, cfg, batch, ctx=ctx),
        forward=lambda p, cfg, batch, ctx: gru_lm.forward(p, cfg, batch, ctx=ctx),
        prefill=lambda p, cfg, batch, ctx: gru_lm.prefill(p, cfg, batch, ctx=ctx),
        decode_step=lambda p, cfg, cache, x, ctx: gru_lm.decode_step(p, cfg, cache, x, ctx=ctx),
        cache_specs=gru_lm.cache_specs,
        init_cache=gru_lm.init_cache,
    )


def _slstm_api():
    return SimpleNamespace(
        specs=slstm_lm.lm_specs,
        prepare_params=slstm_lm.prepare_params,    # one-time serving prep
        executable=slstm_lm.serve_executable,      # compiled-plan introspection
        loss_fn=lambda p, cfg, batch, ctx: slstm_lm.loss_fn(p, cfg, batch, ctx=ctx),
        forward=lambda p, cfg, batch, ctx: slstm_lm.forward(p, cfg, batch, ctx=ctx),
        prefill=lambda p, cfg, batch, ctx: slstm_lm.prefill(p, cfg, batch, ctx=ctx),
        decode_step=lambda p, cfg, cache, x, ctx: slstm_lm.decode_step(p, cfg, cache, x, ctx=ctx),
        cache_specs=slstm_lm.cache_specs,
        init_cache=slstm_lm.init_cache,
    )


_FAMS: Dict[str, Callable] = {
    "dense": _transformer_api,
    "moe": _transformer_api,
    "vlm": _llava_api,
    "audio": _whisper_api,
    "ssm": _xlstm_api,
    "hybrid": _hymba_api,
    "gru": _gru_api,
    "slstm": _slstm_api,
}


def get_api(cfg: ModelConfig) -> SimpleNamespace:
    try:
        return _FAMS[cfg.family]()
    except KeyError:
        # typed (still a KeyError subclass): serving surfaces fail loudly
        # on an unregistered family instead of silently degrading
        raise cell_families.UnknownCellFamily(
            cfg.family,
            known=set(_FAMS) | set(cell_families.families())) from None


# ---------------------------------------------------------------------------
# input specs: abstract (dry-run) and concrete (smoke/bench) batches
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Spec tree for the model inputs of one (arch x shape) cell.

    kind="train"/"prefill": the full batch. kind="decode": ONLY the new
    token(s) — the cache is built separately from cache_specs."""
    B, S = shape.global_batch, shape.seq_len
    i32 = "int32"
    if cell_families.is_cell_family(cfg.family):
        # every cell family (gru, slstm, ...) describes its stack shapes
        # through the same GRUConfig fields
        g = cfg.gru
        if shape.kind == "decode":
            return {"x": Spec((B, g.input_dim), ("batch", None), dtype=cfg.dtype)}
        batch = {"features": Spec((B, S, g.input_dim), ("batch", "act_seq", None),
                                  dtype=cfg.dtype),
                 "labels": Spec((B,), ("batch",), dtype=i32)}
        return batch
    if shape.kind == "decode":
        return {"tokens": Spec((B,), ("batch",), dtype=i32)}
    batch = {"tokens": Spec((B, S), ("batch", "act_seq"), dtype=i32)}
    if shape.kind == "train":
        batch["targets"] = Spec((B, S), ("batch", "act_seq"), dtype=i32)
    if cfg.family == "audio":
        batch["frames"] = Spec((B, cfg.encoder.num_frames, cfg.d_model),
                               ("batch", None, None), dtype=cfg.dtype)
    if cfg.family == "vlm":
        batch["patches"] = Spec((B, cfg.vision.num_patches, cfg.vision.embed_dim),
                                ("batch", None, None), dtype=cfg.dtype)
    return batch


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Small deterministic concrete batch for smoke tests and benchmarks."""
    specs = input_specs(cfg, shape)
    rng = np.random.default_rng(seed)

    def make(s: Spec):
        dt = jnp.dtype(s.dtype or "float32")
        if jnp.issubdtype(dt, jnp.integer):
            hi = (cfg.gru.num_classes
                  if cell_families.is_cell_family(cfg.family)
                  else cfg.vocab_size)
            return jnp.asarray(rng.integers(0, hi, size=s.shape), dt)
        return jnp.asarray(rng.normal(size=s.shape), jnp.float32).astype(dt)

    return jax.tree_util.tree_map(make, specs,
                                  is_leaf=lambda x: isinstance(x, Spec))
