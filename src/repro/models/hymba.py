"""Hymba: hybrid-head blocks — attention and Mamba SSM heads in PARALLEL
within every layer (arXiv:2411.13676), most layers sliding-window, three
global-attention layers (first / middle / last).

Simplifications recorded in DESIGN.md §Arch-applicability: meta-tokens are
omitted; the two paths are fused as the mean of per-path RMS-normed outputs.

Layer layout: [g0][swa x14][g15][swa x15][g31]. SWA groups are scanned
(stacked params); global layers are unrolled — this keeps ragged KV-cache
capacities honest (global layers carry full-context caches; SWA layers a
ring buffer of the window) while the HLO stays one-block-sized per group.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.params import Spec, init_params, stack_specs
from repro.distributed.sharding import ShardCtx, constrain
from repro.models import attention as attn_mod
from repro.models import layers, ssm as ssm_mod
from repro.models.layers import cdtype, dense_apply
from repro.models.transformer import chunked_ce

_GROUPS = ("g0", "swa_a", "g1", "swa_b", "g2")


def _group_sizes(cfg: ModelConfig) -> dict:
    L = cfg.num_layers
    mid = L // 2 - 1                        # 15 for 32 layers
    return {"g0": 1, "swa_a": mid - 1, "g1": 1, "swa_b": L - mid - 2, "g2": 1}


def block_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "ln1": layers.norm_specs(d, cfg.norm),
        "attn": attn_mod.attn_specs(cfg),
        "ssm": ssm_mod.ssm_specs(cfg),
        "norm_a": layers.norm_specs(d, "rmsnorm"),
        "norm_s": layers.norm_specs(d, "rmsnorm"),
        "ln2": layers.norm_specs(d, cfg.norm),
        "mlp": layers.mlp_specs(d, cfg.d_ff, cfg.mlp),
    }


def lm_specs(cfg: ModelConfig) -> dict:
    sizes = _group_sizes(cfg)
    blocks = {}
    for g in _GROUPS:
        b = block_specs(cfg)
        blocks[g] = stack_specs(b, sizes[g]) if g.startswith("swa") else b
    return {
        "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),
        "blocks": blocks,
        "final_norm": layers.norm_specs(cfg.d_model, cfg.norm),
        "lm_head": Spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                        init="fan_in"),
    }


def block_apply(p: dict, cfg: ModelConfig, x: jax.Array, positions, *,
                ctx: ShardCtx, window: int, collect_cache: bool = False):
    h = layers.norm_apply(p["ln1"], x, cfg.norm)
    a, kv = attn_mod.attention(p["attn"], cfg, h, ctx=ctx, window=window,
                               positions=positions)
    if collect_cache:
        s, ssm_state = ssm_mod.ssm_apply(p["ssm"], cfg, h, return_state=True)
    else:
        s = ssm_mod.ssm_apply(p["ssm"], cfg, h)
    fused = 0.5 * (layers.norm_apply(p["norm_a"], a, "rmsnorm")
                   + layers.norm_apply(p["norm_s"], s, "rmsnorm"))
    x = x + fused
    x = x + layers.mlp_apply(p["mlp"], layers.norm_apply(p["ln2"], x, cfg.norm),
                             cfg.mlp)
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)
    if not collect_cache:
        return x
    # build this layer's decode cache: KV ring (last `cap` positions) + SSM
    k, v = kv                                              # (B,S,Hkv,hd)
    S = k.shape[1]
    cap = min(window, S) if window else S
    kc = jnp.moveaxis(k[:, S - cap:], 1, 2)                # (B,Hkv,cap,hd)
    vc = jnp.moveaxis(v[:, S - cap:], 1, 2)
    slot = jnp.arange(S - cap, S, dtype=jnp.int32)
    if window:
        # ring-buffer layout: absolute position p lives in slot p % cap
        order = jnp.argsort(slot % cap)
        kc, vc, slot = kc[:, :, order], vc[:, :, order], slot[order]
    else:
        # global layer: headroom so decode never wraps onto the prompt
        hr = 64
        kc = jnp.pad(kc, ((0, 0), (0, 0), (0, hr), (0, 0)))
        vc = jnp.pad(vc, ((0, 0), (0, 0), (0, hr), (0, 0)))
        slot = jnp.concatenate([slot, jnp.full((hr,), -1, jnp.int32)])
    return x, {"attn": {"k": kc, "v": vc, "slot_pos": slot},
               "ssm": ssm_state}


def hidden_states(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
                  ctx: ShardCtx):
    B, S = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cdtype(cfg))
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def one(p, x, window):
        fn = functools.partial(block_apply, cfg=cfg, ctx=ctx, window=window,
                               positions=positions)
        if cfg.remat:
            return jax.checkpoint(fn, prevent_cse=False)(p, x=x)
        return fn(p, x=x)

    for g in _GROUPS:
        p_g = params["blocks"][g]
        if g.startswith("swa"):
            def body(x, p_layer):
                return one(p_layer, x, cfg.sliding_window), None
            x, _ = jax.lax.scan(body, x, p_g)
        else:
            x = one(p_g, x, 0)                             # global attention
    return layers.norm_apply(params["final_norm"], x, cfg.norm)


def forward(params, cfg, tokens, *, ctx: ShardCtx = ShardCtx()):
    h = hidden_states(params, cfg, tokens, ctx=ctx)
    return layers.unembed_apply(params["lm_head"], h, tied=False)


def loss_fn(params, cfg, batch, *, ctx: ShardCtx = ShardCtx()):
    h = hidden_states(params, cfg, batch["tokens"], ctx=ctx)
    ce = chunked_ce(h, params["lm_head"], batch["targets"], batch.get("mask"),
                    tied=False)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# --- serving ------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    """Global layers: full-capacity KV; SWA layers: window ring buffer.
    Every layer additionally carries SSM conv+state (O(1) in context)."""
    sizes = _group_sizes(cfg)
    win_cap = min(cfg.sliding_window, capacity)
    out = {}
    for g in _GROUPS:
        n = sizes[g]
        cap = capacity if not g.startswith("swa") else win_cap
        lead = 0 if not g.startswith("swa") else n
        out[g] = {
            "attn": attn_mod.init_cache_specs(cfg, batch, cap, layers_axis=lead),
            "ssm": ssm_mod.ssm_cache_specs(cfg, batch, layers_axis=lead),
        }
    out["pos"] = Spec((), (), init="zeros", dtype="int32")
    return out


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    c = init_params(cache_specs(cfg, batch, capacity), jax.random.key(0))
    for g in _GROUPS:
        c[g]["attn"]["slot_pos"] = c[g]["attn"]["slot_pos"] - 1
    return c


def _block_decode(p, cfg, x, cache_b, pos, *, ctx, window):
    h = layers.norm_apply(p["ln1"], x, cfg.norm)
    a, attn_cache = attn_mod.decode_attention(p["attn"], cfg, h, cache_b["attn"],
                                              pos, ctx=ctx, window=window)
    s, ssm_cache = ssm_mod.ssm_decode_step(p["ssm"], cfg, h, cache_b["ssm"])
    fused = 0.5 * (layers.norm_apply(p["norm_a"], a, "rmsnorm")
                   + layers.norm_apply(p["norm_s"], s, "rmsnorm"))
    x = x + fused
    x = x + layers.mlp_apply(p["mlp"], layers.norm_apply(p["ln2"], x, cfg.norm),
                             cfg.mlp)
    return x, {"attn": attn_cache, "ssm": ssm_cache}


def decode_step(params: dict, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                *, ctx: ShardCtx = ShardCtx()):
    pos = cache["pos"] + 1
    x = layers.embed_apply(params["embed"], tokens[:, None], cdtype(cfg))
    new_cache = {"pos": pos}
    for g in _GROUPS:
        p_g = params["blocks"][g]
        if g.startswith("swa"):
            def body(x, inp):
                p_layer, cache_l = inp
                return _block_decode(p_layer, cfg, x, cache_l, pos, ctx=ctx,
                                     window=cfg.sliding_window)
            x, new_cache[g] = jax.lax.scan(body, x, (p_g, cache[g]))
        else:
            x, new_cache[g] = _block_decode(p_g, cfg, x, cache[g], pos,
                                            ctx=ctx, window=0)
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    logits = layers.unembed_apply(params["lm_head"], x[:, 0], tied=False)
    return logits, new_cache


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            ctx: ShardCtx = ShardCtx()):
    """PARALLEL prefill (§Perf H1): one full forward collects the KV ring
    buffers (last-window slices, ring-ordered) and SSM states per layer;
    weights stream once, not once per token. Sequential baseline kept as
    ``prefill_sequential``."""
    B, S = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cdtype(cfg))
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    new_cache = {"pos": jnp.array(S - 1, jnp.int32)}
    for g in _GROUPS:
        p_g = params["blocks"][g]
        win = cfg.sliding_window if g.startswith("swa") else 0
        if g.startswith("swa"):
            def body(x, p_layer):
                return block_apply(p_layer, cfg, x, positions, ctx=ctx,
                                   window=win, collect_cache=True)
            x, new_cache[g] = jax.lax.scan(body, x, p_g)
        else:
            x, new_cache[g] = block_apply(p_g, cfg, x, positions, ctx=ctx,
                                          window=0, collect_cache=True)
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    logits = layers.unembed_apply(params["lm_head"], x[:, -1], tied=False)
    return logits, new_cache


def prefill_sequential(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
                       ctx: ShardCtx = ShardCtx()):
    """Baseline per-token prefill (§Perf before/after)."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, S)
    cache["pos"] = jnp.array(-1, jnp.int32)

    def body(cache, t):
        logits, cache = decode_step(params, cfg, cache, t, ctx=ctx)
        return cache, None

    cache, _ = jax.lax.scan(body, cache, jnp.moveaxis(tokens[:, :-1], 1, 0))
    return decode_step(params, cfg, cache, tokens[:, -1], ctx=ctx)
