"""The paper's own model family (``gru-jet`` and deep stacks) behind the
framework model API.

Forward/loss = the jet-tagging sequence classifier (GRU stack + linear
head; the paper's validated configuration is one layer, H=20, X=5, 5
classes). Serving = single-step recurrent decode through the whole stack,
the paper's latency-measurement path; the cache carries one hidden state
per layer.

All GRU execution routes through the capability-dispatched executor
(``repro.core.runtime``) via its two-stage compile/execute API:
``prefill``/``decode_step`` ask ``compile()`` for a memoized
``GRUExecutable`` (fused Pallas stack, per-layer Pallas chain, XLA scan,
or the shard_map programs when the ``ShardCtx`` carries a mesh — the ctx
mesh becomes the executable's ``Placement``, and mesh prefill resolves
to ``pallas_sharded``, the fused shard kernels INSIDE the shard_map,
unless pinned or calibrated otherwise), and ``serve_executable`` exposes
the resolved executable so the serving engine can record which backend
actually runs (e.g. that a masked bucketed prefill executes the Pallas
kernel, not an XLA fallback).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import gru as gru_core
from repro.core import runtime
from repro.core.params import Spec, init_params
from repro.distributed.sharding import ShardCtx, constrain


def lm_specs(cfg: ModelConfig) -> dict:
    return gru_core.gru_classifier_specs(cfg.gru)


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()) -> jax.Array:
    """batch: {features (B,T,X)} -> class logits (B,C)."""
    return gru_core.gru_classify(params, batch["features"], cfg=cfg.gru)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()):
    """batch: {features (B,T,X), labels (B,)} -> softmax CE."""
    logits = forward(params, cfg, batch, ctx=ctx).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    loss = (lse - ll).mean()
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return loss, {"ce": loss, "acc": acc, "aux": jnp.zeros((), jnp.float32)}


# --- serving: the paper's latency path ---------------------------------------

def _placement(ctx: ShardCtx) -> runtime.Placement:
    """The ctx mesh resolved to an executor Placement (host if none)."""
    return (runtime.HOST if ctx.mesh is None
            else runtime.Placement(mesh=ctx.mesh))


def prepare_params(params: dict, cfg: ModelConfig,
                   ctx: ShardCtx = ShardCtx()) -> dict:
    """One-time serving prep, delegated to ``runtime.prepare`` with the
    ctx's placement: attach the stacked-weight views the fused kernels
    consume (``"stacked_cells"``) so the per-step decode trace never
    restacks U/W/b, and — under a mesh — perform the sharded backends'
    gate-major reshapes and ``device_put``s up front
    (``"placed_cells"``), so traced execute calls do no weight placement.
    When the config requests the q8 datapath (``cfg.gru.quant`` or a
    ``*_q8`` backend pin) the int8 weight views are computed here too
    (``"quant_cells"``) — the serve trace then contains no weight
    quantization ops. No-op for already-prepared params."""
    sp = runtime.prepare(params, cfg.gru, _placement(ctx))
    out = {"cells": sp.cells, "head": params["head"]}
    if sp.stacked is not None:
        out["stacked_cells"] = sp.stacked
    if sp.placed is not None:
        out["placed_cells"] = sp.placed
    if sp.quant is not None:
        out["quant_cells"] = sp.quant
    return out


def serve_executable(cfg: ModelConfig, *, batch: int, seq: int = None,
                     masked: bool = False, mode: str = "serve",
                     mesh=None) -> runtime.GRUExecutable:
    """The executable a serving call with these shapes will use (same
    memoized object ``prefill``/``decode_step`` resolve internally) —
    lets the engine assert/record backend choices without re-compiling."""
    return runtime.compile(cfg.gru, batch=batch, seq=seq, placement=mesh,
                           mask=masked, mode=mode)


def cache_specs(cfg: ModelConfig, batch: int, capacity: int = 0) -> dict:
    """Recurrent cache: one hidden state PER LAYER of the stack."""
    return {
        "h": tuple(
            Spec((batch, h), ("batch", "act_gates"), init="zeros",
                 dtype="float32")
            for h in cfg.gru.resolved_layer_dims),
        "pos": Spec((), (), init="zeros", dtype="int32"),
    }


def init_cache(cfg: ModelConfig, batch: int, capacity: int = 0) -> dict:
    return init_params(cache_specs(cfg, batch), jax.random.key(0))


def decode_step(params: dict, cfg: ModelConfig, cache: dict, x: jax.Array, *,
                ctx: ShardCtx = ShardCtx()):
    """One recurrent step through the stack: x (B,X) features ->
    (class logits so far, cache).

    The executor dispatches: with ``cfg.gru.backend == "pallas"`` (uniform
    layer sizes) the whole depth runs as ONE fused pallas_call — the
    per-layer cache states are stacked device-side and fed straight to the
    kernel, no host round trips on the latency-critical path; hetero
    stacks run the per-layer Pallas chain. Params prepared by
    ``prepare_params`` carry pre-stacked (and, under a mesh, pre-placed)
    weights so the step also does no per-token weight restacking."""
    p = runtime.compile(cfg.gru, batch=x.shape[0], mode="decode",
                        placement=_placement(ctx))
    hs = p.decode(params, cache["h"], x)
    hs = tuple(constrain(h, ("batch", "act_gates"), ctx) for h in hs)
    logits = hs[-1] @ params["head"]["w"] + params["head"]["b"]
    return logits.astype(jnp.float32), {"h": hs, "pos": cache["pos"] + 1}


def prefill(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()):
    """Run the full sequence, return (logits, per-layer recurrent state).

    ``batch["mask"]`` (B, T) bool, optional: False timesteps freeze the
    recurrence, so left-padded bucketed prompts (ServeEngine) yield the
    same state as their unpadded originals — streamed through whichever
    backend the executor picks (the fused Pallas kernels included; masked
    bucketed prefill no longer falls back to the XLA scan)."""
    xs = batch["features"]
    B = xs.shape[0]
    mask = batch.get("mask")
    h0s = gru_core.stack_h0(cfg.gru, B, xs.dtype)
    p = runtime.compile(cfg.gru, batch=B, seq=xs.shape[1],
                        mask=mask is not None, mode="prefill",
                        placement=_placement(ctx))
    finals = p.prefill(params, h0s, xs, mask=mask)
    logits = (finals[-1] @ params["head"]["w"]
              + params["head"]["b"]).astype(jnp.float32)
    cache = {"h": tuple(h.astype(jnp.float32) for h in finals),
             "pos": jnp.array(xs.shape[1] - 1, jnp.int32)}
    return logits, cache
