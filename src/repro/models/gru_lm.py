"""The paper's own model (``gru-jet``) behind the framework model API.

Forward/loss = the jet-tagging sequence classifier (GRU + linear head,
H=20, X=5, 5 classes in the paper's validated configuration). Serving =
single-step recurrent decode, the paper's latency-measurement path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import gru as gru_core
from repro.core.params import Spec, init_params
from repro.distributed.sharding import ShardCtx, constrain


def lm_specs(cfg: ModelConfig) -> dict:
    return gru_core.gru_classifier_specs(cfg.gru)


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()) -> jax.Array:
    """batch: {features (B,T,X)} -> class logits (B,C)."""
    return gru_core.gru_classify(params, batch["features"], cfg=cfg.gru)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()):
    """batch: {features (B,T,X), labels (B,)} -> softmax CE."""
    logits = forward(params, cfg, batch, ctx=ctx).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    loss = (lse - ll).mean()
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return loss, {"ce": loss, "acc": acc, "aux": jnp.zeros((), jnp.float32)}


# --- serving: the paper's latency path ---------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, capacity: int = 0) -> dict:
    return {
        "h": Spec((batch, cfg.gru.hidden_dim), ("batch", "act_gates"),
                  init="zeros", dtype="float32"),
        "pos": Spec((), (), init="zeros", dtype="int32"),
    }


def init_cache(cfg: ModelConfig, batch: int, capacity: int = 0) -> dict:
    return init_params(cache_specs(cfg, batch), jax.random.key(0))


def decode_step(params: dict, cfg: ModelConfig, cache: dict, x: jax.Array, *,
                ctx: ShardCtx = ShardCtx()):
    """One recurrent step: x (B,X) features -> (class logits so far, cache)."""
    h = gru_core.gru_step(params["cell"], cache["h"], x=x, cfg=cfg.gru)
    h = constrain(h, ("batch", "act_gates"), ctx)
    logits = h @ params["head"]["w"] + params["head"]["b"]
    return logits.astype(jnp.float32), {"h": h, "pos": cache["pos"] + 1}


def prefill(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()):
    """Run the full sequence, return (logits, final recurrent state)."""
    xs = batch["features"]
    B = xs.shape[0]
    h0 = jnp.zeros((B, cfg.gru.hidden_dim), xs.dtype)
    hT, _ = gru_core.gru_sequence(params["cell"], h0, xs, cfg=cfg.gru)
    logits = (hT @ params["head"]["w"] + params["head"]["b"]).astype(jnp.float32)
    cache = {"h": hT.astype(jnp.float32),
             "pos": jnp.array(xs.shape[1] - 1, jnp.int32)}
    return logits, cache
