"""LLaVA-NeXT-style VLM: mistral-7b backbone + 2-layer GELU projector.

The vision tower / anyres tiling is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings (B, P, vis_dim).
Projected patches occupy the FIRST P positions of the sequence (loss-masked),
so every (arch x shape) cell keeps its exact assigned seq_len.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx
from repro.models import layers, transformer
from repro.models.layers import cdtype, dense_apply, dense_specs
from repro.models.transformer import chunked_ce


def lm_specs(cfg: ModelConfig) -> dict:
    s = transformer.lm_specs(cfg)
    v = cfg.vision
    s["projector"] = {
        "w1": dense_specs(v.embed_dim, cfg.d_model, ("vis_embed", "embed"), bias=True),
        "w2": dense_specs(cfg.d_model, cfg.d_model, ("embed", "embed"), bias=True),
    }
    return s


def _merged_embeds(params: dict, cfg: ModelConfig, tokens: jax.Array,
                   patches: jax.Array) -> jax.Array:
    """tokens (B,S) + patches (B,P,vis) -> (B,S,D): patches replace the
    first P token positions."""
    B, S = tokens.shape
    P = patches.shape[1]
    tok = layers.embed_apply(params["embed"], tokens, cdtype(cfg))
    proj = dense_apply(params["projector"]["w2"],
                       jax.nn.gelu(dense_apply(params["projector"]["w1"],
                                               patches.astype(cdtype(cfg)))))
    return jnp.concatenate([proj, tok[:, P:]], axis=1)


def forward(params, cfg, batch, *, ctx: ShardCtx = ShardCtx()):
    x = _merged_embeds(params, cfg, batch["tokens"], batch["patches"])
    h, _, _ = transformer.hidden_states(params, cfg, batch["tokens"], ctx=ctx,
                                        inputs_embeds=x)
    table, tied = transformer._unembed_table(params, cfg)
    return layers.unembed_apply(table, h, tied)


def loss_fn(params, cfg, batch, *, ctx: ShardCtx = ShardCtx()):
    x = _merged_embeds(params, cfg, batch["tokens"], batch["patches"])
    h, aux, _ = transformer.hidden_states(params, cfg, batch["tokens"], ctx=ctx,
                                          inputs_embeds=x)
    P = batch["patches"].shape[1]
    B, S = batch["tokens"].shape
    mask = batch.get("mask")
    text_mask = jnp.broadcast_to((jnp.arange(S) >= P)[None, :],
                                 (B, S)).astype(jnp.float32)
    mask = text_mask if mask is None else mask * text_mask
    table, tied = transformer._unembed_table(params, cfg)
    ce = chunked_ce(h, table, batch["targets"], mask, tied)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


cache_specs = transformer.cache_specs
init_cache = transformer.init_cache
decode_step = transformer.decode_step     # images only matter at prefill


def prefill(params, cfg, batch, *, ctx: ShardCtx = ShardCtx()):
    x = _merged_embeds(params, cfg, batch["tokens"], batch["patches"])
    return transformer.prefill(params, cfg, batch["tokens"], ctx=ctx,
                               inputs_embeds=x)
