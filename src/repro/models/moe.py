"""Mixture-of-Experts: sort-based capacity dispatch + expert parallelism.

Dispatch is the production EP pattern: tokens are sorted by expert id,
scattered into a static ``(E, C, D)`` capacity buffer (overflow drops),
exchanged across the EP mesh axis with ``all_to_all``, run through the local
experts' SwiGLU (TP over ``model`` on the expert hidden dim, closed by a
``psum``), exchanged back and combined with the router weights.

At decode this is exactly the paper's latency regime: per-expert matvecs at
tiny token counts — the row-wise (output-stationary) sharding study applies
to the expert FFN projections verbatim.

The pure-jnp oracle ``moe_ref`` routes without capacity so tests can pin the
EP path against it (with a capacity factor high enough to avoid drops).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, MoEConfig
from repro.core.params import Spec
from repro.distributed.sharding import ShardCtx, resolve_pspec
from repro.models import layers

NEG_INF = -1e30


def padded_experts(m: MoEConfig, multiple: int = 16) -> int:
    """Pad expert count so it divides any EP axis up to ``multiple``."""
    return -(-m.num_experts // multiple) * multiple


def moe_specs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    E = padded_experts(m)
    s = {
        "router": Spec((d, E), ("embed", "experts"), init="fan_in", scale=0.1),
        "wg": Spec((E, d, m.d_expert), ("experts", "embed", "expert_mlp")),
        "wu": Spec((E, d, m.d_expert), ("experts", "embed", "expert_mlp")),
        "wd": Spec((E, m.d_expert, d), ("experts", "expert_mlp", "embed")),
    }
    if m.shared_d_ff:
        s["shared"] = layers.mlp_specs(d, m.shared_d_ff, "swiglu")
        s["shared_gate"] = Spec((d, 1), ("embed", None), init="fan_in")
    return s


def _capacity(tokens_local: int, top_k: int, E: int, factor: float) -> int:
    return max(1, math.ceil(tokens_local * top_k / E * factor))


def _dispatch_compute_combine(x, probs, eidx, wg, wu, wd, *, E: int, C: int,
                              ep_axis: Optional[str], tp_axis: Optional[str],
                              ep_size: int, compute_dtype,
                              tp_mode: str = "psum", tp_size: int = 1) -> jax.Array:
    """Local-shard MoE: x (T,D) -> (T,D). Runs inside shard_map (or plain).

    tp_mode:
      "psum"   — baseline Megatron-style: every model shard processes ALL
                 tokens against its F-slice of the experts; partial outputs
                 close with a psum of the full token buffer (collective-
                 heavy: the §Perf H2 baseline).
      "gather" — weight-gathered EP (§Perf H2): tokens are SLICED across the
                 model axis, each shard all-gathers the (small) F-slices of
                 its experts' weights once per layer, computes its token
                 slice against FULL experts with no partial sums, and the
                 outputs are all-gathered. Same FLOPs/device, ~10x fewer
                 collective bytes (weights << token buffers at LM batch).
    """
    if tp_mode == "gather_sp" and tp_axis is not None and tp_size > 1:
        # tokens ALREADY sharded over the model axis by the sp profile —
        # only the expert weight F-slices are gathered; no token-buffer
        # collective ever happens on the model axis (§Perf H2 iter 2).
        wg = jax.lax.all_gather(wg, tp_axis, axis=2, tiled=True)
        wu = jax.lax.all_gather(wu, tp_axis, axis=2, tiled=True)
        wd = jax.lax.all_gather(wd, tp_axis, axis=1, tiled=True)
        return _dispatch_compute_combine(
            x, probs, eidx, wg, wu, wd, E=E, C=C, ep_axis=ep_axis,
            tp_axis=None, ep_size=ep_size, compute_dtype=compute_dtype,
            tp_size=1)

    if (tp_mode == "gather" and tp_axis is not None and tp_size > 1
            and x.shape[0] % tp_size == 0):
        n = tp_size
        i = jax.lax.axis_index(tp_axis)
        Tm = x.shape[0] // n
        x = jax.lax.dynamic_slice_in_dim(x, i * Tm, Tm, 0)
        probs = jax.lax.dynamic_slice_in_dim(probs, i * Tm, Tm, 0)
        eidx = jax.lax.dynamic_slice_in_dim(eidx, i * Tm, Tm, 0)
        wg = jax.lax.all_gather(wg, tp_axis, axis=2, tiled=True)
        wu = jax.lax.all_gather(wu, tp_axis, axis=2, tiled=True)
        wd = jax.lax.all_gather(wd, tp_axis, axis=1, tiled=True)
        out = _dispatch_compute_combine(
            x, probs, eidx, wg, wu, wd, E=E, C=max(1, C // n),
            ep_axis=ep_axis, tp_axis=None, ep_size=ep_size,
            compute_dtype=compute_dtype, tp_size=1)
        return jax.lax.all_gather(out, tp_axis, axis=0, tiled=True)

    T, D = x.shape
    k = eidx.shape[-1]
    N = T * k
    flat_e = eidx.reshape(N)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_p = probs.reshape(N)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    seg_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(N, dtype=jnp.int32) - seg_start[se].astype(jnp.int32)
    slot = se.astype(jnp.int32) * C + pos
    slot = jnp.where(pos < C, slot, E * C)                # OOB -> dropped

    buf = jnp.zeros((E * C, D), compute_dtype).at[slot].set(
        x[st].astype(compute_dtype), mode="drop")
    buf = buf.reshape(E, C, D)

    if ep_axis is not None and ep_size > 1:
        # EP exchange: every shard keeps its E/ep experts, receives all
        # shards' capacity slices for them.
        buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)              # (E/ep, C*ep, D)

    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(compute_dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(compute_dtype))
    if tp_axis is not None:
        y = jax.lax.psum(y, tp_axis)                      # close TP contraction

    if ep_axis is not None and ep_size > 1:
        y = jax.lax.all_to_all(y, ep_axis, split_axis=1, concat_axis=0,
                               tiled=True)                # (E, C, D)

    y_flat = y.reshape(E * C, D)
    gathered = jnp.take(y_flat, slot, axis=0, mode="fill", fill_value=0.0)
    out = jnp.zeros((T, D), compute_dtype).at[st].add(
        gathered * sp[:, None].astype(compute_dtype))
    return out


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array, *,
              ctx: ShardCtx) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out (B,S,D), aux load-balance loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    E = padded_experts(m)
    xf = x.reshape(B * S, D)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if E > m.num_experts:                                  # mask padding experts
        pad_mask = jnp.arange(E) < m.num_experts
        logits = jnp.where(pad_mask[None, :], logits, NEG_INF)
    probs_full = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs_full, m.top_k)
    if m.norm_topk_prob:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch): E * sum_e f_e * P_e
    occupancy = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f_e = occupancy / (B * S * m.top_k)
    P_e = probs_full.mean(0)
    aux = m.num_experts * jnp.sum(f_e * P_e) * m.router_aux_coef

    ep_size = ctx.axis_size("data")
    tp_size = ctx.axis_size("model")
    T_local = (B * S) // (ctx.axis_size("pod") * max(ep_size, 1))
    # sp profile: the sequence axis is model-sharded end to end, so the MoE
    # sees pre-sliced tokens and never exchanges token buffers on "model".
    sp_tokens = (ctx.profile == "sp" and m.tp_mode == "gather" and tp_size > 1
                 and T_local % tp_size == 0)
    if sp_tokens:
        T_local //= tp_size
    C = _capacity(T_local, m.top_k, E, m.capacity_factor)
    compute = layers.cdtype(cfg)

    if ctx.mesh is None:
        out = _dispatch_compute_combine(
            xf, top_p, top_i, p["wg"], p["wu"], p["wd"], E=E, C=C,
            ep_axis=None, tp_axis=None, ep_size=1, compute_dtype=compute)
    else:
        tok_spec = resolve_pspec(("batch", None), (B * S, D), ctx)
        tok_axes = tok_spec[0] if len(tok_spec) else None
        if sp_tokens:
            prev = (tok_axes if isinstance(tok_axes, tuple)
                    else (tok_axes,) if tok_axes else ())
            tok_axes = (*prev, "model")
            tok_spec = P(tok_axes, *tok_spec[1:])
        sel_spec = P(tok_axes)
        wgt_spec = resolve_pspec(("experts", "embed", "expert_mlp"),
                                 p["wg"].shape, ctx)
        wd_spec = resolve_pspec(("experts", "expert_mlp", "embed"),
                                p["wd"].shape, ctx)
        fn = functools.partial(
            _dispatch_compute_combine, E=E, C=C,
            ep_axis="data" if ep_size > 1 else None,
            tp_axis="model" if tp_size > 1 else None,
            ep_size=ep_size, compute_dtype=compute,
            tp_mode=("gather_sp" if sp_tokens else m.tp_mode),
            tp_size=tp_size)
        out = shard_map(
            fn, mesh=ctx.mesh,
            in_specs=(tok_spec, sel_spec, sel_spec, wgt_spec, wgt_spec, wd_spec),
            out_specs=tok_spec,
            check_vma=False,
        )(xf, top_p, top_i, p["wg"], p["wu"], p["wd"])

    out = out.astype(x.dtype)
    if m.shared_d_ff:
        gate = jax.nn.sigmoid(xf.astype(jnp.float32) @ p["shared_gate"].astype(jnp.float32))
        shared = layers.mlp_apply(p["shared"], x, "swiglu")
        out = out + (shared.reshape(B * S, D) * gate.astype(x.dtype))
    return out.reshape(B, S, D), aux


# --- oracle ------------------------------------------------------------------

def moe_ref(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """No-capacity fp32 reference: loop over experts, mask-select tokens."""
    m = cfg.moe
    B, S, D = x.shape
    E = padded_experts(m)
    xf = x.reshape(-1, D).astype(jnp.float32)
    logits = xf @ p["router"].astype(jnp.float32)
    if E > m.num_experts:
        logits = jnp.where(jnp.arange(E)[None, :] < m.num_experts, logits, NEG_INF)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    if m.norm_topk_prob:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xf)
    for e in range(m.num_experts):
        w = jnp.where(top_i == e, top_p, 0.0).sum(-1)      # (T,)
        g = jax.nn.silu(xf @ p["wg"][e].astype(jnp.float32))
        u = xf @ p["wu"][e].astype(jnp.float32)
        y = (g * u) @ p["wd"][e].astype(jnp.float32)
        out = out + y * w[:, None]
    if m.shared_d_ff:
        gate = jax.nn.sigmoid(xf @ p["shared_gate"].astype(jnp.float32))
        shared = layers.mlp_apply(p["shared"], x.astype(jnp.float32), "swiglu")
        out = out + shared.reshape(-1, D) * gate
    return out.reshape(B, S, D).astype(x.dtype)
