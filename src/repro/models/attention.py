"""Attention: GQA + RoPE + qk-norm + sliding window; three implementations.

* ``naive``     — dense score matrix (oracle; small shapes only)
* ``xla_flash`` — chunked online-softmax ``lax.scan`` over KV blocks with a
  rematerialized chunk body: flash-attention memory behaviour expressed in
  plain XLA ops (compiles for every mesh; this is the dry-run default)
* ``pallas``    — the TPU kernel in ``repro.kernels.flash_attn``

Decode-step attention runs against a ring-buffer KV cache (full or sliding
window) and is the latency-critical matvec regime the paper targets: at
batch*heads ~ chip count the per-step work is exactly a set of row-wise
matvecs against cached KV rows.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.params import Spec
from repro.distributed.sharding import ShardCtx, constrain
from repro.models import layers
from repro.models.layers import dense_apply, dense_specs, head_rmsnorm

NEG_INF = -1e30


def attn_specs(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    s = {
        "wq": dense_specs(d, cfg.num_heads * hd, ("embed", "heads"), cfg.qkv_bias),
        "wk": dense_specs(d, cfg.num_kv_heads * hd, ("embed", "kv_heads"), cfg.qkv_bias),
        "wv": dense_specs(d, cfg.num_kv_heads * hd, ("embed", "kv_heads"), cfg.qkv_bias),
        "wo": dense_specs(cfg.num_heads * hd, d, ("heads", "embed"), cfg.out_bias),
    }
    if cfg.qk_norm:
        s["q_norm"] = Spec((hd,), ("head_dim",), init="ones")
        s["k_norm"] = Spec((hd,), ("head_dim",), init="ones")
    return s


def _project_qkv(p: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array,
                 rope: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,D) -> q (B,S,Hq,Dh), k/v (B,S,Hkv,Dh)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = dense_apply(p["wq"], x).reshape(B, S, cfg.num_heads, hd)
    k = dense_apply(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
    v = dense_apply(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(p["q_norm"], q)
        k = head_rmsnorm(p["k_norm"], k)
    if rope and cfg.rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal: bool, window: int) -> jax.Array:
    """(B,S,Hq,Dh) layout in, dense scores (oracle path)."""
    from repro.kernels.flash_attn.ref import attention_ref
    qt, kt, vt = (jnp.moveaxis(a, 2, 1) for a in (q, k, v))
    o = attention_ref(qt, kt, vt, causal=causal, window=window)
    return jnp.moveaxis(o, 1, 2).astype(q.dtype)


def _xla_flash(q, k, v, causal: bool, window: int, chunk: int) -> jax.Array:
    """Chunked online softmax over KV; (B,S,H,D) layout; fp32 running stats."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    ck = min(chunk, Sk)
    nk = -(-Sk // ck)
    pad = nk * ck - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nk, ck, Hkv, D)
    vc = v.reshape(B, nk, ck, Hkv, D)
    qf = q.reshape(B, Sq, Hkv, G, D)
    scale = 1.0 / (D ** 0.5)
    q_pos = jnp.arange(Sq)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, blk):
        m, l, acc = carry
        kb, vb, j = blk
        k_pos = j * ck + jnp.arange(ck)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf.astype(jnp.float32) * scale,
                       kb.astype(jnp.float32))
        mask = (k_pos[None, :] < Sk)
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window > 0:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        # probabilities materialize in the model's compute dtype (fp32 running
        # stats keep the numerics); halves the dominant HBM boundary traffic
        # for bf16 models — §Perf
        pdt = q.dtype if q.dtype != jnp.float32 else jnp.float32
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(pdt),
            vb.astype(pdt)).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nk)))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def _banded_attention(q, k, v, window: int) -> jax.Array:
    """Exact sliding-window attention in O(S * 2W) (§Perf H1-iter2).

    q blocks of width W attend only kv blocks (i-1, i): every in-window
    key lands in that 2W band, everything else is masked by the window
    anyway. Replaces the O(S^2) chunk sweep for SWA layers."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    W = window
    nb = S // W
    scale = 1.0 / (D ** 0.5)
    qb = q.reshape(B, nb, W, Hkv, G, D)
    kb = k.reshape(B, nb, W, Hkv, D)
    vb = v.reshape(B, nb, W, Hkv, D)
    z = jnp.zeros_like(kb[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([z, kb[:, :-1]], 1), kb], 2)  # (B,nb,2W,Hkv,D)
    v2 = jnp.concatenate([jnp.concatenate([z, vb[:, :-1]], 1), vb], 2)
    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qb.astype(jnp.float32) * scale,
                   k2.astype(jnp.float32))                # (B,nb,Hkv,G,W,2W)
    q_pos = jnp.arange(W)[:, None] + W                    # within-band coords
    k_pos = jnp.arange(2 * W)[None, :]
    band = (q_pos >= k_pos) & (q_pos - k_pos < W)
    blk = jnp.arange(nb)
    first = (blk == 0)[:, None, None] & (k_pos[None] < W)  # block 0 has no left
    mask = band[None] & ~first                             # (nb, W, 2W)
    s = jnp.where(mask[None, :, None, None], s, NEG_INF)
    pdt = q.dtype if q.dtype != jnp.float32 else jnp.float32
    p = jax.nn.softmax(s, axis=-1).astype(pdt)             # compute-dtype boundary
    o = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p, v2.astype(pdt))
    return o.reshape(B, S, Hq, D).astype(q.dtype)


def _pallas_attention(q, k, v, causal: bool, window: int, chunk: int) -> jax.Array:
    from repro.kernels.flash_attn import ops as fa_ops
    qt, kt, vt = (jnp.moveaxis(a, 2, 1) for a in (q, k, v))
    o = fa_ops.attention(qt, kt, vt, causal=causal, window=window,
                         block_q=min(chunk, q.shape[1]),
                         block_k=min(chunk, k.shape[1]))
    return jnp.moveaxis(o, 1, 2)


def attention(p: dict, cfg: ModelConfig, x: jax.Array, *, ctx: ShardCtx,
              window: int = 0, causal: bool = True,
              positions: Optional[jax.Array] = None,
              kv: Optional[Tuple[jax.Array, jax.Array]] = None):
    """Full-sequence attention. Returns (out (B,S,D), (k, v) for caching).

    ``kv`` overrides the self-attention K/V (cross-attention path)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions, rope=kv is None)
    if kv is not None:
        k, v = kv
    # TP placement: shard heads when they divide the model axis; otherwise
    # fall back to sequence-parallel attention (q rows sharded, kv gathered)
    # instead of full replication.
    m = ctx.axis_size("model")
    seq_ax = "act_seq" if cfg.num_heads % max(m, 1) == 0 else "act_seq_tp"
    q = constrain(q, ("batch", seq_ax, "act_heads", None), ctx)
    k = constrain(k, ("batch", "act_seq", "act_kv_heads", None), ctx)
    impl = cfg.attn_impl
    if impl == "naive":
        o = _naive_attention(q, k, v, causal, window)
    elif impl == "pallas":
        o = _pallas_attention(q, k, v, causal, window, cfg.attn_chunk)
    elif (window > 0 and causal and kv is None and S % window == 0
          and S >= 2 * window):
        o = _banded_attention(q, k, v, window)             # O(S*2W) exact SWA
    else:
        o = _xla_flash(q, k, v, causal, window, cfg.attn_chunk)
    o = o.reshape(B, S, -1)
    return dense_apply(p["wo"], o), (k, v)


# ---------------------------------------------------------------------------
# decode-step attention vs a ring-buffer cache
# ---------------------------------------------------------------------------

def init_cache_specs(cfg: ModelConfig, batch: int, capacity: int,
                     layers_axis: int = 0) -> dict:
    """KV ring buffer spec for ONE layer group. slot_pos tracks the absolute
    position written into each slot (-1 = empty), shared across batch."""
    hd = cfg.resolved_head_dim
    shape_kv = (batch, cfg.num_kv_heads, capacity, hd)
    axes_kv = ("batch", "kv_heads", "act_kv_seq", None)
    if layers_axis:
        shape_kv = (layers_axis,) + shape_kv
        axes_kv = ("layers",) + axes_kv
        slot = Spec((layers_axis, capacity), ("layers", None), init="zeros", dtype="int32")
    else:
        slot = Spec((capacity,), (None,), init="zeros", dtype="int32")
    return {
        "k": Spec(shape_kv, axes_kv, init="zeros", dtype=cfg.dtype),
        "v": Spec(shape_kv, axes_kv, init="zeros", dtype=cfg.dtype),
        "slot_pos": slot,  # initialized to -1 by init_cache()
    }


def decode_update_stacked(cache_layers: dict, layer: int, k_new: jax.Array,
                          v_new: jax.Array, pos: jax.Array) -> dict:
    """Write ONE token's K/V into the (L,B,Hkv,C,hd) stacked cache in place
    (§Perf H3): the update is (1,B,Hkv,1,hd) — with donated buffers this is
    a true in-place ring write, no restacking/copies.

    k_new/v_new: (B,1,Hkv,hd) from the projection."""
    C = cache_layers["k"].shape[3]
    slot = (pos % C).astype(jnp.int32)
    upd_k = jnp.moveaxis(k_new, 1, 2)[None].astype(cache_layers["k"].dtype)
    upd_v = jnp.moveaxis(v_new, 1, 2)[None].astype(cache_layers["v"].dtype)
    k = jax.lax.dynamic_update_slice(cache_layers["k"], upd_k,
                                     (layer, 0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(cache_layers["v"], upd_v,
                                     (layer, 0, 0, slot, 0))
    sp = jax.lax.dynamic_update_slice(cache_layers["slot_pos"],
                                      pos[None, None].astype(jnp.int32),
                                      (layer, slot))
    return {"k": k, "v": v, "slot_pos": sp}


def decode_attend(p: dict, cfg: ModelConfig, q: jax.Array, k_cache, v_cache,
                  slot_pos, pos: jax.Array, *, window: int = 0) -> jax.Array:
    """Attend one query token against a (B,Hkv,C,hd) cache slice.

    ``attn_impl="pallas"`` routes through the flash-decode kernel
    (scores/probs stay in VMEM across the cache sweep — §Perf H3 endgame);
    default is the XLA einsum path (compiles for every dry-run mesh)."""
    B = q.shape[0]
    hd = cfg.resolved_head_dim
    G = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(B, cfg.num_kv_heads, G, hd)
    if cfg.attn_impl == "pallas":
        from repro.kernels.decode_attn import ops as da_ops
        o = da_ops.decode_attend_pallas(qg.astype(k_cache.dtype), k_cache,
                                        v_cache, slot_pos, pos, window)
        o = o.reshape(B, 1, cfg.num_heads * hd).astype(q.dtype)
        return dense_apply(p["wo"], o)
    valid = slot_pos >= 0
    if window > 0:
        valid = valid & (slot_pos > pos - window)
    valid = valid & (slot_pos <= pos)
    s = jnp.einsum("bhgd,bhcd->bhgc", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bhcd->bhgd", w.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, cfg.num_heads * hd).astype(q.dtype)
    return dense_apply(p["wo"], o)


def decode_attention(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                     pos: jax.Array, *, ctx: ShardCtx, window: int = 0,
                     cross: bool = False):
    """One-token attention. x: (B,1,D); cache {k,v: (B,Hkv,C,Dh), slot_pos:(C,)}.

    Returns (out (B,1,D), updated cache). ``cross=True`` reads the cache
    without writing (encoder KV precomputed at prefill — the paper's
    decoupled-projection idea applied to cross-attention)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.broadcast_to(pos[None, None] if pos.ndim == 0 else pos[:, None], (B, 1))
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, rope=not cross and cfg.rope)
    k_cache, v_cache, slot_pos = cache["k"], cache["v"], cache["slot_pos"]
    C = k_cache.shape[2]
    if not cross:
        slot = (pos % C).astype(jnp.int32)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, jnp.moveaxis(k_new, 1, 2).astype(k_cache.dtype),
            (0, 0, slot, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, jnp.moveaxis(v_new, 1, 2).astype(v_cache.dtype),
            (0, 0, slot, 0))
        slot_pos = jax.lax.dynamic_update_slice(slot_pos, pos[None].astype(jnp.int32), (slot,))
    # mask: written slots, not older than the window; cross-attention reads
    # the whole (precomputed) cache regardless of decode position
    valid = slot_pos >= 0
    if not cross:
        if window > 0:
            valid = valid & (slot_pos > pos - window)
        valid = valid & (slot_pos <= pos)

    G = cfg.num_heads // cfg.num_kv_heads
    qg = q.reshape(B, cfg.num_kv_heads, G, hd)
    # cache stays in its storage dtype on the wire; fp32 only in the MXU
    # accumulator (§Perf H3: no full-cache upcast copies)
    s = jnp.einsum("bhgd,bhcd->bhgc", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bhcd->bhgd", w.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype)
    out = dense_apply(p["wo"], o)
    return out, {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}
