"""The sLSTM family (``slstm-jet``) behind the framework model API.

Mirror of :mod:`repro.models.gru_lm` with the cell family switched to the
exponential-gated sLSTM (``repro.core.slstm``): same jet-tagging
classifier shape (recurrent stack + linear head), same serving path
(bucketed masked prefill + fixed-slot single-step decode), all execution
through the capability-dispatched executor with ``cfg.gru.family ==
"slstm"`` — ``compile()`` resolves backends from the ``(slstm, ·)``
registry namespace (fused Pallas stack kernels or the XLA-scan fallback).

The recurrent cache carries the family's FLAT state tuple under ``"h"``:
four leaves per layer, layer-major — ``(c0, n0, m0, h0, c1, ...)`` — each
a ``(B, H)`` array, so the engine's slot scatter/gather and the cache
specs work leaf-by-leaf exactly as they do for the GRU's one-leaf state.
The readout hidden state is the LAST leaf (layer L-1's ``h``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import runtime
from repro.core import slstm as slstm_core
from repro.core.gru import stack_cell_params
from repro.core.params import Spec, init_params
from repro.distributed.sharding import ShardCtx, constrain

_LEAVES = slstm_core.STATE_LEAVES


def slstm_classifier_specs(cfg) -> dict:
    """sLSTM stack + linear classifier head over the last layer's h."""
    head_in = cfg.resolved_layer_dims[-1]
    return {
        "cells": slstm_core.slstm_stack_specs(cfg),
        "head": {
            "w": Spec((head_in, cfg.num_classes), ("hidden", None)),
            "b": Spec((cfg.num_classes,), (None,), init="zeros"),
        },
    }


def lm_specs(cfg: ModelConfig) -> dict:
    return slstm_classifier_specs(cfg.gru)


def forward(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()) -> jax.Array:
    """batch: {features (B,T,X)} -> class logits (B,C)."""
    xs = batch["features"]
    cells = stack_cell_params(params, cfg.gru)
    state0 = slstm_core.stack_state0(cfg.gru, xs.shape[0], jnp.float32)
    finals, _ = runtime.sequence(cells, state0, xs, cfg=cfg.gru)
    return finals[-1] @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()):
    """batch: {features (B,T,X), labels (B,)} -> softmax CE."""
    logits = forward(params, cfg, batch, ctx=ctx).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    loss = (lse - ll).mean()
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return loss, {"ce": loss, "acc": acc, "aux": jnp.zeros((), jnp.float32)}


# --- serving -----------------------------------------------------------------

def _placement(ctx: ShardCtx) -> runtime.Placement:
    """The ctx mesh resolved to an executor Placement (host if none; the
    slstm family registers no mesh backends, so a mesh placement simply
    resolves to the replicated backends)."""
    return (runtime.HOST if ctx.mesh is None
            else runtime.Placement(mesh=ctx.mesh))


def prepare_params(params: dict, cfg: ModelConfig,
                   ctx: ShardCtx = ShardCtx()) -> dict:
    """One-time serving prep via ``runtime.prepare``: attach the fused
    kernels' stacked-weight views (``"stacked_cells"``, 4H gate columns)
    so the per-step decode trace never restacks U/W/b. No-op for
    already-prepared params."""
    sp = runtime.prepare(params, cfg.gru, _placement(ctx))
    out = {"cells": sp.cells, "head": params["head"]}
    if sp.stacked is not None:
        out["stacked_cells"] = sp.stacked
    return out


def serve_executable(cfg: ModelConfig, *, batch: int, seq: int = None,
                     masked: bool = False, mode: str = "serve",
                     mesh=None) -> runtime.GRUExecutable:
    """The executable a serving call with these shapes will use (same
    memoized object ``prefill``/``decode_step`` resolve internally)."""
    return runtime.compile(cfg.gru, batch=batch, seq=seq, placement=mesh,
                           mask=masked, mode=mode)


def cache_specs(cfg: ModelConfig, batch: int, capacity: int = 0) -> dict:
    """Recurrent cache: the flat sLSTM state — four (B, H) leaves per
    layer (c, n, m, h), layer-major. NOTE: the stabilizer leaf ``m`` must
    start at ``slstm.M_INIT``, not zero — use :func:`init_cache` (or a
    ``prefill``-produced cache), never ``init_params`` on these specs."""
    return {
        "h": tuple(
            Spec((batch, h), ("batch", "act_gates"), init="zeros",
                 dtype="float32")
            for h in cfg.gru.resolved_layer_dims
            for _ in range(_LEAVES)),
        "pos": Spec((), (), init="zeros", dtype="int32"),
    }


def init_cache(cfg: ModelConfig, batch: int, capacity: int = 0) -> dict:
    cache = init_params(cache_specs(cfg, batch), jax.random.key(0))
    cache["h"] = slstm_core.stack_state0(cfg.gru, batch)  # m leaf = M_INIT
    return cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict, x: jax.Array, *,
                ctx: ShardCtx = ShardCtx()):
    """One recurrent step through the stack: x (B,X) features ->
    (class logits so far, cache). The executor dispatches within the
    ``(slstm, ·)`` namespace — uniform stacks run the fused decode kernel
    (all four state leaves advanced in ONE pallas_call)."""
    p = runtime.compile(cfg.gru, batch=x.shape[0], mode="decode",
                        placement=_placement(ctx))
    hs = p.decode(params, cache["h"], x)
    hs = tuple(constrain(h, ("batch", "act_gates"), ctx) for h in hs)
    logits = hs[-1] @ params["head"]["w"] + params["head"]["b"]
    return logits.astype(jnp.float32), {"h": hs, "pos": cache["pos"] + 1}


def prefill(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()):
    """Run the full sequence, return (logits, flat recurrent state).

    ``batch["mask"]`` (B, T) bool, optional: False timesteps freeze all
    four state leaves (stabilizer included), so left-padded bucketed
    prompts yield the same state as their unpadded originals — streamed
    through whichever backend the executor picks."""
    xs = batch["features"]
    B = xs.shape[0]
    mask = batch.get("mask")
    state0 = slstm_core.stack_state0(cfg.gru, B, jnp.float32)
    p = runtime.compile(cfg.gru, batch=B, seq=xs.shape[1],
                        mask=mask is not None, mode="prefill",
                        placement=_placement(ctx))
    finals = p.prefill(params, state0, xs, mask=mask)
    logits = (finals[-1] @ params["head"]["w"]
              + params["head"]["b"]).astype(jnp.float32)
    cache = {"h": tuple(h.astype(jnp.float32) for h in finals),
             "pos": jnp.array(xs.shape[1] - 1, jnp.int32)}
    return logits, cache
