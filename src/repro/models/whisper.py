"""Whisper-style encoder-decoder (audio family). The conv frontend is a STUB
per the assignment: ``input_specs()`` provides precomputed frame embeddings
(B, F, D) — everything after the convs is implemented.

Paper-technique mapping: the encoder KV for cross-attention is projected
ONCE at prefill and reused every decode step — the enc-dec analogue of the
paper's decoupled ``W.x`` prefetch (input-dependent work hoisted off the
sequential decode path).

Positions: sinusoidal for both stacks (whisper uses learned decoder
positions; sinusoidal avoids coupling a table size to the 32k decode cell —
recorded in DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.params import Spec, init_params, stack_specs
from repro.distributed.sharding import ShardCtx, constrain
from repro.models import attention as attn_mod
from repro.models import layers
from repro.models.layers import cdtype, dense_apply
from repro.models.transformer import chunked_ce


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """(..., S) int -> (..., S, D) float32 sinusoidal embedding."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def enc_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layers.norm_specs(cfg.d_model, cfg.norm),
        "attn": attn_mod.attn_specs(cfg),
        "ln2": layers.norm_specs(cfg.d_model, cfg.norm),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp),
    }


def dec_block_specs(cfg: ModelConfig) -> dict:
    return {
        "ln1": layers.norm_specs(cfg.d_model, cfg.norm),
        "self_attn": attn_mod.attn_specs(cfg),
        "ln_c": layers.norm_specs(cfg.d_model, cfg.norm),
        "cross_attn": attn_mod.attn_specs(cfg),
        "ln2": layers.norm_specs(cfg.d_model, cfg.norm),
        "mlp": layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp),
    }


def lm_specs(cfg: ModelConfig) -> dict:
    enc_layers = cfg.encoder.num_layers
    return {
        "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),  # tied unembed
        "enc_blocks": stack_specs(enc_block_specs(cfg), enc_layers),
        "enc_norm": layers.norm_specs(cfg.d_model, cfg.norm),
        "dec_blocks": stack_specs(dec_block_specs(cfg), cfg.num_layers),
        "final_norm": layers.norm_specs(cfg.d_model, cfg.norm),
    }


def encode(params: dict, cfg: ModelConfig, frames: jax.Array, *,
           ctx: ShardCtx) -> jax.Array:
    """frames: (B,F,D) precomputed post-conv embeddings -> (B,F,D)."""
    B, F, _ = frames.shape
    x = (frames.astype(cdtype(cfg))
         + sinusoid(jnp.arange(F), cfg.d_model)[None].astype(cdtype(cfg)))
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)
    positions = jnp.broadcast_to(jnp.arange(F)[None, :], (B, F))

    def blockfn(p, x):
        h = layers.norm_apply(p["ln1"], x, cfg.norm)
        a, _ = attn_mod.attention(p["attn"], cfg, h, ctx=ctx, causal=False,
                                  positions=positions)
        x = x + a
        h2 = layers.norm_apply(p["ln2"], x, cfg.norm)
        x = x + layers.mlp_apply(p["mlp"], h2, cfg.mlp)
        return constrain(x, ("batch", "act_seq", "act_embed"), ctx)

    def body(x, p):
        if cfg.remat:
            return jax.checkpoint(blockfn, prevent_cse=False)(p, x), None
        return blockfn(p, x), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.norm_apply(params["enc_norm"], x, cfg.norm)


def _cross_kv(p_attn: dict, cfg: ModelConfig, enc_out: jax.Array):
    """Project encoder output to cross K/V once (the decoupled path)."""
    B, F, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = dense_apply(p_attn["wk"], enc_out).reshape(B, F, cfg.num_kv_heads, hd)
    v = dense_apply(p_attn["wv"], enc_out).reshape(B, F, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        k = layers.head_rmsnorm(p_attn["k_norm"], k)
    return k, v


def dec_block_apply(p: dict, cfg: ModelConfig, x: jax.Array, enc_out, positions,
                    *, ctx: ShardCtx, collect_kv: bool = False):
    h = layers.norm_apply(p["ln1"], x, cfg.norm)
    a, kv = attn_mod.attention(p["self_attn"], cfg, h, ctx=ctx, causal=True,
                               positions=positions)
    x = x + a
    hc = layers.norm_apply(p["ln_c"], x, cfg.norm)
    ckv = _cross_kv(p["cross_attn"], cfg, enc_out)
    c, _ = attn_mod.attention(p["cross_attn"], cfg, hc, ctx=ctx, causal=False,
                              positions=positions, kv=ckv)
    x = x + c
    h2 = layers.norm_apply(p["ln2"], x, cfg.norm)
    x = x + layers.mlp_apply(p["mlp"], h2, cfg.mlp)
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)
    return x, (kv if collect_kv else None), (ckv if collect_kv else None)


def decode_hidden(params: dict, cfg: ModelConfig, tokens: jax.Array,
                  enc_out: jax.Array, *, ctx: ShardCtx, collect_kv=False):
    B, S = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cdtype(cfg))
    x = x + sinusoid(jnp.arange(S), cfg.d_model)[None].astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def body(x, p):
        fn = functools.partial(dec_block_apply, cfg=cfg, enc_out=enc_out,
                               positions=positions, ctx=ctx,
                               collect_kv=collect_kv)
        if cfg.remat and not collect_kv:
            x2, kv, ckv = jax.checkpoint(fn, prevent_cse=False)(p, x=x)
        else:
            x2, kv, ckv = fn(p, x=x)
        return x2, (kv, ckv)

    x, (kvs, ckvs) = jax.lax.scan(body, x, params["dec_blocks"])
    return layers.norm_apply(params["final_norm"], x, cfg.norm), kvs, ckvs


def forward(params, cfg, batch, *, ctx: ShardCtx = ShardCtx()):
    enc_out = encode(params, cfg, batch["frames"], ctx=ctx)
    h, _, _ = decode_hidden(params, cfg, batch["tokens"], enc_out, ctx=ctx)
    return layers.unembed_apply(params["embed"], h, tied=True)


def loss_fn(params, cfg, batch, *, ctx: ShardCtx = ShardCtx()):
    enc_out = encode(params, cfg, batch["frames"], ctx=ctx)
    h, _, _ = decode_hidden(params, cfg, batch["tokens"], enc_out, ctx=ctx)
    ce = chunked_ce(h, params["embed"], batch["targets"], batch.get("mask"),
                    tied=True)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# --- serving ------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    F = cfg.encoder.num_frames
    hd = cfg.resolved_head_dim
    L = cfg.num_layers
    return {
        "self": attn_mod.init_cache_specs(cfg, batch, capacity, layers_axis=L),
        "cross": {
            "k": Spec((L, batch, cfg.num_kv_heads, F, hd),
                      ("layers", "batch", "kv_heads", None, None),
                      init="zeros", dtype=cfg.dtype),
            "v": Spec((L, batch, cfg.num_kv_heads, F, hd),
                      ("layers", "batch", "kv_heads", None, None),
                      init="zeros", dtype=cfg.dtype),
            "slot_pos": Spec((L, F), ("layers", None), init="zeros", dtype="int32"),
        },
        "pos": Spec((), (), init="zeros", dtype="int32"),
    }


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> dict:
    c = init_params(cache_specs(cfg, batch, capacity), jax.random.key(0))
    c["self"]["slot_pos"] = c["self"]["slot_pos"] - 1
    c["cross"]["slot_pos"] = (c["cross"]["slot_pos"] * 0
                              + jnp.arange(cfg.encoder.num_frames)[None])
    return c


def prefill(params: dict, cfg: ModelConfig, batch: dict, *,
            ctx: ShardCtx = ShardCtx()):
    """batch: {frames (B,F,D), tokens (B,S)} -> (last logits, cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(params, cfg, batch["frames"], ctx=ctx)
    h, kvs, ckvs = decode_hidden(params, cfg, tokens, enc_out, ctx=ctx,
                                 collect_kv=True)
    logits = layers.unembed_apply(params["embed"], h[:, -1], tied=True)
    (k, v), (ck, cv) = kvs, ckvs
    L = cfg.num_layers
    cache = {
        "self": {
            "k": jnp.moveaxis(k, 2, 3), "v": jnp.moveaxis(v, 2, 3),
            "slot_pos": jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (L, S)),
        },
        "cross": {
            "k": jnp.moveaxis(ck, 2, 3), "v": jnp.moveaxis(cv, 2, 3),
            "slot_pos": jnp.broadcast_to(
                jnp.arange(cfg.encoder.num_frames, dtype=jnp.int32)[None],
                (L, cfg.encoder.num_frames)),
        },
        "pos": jnp.array(S - 1, jnp.int32),
    }
    return logits, cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                *, ctx: ShardCtx = ShardCtx()):
    pos = cache["pos"] + 1
    x = layers.embed_apply(params["embed"], tokens[:, None], cdtype(cfg))
    x = x + sinusoid(pos[None, None], cfg.d_model).astype(x.dtype)

    def body(x, inp):
        p, self_c, cross_c = inp
        h = layers.norm_apply(p["ln1"], x, cfg.norm)
        a, new_self = attn_mod.decode_attention(p["self_attn"], cfg, h, self_c,
                                                pos, ctx=ctx)
        x = x + a
        hc = layers.norm_apply(p["ln_c"], x, cfg.norm)
        c, _ = attn_mod.decode_attention(p["cross_attn"], cfg, hc, cross_c,
                                         pos, ctx=ctx, cross=True)
        x = x + c
        h2 = layers.norm_apply(p["ln2"], x, cfg.norm)
        x = x + layers.mlp_apply(p["mlp"], h2, cfg.mlp)
        return x, new_self

    x, new_self = jax.lax.scan(body, x,
                               (params["dec_blocks"], cache["self"], cache["cross"]))
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    logits = layers.unembed_apply(params["embed"], x[:, 0], tied=True)
    return logits, {"self": new_self, "cross": cache["cross"], "pos": pos}
