"""xLSTM LM: alternating mLSTM (matrix-memory) and sLSTM (scalar-memory)
blocks, per arXiv:2405.04517, adapted to this framework.

Paper-technique mapping (DESIGN.md §4):

* sLSTM is a gated recurrence isomorphic to the paper's GRU: per step,
  gate pre-activations are ``x W + h R + b``. The ``x W`` term is hoisted
  out of the recurrence as one sequence-level GEMM (decoupled W.x), and the
  recurrent ``h R`` matvec row-shards over the ``gates`` logical axis — the
  paper's row-wise scheme, with the per-step all-gather of h as the
  aggregation path.
* mLSTM trains chunkwise-parallel (quadratic within a chunk, recurrent
  across chunks, exp-gating stabilized); its DECODE step is the same
  state-update matvec regime the paper targets.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.params import Spec, stack_specs
from repro.distributed.sharding import ShardCtx, constrain
from repro.models import layers
from repro.models.layers import cdtype, dense_apply, dense_specs
from repro.models.ssm import _causal_conv
from repro.models.transformer import _unembed_table, chunked_ce


def _mdims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d = cfg.d_model
    di = int(cfg.xlstm.proj_factor * d)
    nh = cfg.num_heads
    return di, nh, di // nh


def _sdims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d = cfg.d_model
    nh = cfg.num_heads
    return d, nh, d // nh


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------

def mlstm_recurrent_step(q, k, v, i_gate, f_gate, state):
    """Single-step stabilized mLSTM. q/k/v: (B,NH,DH); i/f: (B,NH);
    state = (C (B,NH,DH,DH), n (B,NH,DH), m (B,NH))."""
    C, n, m = state
    DH = q.shape[-1]
    k = k * (DH ** -0.5)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    logi = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, logi)
    fs = jnp.exp(logf + m - m_new)[..., None]
    is_ = jnp.exp(logi - m_new)[..., None]
    kf, vf, qf = (a.astype(jnp.float32) for a in (k, v, q))
    C_new = fs[..., None] * C + is_[..., None] * (kf[..., :, None] * vf[..., None, :])
    n_new = fs * n + is_ * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return num / den, (C_new, n_new, m_new)


def mlstm_chunkwise(q, k, v, i_gate, f_gate, state, chunk: int = 64):
    """q/k/v: (B,NH,S,DH); i/f: (B,NH,S). Returns (h (B,NH,S,DH), state')."""
    B, NH, S, DH = q.shape
    L = min(chunk, S)
    while S % L:
        L -= 1
    NC = S // L
    k = k * (DH ** -0.5)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32)).reshape(B, NH, NC, L)
    logi = i_gate.astype(jnp.float32).reshape(B, NH, NC, L)
    qc = q.reshape(B, NH, NC, L, DH).astype(jnp.float32)
    kc = k.reshape(B, NH, NC, L, DH).astype(jnp.float32)
    vc = v.reshape(B, NH, NC, L, DH).astype(jnp.float32)

    def chunk_step(carry, blk):
        C, n, m = carry
        qb, kb, vb, lf, li = blk                    # (B,NH,L,DH)... (B,NH,L)
        b = jnp.cumsum(lf, axis=-1)                 # within-chunk log-decay
        BL = b[..., -1:]
        g = jax.lax.cummax(li - b, axis=li.ndim - 1)  # max_j<=t (logi_j - b_j)
        m_intra = b + g
        m_inter = b + m[..., None]
        m_t = jnp.maximum(m_inter, m_intra)         # (B,NH,L)
        # intra-chunk quadratic part
        dmat = (b[..., :, None] - b[..., None, :] + li[..., None, :]
                - m_t[..., :, None])                # (B,NH,L,L)
        tri = jnp.tril(jnp.ones((L, L), bool))
        dmat = jnp.where(tri[None, None], dmat, -jnp.inf)
        scores = jnp.einsum("bhld,bhmd->bhlm", qb, kb) * jnp.exp(dmat)
        num = jnp.einsum("bhlm,bhmd->bhld", scores, vb)
        den = scores.sum(-1)
        # inter-chunk (previous state) part
        sc_inter = jnp.exp(b + m[..., None] - m_t)  # (B,NH,L)
        num = num + jnp.einsum("bhld,bhde->bhle", qb, C) * sc_inter[..., None]
        den = den + jnp.einsum("bhld,bhd->bhl", qb, n) * sc_inter
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to end of chunk
        m_new = jnp.maximum((BL + m[..., None])[..., 0], (BL + g[..., -1:])[..., 0])
        w = jnp.exp(BL - b + li - m_new[..., None])  # (B,NH,L)
        C_new = (jnp.exp(BL[..., 0] + m - m_new)[..., None, None] * C
                 + jnp.einsum("bhl,bhld,bhle->bhde", w, kb, vb))
        n_new = (jnp.exp(BL[..., 0] + m - m_new)[..., None] * n
                 + jnp.einsum("bhl,bhld->bhd", w, kb))
        return (C_new, n_new, m_new), h

    blks = tuple(jnp.moveaxis(a, 2, 0) for a in (qc, kc, vc, logf, logi))
    state, hs = jax.lax.scan(chunk_step, state, blks)
    h = jnp.moveaxis(hs, 0, 2).reshape(B, NH, S, DH)
    return h, state


def mlstm_init_state(batch: int, nh: int, dh: int):
    return (jnp.zeros((batch, nh, dh, dh), jnp.float32),
            jnp.zeros((batch, nh, dh), jnp.float32),
            jnp.full((batch, nh), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def mlstm_block_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nh, dh = _mdims(cfg)
    w = cfg.xlstm.conv_width
    return {
        "ln": layers.norm_specs(d, cfg.norm),
        "w_up": dense_specs(d, 2 * di, ("embed", "gates")),
        "conv": Spec((w, di), ("conv", "gates"), init="fan_in"),
        "conv_b": Spec((di,), ("gates",), init="zeros"),
        "wq": dense_specs(di, di, ("gates", "heads")),
        "wk": dense_specs(di, di, ("gates", "heads")),
        "wv": dense_specs(di, di, ("gates", "heads")),
        "w_i": dense_specs(di, nh, ("gates", None), bias=True),
        "w_f": dense_specs(di, nh, ("gates", None), bias=True),
        "out_norm": Spec((nh, dh), (None, "head_dim"), init="ones"),
        "w_down": dense_specs(di, d, ("gates", "embed")),
        "skip": Spec((di,), ("gates",), init="ones"),
    }


def _heads(x, nh):
    B, S, D = x.shape
    return jnp.moveaxis(x.reshape(B, S, nh, D // nh), 1, 2)  # (B,NH,S,DH)


def _headnorm(scale, h, eps=1e-6):
    """Per-head RMS norm. h: (B,NH,S,DH) or (B,NH,DH)."""
    hf = h.astype(jnp.float32)
    var = (hf * hf).mean(-1, keepdims=True)
    s = scale.astype(jnp.float32)
    if h.ndim == 4:
        s = s[None, :, None, :]
    else:
        s = s[None, :, :]
    return hf * jax.lax.rsqrt(var + eps) * s


def mlstm_block_apply(p: dict, cfg: ModelConfig, x: jax.Array, *,
                      ctx: ShardCtx, chunk: int = 64,
                      return_state: bool = False):
    di, nh, dh = _mdims(cfg)
    B, S, _ = x.shape
    hln = layers.norm_apply(p["ln"], x, cfg.norm)
    up = dense_apply(p["w_up"], hln)
    xi, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xi, p["conv"], p["conv_b"]))
    q = _heads(dense_apply(p["wq"], xc), nh)
    k = _heads(dense_apply(p["wk"], xc), nh)
    v = _heads(dense_apply(p["wv"], xi), nh)
    ig = jnp.moveaxis(dense_apply(p["w_i"], xc), -1, 1)    # (B,NH,S)
    fg = jnp.moveaxis(dense_apply(p["w_f"], xc), -1, 1)
    h, (C, n, m) = mlstm_chunkwise(q, k, v, ig, fg,
                                   mlstm_init_state(B, nh, dh), chunk)
    h = _headnorm(p["out_norm"], h)                        # (B,NH,S,DH)
    h = jnp.moveaxis(h, 1, 2).reshape(B, S, di).astype(x.dtype)
    h = (h + xc * p["skip"].astype(x.dtype)[None, None, :]) * jax.nn.silu(z)
    out = x + dense_apply(p["w_down"], h)
    if not return_state:
        return out
    w = cfg.xlstm.conv_width
    tail = xi[:, S - (w - 1):, :]
    return out, {"conv_buf": tail, "C": C, "n": n, "mm": m}


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_block_specs(cfg: ModelConfig) -> dict:
    d, nh, dh = _sdims(cfg)
    w = cfg.xlstm.conv_width
    ff = -(-int(d * 4 / 3) // 64) * 64
    return {
        "ln": layers.norm_specs(d, cfg.norm),
        "conv": Spec((w, d), ("conv", "embed"), init="fan_in"),
        "conv_b": Spec((d,), ("embed",), init="zeros"),
        # decoupled input projection: one GEMM for all 4 gates, whole sequence
        "w": dense_specs(d, 4 * d, ("embed", "gates")),
        # recurrent block-diagonal matrix: the paper's row-wise target
        "r": Spec((nh, dh, 4 * dh), (None, "hidden", "gates"), init="recurrent"),
        "b": Spec((4 * d,), ("gates",), init="zeros"),
        "out_norm": Spec((nh, dh), (None, "head_dim"), init="ones"),
        "up": dense_specs(d, 2 * ff, ("embed", "mlp")),
        "down": dense_specs(ff, d, ("mlp", "embed")),
    }


def slstm_step(p: dict, cfg: ModelConfig, state, xw_t: jax.Array):
    """One sLSTM step. xw_t: (B,4D) precomputed x W (decoupled);
    state = (c,n,m,h) each (B,D). Returns (state', h_out (B,D))."""
    d, nh, dh = _sdims(cfg)
    c, n, m, h = state
    B = h.shape[0]
    hh = h.reshape(B, nh, dh)
    rg = jnp.einsum("bhd,hde->bhe", hh.astype(jnp.float32),
                    p["r"].astype(jnp.float32)).reshape(B, 4 * d)
    g = xw_t.astype(jnp.float32) + rg + p["b"].astype(jnp.float32)
    zt, it, ft, ot = jnp.split(g, 4, axis=-1)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c_new = f_ * c + i_ * jnp.tanh(zt)
    n_new = f_ * n + i_
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new), h_new


def slstm_init_state(batch: int, d: int):
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, jnp.full((batch, d), -1e30, jnp.float32), z)


def slstm_block_apply(p: dict, cfg: ModelConfig, x: jax.Array, *,
                      ctx: ShardCtx, return_state: bool = False):
    d, nh, dh = _sdims(cfg)
    B, S, _ = x.shape
    hln = layers.norm_apply(p["ln"], x, cfg.norm)
    xc = jax.nn.silu(_causal_conv(hln, p["conv"], p["conv_b"]))
    xw = dense_apply(p["w"], xc)                           # (B,S,4D) one GEMM

    def body(state, xw_t):
        return slstm_step(p, cfg, state, xw_t)

    (c, n, m, hT), hs = jax.lax.scan(body, slstm_init_state(B, d),
                                     jnp.moveaxis(xw, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                             # (B,S,D)
    h = _headnorm(p["out_norm"], jnp.moveaxis(h.reshape(B, S, nh, dh), 1, 2))
    h = jnp.moveaxis(h, 1, 2).reshape(B, S, d).astype(x.dtype)
    x = x + h
    u, zg = jnp.split(dense_apply(p["up"], x), 2, axis=-1)
    out = x + dense_apply(p["down"], jax.nn.gelu(u) * zg)
    if not return_state:
        return out
    w = cfg.xlstm.conv_width
    tail = hln[:, S - (w - 1):, :]
    return out, {"conv_buf": tail, "c": c, "n": n, "sm": m, "h": hT}


# ---------------------------------------------------------------------------
# full LM (family "ssm": xlstm-125m)
# ---------------------------------------------------------------------------

def lm_specs(cfg: ModelConfig) -> dict:
    pairs = cfg.num_layers // 2
    return {
        "embed": layers.embed_specs(cfg.vocab_size, cfg.d_model),
        "pairs": stack_specs({"m": mlstm_block_specs(cfg),
                              "s": slstm_block_specs(cfg)}, pairs),
        "final_norm": layers.norm_specs(cfg.d_model, cfg.norm),
        "lm_head": Spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                        init="fan_in"),
    }


def hidden_states(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
                  ctx: ShardCtx):
    x = layers.embed_apply(params["embed"], tokens, cdtype(cfg))
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)

    def body(x, p_pair):
        def blockfn(p_pair, x):
            x = mlstm_block_apply(p_pair["m"], cfg, x, ctx=ctx)
            x = slstm_block_apply(p_pair["s"], cfg, x, ctx=ctx)
            return constrain(x, ("batch", "act_seq", "act_embed"), ctx)
        if cfg.remat:
            x = jax.checkpoint(blockfn, prevent_cse=False)(p_pair, x)
        else:
            x = blockfn(p_pair, x)
        return x, None

    x, _ = jax.lax.scan(body, x, params["pairs"])
    return layers.norm_apply(params["final_norm"], x, cfg.norm)


def forward(params, cfg, tokens, *, ctx: ShardCtx = ShardCtx()):
    h = hidden_states(params, cfg, tokens, ctx=ctx)
    return layers.unembed_apply(params["lm_head"], h, tied=False)


def loss_fn(params, cfg, batch, *, ctx: ShardCtx = ShardCtx()):
    h = hidden_states(params, cfg, batch["tokens"], ctx=ctx)
    ce = chunked_ce(h, params["lm_head"], batch["targets"], batch.get("mask"),
                    tied=False)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# --- serving ------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, capacity: int = 0) -> dict:
    """Recurrent state only — O(1) in context length (long_500k runs here)."""
    pairs = cfg.num_layers // 2
    d = cfg.d_model
    di, nh, dh = _mdims(cfg)
    w = cfg.xlstm.conv_width
    f32 = "float32"
    return {
        "m": {
            "conv_buf": Spec((pairs, batch, w - 1, di), ("layers", "batch", None, "gates"), init="zeros", dtype=cfg.dtype),
            "C": Spec((pairs, batch, nh, dh, dh), ("layers", "batch", None, "head_dim", None), init="zeros", dtype=f32),
            "n": Spec((pairs, batch, nh, dh), ("layers", "batch", None, "head_dim"), init="zeros", dtype=f32),
            "mm": Spec((pairs, batch, nh), ("layers", "batch", None), init="zeros", dtype=f32),
        },
        "s": {
            "conv_buf": Spec((pairs, batch, w - 1, d), ("layers", "batch", None, "embed"), init="zeros", dtype=cfg.dtype),
            "c": Spec((pairs, batch, d), ("layers", "batch", None), init="zeros", dtype=f32),
            "n": Spec((pairs, batch, d), ("layers", "batch", None), init="zeros", dtype=f32),
            "sm": Spec((pairs, batch, d), ("layers", "batch", None), init="zeros", dtype=f32),
            "h": Spec((pairs, batch, d), ("layers", "batch", None), init="zeros", dtype=f32),
        },
        "pos": Spec((), (), init="zeros", dtype="int32"),
    }


def init_cache(cfg: ModelConfig, batch: int, capacity: int = 0) -> dict:
    from repro.core.params import init_params
    c = init_params(cache_specs(cfg, batch), jax.random.key(0))
    c["m"]["mm"] = c["m"]["mm"] - 1e30
    c["s"]["sm"] = c["s"]["sm"] - 1e30
    return c


def _mlstm_decode(p, cfg, x, cache_m):
    di, nh, dh = _mdims(cfg)
    B = x.shape[0]
    hln = layers.norm_apply(p["ln"], x, cfg.norm)[:, 0]    # (B,D)
    up = dense_apply(p["w_up"], hln)
    xi, z = jnp.split(up, 2, axis=-1)
    window = jnp.concatenate([cache_m["conv_buf"],
                              xi[:, None, :].astype(cache_m["conv_buf"].dtype)], 1)
    xc = jax.nn.silu((window * p["conv"].astype(window.dtype)[None]).sum(1)
                     + p["conv_b"].astype(window.dtype))
    q = dense_apply(p["wq"], xc).reshape(B, nh, dh)
    k = dense_apply(p["wk"], xc).reshape(B, nh, dh)
    v = dense_apply(p["wv"], xi).reshape(B, nh, dh)
    ig = dense_apply(p["w_i"], xc)                         # (B,NH)
    fg = dense_apply(p["w_f"], xc)
    h, (C, n, m) = mlstm_recurrent_step(q, k, v, ig, fg,
                                        (cache_m["C"], cache_m["n"], cache_m["mm"]))
    h = _headnorm(p["out_norm"], h).reshape(B, di).astype(x.dtype)
    h = (h + xc * p["skip"].astype(x.dtype)[None, :]) * jax.nn.silu(z)
    out = x + dense_apply(p["w_down"], h)[:, None, :]
    return out, {"conv_buf": window[:, 1:], "C": C, "n": n, "mm": m}


def _slstm_decode(p, cfg, x, cache_s):
    d, nh, dh = _sdims(cfg)
    hln = layers.norm_apply(p["ln"], x, cfg.norm)[:, 0]
    window = jnp.concatenate([cache_s["conv_buf"],
                              hln[:, None, :].astype(cache_s["conv_buf"].dtype)], 1)
    xc = jax.nn.silu((window * p["conv"].astype(window.dtype)[None]).sum(1)
                     + p["conv_b"].astype(window.dtype))
    xw = dense_apply(p["w"], xc)
    state = (cache_s["c"], cache_s["n"], cache_s["sm"], cache_s["h"])
    (c, n, m, h), h_out = slstm_step(p, cfg, state, xw)
    B = x.shape[0]
    hn = _headnorm(p["out_norm"],
                   h_out.reshape(B, nh, dh)).reshape(B, d).astype(x.dtype)
    x = x + hn[:, None, :]
    u, zg = jnp.split(dense_apply(p["up"], x), 2, axis=-1)
    x = x + dense_apply(p["down"], jax.nn.gelu(u) * zg)
    return x, {"conv_buf": window[:, 1:], "c": c, "n": n, "sm": m, "h": h}


def decode_step(params: dict, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                *, ctx: ShardCtx = ShardCtx()):
    x = layers.embed_apply(params["embed"], tokens[:, None], cdtype(cfg))

    def body(x, inp):
        p_pair, cm, cs = inp
        x, cm2 = _mlstm_decode(p_pair["m"], cfg, x, cm)
        x, cs2 = _slstm_decode(p_pair["s"], cfg, x, cs)
        return x, (cm2, cs2)

    x, (new_m, new_s) = jax.lax.scan(body, x, (params["pairs"], cache["m"], cache["s"]))
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    logits = layers.unembed_apply(params["lm_head"], x[:, 0], tied=False)
    return logits, {"m": new_m, "s": new_s, "pos": cache["pos"] + 1}


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            ctx: ShardCtx = ShardCtx()):
    """CHUNKWISE-PARALLEL prefill (§Perf H1): the full sequence runs through
    the parallel forward (mLSTM chunkwise, sLSTM with the decoupled xW GEMM)
    and the decode cache is the per-block final state. Weights stream from
    HBM once per block instead of once per token — the paper's row-reuse
    insight applied at the serving layer. (The naive per-token prefill is
    ``prefill_sequential``, kept as the recorded baseline.)"""
    B, S = tokens.shape
    x = layers.embed_apply(params["embed"], tokens, cdtype(cfg))
    x = constrain(x, ("batch", "act_seq", "act_embed"), ctx)

    def body(x, p_pair):
        x, m_state = mlstm_block_apply(p_pair["m"], cfg, x, ctx=ctx,
                                       return_state=True)
        x, s_state = slstm_block_apply(p_pair["s"], cfg, x, ctx=ctx,
                                       return_state=True)
        return x, (m_state, s_state)

    x, (m_states, s_states) = jax.lax.scan(body, x, params["pairs"])
    x = layers.norm_apply(params["final_norm"], x, cfg.norm)
    logits = layers.unembed_apply(params["lm_head"], x[:, -1], tied=False)
    cache = {"m": m_states, "s": s_states,
             "pos": jnp.array(S - 1, jnp.int32)}
    return logits, cache


def prefill_sequential(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
                       ctx: ShardCtx = ShardCtx()):
    """Baseline: per-token prefill through decode steps (re-reads every
    weight each step — kept for the §Perf before/after)."""
    B, S = tokens.shape
    cache = init_cache(cfg, B)

    def body(cache, t):
        logits, cache = decode_step(params, cfg, cache, t, ctx=ctx)
        return cache, None

    cache, _ = jax.lax.scan(body, cache, jnp.moveaxis(tokens[:, :-1], 1, 0))
    logits, cache = decode_step(params, cfg, cache, tokens[:, -1], ctx=ctx)
    return logits, cache
