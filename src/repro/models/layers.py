"""Shared building blocks: norms, dense, RoPE, MLPs, embeddings.

Everything is functional: ``*_specs`` returns a Spec tree (single source of
truth for init/abstract/sharding); ``*_apply`` consumes the matching params.
Compute dtype discipline: params may be fp32 masters; activations run in
``cfg.dtype``; norms/softmax accumulate fp32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.params import Spec


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


# --- norms -----------------------------------------------------------------

def norm_specs(d: int, kind: str = "rmsnorm") -> dict:
    s = {"scale": Spec((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        s["bias"] = Spec((d,), ("embed",), init="zeros")
    return s


def norm_apply(p: dict, x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if kind == "layernorm":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def head_rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3): x (..., D_head), scale (D_head,)."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --- dense -----------------------------------------------------------------

def dense_specs(d_in: int, d_out: int, axes: Tuple[Optional[str], Optional[str]],
                bias: bool = False, init: str = "fan_in", scale: float = 1.0) -> dict:
    s = {"w": Spec((d_in, d_out), axes, init=init, scale=scale)}
    if bias:
        s["b"] = Spec((d_out,), (axes[1],), init="zeros")
    return s


def dense_apply(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# --- rotary embeddings ------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                           # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    if x.ndim == ang.ndim + 1:                              # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- MLP --------------------------------------------------------------------

def mlp_specs(d_model: int, d_ff: int, kind: str = "swiglu",
              bias: bool = False) -> dict:
    if kind == "swiglu":
        return {
            "wg": dense_specs(d_model, d_ff, ("embed", "mlp"), bias),
            "wu": dense_specs(d_model, d_ff, ("embed", "mlp"), bias),
            "wd": dense_specs(d_ff, d_model, ("mlp", "embed"), bias),
        }
    return {
        "w1": dense_specs(d_model, d_ff, ("embed", "mlp"), bias),
        "w2": dense_specs(d_ff, d_model, ("mlp", "embed"), bias),
    }


def mlp_apply(p: dict, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(dense_apply(p["wg"], x)) * dense_apply(p["wu"], x)
        return dense_apply(p["wd"], h)
    return dense_apply(p["w2"], jax.nn.gelu(dense_apply(p["w1"], x)))


# --- embedding / unembedding -------------------------------------------------

def embed_specs(vocab: int, d_model: int) -> Spec:
    return Spec((vocab, d_model), ("vocab", "embed"), init="embed", scale=0.02)


def embed_apply(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def unembed_apply(table_or_w: jax.Array, x: jax.Array, tied: bool) -> jax.Array:
    """logits in fp32 (loss numerics)."""
    w = table_or_w.astype(x.dtype)
    if tied:
        return (x @ w.T).astype(jnp.float32)
    return (x @ w).astype(jnp.float32)


# --- losses ------------------------------------------------------------------

def softmax_xent(logits: jax.Array, targets: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits (..., V) fp32, targets (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()
