"""Selective SSM (Mamba-style) mixer — hymba's parallel-head SSM path.

The decode step ``h' = A_bar * h + B_bar * x`` is the same latency-critical
recurrent matvec regime as the paper's GRU: the input-dependent projections
(delta, B, C — the analogue of the decoupled ``W.x``) are computed off the
recurrent path, and the state update is an elementwise + small-matvec
recurrence that row-shards over the inner dimension.

Training uses a sequential ``lax.scan`` over time (state-sized memory);
a chunked associative scan is a recorded hillclimb option (EXPERIMENTS §Perf).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.params import Spec
from repro.models.layers import dense_apply, dense_specs


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, s.state_dim


def ssm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, dtr, n = _dims(cfg)
    w = cfg.ssm.conv_width
    return {
        "in_proj": dense_specs(d, 2 * di, ("embed", "gates")),     # x and z
        "conv": Spec((w, di), ("conv", "gates"), init="fan_in"),
        "conv_b": Spec((di,), ("gates",), init="zeros"),
        "x_proj": dense_specs(di, dtr + 2 * n, ("gates", "dt")),
        "dt_proj": dense_specs(dtr, di, ("dt", "gates"), init="fan_in"),
        "dt_bias": Spec((di,), ("gates",), init="zeros"),
        "a_log": Spec((di, n), ("gates", "state"), init="zeros"),  # A = -exp(a_log)-1
        "d_skip": Spec((di,), ("gates",), init="ones"),
        "out_proj": dense_specs(di, d, ("gates", "embed")),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,S,Di), kernel: (W,Di) -> (B,S,Di)."""
    W = kernel.shape[0]
    kernel = kernel.astype(x.dtype)
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for w in range(W):
        out = out + xp[:, w:w + x.shape[1], :] * kernel[w][None, None, :]
    return out + bias.astype(x.dtype)[None, None, :]


def _ssm_params(p: dict, xc: jax.Array, cfg: ModelConfig):
    """Input-dependent (decoupled) projections. xc: (...,Di)."""
    di, dtr, n = _dims(cfg)
    proj = dense_apply(p["x_proj"], xc)
    dt_in, B, C = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dense_apply(p["dt_proj"], dt_in) + p["dt_bias"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32)) - 1.0     # (Di,N), stable
    return dt, A, B, C


def ssm_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              return_state: bool = False):
    """Full-sequence mixer: x (B,S,D) -> (B,S,D).

    ``return_state=True`` additionally returns the decode cache after the
    last position ({conv_buf, state}) — the parallel-prefill path (all
    input-dependent projections run as sequence-level GEMMs; only the tiny
    state recurrence is sequential)."""
    B_, S, _ = x.shape
    di, dtr, n = _dims(cfg)
    xz = dense_apply(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xi, p["conv"], p["conv_b"]))
    dt, A, Bm, Cm = _ssm_params(p, xc, cfg)                # dt (B,S,Di), B/C (B,S,N)

    def step(h, t):
        xct, dtt, Bt, Ct = t                               # (B,Di),(B,Di),(B,N),(B,N)
        dA = jnp.exp(dtt[..., None].astype(jnp.float32) * A[None])      # (B,Di,N)
        dBx = (dtt * xct)[..., None].astype(jnp.float32) * Bt[:, None, :]
        h = dA * h + dBx                                   # (B,Di,N)
        y = jnp.einsum("bdn,bn->bd", h, Ct.astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((B_, di, n), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dt, 1, 0),
                                     jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0)))
    y = (jnp.moveaxis(ys, 0, 1).astype(x.dtype)
         + xc * p["d_skip"].astype(x.dtype)[None, None, :])
    y = y * jax.nn.silu(z)
    out = dense_apply(p["out_proj"], y)
    if not return_state:
        return out
    w = cfg.ssm.conv_width
    pad = max(w - 1 - S, 0)
    tail = xi[:, S - (w - 1 - pad):, :].astype(cdtype_of(x))
    if pad:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    return out, {"conv_buf": tail, "state": hT}


def cdtype_of(x):
    return x.dtype


# --- decode -----------------------------------------------------------------

def ssm_cache_specs(cfg: ModelConfig, batch: int, layers_axis: int = 0) -> dict:
    di, _, n = _dims(cfg)
    w = cfg.ssm.conv_width
    lead = (layers_axis,) if layers_axis else ()
    lax_ = ("layers",) if layers_axis else ()
    return {
        "conv_buf": Spec(lead + (batch, w - 1, di), lax_ + ("batch", None, "gates"),
                         init="zeros", dtype=cfg.dtype),
        "state": Spec(lead + (batch, di, n), lax_ + ("batch", "gates", "state"),
                      init="zeros", dtype="float32"),
    }


def ssm_decode_step(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict):
    """One token: x (B,1,D) -> (y (B,1,D), new cache). The recurrent state
    update is the paper's latency regime (row-parallel over Di)."""
    di, dtr, n = _dims(cfg)
    xz = dense_apply(p["in_proj"], x[:, 0])                # (B,2Di)
    xi, z = jnp.split(xz, 2, axis=-1)
    # conv over ring buffer of the last W-1 inputs
    buf = cache["conv_buf"]                                # (B,W-1,Di)
    window = jnp.concatenate([buf, xi[:, None, :].astype(buf.dtype)], axis=1)
    conv = ((window * p["conv"].astype(buf.dtype)[None]).sum(1)
            + p["conv_b"].astype(buf.dtype))
    xc = jax.nn.silu(conv)
    dt, A, Bm, Cm = _ssm_params(p, xc, cfg)                # (B,Di),(Di,N),(B,N),(B,N)
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A[None])
    dBx = (dt * xc)[..., None].astype(jnp.float32) * Bm[:, None, :]
    h = dA * cache["state"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm.astype(jnp.float32)).astype(x.dtype)
    y = y + xc * p["d_skip"].astype(x.dtype)[None, :]
    y = y * jax.nn.silu(z)
    out = dense_apply(p["out_proj"], y)[:, None, :]
    return out, {"conv_buf": window[:, 1:], "state": h}
