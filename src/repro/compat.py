"""Compatibility layer for jax API drift.

The codebase targets the modern surface (``jax.shard_map(check_vma=...)``,
``jax.make_mesh(axis_types=...)``, ``jax.sharding.AxisType``); older jax
(0.4.x) still ships ``jax.experimental.shard_map.shard_map(check_rep=...,
auto=...)`` and a mesh without axis types. Everything in the repo goes
through these two helpers so both toolchains work unchanged.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:           # jax < 0.5: every mesh axis behaves as Auto
    AxisType = None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the concept exists."""
    kw = {} if devices is None else {"devices": devices}
    if AxisType is not None:
        kw["axis_types"] = (AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False,
              axis_names=None):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` shim on
    old. ``axis_names`` (manual axes; the rest stay auto) maps to old jax's
    complementary ``auto`` set; ``check_vma`` maps to ``check_rep``."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return sm(f, **kw)
    from jax.experimental.shard_map import shard_map as esm
    # Old jax: partial-manual regions (auto= on a multi-axis mesh) crash
    # XLA's SPMD partitioner (IsManualSubgroup check), so run fully manual
    # instead: axes outside ``axis_names`` are simply unused by the body and
    # the computation is replicated across them — identical numerics, no
    # auto-sharding inside the region.
    return esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
