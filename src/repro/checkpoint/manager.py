"""Fault-tolerant checkpointing: atomic, async, content-verified, reshardable.

Layout (one directory per step)::

    <dir>/step_000100/
        manifest.json       # tree structure, shapes, dtypes, sha256 per leaf
        <flat.key>.npy      # one file per leaf
    <dir>/step_000100.COMMITTED   # empty marker written LAST (atomicity)

* Writes go to ``step_k.tmp-<pid>`` then ``os.rename`` (atomic on POSIX);
  the COMMITTED marker makes partially-written checkpoints invisible to
  restore even across the rename.
* ``save_async`` snapshots to host memory synchronously (cheap) and writes
  in a background thread — the training loop never blocks on disk.
* ``restore`` takes the CURRENT ShardCtx and reshards whatever mesh the
  checkpoint was written under onto it (elastic restarts: survivors form a
  smaller mesh and restore proceeds) — leaves are stored unsharded, so any
  target topology works.
* keep_last_k garbage collection, checksum verification on restore.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np

from repro.distributed.sharding import ShardCtx, param_shardings
from repro.core.params import is_spec


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------

    def save(self, state, step: int):
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        # serialize with any in-flight async write: both would share the
        # per-pid tmp dir when saving the same step (e.g. a final save right
        # after the loop's save_async) and race the rename
        self.wait()
        self._write(host_state, step)

    def save_async(self, state, step: int):
        """Snapshot now, write in the background."""
        host_state = jax.tree_util.tree_map(lambda x: np.asarray(x), state)
        self.wait()
        self._thread = threading.Thread(target=self._write,
                                        args=(host_state, step), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state, step: int):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, f"{name}.tmp-{os.getpid()}")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(host_state)
        manifest = {"step": step, "leaves": {}}
        treedef = jax.tree_util.tree_structure(host_state)
        manifest["treedef"] = str(treedef)
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            fn = key.replace("/", ".") + ".npy"
            np.save(os.path.join(tmp, fn), arr, allow_pickle=False)
            manifest["leaves"][key] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": _sha(arr),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # commit marker LAST: restore only trusts marked checkpoints
        open(final + ".COMMITTED", "w").close()
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            name = os.path.join(self.dir, f"step_{s:08d}")
            if os.path.exists(name + ".COMMITTED"):
                os.remove(name + ".COMMITTED")
            if os.path.exists(name):
                shutil.rmtree(name)

    # -- restore ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for f in os.listdir(self.dir):
            if f.endswith(".COMMITTED"):
                out.append(int(f[len("step_"):-len(".COMMITTED")]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_state, step: Optional[int] = None,
                ctx: Optional[ShardCtx] = None, state_specs=None,
                verify: bool = True):
        """Rebuild ``like_state``'s tree from disk; reshard onto ``ctx``.

        ``like_state`` provides the tree structure (values unused).
        ``state_specs`` (Spec tree) + ``ctx`` give target shardings; without
        them leaves land on the default device.
        """
        step = step if step is not None else self.latest_step()
        assert step is not None, "no committed checkpoints found"
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten(like_state)
        shardings = None
        if ctx is not None and ctx.mesh is not None and state_specs is not None:
            shardings = _flatten(param_shardings(state_specs, ctx))
        out_flat = {}
        for key in flat_like:
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(path, meta["file"]), allow_pickle=False)
            if verify and _sha(arr) != meta["sha256"]:
                raise IOError(f"checksum mismatch for {key} at step {step}")
            if shardings is not None and key in shardings:
                out_flat[key] = jax.device_put(arr, shardings[key])
            else:
                out_flat[key] = jax.device_put(arr)
        # reassemble in like_state's structure
        leaves, treedef = jax.tree_util.tree_flatten(like_state)
        paths = list(_flatten(like_state).keys())
        return jax.tree_util.tree_unflatten(
            treedef, [out_flat[p] for p in paths])
