"""Deterministic, seekable synthetic data pipeline.

Fault-tolerance contract: ``batch_at(step)`` is a pure function of
(seed, step, shape), so a restart from a checkpoint at step k replays the
EXACT stream — no data-loader state to checkpoint. Sharded host loading:
each host materializes only its addressable slice and assembles a global
``jax.Array`` via ``make_array_from_single_device_arrays``; a device-side
prefetcher double-buffers the next batch.

The LM stream is a noisy deterministic bigram process (next = a*cur + c mod V
with probability 1-eps), so CE on it genuinely decreases during the
end-to-end example runs. The GRU stream labels come from a fixed random
linear teacher over mean features — learnable for the jet-tagging example.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class PipelineConfig:
    seed: int = 0
    bigram_eps: float = 0.25     # fraction of uniform-random next-tokens
    prefetch: int = 2


class SyntheticStream:
    """step -> batch dict of numpy arrays (global shapes)."""

    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 pcfg: PipelineConfig = PipelineConfig()):
        self.cfg = model_cfg
        self.shape = shape
        self.pcfg = pcfg
        v = max(model_cfg.vocab_size, 2)
        r = np.random.default_rng(pcfg.seed ^ 0x5EED)
        self._a = int(r.integers(1, v))
        self._c = int(r.integers(0, v))
        if model_cfg.family == "gru":
            g = model_cfg.gru
            self._teacher = r.normal(size=(g.input_dim, g.num_classes))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shape = self.cfg, self.shape
        B, S = shape.global_batch, shape.seq_len
        rng = np.random.default_rng((self.pcfg.seed << 20) ^ step)
        if cfg.family == "gru":
            g = cfg.gru
            feats = rng.normal(size=(B, S, g.input_dim)).astype(np.float32)
            # teacher weights recent timesteps (aligned with the recurrence)
            w_t = np.linspace(0.2, 1.0, S)[None, :, None]
            pooled = (feats * w_t).sum(1) / w_t.sum()
            labels = (pooled @ self._teacher).argmax(-1).astype(np.int32)
            return {"features": feats, "labels": labels}
        v = cfg.vocab_size
        first = rng.integers(0, v, size=(B, 1))
        noise = rng.random(size=(B, S)) < self.pcfg.bigram_eps
        rand = rng.integers(0, v, size=(B, S))
        seq = np.empty((B, S + 1), np.int64)
        seq[:, :1] = first
        for t in range(S):
            nxt = (seq[:, t] * self._a + self._c) % v
            seq[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        batch = {"tokens": seq[:, :S].astype(np.int32),
                 "targets": seq[:, 1:].astype(np.int32)}
        if cfg.family == "audio":
            batch["frames"] = rng.normal(
                size=(B, cfg.encoder.num_frames, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            batch["patches"] = rng.normal(
                size=(B, cfg.vision.num_patches, cfg.vision.embed_dim)).astype(np.float32)
        return batch


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict[str, jax.Array]:
    """Host -> device with the given NamedSharding tree. Only the
    addressable shard of each array is materialized on this host."""
    def put(x, sh):
        if sh is None:
            return jnp.asarray(x)
        # per-device shards: slice the numpy array per addressable device
        arrs = []
        for d, idx in sh.addressable_devices_indices_map(x.shape).items():
            arrs.append(jax.device_put(x[idx], d))
        return jax.make_array_from_single_device_arrays(x.shape, sh, arrs)
    return jax.tree_util.tree_map(put, batch, shardings)


class Prefetcher:
    """Background thread that keeps ``depth`` device batches ready."""

    def __init__(self, stream: SyntheticStream, shardings, start_step: int = 0,
                 depth: int = 2):
        self.stream = stream
        self.shardings = shardings
        self.step = start_step
        self.depth = depth
        self._buf: Dict[int, dict] = {}
        self._lock = threading.Lock()

    def _fill(self, upto: int):
        for s in range(self.step, upto):
            if s not in self._buf:
                self._buf[s] = shard_batch(self.stream.batch_at(s), self.shardings)

    def next(self) -> dict:
        with self._lock:
            self._fill(self.step + self.depth)
            b = self._buf.pop(self.step)
            self.step += 1
            return b

    def seek(self, step: int):
        with self._lock:
            self._buf.clear()
            self.step = step
