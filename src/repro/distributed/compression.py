"""Gradient compression for slow (cross-pod) links, with error feedback.

Used by the trainer's explicit-DP mode: the train step is shard_map'd
manually over the ``pod`` axis (GSPMD stays auto inside the pod), and the
per-pod gradients are exchanged with a quantized all-reduce:

* ``int8_ef`` — int8 on the wire (4x vs fp32): scales are agreed FIRST via
  a pmax of the per-pod max-abs (tiny collective), every pod quantizes with
  the shared scale, the psum runs on int32, and the quantization residual
  feeds back into the next step's gradient (error feedback keeps the
  compression unbiased over time).
* ``bf16`` — round-to-bf16 + fp32-wire reduce. (A true bf16-wire reduce
  trips an XLA-CPU AllReducePromotion bug in this environment; on TPU the
  same program reduces in bf16. Recorded in DESIGN.md.)
* ``none`` — plain fp32 psum.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def pod_allreduce_mean(grads, method: str, axis: str, ef=None):
    """All-reduce-mean a gradient pytree across ``axis`` (inside shard_map).

    Returns (mean_grads, new_error_feedback). ``ef`` must be a zeros-like
    tree for the first step when method needs it.
    """
    n = jax.lax.psum(1, axis)

    if method == "none":
        out = jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g, axis) / n, grads)
        return out, ef

    if method == "bf16":
        def red(g):
            gq = g.astype(jnp.bfloat16).astype(g.dtype)
            return jax.lax.psum(gq, axis) / n
        return jax.tree_util.tree_map(red, grads), ef

    if method == "int8_ef":
        assert ef is not None, "int8_ef needs an error-feedback tree"

        def red(g, e):
            gc = g + e                                    # apply EF residual
            scale = jnp.maximum(jnp.abs(gc).max(), 1e-12) / 127.0
            scale = jax.lax.pmax(scale, axis)             # agree on the scale
            q = jnp.clip(jnp.round(gc / scale), -127, 127).astype(jnp.int8)
            e_new = gc - q.astype(g.dtype) * scale        # residual stays local
            mean = (jax.lax.psum(q.astype(jnp.int32), axis).astype(g.dtype)
                    * scale / n)
            return mean, e_new

        flat_g, tree = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(ef)
        out = [red(g, e) for g, e in zip(flat_g, flat_e)]
        means = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
        efs = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
        return means, efs

    raise ValueError(f"unknown compression method {method!r}")


def compressed_bytes_per_param(method: str) -> float:
    """Wire bytes per gradient element (roofline accounting)."""
    return {"none": 4.0, "bf16": 2.0, "int8_ef": 1.0}[method]
