"""Fault tolerance for 1000+ node meshes: heartbeats, straggler detection,
elastic re-meshing, and a supervised training loop.

On a real multi-host deployment the signals come from the cluster manager
(missed heartbeats, ICI link errors); here the control logic is implemented
fully and exercised by tests with injected failures — the policy layer is
host-side pure Python and identical either way.

All timing flows through one injectable :class:`Clock`: the monitors, the
training :class:`Supervisor`, and the serving fleet
(``repro.serve.fleet``) share a single time source, so tests drive every
failure path deterministically with a :class:`ManualClock` — no wall-clock
sleeps, no mixed time bases. (The monitors previously accepted per-call
``now=`` overrides that silently mixed with ``time.monotonic()`` defaults;
the Clock is the fix: one source, injected once.)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class Clock:
    """Injectable monotonic time source (seconds)."""

    def now(self) -> float:
        raise NotImplementedError


class SystemClock(Clock):
    """Real time (``time.monotonic``)."""

    def now(self) -> float:
        return time.monotonic()


class ManualClock(Clock):
    """Deterministic test/simulation clock: time moves only when the
    harness advances it."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance(self, dt_s: float) -> float:
        assert dt_s >= 0.0, "time is monotonic"
        self._now += float(dt_s)
        return self._now


@dataclass
class HeartbeatMonitor:
    """Tracks per-host heartbeats; a host is dead after ``timeout_s``.

    Timestamps come from the injected ``clock`` — beats and liveness
    checks always share one time base.
    """
    timeout_s: float = 60.0
    clock: Clock = field(default_factory=SystemClock)
    _last: Dict[str, float] = field(default_factory=dict)

    def beat(self, host: str):
        self._last[host] = self.clock.now()

    def dead_hosts(self) -> List[str]:
        now = self.clock.now()
        return [h for h, t in self._last.items() if now - t > self.timeout_s]

    def alive_hosts(self) -> List[str]:
        now = self.clock.now()
        return [h for h, t in self._last.items() if now - t <= self.timeout_s]


@dataclass
class StragglerMonitor:
    """Flags hosts whose step times exceed ``factor`` x the fleet median.

    Samples are timestamped with the injected ``clock``; ``max_age_s > 0``
    additionally drops samples older than that horizon, so a host that was
    slow long ago is not flagged forever.

    Mitigation hook: the supervisor can drop a straggler from the mesh
    (treat as failed) or trigger data-rebalancing — policy is pluggable
    (the serving fleet hedges a straggler's in-flight requests instead).
    """
    factor: float = 2.0
    window: int = 16
    max_age_s: float = 0.0           # 0 = keep the last `window` regardless
    clock: Clock = field(default_factory=SystemClock)
    _times: Dict[str, List[Tuple[float, float]]] = field(default_factory=dict)

    def record(self, host: str, step_time_s: float):
        self._times.setdefault(host, []).append(
            (self.clock.now(), float(step_time_s)))
        self._times[host] = self._times[host][-self.window:]

    def medians(self) -> Dict[str, float]:
        horizon = (self.clock.now() - self.max_age_s
                   if self.max_age_s > 0 else -np.inf)
        out = {}
        for h, samples in self._times.items():
            vals = [v for t, v in samples if t >= horizon]
            if vals:
                out[h] = float(np.median(vals))
        return out

    def stragglers(self) -> List[str]:
        med = self.medians()
        if len(med) < 2:
            return []
        fleet = float(np.median(list(med.values())))
        return [h for h, m in med.items() if m > self.factor * fleet]


def largest_feasible_mesh(n_devices: int, model_parallel: int,
                          prefer_pods: int = 1) -> Tuple[int, ...]:
    """Elastic re-mesh policy: keep the model axis intact (parameter layout
    survives), shrink data (and pod) parallelism to the largest multiple
    that the survivors support. Returns (pod, data, model) or (data, model).
    """
    assert n_devices >= model_parallel, "cannot keep model axis"
    rest = n_devices // model_parallel
    if prefer_pods > 1 and rest % prefer_pods == 0 and rest >= 2 * prefer_pods:
        return (prefer_pods, rest // prefer_pods, model_parallel)
    return (rest, model_parallel)


@dataclass
class ElasticMeshManager:
    """Owns the current mesh shape; on failure, computes the next one."""
    total_devices: int
    model_parallel: int
    pods: int = 1
    failed: set = field(default_factory=set)

    def survivors(self) -> int:
        return self.total_devices - len(self.failed)

    def fail(self, device_ids: Sequence[int]):
        self.failed.update(device_ids)

    def heal(self, device_ids: Sequence[int]):
        self.failed.difference_update(device_ids)

    def current_shape(self) -> Tuple[int, ...]:
        # shrink to the largest data multiple the survivors allow
        n = self.survivors()
        usable = (n // self.model_parallel) * self.model_parallel
        if usable == 0:
            raise RuntimeError("not enough survivors to keep the model axis")
        return largest_feasible_mesh(usable, self.model_parallel, self.pods)


class Supervisor:
    """Run a training loop with checkpoint/restart on injected failures.

    ``build_fn(mesh_shape) -> (step_fn, state, save_fn, restore_fn)`` lets
    tests rebuild the jitted step for a shrunken mesh. Any exception from
    ``step_fn`` is treated as a node failure: the supervisor marks devices
    failed, re-meshes, restores the last committed checkpoint and resumes.
    """

    def __init__(self, mesh_mgr: ElasticMeshManager, build_fn: Callable,
                 checkpoint_every: int = 10, max_restarts: int = 8,
                 clock: Optional[Clock] = None):
        self.mesh_mgr = mesh_mgr
        self.build_fn = build_fn
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.restarts = 0
        self.clock = clock or SystemClock()
        self.stragglers = StragglerMonitor(clock=self.clock)
        self.heartbeats = HeartbeatMonitor(clock=self.clock)

    def run(self, total_steps: int, inject: Optional[Dict[int, Sequence[int]]] = None):
        """inject: {step: [device_ids]} failures to raise at given steps."""
        inject = inject or {}
        shape = self.mesh_mgr.current_shape()
        step_fn, state, save_fn, restore_fn = self.build_fn(shape)
        step = 0
        history = []
        while step < total_steps:
            try:
                if step in inject:
                    self.mesh_mgr.fail(inject.pop(step))
                    raise RuntimeError("injected node failure")
                t0 = self.clock.now()
                state, metrics = step_fn(state, step)
                self.stragglers.record("host0", self.clock.now() - t0)
                history.append((step, metrics))
                step += 1
                if step % self.checkpoint_every == 0:
                    save_fn(state, step)
            except Exception:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                shape = self.mesh_mgr.current_shape()   # shrunken mesh
                step_fn, state, save_fn, restore_fn = self.build_fn(shape)
                state, step = restore_fn(state)
        return state, step, history
