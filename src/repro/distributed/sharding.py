"""Logical-axis -> mesh-axis sharding rules (MaxText-style), with profiles.

Params declare LOGICAL axes (repro.core.params.Spec); activations are
annotated with logical tuples at block boundaries. This module resolves both
to ``PartitionSpec``s for a concrete mesh, dropping axes that don't divide
and de-duplicating mesh-axis use.

Profiles (the paper's design study, system-wide):

* ``default``   — Megatron TP over "model" (+ FSDP params over "data"):
  column-parallel in-projections, row-parallel out-projections (psum).
* ``sp``        — default + sequence parallelism: activations between blocks
  shard their sequence axis over "model" (reduce-scatter/all-gather pairs).
* ``rowwise``   — the PAPER's scheme applied to recurrent/decode matvecs:
  output rows (GRU "gates", recurrent "hidden") sharded over "model"; every
  shard emits finished outputs; aggregation is an all-gather of activations,
  never a psum of partials.
* ``cascade``   — the paper's baseline: recurrent CONTRACTION dims sharded
  over "model" (partial sums -> psum), output rows replicated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.params import Spec, is_spec, logical_axes

Rules = Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...]

_BASE: Rules = (
    # --- activations ---
    ("batch", ("pod", "data")),
    ("act_seq", ()),                 # () = explicitly replicated
    ("act_embed", ()),
    ("act_heads", ("model",)),
    ("act_kv_heads", ("model",)),
    ("act_mlp", ("model",)),
    ("act_experts", ("data",)),
    ("act_gates", ("model",)),       # row-parallel recurrent activations
    ("act_hidden", ()),
    # KV-cache capacity: picks up "model" when kv_heads cannot divide it
    # (GQA kv<16) — flash-decode-style sequence sharding of the cache.
    ("act_kv_seq", ("model",)),
    # SP-attention fallback: shard the sequence over model when heads can't
    # (hymba 25H, whisper 20H, xlstm 4H vs model=16)
    ("act_seq_tp", ("model",)),
    # --- params ---
    ("layers", ()),
    ("vocab", ("model",)),
    ("embed", ("data",)),            # FSDP/ZeRO-3 weight shard
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("head_dim", ()),
    ("mlp", ("model",)),
    ("experts", ("data",)),          # EP
    ("expert_mlp", ("model",)),
    # --- recurrent cells (paper) ---
    ("gates", ("model",)),           # U/W output rows -> the row-wise scheme
    ("hidden", ()),                  # contraction replicated (rowwise)
    ("rnn_in", ()),
    ("state", ()), ("conv", ()), ("dt", ()),
    ("frames", ()), ("patches", ()), ("vis_embed", ()),
    ("podwise", ("pod",)),           # per-pod local state (EF residuals)
)


def _with(rules: Rules, **over) -> Rules:
    d = dict(rules)
    for k, v in over.items():
        d[k] = v
    return tuple(d.items())


PROFILES: dict = {
    "default": _BASE,
    # sequence parallelism: inter-block activations shard seq over model
    "sp": _with(_BASE, act_seq=("model",)),
    # paper's row-wise scheme (it IS the default for recurrent axes; this
    # profile additionally row-shards decode-time activations)
    "rowwise": _BASE,
    # paper's baseline: contraction-parallel recurrence (cascade + psum)
    "cascade": _with(_BASE, gates=(), hidden=("model",),
                     act_gates=(), act_hidden=()),
}


@dataclass(frozen=True)
class ShardCtx:
    """Everything a model needs to place itself on a mesh.

    ``manual`` lists mesh axes already consumed by an enclosing shard_map
    (e.g. the pod-explicit trainer): sharding constraints inside may only
    reference the remaining auto axes."""
    mesh: Optional[Mesh] = None
    profile: str = "default"
    manual: Tuple[str, ...] = ()

    @property
    def rules(self) -> Rules:
        return PROFILES[self.profile]

    def axis_size(self, name: str) -> int:
        if self.mesh is None or name not in self.mesh.axis_names:
            return 1
        return self.mesh.shape[name]


NO_SHARD = ShardCtx()


def resolve_pspec(axes: Sequence[Optional[str]], shape: Sequence[int],
                  ctx: ShardCtx) -> P:
    """Logical axes tuple -> PartitionSpec, with divisibility + dedup guards."""
    if ctx.mesh is None:
        return P()
    rules = dict(ctx.rules)
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        entry: Tuple[str, ...] = ()
        if name is not None:
            entry = tuple(rules.get(name, ()) or ())
        # keep only axes present in this mesh, unused so far, and dividing
        picked = []
        size = 1
        for ax in entry:
            if ax not in ctx.mesh.axis_names or ax in used or ax in ctx.manual:
                continue
            if dim % (size * ctx.mesh.shape[ax]) != 0:
                continue
            picked.append(ax)
            size *= ctx.mesh.shape[ax]
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(tuple(picked))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspecs(specs, ctx: ShardCtx):
    """Spec tree -> PartitionSpec tree."""
    return jax.tree_util.tree_map(
        lambda s: resolve_pspec(s.axes, s.shape, ctx), specs, is_leaf=is_spec)


def param_shardings(specs, ctx: ShardCtx):
    """Spec tree -> NamedSharding tree (jit in_shardings for the dry-run)."""
    assert ctx.mesh is not None
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, resolve_pspec(s.axes, s.shape, ctx)),
        specs, is_leaf=is_spec)


def constrain(x: jax.Array, axes: Sequence[Optional[str]],
              ctx: ShardCtx) -> jax.Array:
    """with_sharding_constraint via logical axes; no-op without a mesh."""
    if ctx.mesh is None:
        return x
    ps = resolve_pspec(axes, x.shape, ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, ps))


def sharding_for(x_shape: Sequence[int], axes: Sequence[Optional[str]],
                 ctx: ShardCtx) -> NamedSharding:
    assert ctx.mesh is not None
    return NamedSharding(ctx.mesh, resolve_pspec(axes, x_shape, ctx))
