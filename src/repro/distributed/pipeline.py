"""Pipeline parallelism over the ``pod`` axis: GPipe microbatch schedule as
an explicit shard_map + collective_permute program.

Stage s processes microbatch m at tick t = s + m; activations hop to the
next stage with ``ppermute`` after every tick (total ticks = M + n - 1).
All stages execute the same SPMD program with activity masking — this is
the standard TPU pipeline pattern, proven to lower for the multi-pod mesh
in the dry-run and validated numerically against sequential execution.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(stage_fn: Callable, stage_params, xs: jax.Array, *,
                   mesh: Mesh, axis: str = "pod") -> jax.Array:
    """Run ``stage_fn(params_s, x)`` as an n-stage pipeline.

    stage_params: pytree with leading dim = n_stages on every leaf (sharded
    over ``axis``). xs: (M, mb, d) microbatches (replicated). Returns
    (M, mb, d) outputs (replicated).
    """
    n = mesh.shape[axis]

    def f(params_local, xs_full):
        # params_local leaves: (1, ...) — this stage's slice
        p_stage = jax.tree_util.tree_map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        M, mb, d = xs_full.shape
        ticks = M + n - 1

        def tick(t, carry):
            act, outs = carry
            m = t - idx                                   # my microbatch id
            active = jnp.logical_and(m >= 0, m < M)
            x_in = jnp.where(idx == 0,
                             xs_full[jnp.clip(m, 0, M - 1)], act)
            y = stage_fn(p_stage, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage banks its finished microbatch
            is_last = idx == n - 1
            slot = jnp.clip(m, 0, M - 1)
            outs = jax.lax.cond(
                jnp.logical_and(is_last, active),
                lambda o: o.at[slot].set(y),
                lambda o: o, outs)
            # hop to the next stage
            act_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n) for i in range(n)])
            return act_next, outs

        act0 = jnp.zeros((mb, d), xs_full.dtype)
        outs0 = jnp.zeros((M, mb, d), xs_full.dtype)
        _, outs = jax.lax.fori_loop(0, ticks, tick, (act0, outs0))
        # outputs live on the last stage only; replicate them
        outs = jax.lax.psum(
            jnp.where(idx == n - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspecs = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    smapped = shard_map(f, mesh=mesh, axis_names={axis},
                            in_specs=(pspecs, P()), out_specs=P(),
                            check_vma=False)
    # partial-manual shard_map (auto axes remaining) requires a jit context
    return jax.jit(smapped)(stage_params, xs)


def sequential_reference(stage_fn: Callable, stage_params, xs: jax.Array):
    """Oracle: apply stages one after another on every microbatch."""
    n = jax.tree_util.tree_leaves(stage_params)[0].shape[0]

    def run_one(x):
        for s in range(n):
            p = jax.tree_util.tree_map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(run_one)(xs)
