"""Jit'd public wrappers for the rowwise/cascade matvec kernels.

On CPU (tests/benches) the kernels run with ``interpret=True``; on TPU the
same ``pallas_call`` lowers to Mosaic. ``auto_blocks`` picks MXU-aligned
block shapes that keep the working set within a VMEM budget.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.rowwise_matvec.kernel import cascade_matmul, rowwise_matmul


def auto_blocks(B: int, K: int, N: int, itemsize: int = 4,
                vmem_budget: int = 8 * 1024 * 1024) -> Tuple[int, int, int]:
    """(block_b, block_n, block_k): MXU-aligned (multiples of 128 where the
    dim allows), sized so x-block + w-block + out-block fit the budget."""
    def _align(n):
        for c in (512, 256, 128, 64, 32, 16, 8):
            if n % c == 0 and c <= n:
                return c
        return n
    bn = _align(N)
    bk = _align(K)
    bb = B
    while bb > 8 and (bb * K + K * bn + bb * bn) * itemsize > vmem_budget:
        bb //= 2
    while bn > 128 and (bb * K + K * bn + bb * bn) * itemsize > vmem_budget:
        bn //= 2
    return bb, bn, bk


def rowwise(x: jax.Array, w: jax.Array, block_n: int = 0) -> jax.Array:
    """Output-stationary y = x @ w (the paper's row-wise scheme)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    B, K = x.shape
    _, N = w.shape
    bb, bn, _ = auto_blocks(B, K, N, x.dtype.itemsize)
    y = rowwise_matmul(x, w, block_b=bb, block_n=block_n or bn,
                       interpret=on_cpu())
    return y[0] if squeeze else y


def cascade(x: jax.Array, w: jax.Array, block_k: int = 0) -> jax.Array:
    """Contraction-blocked sequential-accumulation baseline."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    B, K = x.shape
    _, N = w.shape
    bb, bn, bk = auto_blocks(B, K, N, x.dtype.itemsize)
    y = cascade_matmul(x, w, block_b=bb, block_n=bn, block_k=block_k or bk,
                       interpret=on_cpu()).astype(x.dtype)
    return y[0] if squeeze else y
