"""Pure-jnp oracle for the rowwise/cascade matvec kernels."""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, w):
    """fp32 dense reference: y = x @ w."""
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)
