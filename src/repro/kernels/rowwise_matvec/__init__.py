from repro.kernels.rowwise_matvec import ops, ref
from repro.kernels.rowwise_matvec.kernel import cascade_matmul, rowwise_matmul

__all__ = ["ops", "ref", "rowwise_matmul", "cascade_matmul"]
