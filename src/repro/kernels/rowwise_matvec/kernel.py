"""Row-wise (output-stationary) matvec/matmul Pallas kernel — the paper's
core tiling idea, TPU-native.

The AIE design gives each tile a set of WHOLE MATRIX ROWS; the input vector
is broadcast once and then reused from tile-local memory ("row reuse"), so
each tile emits FINISHED output elements with no cross-tile reduction.

TPU translation: a Pallas grid over output-row blocks. The activation block's
``index_map`` is constant in the row-block coordinate, so the Pallas pipeline
keeps it resident in VMEM across the whole sweep (the row-reuse), while each
grid step streams in only its own rows of W. No accumulator is ever shared
between grid steps — output-stationary, like the paper.

The ``cascade`` kernel is the baseline the paper argues against: the grid
walks the CONTRACTION dimension and partial sums accumulate sequentially in
the output block across grid steps (the AIE cascade-stream pipeline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rowwise_kernel(x_ref, w_ref, o_ref):
    # x: (bb, K) resident across row blocks; w: (K, bn) this block's rows
    # (stored column-major as (K, N) so "rows of W^T" = columns here).
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def rowwise_matmul(x: jax.Array, w: jax.Array, *, block_b: int = 0,
                   block_n: int = 128, interpret: bool = False) -> jax.Array:
    """y = x @ w, output-stationary grid. x: (B, K), w: (K, N) -> (B, N)."""
    B, K = x.shape
    K2, N = w.shape
    assert K == K2
    bb = block_b or B
    bn = min(block_n, N)
    assert B % bb == 0 and N % bn == 0, (B, bb, N, bn)
    return pl.pallas_call(
        _rowwise_kernel,
        grid=(B // bb, N // bn),
        in_specs=[
            # constant in j -> x stays in VMEM across the row sweep
            pl.BlockSpec((bb, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=interpret,
    )(x, w)


def _cascade_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "block_k", "interpret"))
def cascade_matmul(x: jax.Array, w: jax.Array, *, block_b: int = 0,
                   block_n: int = 128, block_k: int = 128,
                   interpret: bool = False) -> jax.Array:
    """Baseline: contraction-blocked with sequential accumulation (cascade).

    The output block is revisited across the k axis of the grid; partial sums
    accumulate in place (fp32 accumulation via the output dtype upcast in
    ops.py when x is low-precision).
    """
    B, K = x.shape
    K2, N = w.shape
    assert K == K2
    bb = block_b or B
    bn, bk = min(block_n, N), min(block_k, K)
    assert B % bb == 0 and N % bn == 0 and K % bk == 0
    return pl.pallas_call(
        _cascade_kernel,
        grid=(B // bb, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bb, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(x, w)
