"""Pallas TPU kernels (validated with interpret=True on CPU).

The paper's compute hot-spot IS the kernel story: latency-constrained
recurrent matvecs with fused gate epilogues. Each kernel is a subpackage:
``kernel.py`` (pl.pallas_call + explicit BlockSpec VMEM tiling),
``ops.py`` (jit'd public wrapper), ``ref.py`` (pure-jnp oracle).
"""
from __future__ import annotations

import functools

import jax


@functools.cache
def on_cpu() -> bool:
    """True when the default backend is CPU -> kernels run interpret=True."""
    return jax.default_backend() == "cpu"
