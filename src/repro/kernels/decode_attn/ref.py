"""Oracle: masked single-token attention against the cache (fp32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_decode_ref(q, k_cache, v_cache, mask):
    """q: (B,Hkv,G,D); cache: (B,Hkv,C,D); mask: (C,) -> (B,Hkv,G,D)."""
    D = q.shape[-1]
    s = jnp.einsum("bhgd,bhcd->bhgc", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / (D ** 0.5)
    s = jnp.where(mask[None, None, None, :] != 0, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgc,bhcd->bhgd", w, v_cache.astype(jnp.float32))
