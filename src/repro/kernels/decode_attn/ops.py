"""Public wrapper for the flash-decode kernel (matches the shapes used by
``models.attention.decode_attend``)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.decode_attn.kernel import flash_decode


def decode_attend_pallas(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         slot_pos: jax.Array, pos: jax.Array,
                         window: int = 0, block_c: int = 512) -> jax.Array:
    """q: (B, Hkv, G, D); caches (B, Hkv, C, D); slot_pos (C,) absolute
    positions (-1 empty) -> (B, Hkv, G, D) fp32."""
    valid = slot_pos >= 0
    if window > 0:
        valid = valid & (slot_pos > pos - window)
    valid = valid & (slot_pos <= pos)
    C = k_cache.shape[2]
    bc = block_c
    while C % bc:
        bc //= 2
    out = flash_decode(q, k_cache, v_cache, valid, block_c=max(bc, 1),
                       interpret=on_cpu())
    return out.astype(jnp.float32)
