"""Flash-decode Pallas kernel: one query token vs a ring-buffer KV cache.

The §Perf H3 endgame: at decode, HBM traffic should be exactly one read of
the cache block sweep — scores/probabilities never leave VMEM. Grid =
(batch, kv_heads, cache_blocks), cache axis minor; online-softmax running
stats live in VMEM scratch across the block sweep; the output tile is
finalized on the last block. Validity (ring occupancy + sliding window) is
precomputed host-side as a (1, C) mask so the kernel body is pure MAC +
epilogue — the fused-aggregation idea of the paper applied to attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_s, l_s, acc_s, *,
            scale: float):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[...][0, 0].astype(jnp.float32) * scale       # (G, D)
    k = k_ref[...][0, 0].astype(jnp.float32)               # (bc, D)
    v = v_ref[...][0, 0].astype(jnp.float32)               # (bc, D)
    mask = mask_ref[...][0] != 0                           # (bc,)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (G, bc)
    s = jnp.where(mask[None, :], s, _NEG_INF)
    m_prev, l_prev = m_s[...], l_s[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask[None, :], p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_s[...] = alpha * l_prev + p.sum(-1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(c == nc - 1)
    def _fin():
        l = l_s[...]
        o_ref[...] = (acc_s[...] / jnp.where(l == 0.0, 1.0, l)
                      )[None, None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 mask: jax.Array, *, block_c: int = 512,
                 interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, D); k/v cache: (B, Hkv, C, D); mask: (C,) int8/bool
    (1 = valid slot) -> (B, Hkv, G, D)."""
    B, Hkv, G, D = q.shape
    C = k_cache.shape[2]
    bc = min(block_c, C)
    assert C % bc == 0, (C, bc)
    scale = 1.0 / (D ** 0.5)
    mask2 = mask.astype(jnp.int8)[None, :]                 # (1, C)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(B, Hkv, C // bc),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bc, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, bc, D), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, bc), lambda b, h, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, mask2)
