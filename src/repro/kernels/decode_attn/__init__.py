from repro.kernels.decode_attn import ops, ref
from repro.kernels.decode_attn.kernel import flash_decode

__all__ = ["ops", "ref", "flash_decode"]
