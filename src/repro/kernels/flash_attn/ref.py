"""Naive fp32 attention oracle (materializes the score matrix)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: (B,Hq,Sq,D), k/v: (B,Hkv,Sk,D) -> (B,Hq,Sq,D), GQA-aware."""
    q = jnp.asarray(q, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    v = jnp.asarray(v, jnp.float32)
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(D)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= ki
    if window > 0:
        mask &= (qi - ki) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.where(mask.any(-1, keepdims=True), jnp.exp(s - s.max(-1, keepdims=True)), 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
