"""Public wrapper for the flash attention kernel."""
from __future__ import annotations

import jax

from repro.kernels import on_cpu
from repro.kernels.flash_attn.kernel import flash_attention


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
              window: int = 0, block_q: int = 128, block_k: int = 128) -> jax.Array:
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k, interpret=on_cpu())
