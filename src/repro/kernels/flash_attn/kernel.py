"""Blocked causal attention (flash-style) Pallas kernel — prefill path.

Online-softmax forward: grid = (batch, q_heads, q_blocks, kv_blocks), kv
minor. Running max / denominator / output accumulator live in VMEM scratch
and persist across the kv sweep (TPU grids are sequential); the output block
is finalized on the last kv step. GQA is expressed in the K/V ``index_map``
(q-head -> kv-head integer division), sliding-window and causal masking via
block-local index arithmetic, with fully-masked kv blocks skipped by
``pl.when`` (they still iterate but do no FLOPs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                 scale: float, block_q: int, block_k: int, causal: bool,
                 window: int, kv_len: int):
    i, j = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # block-level skip: with causal masking, kv blocks entirely above the
    # diagonal (or entirely outside the window) contribute nothing.
    run = True
    if causal:
        run = (j * block_k) <= (i * block_q + block_q - 1)
    if window > 0:
        in_win = (j * block_k + block_k - 1) >= (i * block_q - window + 1)
        run = jnp.logical_and(run, in_win) if causal else in_win

    @pl.when(run if isinstance(run, jax.Array) else (jnp.bool_(run)))
    def _compute():
        q = q_ref[...][0, 0].astype(jnp.float32) * scale       # (bq, D)
        k = k_ref[...][0, 0].astype(jnp.float32)               # (bk, D)
        v = v_ref[...][0, 0].astype(jnp.float32)               # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev, l_prev = m_s[...], l_s[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)             # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_s[...] = m_new
        l_s[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_s[...]
        o = acc_s[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = o[None, None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D), k/v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D).

    GQA when Hq > Hkv (Hq % Hkv == 0). ``window`` > 0 = sliding-window
    causal attention (kv positions within [q-window+1, q]).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    # pad sequence dims up to block multiples
    Sq_p, Sk_p = -(-Sq // bq) * bq, -(-Sk // bk) * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    scale = 1.0 / (D ** 0.5)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, block_q=bq, block_k=bk,
                          causal=causal, window=window, kv_len=Sk),
        grid=(B, Hq, Sq_p // bq, Sk_p // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
