from repro.kernels.flash_attn import ops, ref
from repro.kernels.flash_attn.kernel import flash_attention

__all__ = ["ops", "ref", "flash_attention"]
