"""Fused sLSTM Pallas kernels: grid = time (sequence) / batch (decode),
weights pinned in VMEM, the exponential-gate stabilizer carried per step.

Same structure as :mod:`repro.kernels.gru_sequence.kernel`, adapted to the
sLSTM family's four-leaf state: a depth-L stack runs as ONE ``pallas_call``
whose recurrent state — cell ``c``, normalizer ``n``, stabilizer ``m`` and
hidden ``h`` per layer — lives in four ``(L, B, H)`` VMEM scratch buffers
across grid steps. All layers' recurrent matrices U (``(L, H, 4H)``) and
the deep layers' input projections use constant ``index_map``s, so the
Pallas pipeline fetches them from HBM exactly once; per sequence step only
the ``(1, B, 4H)`` slice of the precomputed layer-0 ``W.x`` streams in.

The stabilizer is the part that makes sLSTM more than a re-gated GRU: the
exponential input/forget gates are only finite because ``m`` tracks their
running log-scale max, and it is genuinely recurrent state — it rides in
VMEM scratch next to ``h``, is frozen by the mask on padded rows, and is
returned per layer so decode can continue a prefilled sequence exactly.

``slstm_stack_decode_kernel`` is the latency path: one grid step of the
same fused structure advancing a whole batch through all L layers for ONE
token, batch-tiled with ``dimension_semantics=("parallel",)`` (megacore
may split independent tiles across cores), weights resident across tiles.

Both sequence variants take an optional (T, B) mask streamed one (1, B)
slice per step: False rows keep ALL FOUR state leaves (``where`` selects,
it does not perturb), so bucketed left-padded prefill runs the fused
kernel bitwise-identical to unpadded prompts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _gate_math(c, n, m, h, xp, u, b):
    """One sLSTM cell update in fp32 (matches
    ``repro.core.slstm.slstm_gate_math`` op for op). c/n/m/h: (B,H);
    xp: (B,4H); u: (H,4H); b: (1,4H). Gate order [z, i, f, o]."""
    H = h.shape[-1]
    g = xp + _dot(h.astype(u.dtype), u) + b              # (B, 4H) fused gates
    z, i = g[:, :H], g[:, H:2 * H]
    f, o = g[:, 2 * H:3 * H], g[:, 3 * H:]
    logf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(logf + m, i)
    i_ = jnp.exp(i - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c_new = f_ * c + i_ * jnp.tanh(z)
    n_new = f_ * n + i_
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1e-6)
    return c_new, n_new, m_new, h_new


def _store(refs, l, leaves):
    for ref, leaf in zip(refs, leaves):
        ref[l] = leaf


def _stack_kernel(c0_ref, n0_ref, m0_ref, h0_ref, xp_ref, u_ref, wd_ref,
                  b_ref, o_ref, cT_ref, nT_ref, mT_ref, hT_ref,
                  c_s, n_s, m_s, h_s, *, num_layers: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        c_s[...] = c0_ref[...].astype(jnp.float32)
        n_s[...] = n0_ref[...].astype(jnp.float32)
        m_s[...] = m0_ref[...].astype(jnp.float32)
        h_s[...] = h0_ref[...].astype(jnp.float32)

    b = b_ref[...].astype(jnp.float32)                    # (L, 4H)
    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 4H): layer-0 Wx
    for l in range(num_layers):                           # static unroll
        new = _gate_math(c_s[l], n_s[l], m_s[l], h_s[l], xp, u_ref[l],
                         b[l:l + 1])
        _store((c_s, n_s, m_s, h_s), l, new)
        if l + 1 < num_layers:
            # next layer's input projection, same timestep, stays in VMEM
            xp = _dot(new[3].astype(wd_ref.dtype), wd_ref[l])
    o_ref[...] = new[3][None].astype(o_ref.dtype)
    cT_ref[...] = c_s[...].astype(cT_ref.dtype)
    nT_ref[...] = n_s[...].astype(nT_ref.dtype)
    mT_ref[...] = m_s[...].astype(mT_ref.dtype)
    hT_ref[...] = h_s[...].astype(hT_ref.dtype)


def _stack_kernel_masked(c0_ref, n0_ref, m0_ref, h0_ref, xp_ref, u_ref,
                         wd_ref, b_ref, m_ref, o_ref, cT_ref, nT_ref, mT_ref,
                         hT_ref, c_s, n_s, m_s, h_s, *, num_layers: int):
    """Masked fused stack: ONE shared (1, B) mask slice per step freezes
    every layer's FOUR state leaves on False rows (the stabilizer must
    freeze with the gates, or live steps after padding would see a wrong
    log-scale max). Unmasked rows run exactly the unmasked arithmetic."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        c_s[...] = c0_ref[...].astype(jnp.float32)
        n_s[...] = n0_ref[...].astype(jnp.float32)
        m_s[...] = m0_ref[...].astype(jnp.float32)
        h_s[...] = h0_ref[...].astype(jnp.float32)

    b = b_ref[...].astype(jnp.float32)                    # (L, 4H)
    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 4H): layer-0 Wx
    keep = m_ref[...][0] != 0.0                           # (B,) this step
    for l in range(num_layers):                           # static unroll
        new = _gate_math(c_s[l], n_s[l], m_s[l], h_s[l], xp, u_ref[l],
                         b[l:l + 1])
        new = tuple(jnp.where(keep[:, None], a, s[l])
                    for a, s in zip(new, (c_s, n_s, m_s, h_s)))
        _store((c_s, n_s, m_s, h_s), l, new)
        if l + 1 < num_layers:
            xp = _dot(new[3].astype(wd_ref.dtype), wd_ref[l])
    o_ref[...] = new[3][None].astype(o_ref.dtype)
    cT_ref[...] = c_s[...].astype(cT_ref.dtype)
    nT_ref[...] = n_s[...].astype(nT_ref.dtype)
    mT_ref[...] = m_s[...].astype(mT_ref.dtype)
    hT_ref[...] = h_s[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def slstm_stack_sequence_kernel(c0: jax.Array, n0: jax.Array, m0: jax.Array,
                                h0: jax.Array, x_proj: jax.Array,
                                u: jax.Array, w_deep: jax.Array, b: jax.Array,
                                mask=None, *, interpret: bool = False):
    """Depth-L fused sLSTM stack (uniform hidden size H across layers).

    c0/n0/m0/h0: (L,B,H) per-layer initial state leaves; x_proj: (T,B,4H)
    time-major precomputed layer-0 Wx; u: (L,H,4H); w_deep: (L-1,H,4H)
    deep-layer input projections ((1,1,4H) zeros for L=1, unused);
    b: (L,4H). Returns (last-layer h states (T,B,H), then the four
    per-layer final leaves cT/nT/mT/hT, each (L,B,H)).

    ``mask`` (T,B) float (nonzero = live step), optional: streamed one
    (1,B) slice per grid step; False steps freeze every layer's c/n/m/h
    in-kernel (bucketed prefill runs the fused kernel, no XLA fallback).
    """
    T, B, H4 = x_proj.shape
    H = H4 // 4
    L = h0.shape[0]
    Ld = max(L - 1, 1)
    state_spec = pl.BlockSpec((L, B, H), lambda t: (0, 0, 0))  # resident
    in_specs = [
        state_spec, state_spec, state_spec, state_spec,
        pl.BlockSpec((1, B, 4 * H), lambda t: (t, 0, 0)),  # stream step t
        pl.BlockSpec((L, H, 4 * H), lambda t: (0, 0, 0)),  # all U: ONCE
        pl.BlockSpec((Ld,) + w_deep.shape[1:], lambda t: (0, 0, 0)),
        pl.BlockSpec((L, 4 * H), lambda t: (0, 0)),
    ]
    args = [c0, n0, m0, h0, x_proj, u, w_deep, b]
    if mask is None:
        kern = functools.partial(_stack_kernel, num_layers=L)
    else:
        kern = functools.partial(_stack_kernel_masked, num_layers=L)
        in_specs.append(pl.BlockSpec((1, B), lambda t: (t, 0)))  # step's mask
        args.append(mask.astype(jnp.float32))
    fin = jax.ShapeDtypeStruct((L, B, H), h0.dtype)
    hs, cT, nT, mT, hT = pl.pallas_call(
        kern,
        grid=(T,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, B, H), lambda t: (t, 0, 0))]
        + [pl.BlockSpec((L, B, H), lambda t: (0, 0, 0))] * 4,
        out_shape=[jax.ShapeDtypeStruct((T, B, H), h0.dtype),
                   fin, fin, fin, fin],
        scratch_shapes=[pltpu.VMEM((L, B, H), jnp.float32)
                        for _ in range(4)],                # carried c/n/m/h
        interpret=interpret,
    )(*args)
    return hs, cT, nT, mT, hT


# ---------------------------------------------------------------------------
# fused decode step (the latency path)
# ---------------------------------------------------------------------------

def _decode_kernel(c_ref, n_ref, m_ref, h_ref, xp_ref, u_ref, wd_ref, b_ref,
                   co_ref, no_ref, mo_ref, ho_ref, *, num_layers: int):
    """One token through all L layers for one batch tile. Weights resident;
    layer l+1 consumes layer l's same-token hidden state straight from
    registers (nothing round-trips through HBM)."""
    b = b_ref[...].astype(jnp.float32)                    # (L, 4H)
    xp = xp_ref[...].astype(jnp.float32)                  # (Bt, 4H)
    for l in range(num_layers):                           # static unroll
        new = _gate_math(c_ref[l].astype(jnp.float32),
                         n_ref[l].astype(jnp.float32),
                         m_ref[l].astype(jnp.float32),
                         h_ref[l].astype(jnp.float32),
                         xp, u_ref[l], b[l:l + 1])
        co_ref[l] = new[0].astype(co_ref.dtype)
        no_ref[l] = new[1].astype(no_ref.dtype)
        mo_ref[l] = new[2].astype(mo_ref.dtype)
        ho_ref[l] = new[3].astype(ho_ref.dtype)
        if l + 1 < num_layers:
            xp = _dot(new[3].astype(wd_ref.dtype), wd_ref[l])


def _pick_batch_block(B: int, limit: int = 256) -> int:
    """Largest divisor of B that fits the VMEM budget heuristic."""
    blk = min(B, limit)
    while B % blk:
        blk -= 1
    return blk


@functools.partial(jax.jit, static_argnames=("batch_block", "interpret"))
def slstm_stack_decode_kernel(c: jax.Array, n: jax.Array, m: jax.Array,
                              h: jax.Array, x_proj: jax.Array, u: jax.Array,
                              w_deep: jax.Array, b: jax.Array, *,
                              batch_block: int = 0, interpret: bool = False):
    """Fused decode step for a depth-L sLSTM stack (uniform hidden size).

    c/n/m/h: (L,B,H) per-layer state leaves; x_proj: (B,4H) precomputed
    layer-0 Wx for the ONE new token; u: (L,H,4H); w_deep: (L-1,H,4H)
    ((1,1,4H) zeros for L=1, unused); b: (L,4H). Returns the four new
    per-layer leaves (L,B,H) each.

    Grid = batch tiles (``batch_block`` rows each, 0 = auto): weights use
    constant index_maps (fetched once regardless of tile count) and the
    tiles carry no cross-tile state, so the axis is ``parallel``.
    """
    L, B, H = h.shape
    Bt = batch_block or _pick_batch_block(B)
    assert B % Bt == 0, (B, Bt)
    Ld = max(L - 1, 1)
    tile = pl.BlockSpec((L, Bt, H), lambda i: (0, i, 0))
    out = jax.ShapeDtypeStruct((L, B, H), h.dtype)
    return pl.pallas_call(
        functools.partial(_decode_kernel, num_layers=L),
        grid=(B // Bt,),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        in_specs=[
            tile, tile, tile, tile,                        # this batch tile
            pl.BlockSpec((Bt, 4 * H), lambda i: (i, 0)),   # its Wx slab
            pl.BlockSpec((L, H, 4 * H), lambda i: (0, 0, 0)),  # all U: ONCE
            pl.BlockSpec((Ld,) + w_deep.shape[1:], lambda i: (0, 0, 0)),
            pl.BlockSpec((L, 4 * H), lambda i: (0, 0)),
        ],
        out_specs=[tile, tile, tile, tile],
        out_shape=[out, out, out, out],
        interpret=interpret,
    )(c, n, m, h, x_proj, u, w_deep, b)
