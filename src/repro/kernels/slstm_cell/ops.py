"""Pallas sLSTM executor backend: the fused whole-stack kernels.

These wrappers implement the ``(slstm, pallas_fused)`` backend of
:mod:`repro.core.runtime` — registered via
:func:`register_runtime_backends` (called on package import and by
``runtime.compile()`` on first use). Nothing outside ``repro.core`` /
``repro.kernels`` should import them directly (CI enforces the boundary);
go through ``runtime.compile()`` with ``cfg.family="slstm"``.

Same split as the GRU backends: the layer-0 input projection (decoupled
``W.x``) is one MXU GEMM outside the kernel; the kernel owns the recurrent
path — all layers, all four state leaves (c, n, stabilizer m, h) in VMEM
scratch — in one ``pallas_call``. A (B, T) length mask, when
given, streams through the kernel per step. The XLA-scan fallback
(``(slstm, xla)``) registers from :mod:`repro.core.slstm`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.slstm import STATE_LEAVES, flatten_states, group_states
from repro.kernels import on_cpu
from repro.kernels.slstm_cell.kernel import (slstm_stack_decode_kernel,
                                             slstm_stack_sequence_kernel)


def _time_major_mask(mask: Optional[jax.Array]) -> Optional[jax.Array]:
    """(B, T) bool/float -> (T, B) float32 for per-step kernel streaming."""
    if mask is None:
        return None
    return jnp.moveaxis(mask, -1, 0).astype(jnp.float32)


def _stacked_weights(params: tuple):
    """(u (L,H,4H), w_deep (max(L-1,1),·,4H), b (L,4H)) device-side stacks."""
    L = len(params)
    H = params[0]["u"].shape[0]
    u = jnp.stack([p["u"] for p in params], 0)
    if L > 1:
        w_deep = jnp.stack([p["w"] for p in params[1:]], 0)
    else:
        w_deep = jnp.zeros((1, 1, 4 * H), params[0]["w"].dtype)
    b = jnp.stack([p["b"] for p in params], 0)
    return u, w_deep, b


def prepare_stacked_cells(params: tuple) -> dict:
    """Precompute the stacked-weight views the fused kernels want
    ({u (L,H,4H), w_deep, b (L,4H)}). Done ONCE by ``runtime.prepare`` so
    the decode trace carries no per-token weight restacking."""
    u, w_deep, b = _stacked_weights(tuple(params))
    return {"u": u, "w_deep": w_deep, "b": b}


def _leaf_stacks(state: tuple, L: int):
    """Flat (4L,) state tuple -> four (L,B,H) leaf stacks (c, n, m, h)."""
    groups = group_states(state, L)
    return tuple(jnp.stack([g[k] for g in groups], 0)
                 for k in range(STATE_LEAVES))


def _unstack_leaves(leaves, L: int) -> tuple:
    """Four (L,B,H) leaf stacks -> flat (4L,) state tuple, layer-major."""
    return flatten_states(tuple(tuple(leaf[l] for leaf in leaves)
                                for l in range(L)))


def slstm_stack_sequence_pallas(params: tuple, state0: tuple, xs: jax.Array,
                                *, cfg, return_all: bool = False, mask=None,
                                stacked: Optional[dict] = None):
    """Fused depth-L sLSTM stack (uniform hidden sizes): ONE pallas_call.

    params: per-layer ({w,u,b}, ...), layer 0 first; state0: flat (4L,)
    tuple of (B,H) leaves. Returns (flat finals, optionally last layer's
    (B,T,H) h sequence). ``mask`` (B,T) streams through the kernel (False
    steps freeze every layer's four leaves); ``stacked`` is an optional
    precomputed ``prepare_stacked_cells`` output.
    """
    L = len(params)
    xp = xs @ params[0]["w"]                       # layer-0 decoupled GEMM
    xp_t = jnp.moveaxis(xp, -2, 0)                 # (T,B,4H)
    c0, n0, m0, h0 = _leaf_stacks(tuple(state0), L)
    if stacked is None:
        u, w_deep, b = _stacked_weights(params)
    else:
        u, w_deep, b = stacked["u"], stacked["w_deep"], stacked["b"]
    hs, cT, nT, mT, hT = slstm_stack_sequence_kernel(
        c0, n0, m0, h0, xp_t, u, w_deep, b, _time_major_mask(mask),
        interpret=on_cpu())
    finals = _unstack_leaves((cT, nT, mT, hT), L)
    if return_all:
        return finals, jnp.moveaxis(hs, 0, -2)
    return finals, None


def slstm_stack_decode_pallas(params: tuple, state: tuple, x: jax.Array, *,
                              cfg, stacked: Optional[dict] = None) -> tuple:
    """Fused decode step: ONE pallas_call advances the whole batch through
    all L layers for one token (uniform hidden sizes required). state:
    flat (4L,) tuple; returns the flat new state."""
    L = len(params)
    xp = x @ params[0]["w"]                        # (B,4H)
    c, n, m, h = _leaf_stacks(tuple(state), L)
    if stacked is None:
        stacked = prepare_stacked_cells(params)
    new = slstm_stack_decode_kernel(c, n, m, h, xp, stacked["u"],
                                    stacked["w_deep"], stacked["b"],
                                    interpret=on_cpu())
    return _unstack_leaves(new, L)


# ---------------------------------------------------------------------------
# runtime registration
# ---------------------------------------------------------------------------

_REGISTERED = False


def register_runtime_backends() -> None:
    """Idempotently register ``(slstm, pallas_fused)`` with the executor.
    Called on ``repro.kernels.slstm_cell`` import and by
    ``runtime.compile()`` on first use (whichever happens first)."""
    global _REGISTERED
    if _REGISTERED:
        return
    from repro.core import runtime

    def fused_seq(sp, state0, xs, *, cfg, return_all, mask, placement):
        return slstm_stack_sequence_pallas(sp.cells, tuple(state0), xs,
                                           cfg=cfg, return_all=return_all,
                                           mask=mask, stacked=sp.stacked)

    def fused_dec(sp, state, x, *, cfg, placement):
        return slstm_stack_decode_pallas(sp.cells, tuple(state), x, cfg=cfg,
                                         stacked=sp.stacked)

    runtime.register_backend(runtime.BackendSpec(
        family="slstm",
        name="pallas_fused",
        caps=runtime.Capabilities(supports_mask=True,
                                  supports_hetero_dims=False,
                                  supports_mesh=False, return_all=True,
                                  decode=True, sequence=True),
        cost=10,
        sequence_fn=fused_seq, decode_fn=fused_dec))
    _REGISTERED = True
