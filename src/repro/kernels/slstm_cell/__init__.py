from repro.kernels.slstm_cell import ops, ref
from repro.kernels.slstm_cell.kernel import (slstm_stack_decode_kernel,
                                             slstm_stack_sequence_kernel)

# Plug the fused sLSTM backend into the executor's (family, backend)
# capability registry (repro.core.runtime); runtime.compile() also
# triggers this lazily.
ops.register_runtime_backends()

__all__ = ["ops", "ref", "slstm_stack_sequence_kernel",
           "slstm_stack_decode_kernel"]
