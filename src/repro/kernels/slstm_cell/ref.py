"""Oracle for the fused sLSTM kernels: step-by-step fp32 recurrence on the
same raw-array interface (stacked (L,·,·) weights, four (L,B,H) state
leaves), mirroring :mod:`repro.kernels.gru_sequence.ref`. The model-layout
oracle lives in :func:`repro.core.slstm.slstm_stack_reference`."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.slstm import slstm_gate_math


def _step(state, xp, u, w_deep, b):
    """Advance all L layers one step. state: list of [c,n,m,h] per layer
    (mutated); xp: (B,4H) layer-0 Wx. Returns the last layer's new h."""
    L = len(state)
    xp = jnp.asarray(xp, jnp.float32)
    for l in range(L):
        new = slstm_gate_math(*state[l], xp, jnp.asarray(u[l], jnp.float32),
                              jnp.asarray(b[l], jnp.float32))
        state[l] = list(new)
        if l + 1 < L:
            xp = new[3] @ jnp.asarray(w_deep[l], jnp.float32)
    return state[-1][3]


def _init(c0, n0, m0, h0):
    L = c0.shape[0]
    return [[jnp.asarray(leaf[l], jnp.float32) for leaf in (c0, n0, m0, h0)]
            for l in range(L)]


def slstm_stack_sequence_ref(c0, n0, m0, h0, x_proj, u, w_deep, b):
    """Oracle for the fused stack kernel, same raw-array interface.

    c0/n0/m0/h0: (L,B,H), x_proj: (T,B,4H) layer-0 Wx, u: (L,H,4H),
    w_deep: (L-1,H,4H), b: (L,4H) -> ((T,B,H) last-layer h states, then
    the four (L,B,H) per-layer final leaves)."""
    state = _init(c0, n0, m0, h0)
    out = [jnp.stack([_step(state, x_proj[t], u, w_deep, b)
                      for t in range(x_proj.shape[0])], axis=0)]
    for k in range(4):
        out.append(jnp.stack([layer[k] for layer in state], axis=0))
    return tuple(out)


def slstm_stack_decode_ref(c, n, m, h, x_proj, u, w_deep, b):
    """Oracle for the fused decode-step kernel: (L,B,H) leaves, x_proj
    (B,4H) layer-0 Wx of ONE token -> the four new (L,B,H) leaves."""
    state = _init(c, n, m, h)
    _step(state, x_proj, u, w_deep, b)
    return tuple(jnp.stack([layer[k] for layer in state], axis=0)
                 for k in range(4))
