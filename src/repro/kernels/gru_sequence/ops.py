"""Public wrapper: run a GRU over a sequence with the Pallas backend.

Interface matches ``repro.core.gru.gru_sequence`` (called from there when
``cfg.backend == "pallas"``). The input projection (decoupled W.x) is one
MXU GEMM outside the kernel; the kernel owns only the recurrent path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.gru_sequence.kernel import gru_sequence_kernel


def gru_sequence_pallas(params: dict, h0: jax.Array, xs: jax.Array, *, cfg,
                        return_all: bool = False):
    """params: {w,u,b}; xs: (B,T,X) -> (h_T, optionally (B,T,H))."""
    w, u, b = params["w"], params["u"], params["b"]
    xp = xs @ w                                    # (B,T,3H): the decoupled GEMM
    xp_t = jnp.moveaxis(xp, -2, 0)                 # time-major (T,B,3H)
    hs = gru_sequence_kernel(h0, xp_t, u, b, variant=cfg.variant,
                             interpret=on_cpu())
    hT = hs[-1]
    if return_all:
        return hT, jnp.moveaxis(hs, 0, -2)
    return hT, None
