"""Pallas GRU executor backends: fused whole-stack kernels + per-layer chain.

These wrappers are the implementation of the ``pallas_fused`` and
``pallas_chain`` backends of :mod:`repro.core.runtime` — registered via
:func:`register_runtime_backends` (called on package import). Nothing
outside ``repro.core`` / ``repro.kernels`` should import them directly
(CI enforces the boundary); go through ``runtime.compile()``.

The layer-0 input projection (decoupled W.x) is one MXU GEMM outside the
kernel; the kernel owns the recurrent path — for the fused variant, ALL
layers of it in one ``pallas_call``. A (B, T) length mask, when given, is
streamed through the kernels per step (no XLA fallback for bucketed
prefill). The chain variant runs one kernel per layer and therefore also
serves heterogeneous ``layer_dims``.

The ``pallas_sharded`` backend (registered by ``repro.core.runtime``,
implemented by ``repro.core.rowparallel``'s kernel-invoking shard bodies)
does NOT go through these wrappers: its per-shard step programs are the
shard-shaped entry points in :mod:`repro.kernels.gru_sequence.kernel`
(``gru_rowwise_shard_*`` / ``gru_cascade_shard_*`` / ``gru_shard_matvec``),
each computing the per-shard segment of a GRU step between two shard_map
collectives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.gru_sequence.kernel import (gru_sequence_kernel,
                                               gru_sequence_q8_kernel,
                                               gru_stack_decode_kernel,
                                               gru_stack_decode_q8_kernel,
                                               gru_stack_sequence_kernel,
                                               gru_stack_sequence_q8_kernel)


def _time_major_mask(mask: Optional[jax.Array]) -> Optional[jax.Array]:
    """(B, T) bool/float -> (T, B) float32 for per-step kernel streaming."""
    if mask is None:
        return None
    return jnp.moveaxis(mask, -1, 0).astype(jnp.float32)


def _stacked_weights(params: tuple):
    """(u (L,H,3H), w_deep (max(L-1,1),·,3H), b (L,3H)) device-side stacks."""
    L = len(params)
    H = params[0]["u"].shape[0]
    u = jnp.stack([p["u"] for p in params], 0)
    if L > 1:
        w_deep = jnp.stack([p["w"] for p in params[1:]], 0)
    else:
        w_deep = jnp.zeros((1, 1, 3 * H), params[0]["w"].dtype)
    b = jnp.stack([p["b"] for p in params], 0)
    return u, w_deep, b


def gru_sequence_pallas(params: dict, h0: jax.Array, xs: jax.Array, *, cfg,
                        return_all: bool = False, mask=None):
    """params: {w,u,b}; xs: (B,T,X) -> (h_T, optionally (B,T,H)).
    ``mask`` (B,T): False steps freeze h (streamed through the kernel)."""
    w, u, b = params["w"], params["u"], params["b"]
    xp = xs @ w                                    # (B,T,3H): the decoupled GEMM
    xp_t = jnp.moveaxis(xp, -2, 0)                 # time-major (T,B,3H)
    hs = gru_sequence_kernel(h0, xp_t, u, b, _time_major_mask(mask),
                             variant=cfg.variant, interpret=on_cpu())
    hT = hs[-1]
    if return_all:
        return hT, jnp.moveaxis(hs, 0, -2)
    return hT, None


def gru_stack_sequence_pallas(params: tuple, h0s: tuple, xs: jax.Array, *,
                              cfg, return_all: bool = False, mask=None,
                              stacked: Optional[dict] = None):
    """Fused depth-L stack (uniform hidden sizes): ONE pallas_call.

    params: per-layer ({w,u,b}, ...), layer 0 first; h0s: per-layer (B,H).
    Returns (tuple of per-layer final h, optionally last layer's (B,T,H)).
    ``mask`` (B,T) streams through the kernel (False steps freeze every
    layer); ``stacked`` is an optional precomputed ``prepare_stacked_cells``
    output so a prepared serving path does no per-call weight restacking.
    """
    L = len(params)
    if L == 1:
        hT, hs = gru_sequence_pallas(params[0], h0s[0], xs, cfg=cfg,
                                     return_all=return_all, mask=mask)
        return (hT,), hs
    xp = xs @ params[0]["w"]                       # layer-0 decoupled GEMM
    xp_t = jnp.moveaxis(xp, -2, 0)                 # (T,B,3H)
    h0 = jnp.stack(h0s, 0)                         # (L,B,H)
    if stacked is None:
        u, w_deep, b = _stacked_weights(params)
    else:
        u, w_deep, b = stacked["u"], stacked["w_deep"], stacked["b"]
    hs, hT = gru_stack_sequence_kernel(h0, xp_t, u, w_deep, b,
                                       _time_major_mask(mask),
                                       variant=cfg.variant,
                                       interpret=on_cpu())
    finals = tuple(hT[l] for l in range(L))
    if return_all:
        return finals, jnp.moveaxis(hs, 0, -2)
    return finals, None


def gru_stack_sequence_pallas_chain(params: tuple, h0s: tuple, xs: jax.Array,
                                    *, cfg, return_all: bool = False,
                                    mask=None):
    """Per-layer Pallas chain: one sequence kernel per layer, layer ``l``
    consuming layer ``l-1``'s full hidden sequence. Serves heterogeneous
    ``layer_dims`` (each layer gets its own VMEM block shapes) at the cost
    of L kernel launches and L hidden-sequence HBM round-trips. The shared
    mask is streamed into EVERY layer's kernel (exact: frozen steps feed
    frozen layers)."""
    from repro.core.gru import layer_config
    L = len(params)
    finals, cur, hs = [], xs, None
    for l in range(L):
        last = l == L - 1
        hT, hs = gru_sequence_pallas(params[l], h0s[l], cur,
                                     cfg=layer_config(cfg, l),
                                     return_all=(not last) or return_all,
                                     mask=mask)
        finals.append(hT)
        if not last:
            cur = hs
    return tuple(finals), (hs if return_all else None)


def prepare_stacked_cells(params: tuple) -> dict:
    """Precompute the stacked-weight views the fused kernels want
    ({u (L,H,3H), w_deep, b (L,3H)}). Do this ONCE outside the per-step
    jit (``runtime.prepare`` does) so the decode trace carries no per-token
    weight restacking."""
    u, w_deep, b = _stacked_weights(tuple(params))
    return {"u": u, "w_deep": w_deep, "b": b}


def gru_stack_decode_pallas(params: tuple, hs: tuple, x: jax.Array, *, cfg,
                            stacked: dict = None) -> tuple:
    """Fused decode step: ONE pallas_call advances the whole batch through
    all L layers for one token (uniform hidden sizes required).

    params: per-layer ({w,u,b}, ...); hs: per-layer (B,H) current states;
    x: (B,X) the new token's features; stacked: optional precomputed
    ``prepare_stacked_cells`` output (skips the per-call weight stacking).
    Returns per-layer new states. The layer-0 input projection is one
    small GEMM outside the kernel; the kernel owns the entire recurrent
    critical path.
    """
    xp = x @ params[0]["w"]                        # (B,3H)
    h = jnp.stack(tuple(hs), 0)                    # (L,B,H)
    if stacked is None:
        stacked = prepare_stacked_cells(params)
    h2 = gru_stack_decode_kernel(h, xp, stacked["u"], stacked["w_deep"],
                                 stacked["b"], variant=cfg.variant,
                                 interpret=on_cpu())
    return tuple(h2[l] for l in range(len(params)))


def gru_stack_decode_pallas_chain(params: tuple, hs: tuple, x: jax.Array, *,
                                  cfg) -> tuple:
    """Per-layer Pallas decode: one single-step kernel per layer (supports
    heterogeneous ``layer_dims``, where the fused decode kernel cannot
    apply). Depth-1 is bit-identical to one step of the sequence kernel."""
    cur, out = x, []
    for l, p in enumerate(params):
        xp = cur @ p["w"]                          # (B,3H) this layer's Wx
        h2 = gru_sequence_kernel(hs[l], xp[None], p["u"], p["b"],
                                 variant=cfg.variant, interpret=on_cpu())[0]
        out.append(h2)
        cur = h2
    return tuple(out)


# ---------------------------------------------------------------------------
# q8 backends: same decoupled-GEMM split, int8 recurrent weight rows
# ---------------------------------------------------------------------------
#
# The layer-0 input projection STAYS f32 (one MXU GEMM outside the kernel,
# exactly like the f32 backends); only the latency-critical recurrent path —
# and, for the fused variants, the deep-layer input projections — runs on
# int8 weight rows. The int8 views come from ``StackParams.quant``
# (built once by ``runtime.prepare``); the fallback quantization below is
# for direct raw-param calls only and never runs on the executor path.

def _quant_views(params: tuple, quant):
    if quant is None:
        from repro.core.params import quantize_gru_cells
        quant = quantize_gru_cells(tuple(params))
    return quant


def gru_sequence_pallas_q8(params: dict, qcell: dict, h0: jax.Array,
                           xs: jax.Array, *, cfg,
                           return_all: bool = False, mask=None):
    """Single-layer q8 sequence: f32 W.x GEMM + int8-row recurrent kernel.
    ``qcell``: {"u_q" (3H,H) int8, "u_eff" (3H,)} for THIS layer."""
    xp = xs @ params["w"]                          # (B,T,3H) decoupled, f32
    xp_t = jnp.moveaxis(xp, -2, 0)                 # (T,B,3H)
    hs = gru_sequence_q8_kernel(h0, xp_t, qcell["u_q"], qcell["u_eff"],
                                params["b"], _time_major_mask(mask),
                                variant=cfg.variant, interpret=on_cpu())
    hT = hs[-1]
    if return_all:
        return hT, jnp.moveaxis(hs, 0, -2)
    return hT, None


def gru_stack_sequence_pallas_q8(params: tuple, h0s: tuple, xs: jax.Array,
                                 *, cfg, return_all: bool = False,
                                 mask=None, quant=None):
    """Fused q8 depth-L stack (uniform hidden sizes): ONE pallas_call with
    U and the deep-layer W pinned in VMEM as int8 rows. No L==1 special
    case: the stacked quant views always exist for uniform dims."""
    q = _quant_views(params, quant)
    st = q.stacked
    xp = xs @ params[0]["w"]                       # layer-0 decoupled GEMM
    xp_t = jnp.moveaxis(xp, -2, 0)                 # (T,B,3H)
    h0 = jnp.stack(tuple(h0s), 0)                  # (L,B,H)
    hs, hT = gru_stack_sequence_q8_kernel(h0, xp_t, st["u_q"], st["u_eff"],
                                          st["wd_q"], st["wd_eff"], st["b"],
                                          _time_major_mask(mask),
                                          variant=cfg.variant,
                                          interpret=on_cpu())
    finals = tuple(hT[l] for l in range(len(params)))
    if return_all:
        return finals, jnp.moveaxis(hs, 0, -2)
    return finals, None


def gru_stack_sequence_pallas_chain_q8(params: tuple, h0s: tuple,
                                       xs: jax.Array, *, cfg,
                                       return_all: bool = False, mask=None,
                                       quant=None):
    """Per-layer q8 chain (serves heterogeneous ``layer_dims``): one q8
    sequence kernel per layer, inter-layer input projections kept as f32
    GEMMs outside the kernels (so the traced call still contains no
    activation-quantize ops outside pallas_call)."""
    from repro.core.gru import layer_config
    q = _quant_views(params, quant)
    L = len(params)
    finals, cur, hs = [], xs, None
    for l in range(L):
        last = l == L - 1
        hT, hs = gru_sequence_pallas_q8(params[l], q.cells[l], h0s[l], cur,
                                        cfg=layer_config(cfg, l),
                                        return_all=(not last) or return_all,
                                        mask=mask)
        finals.append(hT)
        if not last:
            cur = hs
    return tuple(finals), (hs if return_all else None)


def gru_stack_decode_pallas_q8(params: tuple, hs: tuple, x: jax.Array, *,
                               cfg, quant=None) -> tuple:
    """Fused q8 decode step: ONE pallas_call, whole stack, one token —
    the latency shape the int8 rows were laid out for (B=1 matvecs are
    bandwidth-bound, and the int8 working set is a quarter of f32)."""
    q = _quant_views(params, quant)
    st = q.stacked
    xp = x @ params[0]["w"]                        # (B,3H), f32
    h = jnp.stack(tuple(hs), 0)                    # (L,B,H)
    h2 = gru_stack_decode_q8_kernel(h, xp, st["u_q"], st["u_eff"],
                                    st["wd_q"], st["wd_eff"], st["b"],
                                    variant=cfg.variant, interpret=on_cpu())
    return tuple(h2[l] for l in range(len(params)))


def gru_stack_decode_pallas_chain_q8(params: tuple, hs: tuple, x: jax.Array,
                                     *, cfg, quant=None) -> tuple:
    """Per-layer q8 decode (heterogeneous ``layer_dims``): one q8 step
    kernel per layer, f32 inter-layer projections."""
    from repro.kernels.gru_cell.ops import gru_step_q8_pallas
    q = _quant_views(params, quant)
    cur, out = x, []
    for l, p in enumerate(params):
        xp = cur @ p["w"]                          # (B,3H) this layer's Wx
        h2 = gru_step_q8_pallas(hs[l], xp, q.cells[l]["u_q"],
                                q.cells[l]["u_eff"], p["b"],
                                variant=cfg.variant)
        out.append(h2)
        cur = h2
    return tuple(out)


# ---------------------------------------------------------------------------
# runtime registration: the kernels package plugs its backends into the
# executor's capability registry (see repro.core.runtime's module docstring
# for the full table).
# ---------------------------------------------------------------------------

_REGISTERED = False


def register_runtime_backends() -> None:
    """Idempotently register ``pallas_fused`` / ``pallas_chain`` with the
    GRU executor. Called on ``repro.kernels.gru_sequence`` import and by
    ``runtime.compile()`` on first use (whichever happens first)."""
    global _REGISTERED
    if _REGISTERED:
        return
    from repro.core import runtime

    def fused_seq(sp, h0s, xs, *, cfg, return_all, mask, placement):
        return gru_stack_sequence_pallas(sp.cells, tuple(h0s), xs, cfg=cfg,
                                         return_all=return_all, mask=mask,
                                         stacked=sp.stacked)

    def fused_dec(sp, hs, x, *, cfg, placement):
        return gru_stack_decode_pallas(sp.cells, tuple(hs), x, cfg=cfg,
                                       stacked=sp.stacked)

    def chain_seq(sp, h0s, xs, *, cfg, return_all, mask, placement):
        return gru_stack_sequence_pallas_chain(sp.cells, tuple(h0s), xs,
                                               cfg=cfg,
                                               return_all=return_all,
                                               mask=mask)

    def chain_dec(sp, hs, x, *, cfg, placement):
        return gru_stack_decode_pallas_chain(sp.cells, tuple(hs), x, cfg=cfg)

    runtime.register_backend(runtime.BackendSpec(
        name="pallas_fused",
        caps=runtime.Capabilities(supports_mask=True,
                                  supports_hetero_dims=False,
                                  supports_mesh=False, return_all=True,
                                  decode=True, sequence=True),
        cost=10,
        sequence_fn=fused_seq, decode_fn=fused_dec))
    runtime.register_backend(runtime.BackendSpec(
        name="pallas_chain",
        caps=runtime.Capabilities(supports_mask=True,
                                  supports_hetero_dims=True,
                                  supports_mesh=False, return_all=True,
                                  decode=True, sequence=True),
        cost=20,
        sequence_fn=chain_seq, decode_fn=chain_dec))

    def fused_seq_q8(sp, h0s, xs, *, cfg, return_all, mask, placement):
        return gru_stack_sequence_pallas_q8(sp.cells, tuple(h0s), xs,
                                            cfg=cfg, return_all=return_all,
                                            mask=mask, quant=sp.quant)

    def fused_dec_q8(sp, hs, x, *, cfg, placement):
        return gru_stack_decode_pallas_q8(sp.cells, tuple(hs), x, cfg=cfg,
                                          quant=sp.quant)

    def chain_seq_q8(sp, h0s, xs, *, cfg, return_all, mask, placement):
        return gru_stack_sequence_pallas_chain_q8(sp.cells, tuple(h0s), xs,
                                                  cfg=cfg,
                                                  return_all=return_all,
                                                  mask=mask, quant=sp.quant)

    def chain_dec_q8(sp, hs, x, *, cfg, placement):
        return gru_stack_decode_pallas_chain_q8(sp.cells, tuple(hs), x,
                                                cfg=cfg, quant=sp.quant)

    # the q8 twins are MEASURED-ONLY (static cost above the runtime's
    # UNCALIBRATED_GATE_COST line): legality already requires the accuracy
    # gate (or an exact pin), and even then `auto` only picks them where a
    # calibration shows the int8 rows actually win at that shape.
    runtime.register_backend(runtime.BackendSpec(
        name="pallas_fused_q8",
        caps=runtime.Capabilities(supports_mask=True,
                                  supports_hetero_dims=False,
                                  supports_mesh=False, return_all=True,
                                  decode=True, sequence=True),
        cost=150,
        sequence_fn=fused_seq_q8, decode_fn=fused_dec_q8))
    runtime.register_backend(runtime.BackendSpec(
        name="pallas_chain_q8",
        caps=runtime.Capabilities(supports_mask=True,
                                  supports_hetero_dims=True,
                                  supports_mesh=False, return_all=True,
                                  decode=True, sequence=True),
        cost=160,
        sequence_fn=chain_seq_q8, decode_fn=chain_dec_q8))
    _REGISTERED = True
