"""Public wrappers: run a GRU (or a whole GRU stack) with the Pallas backend.

Interfaces match ``repro.core.gru.gru_sequence`` / ``gru_stack_sequence``
(called from there when ``cfg.backend == "pallas"``). The layer-0 input
projection (decoupled W.x) is one MXU GEMM outside the kernel; the kernel
owns the recurrent path — for the stack variant, ALL layers of it in one
``pallas_call``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.gru_sequence.kernel import (gru_sequence_kernel,
                                               gru_stack_decode_kernel,
                                               gru_stack_sequence_kernel)


def _stacked_weights(params: tuple):
    """(u (L,H,3H), w_deep (max(L-1,1),·,3H), b (L,3H)) device-side stacks."""
    L = len(params)
    H = params[0]["u"].shape[0]
    u = jnp.stack([p["u"] for p in params], 0)
    if L > 1:
        w_deep = jnp.stack([p["w"] for p in params[1:]], 0)
    else:
        w_deep = jnp.zeros((1, 1, 3 * H), params[0]["w"].dtype)
    b = jnp.stack([p["b"] for p in params], 0)
    return u, w_deep, b


def gru_sequence_pallas(params: dict, h0: jax.Array, xs: jax.Array, *, cfg,
                        return_all: bool = False):
    """params: {w,u,b}; xs: (B,T,X) -> (h_T, optionally (B,T,H))."""
    w, u, b = params["w"], params["u"], params["b"]
    xp = xs @ w                                    # (B,T,3H): the decoupled GEMM
    xp_t = jnp.moveaxis(xp, -2, 0)                 # time-major (T,B,3H)
    hs = gru_sequence_kernel(h0, xp_t, u, b, variant=cfg.variant,
                             interpret=on_cpu())
    hT = hs[-1]
    if return_all:
        return hT, jnp.moveaxis(hs, 0, -2)
    return hT, None


def gru_stack_sequence_pallas(params: tuple, h0s: tuple, xs: jax.Array, *,
                              cfg, return_all: bool = False):
    """Fused depth-L stack (uniform hidden sizes): ONE pallas_call.

    params: per-layer ({w,u,b}, ...), layer 0 first; h0s: per-layer (B,H).
    Returns (tuple of per-layer final h, optionally last layer's (B,T,H)).
    """
    L = len(params)
    if L == 1:
        hT, hs = gru_sequence_pallas(params[0], h0s[0], xs, cfg=cfg,
                                     return_all=return_all)
        return (hT,), hs
    xp = xs @ params[0]["w"]                       # layer-0 decoupled GEMM
    xp_t = jnp.moveaxis(xp, -2, 0)                 # (T,B,3H)
    h0 = jnp.stack(h0s, 0)                         # (L,B,H)
    u, w_deep, b = _stacked_weights(params)
    hs, hT = gru_stack_sequence_kernel(h0, xp_t, u, w_deep, b,
                                       variant=cfg.variant,
                                       interpret=on_cpu())
    finals = tuple(hT[l] for l in range(L))
    if return_all:
        return finals, jnp.moveaxis(hs, 0, -2)
    return finals, None


def prepare_stacked_cells(params: tuple) -> dict:
    """Precompute the stacked-weight views the fused decode kernel wants
    ({u (L,H,3H), w_deep, b (L,3H)}). Do this ONCE outside the per-step
    jit (ServeEngine does, via the model API's ``prepare_params``) so the
    decode trace carries no per-token weight restacking."""
    u, w_deep, b = _stacked_weights(tuple(params))
    return {"u": u, "w_deep": w_deep, "b": b}


def gru_stack_decode_pallas(params: tuple, hs: tuple, x: jax.Array, *, cfg,
                            stacked: dict = None) -> tuple:
    """Fused decode step: ONE pallas_call advances the whole batch through
    all L layers for one token (uniform hidden sizes required).

    params: per-layer ({w,u,b}, ...); hs: per-layer (B,H) current states;
    x: (B,X) the new token's features; stacked: optional precomputed
    ``prepare_stacked_cells`` output (skips the per-call weight stacking).
    Returns per-layer new states. The layer-0 input projection is one
    small GEMM outside the kernel; the kernel owns the entire recurrent
    critical path.
    """
    xp = x @ params[0]["w"]                        # (B,3H)
    h = jnp.stack(tuple(hs), 0)                    # (L,B,H)
    if stacked is None:
        stacked = prepare_stacked_cells(params)
    h2 = gru_stack_decode_kernel(h, xp, stacked["u"], stacked["w_deep"],
                                 stacked["b"], variant=cfg.variant,
                                 interpret=on_cpu())
    return tuple(h2[l] for l in range(len(params)))
