"""Oracle for the whole-sequence kernel: step-by-step fp32 recurrence."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gru_cell.ref import gru_step_ref


def gru_sequence_ref(h0, x_proj, u, b, variant: str = "v1"):
    """h0: (B,H), x_proj: (T,B,3H) -> (T,B,H)."""
    h = jnp.asarray(h0, jnp.float32)
    out = []
    for t in range(x_proj.shape[0]):
        h = gru_step_ref(h, x_proj[t], u, b, variant=variant)
        out.append(h)
    return jnp.stack(out, axis=0)
