"""Oracle for the whole-sequence kernel: step-by-step fp32 recurrence.

The ``*_q8_ref`` twins are the quantize-dequantize oracles for the q8
kernels: same transposed int8 weight rows, same fixed-scale activation
rounding, same dequant-at-the-bias-add — expressed step by step in plain
jnp (see :func:`repro.kernels.gru_cell.ref.gru_step_q8_ref`)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.gru_cell.ref import (_q8_act_ref, gru_step_q8_ref,
                                        gru_step_ref)


def gru_sequence_ref(h0, x_proj, u, b, variant: str = "v1"):
    """h0: (B,H), x_proj: (T,B,3H) -> (T,B,H)."""
    h = jnp.asarray(h0, jnp.float32)
    out = []
    for t in range(x_proj.shape[0]):
        h = gru_step_ref(h, x_proj[t], u, b, variant=variant)
        out.append(h)
    return jnp.stack(out, axis=0)


def gru_stack_sequence_ref(h0, x_proj, u, w_deep, b, variant: str = "v1"):
    """Oracle for the fused stack kernel, same raw-array interface.

    h0: (L,B,H), x_proj: (T,B,3H) layer-0 Wx, u: (L,H,3H),
    w_deep: (L-1,H,3H), b: (L,3H) -> ((T,B,H) last-layer states,
    (L,B,H) per-layer finals)."""
    L = h0.shape[0]
    hs = [jnp.asarray(h0[l], jnp.float32) for l in range(L)]
    out = []
    for t in range(x_proj.shape[0]):
        xp = jnp.asarray(x_proj[t], jnp.float32)
        for l in range(L):
            hs[l] = gru_step_ref(hs[l], xp, u[l], b[l], variant=variant)
            if l + 1 < L:
                xp = hs[l] @ jnp.asarray(w_deep[l], jnp.float32)
        out.append(hs[-1])
    return jnp.stack(out, axis=0), jnp.stack(hs, axis=0)


def gru_stack_decode_ref(h, x_proj, u, w_deep, b, variant: str = "v1"):
    """Oracle for the fused decode-step kernel, same raw-array interface.

    h: (L,B,H) per-layer states, x_proj: (B,3H) layer-0 Wx of ONE token,
    u: (L,H,3H), w_deep: (L-1,H,3H), b: (L,3H) -> new states (L,B,H)."""
    L = h.shape[0]
    xp = jnp.asarray(x_proj, jnp.float32)
    out = []
    for l in range(L):
        h_new = gru_step_ref(h[l], xp, u[l], b[l], variant=variant)
        out.append(h_new)
        if l + 1 < L:
            xp = h_new @ jnp.asarray(w_deep[l], jnp.float32)
    return jnp.stack(out, axis=0)


# ---------------------------------------------------------------------------
# q8 quantize-dequantize oracles
# ---------------------------------------------------------------------------

def gru_sequence_q8_ref(h0, x_proj, u_q, u_eff, b, variant: str = "v1"):
    """h0: (B,H), x_proj: (T,B,3H) f32, u_q: (3H,H) int8 rows, u_eff:
    (3H,) -> (T,B,H)."""
    h = jnp.asarray(h0, jnp.float32)
    out = []
    for t in range(x_proj.shape[0]):
        h = gru_step_q8_ref(h, x_proj[t], u_q, u_eff, b, variant=variant)
        out.append(h)
    return jnp.stack(out, axis=0)


def _deep_xp_q8(h, wd_q, wd_eff):
    """Deep-layer q8 input projection: quantized h against int8 W rows."""
    return (_q8_act_ref(h) @ jnp.asarray(wd_q, jnp.float32).T
            * jnp.asarray(wd_eff, jnp.float32))


def gru_stack_sequence_q8_ref(h0, x_proj, u_q, u_eff, wd_q, wd_eff, b,
                              variant: str = "v1"):
    """Oracle for the fused q8 stack kernel, same raw-array interface.

    h0: (L,B,H), x_proj: (T,B,3H) f32 layer-0 Wx, u_q: (L,3H,H) int8 with
    u_eff (L,3H), wd_q: (L-1,3H,H) int8 with wd_eff (L-1,3H), b: (L,3H)
    -> ((T,B,H) last-layer states, (L,B,H) per-layer finals)."""
    L = h0.shape[0]
    hs = [jnp.asarray(h0[l], jnp.float32) for l in range(L)]
    out = []
    for t in range(x_proj.shape[0]):
        xp = jnp.asarray(x_proj[t], jnp.float32)
        for l in range(L):
            hs[l] = gru_step_q8_ref(hs[l], xp, u_q[l], u_eff[l], b[l],
                                    variant=variant)
            if l + 1 < L:
                xp = _deep_xp_q8(hs[l], wd_q[l], wd_eff[l])
        out.append(hs[-1])
    return jnp.stack(out, axis=0), jnp.stack(hs, axis=0)


def gru_stack_decode_q8_ref(h, x_proj, u_q, u_eff, wd_q, wd_eff, b,
                            variant: str = "v1"):
    """Oracle for the fused q8 decode-step kernel: h (L,B,H), x_proj
    (B,3H) f32 layer-0 Wx of ONE token -> new states (L,B,H)."""
    L = h.shape[0]
    xp = jnp.asarray(x_proj, jnp.float32)
    out = []
    for l in range(L):
        h_new = gru_step_q8_ref(h[l], xp, u_q[l], u_eff[l], b[l],
                                variant=variant)
        out.append(h_new)
        if l + 1 < L:
            xp = _deep_xp_q8(h_new, wd_q[l], wd_eff[l])
    return jnp.stack(out, axis=0)
