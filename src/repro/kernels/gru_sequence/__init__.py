from repro.kernels.gru_sequence import ops, ref
from repro.kernels.gru_sequence.kernel import (gru_sequence_kernel,
                                               gru_stack_decode_kernel,
                                               gru_stack_sequence_kernel)

# Plug the Pallas backends into the GRU executor's capability registry
# (repro.core.runtime); runtime.compile() also triggers this lazily.
ops.register_runtime_backends()

__all__ = ["ops", "ref", "gru_sequence_kernel", "gru_stack_sequence_kernel",
           "gru_stack_decode_kernel"]
