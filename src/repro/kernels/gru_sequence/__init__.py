from repro.kernels.gru_sequence import ops, ref
from repro.kernels.gru_sequence.kernel import (gru_sequence_kernel,
                                               gru_stack_sequence_kernel)

__all__ = ["ops", "ref", "gru_sequence_kernel", "gru_stack_sequence_kernel"]
