"""Whole-sequence GRU Pallas kernel: grid = time, U pinned in VMEM.

The paper's "row reuse": after the first pass, the vector (and here the
recurrent matrix U) lives in tile-local memory, so subsequent steps are
bounded by local-memory bandwidth, not streaming. TPU translation: the
sequence runs as ONE ``pallas_call`` whose grid axis is time. U's
``index_map`` is constant, so the Pallas pipeline fetches it from HBM
exactly once; the hidden state is carried in a VMEM scratch buffer across
grid steps (TPU grids iterate sequentially). Per step, only the
(1, B, 3H) slice of the precomputed input projection streams in — the
decoupled ``W.x`` path feeding the free-running recurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _seq_kernel(h0_ref, xp_ref, u_ref, b_ref, o_ref, h_s, *, variant: str):
    t = pl.program_id(0)
    H = h0_ref.shape[-1]

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    h = h_s[...]
    u = u_ref[...]
    b = b_ref[...].astype(jnp.float32)                    # (1, 3H)
    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H) this step
    xz, xr, xh = xp[:, :H], xp[:, H:2 * H], xp[:, 2 * H:]
    if variant == "v3":
        ua = _dot(h.astype(u.dtype), u) + b
        z = jax.nn.sigmoid(xz + ua[:, :H])
        r = jax.nn.sigmoid(xr + ua[:, H:2 * H])
        ht = jnp.tanh(xh + r * ua[:, 2 * H:])
    else:
        zr = _dot(h.astype(u.dtype), u[:, :2 * H]) + b[:, :2 * H]
        z = jax.nn.sigmoid(xz + zr[:, :H])
        r = jax.nn.sigmoid(xr + zr[:, H:])
        ht = jnp.tanh(xh + _dot((r * h).astype(u.dtype), u[:, 2 * H:]) + b[:, 2 * H:])
    h_new = (1.0 - z) * h + z * ht
    h_s[...] = h_new
    o_ref[...] = h_new[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "interpret"))
def gru_sequence_kernel(h0: jax.Array, x_proj: jax.Array, u: jax.Array,
                        b: jax.Array, *, variant: str = "v1",
                        interpret: bool = False) -> jax.Array:
    """h0: (B,H), x_proj: (T,B,3H) time-major precomputed Wx, u: (H,3H),
    b: (3H,) -> all hidden states (T,B,H)."""
    T, B, H3 = x_proj.shape
    H = H3 // 3
    return pl.pallas_call(
        functools.partial(_seq_kernel, variant=variant),
        grid=(T,),
        in_specs=[
            pl.BlockSpec((B, H), lambda t: (0, 0)),        # h0: resident
            pl.BlockSpec((1, B, 3 * H), lambda t: (t, 0, 0)),  # stream step t
            pl.BlockSpec((H, 3 * H), lambda t: (0, 0)),    # U: fetched ONCE
            pl.BlockSpec((1, 3 * H), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, B, H), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, B, H), h0.dtype),
        scratch_shapes=[pltpu.VMEM((B, H), jnp.float32)],  # carried hidden state
        interpret=interpret,
    )(h0, x_proj, u, b[None, :])
