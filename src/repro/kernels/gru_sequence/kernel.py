"""Whole-sequence GRU Pallas kernels: grid = time, U pinned in VMEM.

The paper's "row reuse": after the first pass, the vector (and here the
recurrent matrix U) lives in tile-local memory, so subsequent steps are
bounded by local-memory bandwidth, not streaming. TPU translation: the
sequence runs as ONE ``pallas_call`` whose grid axis is time. U's
``index_map`` is constant, so the Pallas pipeline fetches it from HBM
exactly once; the hidden state is carried in a VMEM scratch buffer across
grid steps (TPU grids iterate sequentially). Per step, only the
(1, B, 3H) slice of the precomputed input projection streams in — the
decoupled ``W.x`` path feeding the free-running recurrence.

``gru_stack_sequence_kernel`` extends this to a depth-L stack in ONE
``pallas_call``: ALL layers' U matrices (and the deep layers' input
projections W) are pinned in VMEM via constant index_maps, and the L
per-layer hidden states live in one (L, B, H) scratch buffer. Each grid
step runs the whole depth — layer l consumes layer l-1's same-timestep
output directly from registers/VMEM, so an L-layer stack costs one kernel
launch and one weight fetch total, instead of L sequential pallas_calls
with L hidden-state round-trips through HBM.

``gru_stack_decode_kernel`` is the latency-constrained serve path: ONE
grid step of the same fused-stack structure, advancing a whole batch of
per-layer hidden states through all L layers for ONE token. The grid axis
is the BATCH (tiled), not time — weights stay pinned via constant
index_maps while successive batch tiles stream through, so wave size
scales past a single VMEM block without re-fetching a byte of U/W. The
batch tiles are mutually independent, so the grid axis is declared
``dimension_semantics=("parallel",)``: on a megacore TPU the Mosaic
compiler may split the tiles across both cores instead of iterating them
sequentially (time grids, by contrast, are ``"arbitrary"`` — the hidden
state carried in scratch makes them order-dependent). This is the paper's
figure of merit (single-step latency) with the AIE weight-residency story
intact on TPU.

Both sequence kernels take an optional (T, B) length MASK, streamed
through the grid one (1, B) slice per step next to the input projection:
False steps freeze the hidden state (every layer's, for the stack) with an
in-kernel select, so bucketed left-padded prefill runs the fused kernels
— unmasked rows execute bit-identical arithmetic to unpadded prompts.

SHARD-SHAPED entry points (``gru_rowwise_shard_*`` / ``gru_cascade_shard_*``
/ ``gru_shard_matvec``) are the ``pallas_sharded`` backend's kernels: each
one computes exactly the per-shard segment of a GRU step that fits BETWEEN
two collectives of the row-parallel / cascade shard_map programs in
``repro.core.rowparallel`` — the AIE4ML pattern of a per-tile kernel nested
under a global dataflow partition. A rowwise v3 step is ONE kernel per
layer (trailing all-gather outside); paper-math v1 splits at the mid-step
``r*h`` aggregation into a z/r kernel and a candidate kernel; cascade
steps split at their psum(s). The kernel bodies mirror the XLA shard-step
expressions op for op (and elementwise phases commute with the local gate
slicing), so on the same shard shapes the ``pallas_sharded`` backend is
bitwise-equal to the XLA ``sharded`` shard bodies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _gate_math(h, xp, u, b, variant: str):
    """One cell update in fp32. h/xp: (B,H)/(B,3H), u: (H,3H), b: (1,3H)."""
    H = h.shape[-1]
    xz, xr, xh = xp[:, :H], xp[:, H:2 * H], xp[:, 2 * H:]
    if variant == "v3":
        ua = _dot(h.astype(u.dtype), u) + b
        z = jax.nn.sigmoid(xz + ua[:, :H])
        r = jax.nn.sigmoid(xr + ua[:, H:2 * H])
        ht = jnp.tanh(xh + r * ua[:, 2 * H:])
    else:
        zr = _dot(h.astype(u.dtype), u[:, :2 * H]) + b[:, :2 * H]
        z = jax.nn.sigmoid(xz + zr[:, :H])
        r = jax.nn.sigmoid(xr + zr[:, H:])
        ht = jnp.tanh(xh + _dot((r * h).astype(u.dtype), u[:, 2 * H:])
                      + b[:, 2 * H:])
    return (1.0 - z) * h + z * ht


def _seq_kernel(h0_ref, xp_ref, u_ref, b_ref, o_ref, h_s, *, variant: str):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H) this step
    h_new = _gate_math(h_s[...], xp, u_ref[...],
                       b_ref[...].astype(jnp.float32), variant)
    h_s[...] = h_new
    o_ref[...] = h_new[None].astype(o_ref.dtype)


def _seq_kernel_masked(h0_ref, xp_ref, u_ref, b_ref, m_ref, o_ref, h_s, *,
                       variant: str):
    """Masked variant: the (1, B) mask slice streams in next to the step's
    input projection; False rows keep their previous hidden state. Unmasked
    rows run EXACTLY the unmasked arithmetic (``where`` selects, it does not
    perturb), so left-padded bucketed prompts stay bitwise-identical to
    their unpadded originals."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H) this step
    keep = m_ref[...][0] != 0.0                           # (B,) this step
    h_new = _gate_math(h_s[...], xp, u_ref[...],
                       b_ref[...].astype(jnp.float32), variant)
    h_new = jnp.where(keep[:, None], h_new, h_s[...])     # freeze masked rows
    h_s[...] = h_new
    o_ref[...] = h_new[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "interpret"))
def gru_sequence_kernel(h0: jax.Array, x_proj: jax.Array, u: jax.Array,
                        b: jax.Array, mask=None, *, variant: str = "v1",
                        interpret: bool = False) -> jax.Array:
    """h0: (B,H), x_proj: (T,B,3H) time-major precomputed Wx, u: (H,3H),
    b: (3H,) -> all hidden states (T,B,H).

    ``mask`` (T,B) float (nonzero = live step), optional: streamed through
    the grid one (1,B) slice per step; False steps freeze the hidden state
    in-kernel, so bucketed (left-padded) prefill runs the SAME fused kernel
    as unpadded prompts instead of falling back to the XLA scan."""
    T, B, H3 = x_proj.shape
    H = H3 // 3
    in_specs = [
        pl.BlockSpec((B, H), lambda t: (0, 0)),        # h0: resident
        pl.BlockSpec((1, B, 3 * H), lambda t: (t, 0, 0)),  # stream step t
        pl.BlockSpec((H, 3 * H), lambda t: (0, 0)),    # U: fetched ONCE
        pl.BlockSpec((1, 3 * H), lambda t: (0, 0)),
    ]
    args = [h0, x_proj, u, b[None, :]]
    if mask is None:
        kern = functools.partial(_seq_kernel, variant=variant)
    else:
        kern = functools.partial(_seq_kernel_masked, variant=variant)
        in_specs.append(pl.BlockSpec((1, B), lambda t: (t, 0)))  # step's mask
        args.append(mask.astype(jnp.float32))
    return pl.pallas_call(
        kern,
        grid=(T,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, B, H), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, B, H), h0.dtype),
        scratch_shapes=[pltpu.VMEM((B, H), jnp.float32)],  # carried hidden state
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# fused multi-layer stack
# ---------------------------------------------------------------------------

def _stack_kernel(h0_ref, xp_ref, u_ref, wd_ref, b_ref, o_ref, hT_ref, h_s, *,
                  variant: str, num_layers: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    b = b_ref[...].astype(jnp.float32)                    # (L, 3H)
    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H): layer 0 Wx
    for l in range(num_layers):                           # static unroll
        h_new = _gate_math(h_s[l], xp, u_ref[l], b[l:l + 1], variant)
        h_s[l] = h_new
        if l + 1 < num_layers:
            # next layer's input projection, same timestep, never leaves VMEM
            xp = _dot(h_new.astype(wd_ref.dtype), wd_ref[l]).astype(jnp.float32)
    o_ref[...] = h_new[None].astype(o_ref.dtype)
    hT_ref[...] = h_s[...].astype(hT_ref.dtype)


def _stack_kernel_masked(h0_ref, xp_ref, u_ref, wd_ref, b_ref, m_ref, o_ref,
                         hT_ref, h_s, *, variant: str, num_layers: int):
    """Masked fused stack: ONE shared (1, B) mask slice per step freezes
    EVERY layer's state on False rows (exact — during frozen steps upper
    layers ignore their input). The next layer consumes the GATED output,
    matching the layer-by-layer masked semantics of the XLA path."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    b = b_ref[...].astype(jnp.float32)                    # (L, 3H)
    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H): layer 0 Wx
    keep = m_ref[...][0] != 0.0                           # (B,) this step
    for l in range(num_layers):                           # static unroll
        h_new = _gate_math(h_s[l], xp, u_ref[l], b[l:l + 1], variant)
        h_new = jnp.where(keep[:, None], h_new, h_s[l])   # freeze masked rows
        h_s[l] = h_new
        if l + 1 < num_layers:
            xp = _dot(h_new.astype(wd_ref.dtype), wd_ref[l]).astype(jnp.float32)
    o_ref[...] = h_new[None].astype(o_ref.dtype)
    hT_ref[...] = h_s[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "interpret"))
def gru_stack_sequence_kernel(h0: jax.Array, x_proj: jax.Array, u: jax.Array,
                              w_deep: jax.Array, b: jax.Array, mask=None, *,
                              variant: str = "v1", interpret: bool = False):
    """Depth-L fused stack (uniform hidden size H across layers).

    h0: (L,B,H) per-layer initial states; x_proj: (T,B,3H) time-major
    precomputed layer-0 Wx; u: (L,H,3H) recurrent matrices; w_deep:
    (L-1,H,3H) input projections of layers 1..L-1 (pass (1,1,3H) zeros for
    L=1, unused); b: (L,3H). Returns (last-layer states (T,B,H),
    per-layer final states (L,B,H)).

    ``mask`` (T,B) float (nonzero = live step), optional: streamed one
    (1,B) slice per grid step; False steps freeze every layer's hidden
    state in-kernel (bucketed prefill runs the fused kernel, no XLA
    fallback).
    """
    T, B, H3 = x_proj.shape
    H = H3 // 3
    L = h0.shape[0]
    Ld = max(L - 1, 1)
    in_specs = [
        pl.BlockSpec((L, B, H), lambda t: (0, 0, 0)),      # h0: resident
        pl.BlockSpec((1, B, 3 * H), lambda t: (t, 0, 0)),  # stream step t
        pl.BlockSpec((L, H, 3 * H), lambda t: (0, 0, 0)),  # all U: ONCE
        pl.BlockSpec((Ld,) + w_deep.shape[1:], lambda t: (0, 0, 0)),
        pl.BlockSpec((L, 3 * H), lambda t: (0, 0)),
    ]
    args = [h0, x_proj, u, w_deep, b]
    if mask is None:
        kern = functools.partial(_stack_kernel, variant=variant, num_layers=L)
    else:
        kern = functools.partial(_stack_kernel_masked, variant=variant,
                                 num_layers=L)
        in_specs.append(pl.BlockSpec((1, B), lambda t: (t, 0)))  # step's mask
        args.append(mask.astype(jnp.float32))
    hs, hT = pl.pallas_call(
        kern,
        grid=(T,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, B, H), lambda t: (t, 0, 0)),
            pl.BlockSpec((L, B, H), lambda t: (0, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((T, B, H), h0.dtype),
                   jax.ShapeDtypeStruct((L, B, H), h0.dtype)],
        scratch_shapes=[pltpu.VMEM((L, B, H), jnp.float32)],  # per-layer h
        interpret=interpret,
    )(*args)
    return hs, hT


# ---------------------------------------------------------------------------
# fused multi-layer decode step (the latency path)
# ---------------------------------------------------------------------------

def _decode_kernel(h_ref, xp_ref, u_ref, wd_ref, b_ref, o_ref, *,
                   variant: str, num_layers: int):
    """One token through all L layers for one batch tile. Weights resident;
    layer l+1 consumes layer l's same-token output straight from registers
    (nothing round-trips through HBM)."""
    b = b_ref[...].astype(jnp.float32)                    # (L, 3H)
    xp = xp_ref[...].astype(jnp.float32)                  # (Bt, 3H): layer-0 Wx
    for l in range(num_layers):                           # static unroll
        h_new = _gate_math(h_ref[l].astype(jnp.float32), xp, u_ref[l],
                           b[l:l + 1], variant)
        o_ref[l] = h_new.astype(o_ref.dtype)
        if l + 1 < num_layers:
            xp = _dot(h_new.astype(wd_ref.dtype), wd_ref[l]).astype(jnp.float32)


def _pick_batch_block(B: int, limit: int = 256) -> int:
    """Largest divisor of B that fits the VMEM budget heuristic."""
    blk = min(B, limit)
    while B % blk:
        blk -= 1
    return blk


@functools.partial(jax.jit, static_argnames=("variant", "batch_block",
                                             "interpret"))
def gru_stack_decode_kernel(h: jax.Array, x_proj: jax.Array, u: jax.Array,
                            w_deep: jax.Array, b: jax.Array, *,
                            variant: str = "v1", batch_block: int = 0,
                            interpret: bool = False) -> jax.Array:
    """Fused decode step for a depth-L stack (uniform hidden size H).

    h: (L,B,H) per-layer hidden states; x_proj: (B,3H) precomputed layer-0
    Wx for the ONE new token; u: (L,H,3H); w_deep: (L-1,H,3H) deep-layer
    input projections ((1,1,3H) zeros for L=1, unused); b: (L,3H).
    Returns the new per-layer states (L,B,H).

    Grid = batch tiles (``batch_block`` rows each, 0 = auto): all weights
    use constant index_maps so the Pallas pipeline fetches them from HBM
    once regardless of how many tiles stream through. The tiles carry no
    cross-tile state, so the axis is marked ``parallel`` (megacore: big
    waves may run tiles on both TPU cores per chip).
    """
    L, B, H = h.shape
    Bt = batch_block or _pick_batch_block(B)
    assert B % Bt == 0, (B, Bt)
    Ld = max(L - 1, 1)
    return pl.pallas_call(
        functools.partial(_decode_kernel, variant=variant, num_layers=L),
        grid=(B // Bt,),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        in_specs=[
            pl.BlockSpec((L, Bt, H), lambda i: (0, i, 0)),     # this batch tile
            pl.BlockSpec((Bt, 3 * H), lambda i: (i, 0)),       # its Wx slab
            pl.BlockSpec((L, H, 3 * H), lambda i: (0, 0, 0)),  # all U: ONCE
            pl.BlockSpec((Ld,) + w_deep.shape[1:], lambda i: (0, 0, 0)),
            pl.BlockSpec((L, 3 * H), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((L, Bt, H), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, B, H), h.dtype),
        interpret=interpret,
    )(h, x_proj, u, w_deep, b)


# ---------------------------------------------------------------------------
# q8 datapath: int8 weight rows, int32 accumulation, dequant at the bias add
# ---------------------------------------------------------------------------
#
# The paper's AIE lanes MAC int8 weight ROWS against the activation vector;
# these kernels keep that layout literally. U (and the deep layers' W) are
# stored TRANSPOSED, (3H, H) int8 — one contiguous row per output element,
# quantized per row (``repro.core.params.quantize_rows_int8``), so the int8
# reduction runs over contiguous memory and the VMEM-resident weight
# footprint is a quarter of f32 (the depth x H range that stays resident
# roughly quadruples — the AIE local-memory story). Activations use the
# FIXED scale 127 (h and r*h live in (-1,1) — see params.py): quantization
# inside the kernel is one round+clip, no dynamic range scan, and the
# per-row dequant is one multiply folded into the bias add
# (``acc * eff + b`` with ``eff = scale_row / 127`` precomputed at
# prepare() time).


def _doti(a, b):
    """int8 x int8 -> int32, contracting the CONTIGUOUS last axes:
    a (B, K) against row-major weights (N, K)."""
    return jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _q8_act(a):
    """Fixed-scale activation quantization: f32 in [-1, 1] -> int8."""
    return jnp.clip(jnp.round(a * 127.0), -127.0, 127.0).astype(jnp.int8)


def _gate_math_q8(h, xp, uq, eff, b, variant: str):
    """One q8 cell update. h: (B,H) f32 state, xp: (B,3H) f32 input
    projection, uq: (3H,H) int8 weight rows, eff/b: (1,3H) f32 per-row
    dequant scales (activation scale folded) and bias."""
    H = h.shape[-1]
    xz, xr, xh = xp[:, :H], xp[:, H:2 * H], xp[:, 2 * H:]
    hq = _q8_act(h)
    if variant == "v3":
        ua = _doti(hq, uq).astype(jnp.float32) * eff + b
        z = jax.nn.sigmoid(xz + ua[:, :H])
        r = jax.nn.sigmoid(xr + ua[:, H:2 * H])
        ht = jnp.tanh(xh + r * ua[:, 2 * H:])
    else:
        zr = (_doti(hq, uq[:2 * H]).astype(jnp.float32) * eff[:, :2 * H]
              + b[:, :2 * H])
        z = jax.nn.sigmoid(xz + zr[:, :H])
        r = jax.nn.sigmoid(xr + zr[:, H:])
        cand = (_doti(_q8_act(r * h), uq[2 * H:]).astype(jnp.float32)
                * eff[:, 2 * H:] + b[:, 2 * H:])
        ht = jnp.tanh(xh + cand)
    return (1.0 - z) * h + z * ht


def _seq_kernel_q8(h0_ref, xp_ref, uq_ref, eff_ref, b_ref, o_ref, h_s, *,
                   variant: str):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H) this step
    h_new = _gate_math_q8(h_s[...], xp, uq_ref[...], eff_ref[...],
                          b_ref[...].astype(jnp.float32), variant)
    h_s[...] = h_new
    o_ref[...] = h_new[None].astype(o_ref.dtype)


def _seq_kernel_q8_masked(h0_ref, xp_ref, uq_ref, eff_ref, b_ref, m_ref,
                          o_ref, h_s, *, variant: str):
    """Masked q8 sequence: identical freeze semantics to the f32 kernel
    (``where`` selects, it does not perturb — and the quantized arithmetic
    of live rows is independent of dead rows), so bucketed left-padded
    prompts stay bitwise-identical to their unpadded q8 originals."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H) this step
    keep = m_ref[...][0] != 0.0                           # (B,) this step
    h_new = _gate_math_q8(h_s[...], xp, uq_ref[...], eff_ref[...],
                          b_ref[...].astype(jnp.float32), variant)
    h_new = jnp.where(keep[:, None], h_new, h_s[...])     # freeze masked rows
    h_s[...] = h_new
    o_ref[...] = h_new[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "interpret"))
def gru_sequence_q8_kernel(h0: jax.Array, x_proj: jax.Array, u_q: jax.Array,
                           u_eff: jax.Array, b: jax.Array, mask=None, *,
                           variant: str = "v1",
                           interpret: bool = False) -> jax.Array:
    """q8 twin of :func:`gru_sequence_kernel`. h0: (B,H), x_proj: (T,B,3H)
    f32 time-major Wx, u_q: (3H,H) int8 weight rows (pinned in VMEM at a
    quarter of the f32 footprint), u_eff: (3H,) f32 per-row dequant
    scales, b: (3H,) -> all hidden states (T,B,H) f32."""
    T, B, H3 = x_proj.shape
    H = H3 // 3
    in_specs = [
        pl.BlockSpec((B, H), lambda t: (0, 0)),            # h0: resident
        pl.BlockSpec((1, B, 3 * H), lambda t: (t, 0, 0)),  # stream step t
        pl.BlockSpec((3 * H, H), lambda t: (0, 0)),        # int8 U: ONCE
        pl.BlockSpec((1, 3 * H), lambda t: (0, 0)),
        pl.BlockSpec((1, 3 * H), lambda t: (0, 0)),
    ]
    args = [h0, x_proj, u_q, u_eff[None, :], b[None, :]]
    if mask is None:
        kern = functools.partial(_seq_kernel_q8, variant=variant)
    else:
        kern = functools.partial(_seq_kernel_q8_masked, variant=variant)
        in_specs.append(pl.BlockSpec((1, B), lambda t: (t, 0)))  # step's mask
        args.append(mask.astype(jnp.float32))
    return pl.pallas_call(
        kern,
        grid=(T,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, B, H), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, B, H), h0.dtype),
        scratch_shapes=[pltpu.VMEM((B, H), jnp.float32)],
        interpret=interpret,
    )(*args)


def _stack_kernel_q8(h0_ref, xp_ref, uq_ref, eff_ref, wdq_ref, wde_ref,
                     b_ref, o_ref, hT_ref, h_s, *, variant: str,
                     num_layers: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    b = b_ref[...].astype(jnp.float32)                    # (L, 3H)
    eff = eff_ref[...]                                    # (L, 3H)
    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H): layer 0 Wx
    for l in range(num_layers):                           # static unroll
        h_new = _gate_math_q8(h_s[l], xp, uq_ref[l], eff[l:l + 1],
                              b[l:l + 1], variant)
        h_s[l] = h_new
        if l + 1 < num_layers:
            # deep input projection: int8 rows too (h_new is in (-1,1))
            xp = (_doti(_q8_act(h_new), wdq_ref[l]).astype(jnp.float32)
                  * wde_ref[l][None])
    o_ref[...] = h_new[None].astype(o_ref.dtype)
    hT_ref[...] = h_s[...].astype(hT_ref.dtype)


def _stack_kernel_q8_masked(h0_ref, xp_ref, uq_ref, eff_ref, wdq_ref,
                            wde_ref, b_ref, m_ref, o_ref, hT_ref, h_s, *,
                            variant: str, num_layers: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_s[...] = h0_ref[...].astype(jnp.float32)

    b = b_ref[...].astype(jnp.float32)                    # (L, 3H)
    eff = eff_ref[...]                                    # (L, 3H)
    xp = xp_ref[...][0].astype(jnp.float32)               # (B, 3H): layer 0 Wx
    keep = m_ref[...][0] != 0.0                           # (B,) this step
    for l in range(num_layers):                           # static unroll
        h_new = _gate_math_q8(h_s[l], xp, uq_ref[l], eff[l:l + 1],
                              b[l:l + 1], variant)
        h_new = jnp.where(keep[:, None], h_new, h_s[l])   # freeze masked rows
        h_s[l] = h_new
        if l + 1 < num_layers:
            xp = (_doti(_q8_act(h_new), wdq_ref[l]).astype(jnp.float32)
                  * wde_ref[l][None])
    o_ref[...] = h_new[None].astype(o_ref.dtype)
    hT_ref[...] = h_s[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "interpret"))
def gru_stack_sequence_q8_kernel(h0: jax.Array, x_proj: jax.Array,
                                 u_q: jax.Array, u_eff: jax.Array,
                                 wd_q: jax.Array, wd_eff: jax.Array,
                                 b: jax.Array, mask=None, *,
                                 variant: str = "v1",
                                 interpret: bool = False):
    """q8 twin of :func:`gru_stack_sequence_kernel` (uniform hidden size).

    h0: (L,B,H); x_proj: (T,B,3H) f32 layer-0 Wx; u_q: (L,3H,H) int8
    weight rows with u_eff: (L,3H) dequant scales; wd_q: (L-1,3H,H) int8
    deep-layer input projections with wd_eff: (L-1,3H) (pass the
    ``quantize_gru_cells`` placeholders for L=1, unused); b: (L,3H).
    Returns (last-layer states (T,B,H), per-layer finals (L,B,H))."""
    T, B, H3 = x_proj.shape
    H = H3 // 3
    L = h0.shape[0]
    Ld = max(L - 1, 1)
    in_specs = [
        pl.BlockSpec((L, B, H), lambda t: (0, 0, 0)),      # h0: resident
        pl.BlockSpec((1, B, 3 * H), lambda t: (t, 0, 0)),  # stream step t
        pl.BlockSpec((L, 3 * H, H), lambda t: (0, 0, 0)),  # int8 U: ONCE
        pl.BlockSpec((L, 3 * H), lambda t: (0, 0)),
        pl.BlockSpec((Ld,) + wd_q.shape[1:], lambda t: (0, 0, 0)),
        pl.BlockSpec((Ld, 3 * H), lambda t: (0, 0)),
        pl.BlockSpec((L, 3 * H), lambda t: (0, 0)),
    ]
    args = [h0, x_proj, u_q, u_eff, wd_q, wd_eff, b]
    if mask is None:
        kern = functools.partial(_stack_kernel_q8, variant=variant,
                                 num_layers=L)
    else:
        kern = functools.partial(_stack_kernel_q8_masked, variant=variant,
                                 num_layers=L)
        in_specs.append(pl.BlockSpec((1, B), lambda t: (t, 0)))  # step's mask
        args.append(mask.astype(jnp.float32))
    hs, hT = pl.pallas_call(
        kern,
        grid=(T,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, B, H), lambda t: (t, 0, 0)),
            pl.BlockSpec((L, B, H), lambda t: (0, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((T, B, H), h0.dtype),
                   jax.ShapeDtypeStruct((L, B, H), h0.dtype)],
        scratch_shapes=[pltpu.VMEM((L, B, H), jnp.float32)],
        interpret=interpret,
    )(*args)
    return hs, hT


def _decode_kernel_q8(h_ref, xp_ref, uq_ref, eff_ref, wdq_ref, wde_ref,
                      b_ref, o_ref, *, variant: str, num_layers: int):
    """One token through all L layers for one batch tile, int8 weights
    resident (a quarter of the f32 VMEM footprint — the paper's
    local-memory residency at AIE precision)."""
    b = b_ref[...].astype(jnp.float32)                    # (L, 3H)
    eff = eff_ref[...]                                    # (L, 3H)
    xp = xp_ref[...].astype(jnp.float32)                  # (Bt, 3H)
    for l in range(num_layers):                           # static unroll
        h_new = _gate_math_q8(h_ref[l].astype(jnp.float32), xp,
                              uq_ref[l], eff[l:l + 1], b[l:l + 1], variant)
        o_ref[l] = h_new.astype(o_ref.dtype)
        if l + 1 < num_layers:
            xp = (_doti(_q8_act(h_new), wdq_ref[l]).astype(jnp.float32)
                  * wde_ref[l][None])


@functools.partial(jax.jit, static_argnames=("variant", "batch_block",
                                             "interpret"))
def gru_stack_decode_q8_kernel(h: jax.Array, x_proj: jax.Array,
                               u_q: jax.Array, u_eff: jax.Array,
                               wd_q: jax.Array, wd_eff: jax.Array,
                               b: jax.Array, *, variant: str = "v1",
                               batch_block: int = 0,
                               interpret: bool = False) -> jax.Array:
    """q8 twin of :func:`gru_stack_decode_kernel` — the latency path at the
    paper's precision. h: (L,B,H) f32 states; x_proj: (B,3H) f32 layer-0
    Wx; u_q/u_eff, wd_q/wd_eff, b as in the q8 sequence kernel. Returns
    the new per-layer states (L,B,H) f32 (the state itself stays f32: the
    convex update accumulates full precision; only the matvecs are int8)."""
    L, B, H = h.shape
    Bt = batch_block or _pick_batch_block(B)
    assert B % Bt == 0, (B, Bt)
    Ld = max(L - 1, 1)
    return pl.pallas_call(
        functools.partial(_decode_kernel_q8, variant=variant, num_layers=L),
        grid=(B // Bt,),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        in_specs=[
            pl.BlockSpec((L, Bt, H), lambda i: (0, i, 0)),     # batch tile
            pl.BlockSpec((Bt, 3 * H), lambda i: (i, 0)),       # its Wx slab
            pl.BlockSpec((L, 3 * H, H), lambda i: (0, 0, 0)),  # int8 U: ONCE
            pl.BlockSpec((L, 3 * H), lambda i: (0, 0)),
            pl.BlockSpec((Ld,) + wd_q.shape[1:], lambda i: (0, 0, 0)),
            pl.BlockSpec((Ld, 3 * H), lambda i: (0, 0)),
            pl.BlockSpec((L, 3 * H), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((L, Bt, H), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, B, H), h.dtype),
        interpret=interpret,
    )(h, x_proj, u_q, u_eff, wd_q, wd_eff, b)


# ---------------------------------------------------------------------------
# shard-shaped step kernels (the pallas_sharded backend's per-tile programs)
# ---------------------------------------------------------------------------
#
# Each kernel is the largest contiguous per-shard compute segment between
# two collectives of the shard_map GRU step; no grid (one whole-block
# invocation per call — the operands already ARE one shard's working set,
# and they live in VMEM for the duration of the kernel). The bodies repeat
# the XLA shard-step expressions verbatim so interpret-mode results are
# bitwise-identical to the `sharded` backend at the same shard shapes.


def _shard_call(body, out_shape, *args, interpret: bool):
    """One whole-block pallas_call: every operand is a full (already
    shard-local) block; TPU places them in VMEM, CPU runs interpreted."""
    return pl.pallas_call(body, out_shape=out_shape,
                          interpret=interpret)(*args)


def _rowwise_shard_step_body(hf_ref, hl_ref, xp_ref, u_ref, b_ref, o_ref):
    """v3 rowwise step, one shard: all three gate matvecs contract the FULL
    (replicated) h against this shard's output rows; finished local rows
    out (the trailing all-gather runs outside, between kernel calls)."""
    Hl = o_ref.shape[-1]
    hf = hf_ref[...]                                       # (B, H) replicated
    xp, u, b = xp_ref[...], u_ref[...], b_ref[...][0]
    z = jax.nn.sigmoid(xp[:, :Hl] + hf @ u[:, :Hl] + b[:Hl])
    r = jax.nn.sigmoid(xp[:, Hl:2 * Hl] + hf @ u[:, Hl:2 * Hl]
                       + b[Hl:2 * Hl])
    ht = jnp.tanh(xp[:, 2 * Hl:] + r * (hf @ u[:, 2 * Hl:] + b[2 * Hl:]))
    o_ref[...] = (1 - z) * hl_ref[...] + z * ht


def _rowwise_shard_zr_body(hf_ref, hl_ref, xp_ref, u_ref, b_ref, z_ref,
                           rh_ref):
    """v1 rowwise phase 1, one shard: z and r for this shard's rows plus
    the local ``r*h`` contribution the mid-step aggregation gathers."""
    Hl = z_ref.shape[-1]
    hf = hf_ref[...]
    xp, u, b = xp_ref[...], u_ref[...], b_ref[...][0]
    z = jax.nn.sigmoid(xp[:, :Hl] + hf @ u[:, :Hl] + b[:Hl])
    r = jax.nn.sigmoid(xp[:, Hl:] + hf @ u[:, Hl:] + b[Hl:])
    z_ref[...] = z
    rh_ref[...] = r * hl_ref[...]


def _rowwise_shard_candidate_body(rhf_ref, hl_ref, z_ref, xp_ref, u_ref,
                                  b_ref, o_ref):
    """v1 rowwise phase 2, one shard: candidate gate against the gathered
    full ``r*h``, then the convex state update on the local rows."""
    ht = jnp.tanh(xp_ref[...] + rhf_ref[...] @ u_ref[...] + b_ref[...][0])
    z = z_ref[...]
    o_ref[...] = (1 - z) * hl_ref[...] + z * ht


def _shard_matvec_body(x_ref, w_ref, o_ref):
    """Partial-product matvec: this shard's contraction slice (the cascade
    MAC segment; the psum combining shards runs outside)."""
    o_ref[...] = x_ref[...] @ w_ref[...]


def _cascade_shard_gates_body(g_ref, xp_ref, h_ref, o_ref):
    """v3 cascade epilogue, one shard: gate nonlinearities + state update
    on the LOCAL gate slices of the psum'd pre-activations (elementwise,
    so slicing before the kernel is bitwise-free)."""
    Hl = o_ref.shape[-1]
    g, xp = g_ref[...], xp_ref[...]
    z = jax.nn.sigmoid(xp[:, :Hl] + g[:, :Hl])
    r = jax.nn.sigmoid(xp[:, Hl:2 * Hl] + g[:, Hl:2 * Hl])
    ht = jnp.tanh(xp[:, 2 * Hl:] + r * g[:, 2 * Hl:])
    o_ref[...] = (1 - z) * h_ref[...] + z * ht


def _cascade_shard_zr_body(zr_ref, xp_ref, h_ref, u_ref, z_ref, p_ref):
    """v1 cascade mid-phase, one shard: z/r on the local slices of the
    psum'd z,r pre-activations, then this shard's candidate partial
    product ``(r_local * h_local) @ Uh_rows`` (psum'd outside)."""
    Hl = z_ref.shape[-1]
    zr, xp = zr_ref[...], xp_ref[...]
    z = jax.nn.sigmoid(xp[:, :Hl] + zr[:, :Hl])
    r = jax.nn.sigmoid(xp[:, Hl:] + zr[:, Hl:])
    z_ref[...] = z
    p_ref[...] = (r * h_ref[...]) @ u_ref[...]


def _cascade_shard_update_body(z_ref, ht_ref, h_ref, o_ref):
    """v1 cascade epilogue, one shard: candidate tanh on the local slice of
    the psum'd pre-activation, then the convex state update."""
    z = z_ref[...]
    o_ref[...] = (1 - z) * h_ref[...] + z * jnp.tanh(ht_ref[...])


def gru_rowwise_shard_step(h_full, h_local, xp, u, b, *,
                           interpret: bool = False):
    """v3 rowwise shard step. h_full (B,H) replicated f32, h_local (B,Hl)
    this shard's rows, xp (B,3Hl) / u (H,3Hl) / b (3Hl,) this shard's
    gate-major slices -> new local rows (B,Hl)."""
    B, Hl = h_local.shape
    return _shard_call(_rowwise_shard_step_body,
                       jax.ShapeDtypeStruct((B, Hl), jnp.float32),
                       h_full, h_local, xp, u, b[None, :],
                       interpret=interpret)


def gru_rowwise_shard_zr(h_full, h_local, xp_zr, u_zr, b_zr, *,
                         interpret: bool = False):
    """v1 rowwise phase 1 -> (z_local (B,Hl), rh_local (B,Hl))."""
    B, Hl = h_local.shape
    out = [jax.ShapeDtypeStruct((B, Hl), jnp.float32)] * 2
    return _shard_call(_rowwise_shard_zr_body, out, h_full, h_local, xp_zr,
                       u_zr, b_zr[None, :], interpret=interpret)


def gru_rowwise_shard_candidate(rh_full, h_local, z_local, xp_h, u_h, b_h, *,
                                interpret: bool = False):
    """v1 rowwise phase 2: gathered rh_full (B,H) -> new local rows."""
    B, Hl = h_local.shape
    return _shard_call(_rowwise_shard_candidate_body,
                       jax.ShapeDtypeStruct((B, Hl), jnp.float32),
                       rh_full, h_local, z_local, xp_h, u_h, b_h[None, :],
                       interpret=interpret)


def gru_shard_matvec(x, w, *, interpret: bool = False):
    """Cascade partial product: x (B,Hl) @ w (Hl,N) -> (B,N) f32."""
    return _shard_call(_shard_matvec_body,
                       jax.ShapeDtypeStruct((x.shape[0], w.shape[1]),
                                            jnp.float32),
                       x, w, interpret=interpret)


def gru_cascade_shard_gates(g_local, xp_local, h_shard, *,
                            interpret: bool = False):
    """v3 cascade epilogue: local (B,3Hl) gate slices -> new h shard."""
    return _shard_call(_cascade_shard_gates_body,
                       jax.ShapeDtypeStruct(h_shard.shape, jnp.float32),
                       g_local, xp_local, h_shard, interpret=interpret)


def gru_cascade_shard_zr(zr_local, xp_local, h_shard, u_h_rows, *,
                         interpret: bool = False):
    """v1 cascade mid-phase -> (z_local (B,Hl), ht_partial (B,H))."""
    B, Hl = h_shard.shape
    out = [jax.ShapeDtypeStruct((B, Hl), jnp.float32),
           jax.ShapeDtypeStruct((B, u_h_rows.shape[1]), jnp.float32)]
    return _shard_call(_cascade_shard_zr_body, out, zr_local, xp_local,
                       h_shard, u_h_rows, interpret=interpret)


def gru_cascade_shard_update(z_local, ht_in_local, h_shard, *,
                             interpret: bool = False):
    """v1 cascade epilogue: pre-activated local candidate -> new h shard."""
    return _shard_call(_cascade_shard_update_body,
                       jax.ShapeDtypeStruct(h_shard.shape, jnp.float32),
                       z_local, ht_in_local, h_shard, interpret=interpret)
