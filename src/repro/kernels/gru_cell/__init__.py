from repro.kernels.gru_cell import ops, ref
from repro.kernels.gru_cell.kernel import (gru_step_blocked, gru_step_fused,
                                           gru_step_q8)

__all__ = ["ops", "ref", "gru_step_fused", "gru_step_blocked", "gru_step_q8"]
