"""Public wrapper: dispatches fused vs blocked on the VMEM working set."""
from __future__ import annotations

import jax

from repro.kernels import on_cpu
from repro.kernels.gru_cell.kernel import gru_step_blocked, gru_step_fused

# single-block path budget: u (H,3H) + h/x/scratch must fit comfortably.
_FUSED_VMEM_BUDGET = 12 * 1024 * 1024


def gru_step_pallas(h: jax.Array, x_proj: jax.Array, u: jax.Array, b: jax.Array,
                    variant: str = "v1", block_n: int = 256) -> jax.Array:
    B, H = h.shape
    working = (3 * H * H + 4 * B * H + 3 * B * H) * u.dtype.itemsize
    if working <= _FUSED_VMEM_BUDGET or H % block_n:
        return gru_step_fused(h, x_proj, u, b, variant=variant, interpret=on_cpu())
    if variant == "v3":
        # v3's single stacked matvec has no cross-phase dependency; the
        # blocked path only implements paper math -> fall back to fused.
        return gru_step_fused(h, x_proj, u, b, variant=variant, interpret=on_cpu())
    return gru_step_blocked(h, x_proj, u, b, block_n=block_n, interpret=on_cpu())


def gru_step_q8_pallas(h: jax.Array, x_proj: jax.Array, u_q: jax.Array,
                       u_eff: jax.Array, b: jax.Array,
                       variant: str = "v1") -> jax.Array:
    """Public q8 single-step entry (whole-state-resident fused kernel; at
    int8 the (3H,H) weight block fits the single-block budget to 4x the
    f32 hidden-size range, so no blocked variant is needed)."""
    from repro.kernels.gru_cell.kernel import gru_step_q8
    return gru_step_q8(h, x_proj, u_q, u_eff, b, variant=variant,
                       interpret=on_cpu())
