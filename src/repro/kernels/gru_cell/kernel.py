"""Fused GRU step Pallas kernel — the paper's "hybrid aggregation" on TPU.

On the AIE, partial per-row gate results are merged on interface tiles and a
PL FSM applies the activation LUT and reassembles the vector without the
pipeline stall of the in-array aggregator. The TPU analogue is KERNEL FUSION:
bias + sigmoid/tanh + Hadamard combine run in the matvec epilogue inside one
``pallas_call`` — partial results never round-trip through HBM.

Two kernels:

* ``gru_step_fused``   — whole hidden state resident in VMEM, a single grid
  step does both phases (z,r then h~,h'). Covers the paper's sizes (H<=32)
  up through H ~ 1024.
* ``gru_step_blocked`` — 3-phase grid over output-row blocks for large H,
  with the z and r*h vectors staged in VMEM scratch between phases. This is
  the row-wise tiling: each (phase, block) grid step owns whole output rows
  of U and consumes the full h vector, which stays VMEM-resident (constant
  index_map) — the paper's "row reuse".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dot(a, b):
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _fused_kernel(h_ref, xp_ref, u_ref, b_ref, o_ref, *, variant: str):
    H = h_ref.shape[-1]
    h = h_ref[...].astype(jnp.float32)
    xp = xp_ref[...].astype(jnp.float32)
    u = u_ref[...]
    b = b_ref[...].astype(jnp.float32)   # (1, 3H)
    xz, xr, xh = xp[:, :H], xp[:, H:2 * H], xp[:, 2 * H:]
    if variant == "v3":
        # beyond-paper single-phase: one (H,3H) matmul feeds all gates
        ua = _dot(h.astype(u.dtype), u) + b
        z = jax.nn.sigmoid(xz + ua[:, :H])
        r = jax.nn.sigmoid(xr + ua[:, H:2 * H])
        ht = jnp.tanh(xh + r * ua[:, 2 * H:])
    else:
        # paper math, 2 fused phases
        zr = _dot(h.astype(u.dtype), u[:, :2 * H]) + b[:, :2 * H]
        z = jax.nn.sigmoid(xz + zr[:, :H])
        r = jax.nn.sigmoid(xr + zr[:, H:])
        ht = jnp.tanh(xh + _dot((r * h).astype(u.dtype), u[:, 2 * H:]) + b[:, 2 * H:])
    o_ref[...] = ((1.0 - z) * h + z * ht).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "interpret"))
def gru_step_fused(h: jax.Array, x_proj: jax.Array, u: jax.Array, b: jax.Array,
                   *, variant: str = "v1", interpret: bool = False) -> jax.Array:
    """h' for one step; everything VMEM-resident. h: (B,H), x_proj: (B,3H),
    u: (H,3H), b: (3H,)."""
    B, H = h.shape
    return pl.pallas_call(
        functools.partial(_fused_kernel, variant=variant),
        in_specs=[
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((B, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((H, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((1, 3 * H), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, H), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H), h.dtype),
        interpret=interpret,
    )(h, x_proj, u, b[None, :])


def _blocked_kernel(h_ref, xp_ref, u_ref, b_ref, o_ref, z_s, rh_s, *, bn: int):
    """grid = (3 phases, H//bn row blocks); phase 0: z, 1: r*h, 2: h~ + h'."""
    g, j = pl.program_id(0), pl.program_id(1)
    h = h_ref[...].astype(jnp.float32)                  # (B, H) resident
    xp = xp_ref[...][:, 0, :].astype(jnp.float32)       # (B, bn) this gate/block
    u = u_ref[...][:, 0, :]                             # (H, bn) whole rows
    b = b_ref[...].astype(jnp.float32)                  # (1, bn)
    sl = pl.ds(j * bn, bn)

    @pl.when(g == 0)
    def _z():
        z_s[:, sl] = jax.nn.sigmoid(xp + _dot(h.astype(u.dtype), u) + b)

    @pl.when(g == 1)
    def _r():
        r = jax.nn.sigmoid(xp + _dot(h.astype(u.dtype), u) + b)
        rh_s[:, sl] = r * h_ref[:, sl].astype(jnp.float32)

    @pl.when(g == 2)
    def _h():
        rh = rh_s[...]
        ht = jnp.tanh(xp + _dot(rh.astype(u.dtype), u) + b)
        z = z_s[:, sl]
        h_blk = h_ref[:, sl].astype(jnp.float32)
        o_ref[...] = ((1.0 - z) * h_blk + z * ht).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def gru_step_blocked(h: jax.Array, x_proj: jax.Array, u: jax.Array, b: jax.Array,
                     *, block_n: int = 256, interpret: bool = False) -> jax.Array:
    """Row-blocked fused step for hidden sizes whose U exceeds VMEM."""
    B, H = h.shape
    bn = min(block_n, H)
    assert H % bn == 0, (H, bn)
    # gate-major views: (B, 3, H), (H, 3, H), (3, H)
    xp3 = x_proj.reshape(B, 3, H)
    u3 = u.reshape(H, 3, H)
    b3 = b.reshape(3, H)
    return pl.pallas_call(
        functools.partial(_blocked_kernel, bn=bn),
        grid=(3, H // bn),
        in_specs=[
            pl.BlockSpec((B, H), lambda g, j: (0, 0)),          # h resident
            pl.BlockSpec((B, 1, bn), lambda g, j: (0, g, j)),
            pl.BlockSpec((H, 1, bn), lambda g, j: (0, g, j)),
            pl.BlockSpec((1, bn), lambda g, j: (g, j)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda g, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, H), h.dtype),
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),   # z staged between phases
            pltpu.VMEM((B, H), jnp.float32),   # r*h staged between phases
        ],
        interpret=interpret,
    )(h, xp3, u3, b3)


# ---------------------------------------------------------------------------
# q8 fused step: int8 weight rows resident, dequant folded into the bias add
# ---------------------------------------------------------------------------

def _doti(a, b):
    """int8 x int8 -> int32, contracting the CONTIGUOUS last axes (weights
    stored row-major per output element — the paper's per-lane layout)."""
    return jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.int32)


def _q8_act(a):
    """Fixed-scale activation quantization: f32 in [-1, 1] -> int8 (the GRU
    state is a convex combination of h0 and tanh outputs, so no dynamic
    range scan is ever needed — see repro.core.params)."""
    return jnp.clip(jnp.round(a * 127.0), -127.0, 127.0).astype(jnp.int8)


def _q8_step_kernel(h_ref, xp_ref, uq_ref, eff_ref, b_ref, o_ref, *,
                    variant: str):
    H = h_ref.shape[-1]
    h = h_ref[...].astype(jnp.float32)
    xp = xp_ref[...].astype(jnp.float32)
    uq = uq_ref[...]                                     # (3H, H) int8 rows
    eff = eff_ref[...]                                   # (1, 3H)
    b = b_ref[...].astype(jnp.float32)                   # (1, 3H)
    xz, xr, xh = xp[:, :H], xp[:, H:2 * H], xp[:, 2 * H:]
    hq = _q8_act(h)
    if variant == "v3":
        ua = _doti(hq, uq).astype(jnp.float32) * eff + b
        z = jax.nn.sigmoid(xz + ua[:, :H])
        r = jax.nn.sigmoid(xr + ua[:, H:2 * H])
        ht = jnp.tanh(xh + r * ua[:, 2 * H:])
    else:
        zr = (_doti(hq, uq[:2 * H]).astype(jnp.float32) * eff[:, :2 * H]
              + b[:, :2 * H])
        z = jax.nn.sigmoid(xz + zr[:, :H])
        r = jax.nn.sigmoid(xr + zr[:, H:])
        cand = (_doti(_q8_act(r * h), uq[2 * H:]).astype(jnp.float32)
                * eff[:, 2 * H:] + b[:, 2 * H:])
        ht = jnp.tanh(xh + cand)
    o_ref[...] = ((1.0 - z) * h + z * ht).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("variant", "interpret"))
def gru_step_q8(h: jax.Array, x_proj: jax.Array, u_q: jax.Array,
                u_eff: jax.Array, b: jax.Array, *, variant: str = "v1",
                interpret: bool = False) -> jax.Array:
    """q8 twin of :func:`gru_step_fused`: one step, everything
    VMEM-resident, U stored as (3H, H) int8 rows (quarter footprint) with
    per-row dequant scales ``u_eff`` (3H,) applied at the bias add.
    h: (B,H), x_proj: (B,3H), b: (3H,)."""
    B, H = h.shape
    return pl.pallas_call(
        functools.partial(_q8_step_kernel, variant=variant),
        in_specs=[
            pl.BlockSpec((B, H), lambda: (0, 0)),
            pl.BlockSpec((B, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((3 * H, H), lambda: (0, 0)),
            pl.BlockSpec((1, 3 * H), lambda: (0, 0)),
            pl.BlockSpec((1, 3 * H), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, H), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H), h.dtype),
        interpret=interpret,
    )(h, x_proj, u_q, u_eff[None, :], b[None, :])
