"""Pure-jnp fp32 oracle for the fused GRU step kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gru_step_ref(h, x_proj, u, b, variant: str = "v1"):
    """h: (B,H), x_proj: (B,3H) = Wx already applied, u: (H,3H), b: (3H,)."""
    h = jnp.asarray(h, jnp.float32)
    xp = jnp.asarray(x_proj, jnp.float32)
    u = jnp.asarray(u, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    H = h.shape[-1]
    xz, xr, xh = xp[..., :H], xp[..., H:2 * H], xp[..., 2 * H:]
    if variant == "v3":
        ua = h @ u + b
        z = jax.nn.sigmoid(xz + ua[..., :H])
        r = jax.nn.sigmoid(xr + ua[..., H:2 * H])
        ht = jnp.tanh(xh + r * ua[..., 2 * H:])
    else:
        z = jax.nn.sigmoid(xz + h @ u[:, :H] + b[:H])
        r = jax.nn.sigmoid(xr + h @ u[:, H:2 * H] + b[H:2 * H])
        ht = jnp.tanh(xh + (r * h) @ u[:, 2 * H:] + b[2 * H:])
    return (1 - z) * h + z * ht
