"""Pure-jnp fp32 oracle for the fused GRU step kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q8_act_ref(a):
    """Fixed-scale activation quantization kept in f32 (integer-valued):
    the oracle's dots then accumulate EXACTLY the kernel's int32 sums
    (products of int8 pairs and their partial sums stay below 2^24, so f32
    represents them exactly at test sizes)."""
    return jnp.clip(jnp.round(jnp.asarray(a, jnp.float32) * 127.0),
                    -127.0, 127.0)


def gru_step_q8_ref(h, x_proj, u_q, u_eff, b, variant: str = "v1"):
    """Quantize-dequantize oracle for the q8 step kernels.

    h: (B,H) f32 state, x_proj: (B,3H) f32 Wx, u_q: (3H,H) int8 weight
    rows (transposed per-row layout of ``quantize_rows_int8``), u_eff:
    (3H,) f32 per-row dequant scales (activation scale folded), b: (3H,).
    Mirrors the kernel arithmetic op for op — same rounding, same dequant
    multiply at the bias add — in plain jnp."""
    h = jnp.asarray(h, jnp.float32)
    xp = jnp.asarray(x_proj, jnp.float32)
    uqf = jnp.asarray(u_q, jnp.float32)        # (3H, H) integer-valued
    eff = jnp.asarray(u_eff, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    H = h.shape[-1]
    xz, xr, xh = xp[..., :H], xp[..., H:2 * H], xp[..., 2 * H:]
    hq = _q8_act_ref(h)
    if variant == "v3":
        ua = hq @ uqf.T * eff + b
        z = jax.nn.sigmoid(xz + ua[..., :H])
        r = jax.nn.sigmoid(xr + ua[..., H:2 * H])
        ht = jnp.tanh(xh + r * ua[..., 2 * H:])
    else:
        zr = hq @ uqf[:2 * H].T * eff[:2 * H] + b[:2 * H]
        z = jax.nn.sigmoid(xz + zr[..., :H])
        r = jax.nn.sigmoid(xr + zr[..., H:])
        ht = jnp.tanh(xh + _q8_act_ref(r * h) @ uqf[2 * H:].T * eff[2 * H:]
                      + b[2 * H:])
    return (1 - z) * h + z * ht


def gru_step_ref(h, x_proj, u, b, variant: str = "v1"):
    """h: (B,H), x_proj: (B,3H) = Wx already applied, u: (H,3H), b: (3H,)."""
    h = jnp.asarray(h, jnp.float32)
    xp = jnp.asarray(x_proj, jnp.float32)
    u = jnp.asarray(u, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    H = h.shape[-1]
    xz, xr, xh = xp[..., :H], xp[..., H:2 * H], xp[..., 2 * H:]
    if variant == "v3":
        ua = h @ u + b
        z = jax.nn.sigmoid(xz + ua[..., :H])
        r = jax.nn.sigmoid(xr + ua[..., H:2 * H])
        ht = jnp.tanh(xh + r * ua[..., 2 * H:])
    else:
        z = jax.nn.sigmoid(xz + h @ u[:, :H] + b[:H])
        r = jax.nn.sigmoid(xr + h @ u[:, H:2 * H] + b[H:2 * H])
        ht = jnp.tanh(xh + (r * h) @ u[:, 2 * H:] + b[2 * H:])
    return (1 - z) * h + z * ht
