"""One GRU executor: capability-dispatched backends behind ``plan()``/run.

The paper's core idea is a single workload-distribution framework that maps
GRU matvecs onto whichever compute fabric is available (AIE rows vs. the PL
cascade). This module is that framework's TPU translation: every execution
strategy the repo has grown — the XLA structural-mode scan, the fused
Pallas stack kernels, the per-layer Pallas chain, the shard_map row/cascade
programs — registers here as a *backend* with declared capabilities, and
``plan()`` picks the cheapest legal one per call instead of each caller
hard-wiring an entry point.

Capability table (see ``BackendSpec``; costs are dispatch-preference hints,
lower = faster):

=============  ====  ======  ====  ==========  ======  ========  ====
backend        mask  hetero  mesh  return_all  decode  sequence  cost
=============  ====  ======  ====  ==========  ======  ========  ====
pallas_fused   yes   no      no    yes         yes     yes       10
pallas_chain   yes   yes     no    yes         yes     yes       20
xla            yes   yes     no    yes         yes     yes       30
sharded        yes   yes     REQ   yes         no      yes       5
=============  ====  ======  ====  ==========  ======  ========  ====

* ``mask``: a (B, T) length mask streams through the backend (bucketed
  left-padded prefill stays bitwise-identical to unpadded prompts — every
  backend here claims ``mask_exact``). The fused Pallas kernels stream the
  mask in-kernel (one (1, B) slice per grid step); no XLA fallback remains.
* ``hetero``: heterogeneous ``cfg.layer_dims`` (the fused kernel needs one
  uniform VMEM block shape; the chain runs one kernel per layer instead of
  raising or silently degrading).
* ``mesh`` = REQ: the backend *requires* a mesh and is strongly preferred
  for sequence work whenever one is passed (providing a mesh is an explicit
  request to use it). Decode under a mesh falls back to a replicated
  single-host backend: one recurrent step is latency-bound and per-step
  collectives would dominate.

Dispatch: ``cfg.backend`` is a preference — ``"xla"`` (default) and
``"pallas"`` pin their family when legal; ``"auto"`` picks purely by cost.
An illegal preference (e.g. pallas + hetero dims) falls through to the
cheapest legal backend in the same family, then overall — never an error
as long as ANY backend can serve the call.

Surfaces:

* ``prepare(params, cfg, mesh=None) -> StackParams`` — ONE-time param
  normalization subsuming ``stack_cell_params`` / ``prepare_stacked_cells``
  / the model API's ``prepare_params``: accepts every historical layout and
  precomputes the stacked-weight views the fused kernels consume.
* ``plan(cfg, *, batch, seq, mesh, mask, mode) -> ExecPlan`` — memoized;
  the returned ``prefill`` / ``decode`` / ``sequence`` callables are stable
  objects (jit-friendly: re-planning the same key returns the SAME plan)
  and reference-exact w.r.t. ``gru_stack_reference``.
* ``sequence(...)`` / ``decode(...)`` — plan-and-run conveniences; the
  deprecated entry points in ``repro.core.gru`` are thin shims over these.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.configs.base import GRUConfig
from repro.core import gru as gru_core


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend can legally execute (checked by ``plan()``)."""
    supports_mask: bool = False      # (B,T) length mask streams through
    supports_hetero_dims: bool = False   # per-layer hidden sizes may differ
    supports_mesh: bool = False      # True = REQUIRES a mesh (shard_map)
    return_all: bool = False         # can emit the last layer's full sequence
    decode: bool = False             # single-step serve path
    sequence: bool = True            # whole-sequence / prefill path
    mask_exact: bool = True          # masked+padded == unpadded, bitwise


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered execution strategy.

    ``sequence_fn(sp, h0s, xs, *, cfg, return_all, mask, mesh)`` returns
    ``(per-layer finals tuple, last-layer states | None)``;
    ``decode_fn(sp, hs, x, *, cfg)`` returns the per-layer new states.
    ``cost`` is a relative per-call dispatch hint (lower = preferred).
    """
    name: str
    caps: Capabilities
    cost: int
    sequence_fn: Optional[Callable] = None
    decode_fn: Optional[Callable] = None


_REGISTRY: Dict[str, BackendSpec] = {}


def register_backend(spec: BackendSpec) -> None:
    _REGISTRY[spec.name] = spec


def backends() -> Dict[str, BackendSpec]:
    """Snapshot of the registry (name -> spec), for introspection/tests."""
    _ensure_backends()
    return dict(_REGISTRY)


def _ensure_backends() -> None:
    """Make sure the kernels package had a chance to register its backends
    (it does so on import; plan() imports it on first use otherwise, so
    dispatch never depends on import order)."""
    if "pallas_fused" not in _REGISTRY:
        from repro.kernels.gru_sequence import ops as seq_ops
        seq_ops.register_runtime_backends()


# ---------------------------------------------------------------------------
# canonical params: StackParams + prepare()
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StackParams:
    """Canonical GRU stack parameters: the ONE layout every backend takes.

    ``cells``: per-layer ``{"w","u","b"}`` dicts, layer 0 first.
    ``stacked``: the fused kernels' precomputed device-side weight stacks
    (``{"u","w_deep","b"}``) — present for uniform hidden sizes, ``None``
    for heterogeneous stacks (the fused backend doesn't apply there).
    """
    cells: tuple
    stacked: Optional[dict] = None

    def tree_flatten(self):
        return (self.cells, self.stacked), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def dims(self) -> Tuple[int, ...]:
        return tuple(c["u"].shape[0] for c in self.cells)


def prepare(params, cfg: GRUConfig, mesh=None, *,
            want_stacked: bool = True) -> StackParams:
    """One-time normalization of ANY accepted param layout to StackParams.

    Subsumes ``stack_cell_params`` (layout normalization),
    ``prepare_stacked_cells`` (fused-kernel weight stacking) and the model
    API's ``prepare_params`` (serving prep). Accepts ``StackParams``
    (passthrough), ``{"cells": ...}``, ``{"cell": ...}``, a bare
    ``{w,u,b}`` cell, a per-layer sequence, and dicts already carrying a
    precomputed ``"stacked_cells"`` entry (reused, not recomputed). Do this
    ONCE outside the per-step jit so decode traces never restack weights.

    ``want_stacked=False`` skips computing the fused-kernel weight stacks
    (plan callables pass it when the resolved backend never reads them, so
    an XLA-dispatched call doesn't pay L stacking ops per trace).
    ``mesh`` is accepted for signature stability (pre-sharding hook); the
    sharded backend currently shards inside its shard_map.
    """
    if isinstance(params, StackParams):
        return params
    stacked = params.get("stacked_cells") if isinstance(params, dict) else None
    cells = gru_core.stack_cell_params(params, cfg)
    dims = tuple(c["u"].shape[0] for c in cells)
    if (want_stacked and stacked is None
            and all(d == dims[0] for d in dims)):
        from repro.kernels.gru_sequence import ops as seq_ops
        stacked = seq_ops.prepare_stacked_cells(cells)
    return StackParams(cells=cells, stacked=stacked)


# ---------------------------------------------------------------------------
# built-in backends: xla scan + sharded shard_map programs
# ---------------------------------------------------------------------------

def _xla_sequence(sp, h0s, xs, *, cfg, return_all, mask, mesh):
    return gru_core.gru_stack_sequence_xla(sp.cells, h0s, xs, cfg=cfg,
                                           return_all=return_all, mask=mask)


def _xla_decode(sp, hs, x, *, cfg):
    return gru_core.gru_stack_decode_xla(sp.cells, hs, x, cfg=cfg)


def _sharded_sequence(sp, h0s, xs, *, cfg, return_all, mask, mesh):
    from repro.core import rowparallel
    out = rowparallel.gru_stack_sequence_sharded_impl(
        sp.cells, h0s, xs, mesh=mesh, cfg=cfg, return_all=return_all,
        mask=mask)
    if return_all:
        return out
    return out, None


register_backend(BackendSpec(
    name="xla",
    caps=Capabilities(supports_mask=True, supports_hetero_dims=True,
                      supports_mesh=False, return_all=True, decode=True,
                      sequence=True),
    cost=30,
    sequence_fn=_xla_sequence, decode_fn=_xla_decode))

register_backend(BackendSpec(
    name="sharded",
    caps=Capabilities(supports_mask=True, supports_hetero_dims=True,
                      supports_mesh=True, return_all=True, decode=False,
                      sequence=True),
    cost=5,
    sequence_fn=_sharded_sequence, decode_fn=None))


# ---------------------------------------------------------------------------
# plan(): capability filtering + cost choice
# ---------------------------------------------------------------------------

class NoCapableBackend(ValueError):
    """No registered backend can legally serve the requested call."""


@dataclasses.dataclass(frozen=True, eq=False)
class ExecPlan:
    """A resolved execution plan: metadata + jit-stable callables.

    ``sequence(params, h0s, xs, *, return_all=False, mask=None)`` returns
    ``(per-layer finals, last-layer states | None)``; ``prefill`` is the
    finals-only view of the same backend; ``decode(params, hs, x)`` returns
    the per-layer new states. ``params`` may be any layout ``prepare``
    accepts (pass a prepared ``StackParams`` on hot paths).
    """
    cfg: GRUConfig
    batch: Optional[int]
    seq: Optional[int]
    masked: bool
    mesh: object
    mode: str
    sequence_backend: Optional[str]
    decode_backend: Optional[str]
    mask_exact: bool
    sequence: Callable = dataclasses.field(repr=False, default=None)
    prefill: Callable = dataclasses.field(repr=False, default=None)
    decode: Callable = dataclasses.field(repr=False, default=None)

    def describe(self) -> dict:
        return {"sequence_backend": self.sequence_backend,
                "decode_backend": self.decode_backend,
                "masked": self.masked, "mask_exact": self.mask_exact,
                "mesh": self.mesh is not None, "mode": self.mode,
                "batch": self.batch, "seq": self.seq}


def _hetero(cfg: GRUConfig) -> bool:
    dims = cfg.resolved_layer_dims
    return any(d != dims[0] for d in dims)


def _legal(spec: BackendSpec, *, op: str, masked: bool, hetero: bool,
           mesh, need_return_all: bool = False) -> bool:
    c = spec.caps
    if op == "decode":
        if not c.decode or spec.decode_fn is None:
            return False
    else:
        if not c.sequence or spec.sequence_fn is None:
            return False
        if masked and not c.supports_mask:
            return False
        if need_return_all and not c.return_all:
            return False
    if hetero and not c.supports_hetero_dims:
        return False
    if c.supports_mesh and mesh is None:
        return False                      # shard_map backends need a mesh
    return True


def _cost(spec: BackendSpec, cfg: GRUConfig, *, op: str, mesh) -> int:
    cost = spec.cost
    if spec.name.startswith("pallas") and jax.default_backend() not in (
            "cpu", "tpu"):
        # the Pallas kernels target TPU (pltpu VMEM scratch) and run
        # interpret-mode on CPU; on any other platform they cannot lower,
        # so "auto" must never pick them over the XLA scan.
        cost += 1_000_000
    if mesh is not None:
        # a mesh was explicitly provided: backends that actually use it win
        # sequence work outright; the rest run replicated (penalized evenly,
        # so relative single-host preference is preserved for decode).
        cost += -10_000 if spec.caps.supports_mesh else 100
    pref = getattr(cfg, "backend", "xla")
    if pref == "xla" and spec.name == "xla":
        cost -= 1_000
    elif pref == "pallas" and spec.name.startswith("pallas"):
        cost -= 1_000
    return cost


def _select(op: str, cfg: GRUConfig, *, masked: bool, mesh,
            need_return_all: bool = False) -> Optional[BackendSpec]:
    hetero = _hetero(cfg)
    legal = [s for s in _REGISTRY.values()
             if _legal(s, op=op, masked=masked, hetero=hetero, mesh=mesh,
                       need_return_all=need_return_all)]
    if not legal:
        return None
    return min(legal, key=lambda s: (_cost(s, cfg, op=op, mesh=mesh), s.name))


_PLAN_CACHE: Dict[tuple, ExecPlan] = {}


def plan(cfg: GRUConfig, *, batch: Optional[int] = None,
         seq: Optional[int] = None, mesh=None, mask: bool = False,
         mode: str = "serve") -> ExecPlan:
    """Resolve the fastest legal backend(s) for a GRU workload.

    ``mask`` declares whether calls will carry a (B, T) length mask (the
    array itself is a run-time argument). ``mode``: ``"prefill"`` /
    ``"sequence"`` require a sequence backend, ``"decode"`` a decode
    backend, ``"serve"`` both. Plans are memoized — the same key returns
    the SAME ExecPlan object, so its callables are stable across calls and
    jit caches keyed on them never retrace.
    """
    _ensure_backends()
    key = (cfg, batch, seq, mesh, bool(mask), mode)
    hit = _PLAN_CACHE.get(key)
    if hit is not None:
        return hit

    seq_spec = _select("sequence", cfg, masked=bool(mask), mesh=mesh)
    # a finals-only backend may win the primary selection; return_all=True
    # calls then fall through to the cheapest fully-capable backend instead
    # of failing inside the backend (the silent-capability-gap failure mode
    # this module exists to eliminate). Both specs are fixed at plan time,
    # so the callables stay jit-stable.
    seq_spec_ra = (seq_spec if seq_spec is not None
                   and seq_spec.caps.return_all
                   else _select("sequence", cfg, masked=bool(mask),
                                mesh=mesh, need_return_all=True))
    dec_spec = _select("decode", cfg, masked=False, mesh=mesh)
    if mode in ("prefill", "sequence", "serve") and seq_spec is None:
        raise NoCapableBackend(
            f"no sequence backend for cfg.backend={cfg.backend!r} "
            f"mask={mask} dims={cfg.resolved_layer_dims} mesh={mesh}")
    if mode in ("decode", "serve") and dec_spec is None:
        raise NoCapableBackend(
            f"no decode backend for cfg.backend={cfg.backend!r} "
            f"dims={cfg.resolved_layer_dims}")

    def run_sequence(params, h0s, xs, *, return_all=False, mask=None):
        if mask is not None and not key[4]:
            raise ValueError("plan was built with mask=False; re-plan with "
                             "mask=True to pass a length mask")
        spec = seq_spec if not return_all else seq_spec_ra
        if spec is None:
            raise NoCapableBackend(
                f"no return_all-capable sequence backend for "
                f"cfg.backend={cfg.backend!r} mask={mask is not None} "
                f"dims={cfg.resolved_layer_dims} mesh={mesh}")
        sp = prepare(params, cfg,
                     want_stacked=spec.name == "pallas_fused")
        return spec.sequence_fn(sp, tuple(h0s), xs, cfg=cfg,
                                return_all=return_all, mask=mask,
                                mesh=mesh)

    def run_prefill(params, h0s, xs, *, mask=None):
        return run_sequence(params, h0s, xs, mask=mask)[0]

    def run_decode(params, hs, x):
        sp = prepare(params, cfg,
                     want_stacked=dec_spec.name == "pallas_fused")
        return dec_spec.decode_fn(sp, tuple(hs), x, cfg=cfg)

    p = ExecPlan(
        cfg=cfg, batch=batch, seq=seq, masked=bool(mask), mesh=mesh,
        mode=mode,
        sequence_backend=seq_spec.name if seq_spec else None,
        decode_backend=dec_spec.name if dec_spec else None,
        mask_exact=seq_spec.caps.mask_exact if seq_spec else True,
        sequence=run_sequence, prefill=run_prefill,
        decode=run_decode if dec_spec else None)
    _PLAN_CACHE[key] = p
    return p


# ---------------------------------------------------------------------------
# plan-and-run conveniences (the legacy entry points shim onto these)
# ---------------------------------------------------------------------------

def sequence(params, h0s, xs, *, cfg: GRUConfig, return_all: bool = False,
             mask=None, mesh=None):
    """Run a depth-L stack over xs (B,T,X) with the planned backend.
    Returns (per-layer finals, last-layer states | None)."""
    p = plan(cfg, batch=xs.shape[0] if xs.ndim >= 3 else None,
             seq=xs.shape[-2], mesh=mesh, mask=mask is not None,
             mode="sequence")
    return p.sequence(params, h0s, xs, return_all=return_all, mask=mask)


def decode(params, hs, x, *, cfg: GRUConfig, mesh=None):
    """One serve step through the stack with the planned backend.
    Returns the per-layer new hidden states."""
    p = plan(cfg, batch=x.shape[0], mesh=mesh, mode="decode")
    return p.decode(params, hs, x)
