"""One recurrent-stack executor: a two-stage compile/execute API over
capability-dispatched backends, keyed by ``(cell family, backend)``.

The paper's core idea is a single workload-distribution framework that maps
GRU matvecs onto whichever compute fabric is available (AIE rows vs. the PL
cascade) — and, crucially, that weights are placed on the fabric ONCE and
every subsequent inference runs against resident rows. This module is that
framework's TPU translation, split the same way the hardware flow is:

* ``compile(cfg, batch=..., seq=..., placement=...) -> GRUExecutable`` —
  the ahead-of-time step. Resolves WHERE the stack runs (a ``Placement``:
  host, or a mesh + sharding rule) and WHICH backend serves each op, from
  a cost model that prefers *measured* per-shape latency over the static
  preference table. Executables are memoized: the same key returns the
  SAME object, so its callables are jit-stable.
* ``prepare(params, cfg, placement) -> StackParams`` — the weight-placement
  step. All device placement happens HERE, once: for a mesh placement the
  sharded backends' gate-major reshapes and ``device_put``s run up front
  (``StackParams.placed``), and the fused kernels' stacked weight views are
  built once (``StackParams.stacked``) — a traced execute call touches no
  weight-placement ops at all.
* ``executable.sequence/prefill/decode(...)`` — the execute stage: pure
  compute against placement-resident params.

CELL FAMILIES: the executor is not GRU-specific. ``cfg.family`` names a
registered :class:`repro.core.cells.CellFamily` (default ``"gru"``), and
every lookup here — the backend registry, ``compile()``'s selection,
``prepare()``'s weight views, the CostModel's measured rows — is keyed by
``(family, backend)``. Backends register under their family
(``BackendSpec.family``, default ``"gru"`` so the original registrations
are unchanged); an unknown ``cfg.family`` raises the typed
:class:`repro.core.cells.UnknownCellFamily` from ``compile()``. The
second in-tree family is sLSTM (``repro.core.slstm`` +
``repro.kernels.slstm_cell``): ``(slstm, xla)`` scan fallback at static
cost 30 and the fused ``(slstm, pallas_fused)`` kernels at cost 10, both
mask-exact, no mesh backends (a provided mesh falls through to the
replicated backends). A family's runtime state is a FLAT tuple of
per-layer leaves (GRU: one ``h`` per layer; sLSTM: ``c, n, m, h`` per
layer) — the ``h0s``/``hs`` arguments below are that tuple.

Capability table for ``family="gru"`` (see ``BackendSpec``; ``cost`` is
the STATIC dispatch fallback, lower = faster; a loaded :class:`CostModel`
replaces these numbers with measured per-(depth, batch, H) latency
whenever every legal candidate is covered):

===============  ====  ======  ====  ==========  ======  ========  ========
backend          mask  hetero  mesh  return_all  decode  sequence  cost
===============  ====  ======  ====  ==========  ======  ========  ========
pallas_fused     yes   no      no    yes         yes     yes       10
pallas_chain     yes   yes     no    yes         yes     yes       20
xla              yes   yes     no    yes         yes     yes       30
sharded          yes   yes     REQ   yes         no      yes       5
pallas_sharded   yes   yes     REQ   yes         yes     yes       4 / 190*
sharded_decode   n/a   yes     REQ   n/a         yes     no        200
pallas_fused_q8  yes   no      no    yes         yes     yes       150 (+)
pallas_chain_q8  yes   yes     no    yes         yes     yes       160 (+)
===============  ====  ======  ====  ==========  ======  ========  ========

(+) the ``*_q8`` backends are the int8 datapath (int8 weight rows, int32
accumulation, dequant folded into the bias add — see
``repro.kernels.gru_sequence.kernel``). They are DOUBLY gated: a backend
ending in ``_q8`` is a dispatch candidate only when ``cfg.quant ==
"int8"`` AND the recorded accuracy-harness artifact
(``repro/quant/accuracy.py`` -> ``BENCH_quant_accuracy.json``, installed
like the cost model via :func:`load_quant_accuracy` /
``$REPRO_GRU_QUANT_ACC``) reports ``passed`` — an uncalibrated or failing
artifact means q8 is never auto-selected. An EXACT backend-name pin
(``cfg.backend == "pallas_fused_q8"``) bypasses both gates (explicit
opt-in, e.g. the parity tests and the calibration benchmark itself). On
top of that their static costs sit above ``UNCALIBRATED_GATE_COST``:
measured-only backends, picked by ``auto`` only where a calibration shows
them faster per shape.

(*) ``pallas_sharded`` carries a per-op static cost (``cost`` for
sequence work, ``decode_cost`` for decode): under a mesh it is the
statically PREFERRED sequence backend (the fused shard kernels beat the
XLA scan between the same collectives), while its decode — like
``sharded_decode`` — stays statically dispreferred behind the replicated
single-host backends until a calibration measures it faster per shape.

* ``mask``: a (B, T) length mask streams through the backend (bucketed
  left-padded prefill stays bitwise-identical to unpadded — every sequence
  backend here claims ``mask_exact``). Decode steps carry no time axis, so
  the column does not apply to ``sharded_decode``.
* ``hetero``: heterogeneous ``cfg.layer_dims`` (the fused kernel needs one
  uniform VMEM block shape; the chain runs one kernel per layer instead of
  raising or silently degrading).
* ``mesh`` = REQ: the backend *requires* a mesh. Providing a mesh is an
  explicit request to use it for SEQUENCE work (shard_map backends win
  outright). Decode is latency-bound: by static cost it stays on a
  replicated single-host backend (per-step collectives usually dominate),
  but ``sharded_decode`` (one persistent shard_map step over pre-sharded
  weights) is a full candidate — a calibration file that measures it
  faster flips the choice per shape.

Dispatch: ``cfg.backend`` is a preference — ``"xla"`` (default) and
``"pallas"`` pin their family when legal, an exact backend name (e.g.
``"pallas_chain"``, ``"sharded_decode"``) pins that one backend, and
``"auto"`` picks purely by cost: measured (CostModel) when available for
every legal candidate, else the static table. An illegal preference falls
through to the cheapest legal backend — never an error as long as ANY
backend can serve the call.

Cost calibration: ``benchmarks/decode_latency.py --emit-costs`` writes
``BENCH_backend_costs.json``; :func:`load_cost_model` /
:func:`set_cost_model` install it (or it is picked up automatically from
``$REPRO_GRU_COSTS`` / ``./BENCH_backend_costs.json``). A missing or
corrupt file degrades to the static table — selection is then identical
to the pre-CostModel executor.

Legacy surface: ``plan()`` (one-shot resolve) and the ``ExecPlan`` name
are deprecated shims over ``compile()``/``GRUExecutable`` — same memoized
objects, bitwise-equal results, one DeprecationWarning per process.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.configs.base import GRUConfig
from repro.core import cells as cell_families
from repro.core import gru as gru_core
from repro.core.cells import UnknownCellFamily  # noqa: F401 (re-export)
from repro.core.params import QuantStackParams, quantize_gru_cells


# ---------------------------------------------------------------------------
# placement: WHERE a stack runs (resolved at compile/prepare time)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Placement:
    """Where weights live and execution happens.

    ``mesh=None`` is the host placement (single-device, replicated).
    With a mesh, ``axis`` names the mesh axis the sharded backends
    partition over (U output rows for rowwise layers, the contraction dim
    for cascade layers — the rule itself is per-layer via
    ``cfg.layer_matvec_modes``). Hashable: it is part of the executable
    cache key, so distinct meshes compile distinct executables.
    """
    mesh: object = None
    axis: str = "model"

    @property
    def is_host(self) -> bool:
        return self.mesh is None


HOST = Placement()


def _as_placement(p) -> Placement:
    """Normalize None | Mesh | Placement -> Placement."""
    if p is None:
        return HOST
    if isinstance(p, Placement):
        return p
    return Placement(mesh=p)


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a backend can legally execute (checked by ``compile()``)."""
    supports_mask: bool = False      # (B,T) length mask streams through
    supports_hetero_dims: bool = False   # per-layer hidden sizes may differ
    supports_mesh: bool = False      # True = REQUIRES a mesh (shard_map)
    return_all: bool = False         # can emit the last layer's full sequence
    decode: bool = False             # single-step serve path
    sequence: bool = True            # whole-sequence / prefill path
    mask_exact: bool = True          # masked+padded == unpadded, bitwise


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered execution strategy.

    ``sequence_fn(sp, h0s, xs, *, cfg, return_all, mask, placement)``
    returns ``(flat per-layer finals tuple, last-layer states | None)``;
    ``decode_fn(sp, hs, x, *, cfg, placement)`` returns the flat new
    state tuple (see the family's state layout in ``repro.core.cells``).
    ``family`` names the :class:`repro.core.cells.CellFamily` this backend
    serves — the registry key is ``(family, name)``, so each family owns
    its own ``xla``/``pallas_fused``/... namespace. ``cost`` is the STATIC
    relative dispatch hint (lower = preferred), used whenever no measured
    cost covers the call; ``decode_cost`` optionally overrides it for
    decode selection (a backend may be the cheapest way to run a sequence
    yet the wrong default for a single latency-bound step —
    ``pallas_sharded``).
    """
    name: str
    caps: Capabilities
    cost: int
    sequence_fn: Optional[Callable] = None
    decode_fn: Optional[Callable] = None
    decode_cost: Optional[int] = None
    family: str = "gru"

    def static_cost(self, op: str) -> int:
        if op == "decode" and self.decode_cost is not None:
            return self.decode_cost
        return self.cost


_REGISTRY: Dict[Tuple[str, str], BackendSpec] = {}


def register_backend(spec: BackendSpec) -> None:
    _REGISTRY[(spec.family, spec.name)] = spec


def backends(family: str = "gru") -> Dict[str, BackendSpec]:
    """Snapshot of one family's registry (name -> spec), for
    introspection/tests. Defaults to the GRU family (the pre-registry
    call sites all meant that)."""
    _ensure_backends()
    return {name: spec for (fam, name), spec in _REGISTRY.items()
            if fam == family}


def _ensure_backends() -> None:
    """Make sure every family's kernels package had a chance to register
    its backends (they do so on import; compile() imports them on first
    use otherwise, so dispatch never depends on import order)."""
    if ("gru", "pallas_fused") not in _REGISTRY:
        from repro.kernels.gru_sequence import ops as seq_ops
        seq_ops.register_runtime_backends()
    if ("slstm", "xla") not in _REGISTRY:
        from repro.core import slstm as slstm_core
        slstm_core.register_runtime_backends()
    if ("slstm", "pallas_fused") not in _REGISTRY:
        from repro.kernels.slstm_cell import ops as slstm_ops
        slstm_ops.register_runtime_backends()


# ---------------------------------------------------------------------------
# canonical params: StackParams + prepare()
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StackParams:
    """Canonical recurrent-stack parameters: the ONE layout every backend
    takes (any cell family — the gate width of ``w``/``u``/``b`` is the
    family's business).

    ``cells``: per-layer ``{"w","u","b"}`` dicts, layer 0 first.
    ``stacked``: the fused kernels' precomputed device-side weight stacks
    (``{"u","w_deep","b"}``) — present for uniform hidden sizes, ``None``
    for heterogeneous stacks (the fused backend doesn't apply there).
    ``placed``: the sharded backends' per-layer gate-major weight views,
    ``device_put`` onto ``placement.mesh`` up front — present only for a
    mesh placement. ``quant``: the q8 backends' int8 weight views
    (:class:`repro.core.params.QuantStackParams`) — present when the
    config requests quantization (``cfg.quant`` / a ``*_q8`` backend pin);
    scale computation and int8 casting happen HERE, never in a traced
    execute call. ``placement`` (aux data) records where ``placed``
    lives, so a matching ``prepare()`` is a free passthrough.
    """
    cells: tuple
    stacked: Optional[dict] = None
    placed: Optional[tuple] = None
    quant: Optional[QuantStackParams] = None
    placement: Placement = HOST

    def tree_flatten(self):
        return (self.cells, self.stacked, self.placed, self.quant), \
            (self.placement,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, placement=aux[0])

    @property
    def dims(self) -> Tuple[int, ...]:
        return tuple(c["u"].shape[0] for c in self.cells)


def _cfg_wants_quant(cfg) -> bool:
    """Whether this config's execution may route through a q8 backend
    (quant flag or an exact ``*_q8`` pin) — if so, prepare() builds the
    int8 views up front so no traced call quantizes weights."""
    return (getattr(cfg, "quant", "") == "int8"
            or str(getattr(cfg, "backend", "")).endswith("_q8"))


def prepare(params, cfg: GRUConfig, placement=None, *,
            want_stacked: bool = True,
            want_quant: Optional[bool] = None) -> StackParams:
    """One-time normalization of ANY accepted param layout to a
    placement-resident StackParams.

    Subsumes ``stack_cell_params`` (layout normalization),
    ``prepare_stacked_cells`` (fused-kernel weight stacking) and the model
    API's ``prepare_params`` (serving prep). Accepts ``StackParams``
    (passthrough; upgraded in place-of if the placement changed),
    ``{"cells": ...}``, ``{"cell": ...}``, a bare ``{w,u,b}`` cell, a
    per-layer sequence, and dicts already carrying a precomputed
    ``"stacked_cells"`` entry (reused, not recomputed). Do this ONCE
    outside the per-step jit so decode traces never restack weights.

    ``placement`` (a :class:`Placement`, a raw mesh, or None = host):
    with a mesh, ALL device placement happens here — the sharded backends'
    per-layer gate-major reshapes and ``device_put``s run now, so a traced
    execute call contains no weight placement (asserted by the test
    suite via jaxpr inspection). ``want_stacked=False`` skips the fused
    kernels' weight stacks (an executable whose resolved backends never
    read them passes it). ``want_quant`` (default: derived from
    ``cfg.quant`` / a ``*_q8`` backend pin) additionally builds the q8
    backends' int8 weight views — scale computation, rounding, and int8
    casting are placement-stage costs exactly like the reshapes, so a
    traced execute call contains no quantize ops either (jaxpr-asserted).

    Family-aware: ``cfg.family`` picks the :class:`~repro.core.cells.
    CellFamily` whose ``normalize``/``stacked_views`` hooks build the
    views, and the quant/sharded views are built only for families that
    support them (``supports_quant`` / ``supports_placement`` — GRU
    today). For ``family="gru"`` every view is built by exactly the same
    code as before the registry, so prepared params are bitwise-equal.
    """
    pl_ = _as_placement(placement)
    family = cell_families.get_family(cell_families.cfg_family(cfg))
    if not family.supports_placement:
        pl_ = HOST                       # no sharded views for this family
    if want_quant is None:
        want_quant = _cfg_wants_quant(cfg)
    want_quant = want_quant and family.supports_quant
    if isinstance(params, StackParams):
        quant = params.quant
        if want_quant and quant is None:
            quant = quantize_gru_cells(params.cells)
        if pl_.is_host or params.placement == pl_:
            if quant is params.quant:
                return params
            return StackParams(cells=params.cells, stacked=params.stacked,
                               placed=params.placed, quant=quant,
                               placement=params.placement)
        placed = _place_layers(params.cells, cfg, pl_)
        return StackParams(cells=params.cells, stacked=params.stacked,
                           placed=placed, quant=quant, placement=pl_)
    stacked = params.get("stacked_cells") if isinstance(params, dict) else None
    placed = params.get("placed_cells") if isinstance(params, dict) else None
    quant = params.get("quant_cells") if isinstance(params, dict) else None
    cells = family.normalize(params, cfg)
    dims = tuple(c["u"].shape[0] for c in cells)
    if (want_stacked and stacked is None and family.stacked_views is not None
            and all(d == dims[0] for d in dims)):
        stacked = family.stacked_views(cells)
    if want_quant and quant is None:
        quant = quantize_gru_cells(cells)
    if pl_.is_host:
        placed = None
    else:
        if placed is not None and not _placed_on(placed, pl_):
            placed = None                # stale views from another mesh
        if placed is None:
            # no pre-placed views for THIS mesh: place now (traced callers
            # pay this per call — the cost the compile/execute split moves
            # into prepare())
            placed = _place_layers(cells, cfg, pl_)
    return StackParams(cells=cells, stacked=stacked, placed=placed,
                       quant=quant, placement=HOST if pl_.is_host else pl_)


def _place_layers(cells, cfg: GRUConfig, pl_: Placement) -> tuple:
    from repro.core import rowparallel
    return rowparallel.prepare_sharded_layers(cells, cfg, mesh=pl_.mesh,
                                              axis=pl_.axis)


def _placed_on(placed, pl_: Placement) -> bool:
    """Best-effort check that pre-placed views actually live on this
    placement's mesh, so a dict prepared for mesh A is not fed into a
    shard_map over mesh B (which would silently re-transfer the weights
    inside the traced call). Concrete arrays expose their committed
    NamedSharding; tracers (an already-traced hot path) are trusted."""
    try:
        arr = next(iter(placed[0].values()))
        sh = arr.sharding
    except Exception:  # noqa: BLE001 - tracer or exotic layout: trust it
        return True
    from jax.sharding import NamedSharding
    if isinstance(sh, NamedSharding):
        return sh.mesh == pl_.mesh
    return True


# ---------------------------------------------------------------------------
# measured cost model (static table fallback)
# ---------------------------------------------------------------------------

class CostModel:
    """Measured per-backend latency, keyed (family, backend, op, depth,
    hidden) with linear interpolation over batch.

    Loaded from the ``BENCH_backend_costs.json`` artifact that
    ``benchmarks/decode_latency.py --emit-costs`` writes. Entries without
    a ``"family"`` column default to ``"gru"``, so pre-registry
    calibration artifacts keep loading and pricing exactly the same rows.
    Lookups outside the measured batch range clamp to the nearest measured
    batch (the relative backend order at the edge is the best available
    signal). ``lookup`` returns None for any bucket with no measurements;
    selection only trusts the model when EVERY legal candidate is covered
    (µs and static preference ints are not comparable units).
    """

    def __init__(self, table: Dict[tuple, List[tuple]], source: str = "",
                 error: Optional[str] = None):
        # accept legacy 4-tuple keys (backend, op, depth, hidden) — they
        # belong to the GRU family, same as artifact rows without a
        # "family" column
        self._table = {(k if len(k) == 5 else ("gru", *k)): v
                       for k, v in table.items()}
        self.source = source
        self.error = error

    def __len__(self) -> int:
        return sum(len(v) for v in self._table.values())

    @classmethod
    def from_entries(cls, entries, source: str = "") -> "CostModel":
        table: Dict[tuple, List[tuple]] = {}
        for e in entries:
            key = (str(e.get("family", "gru")), str(e["backend"]),
                   str(e.get("op", "decode")),
                   int(e["depth"]), int(e["hidden_dim"]))
            table.setdefault(key, []).append(
                (int(e["batch"]), float(e["p50_us"])))
        for v in table.values():
            v.sort()
        return cls(table, source=source)

    @classmethod
    def load(cls, path) -> "CostModel":
        """Tolerant load: a missing, unreadable, or schema-mismatched file
        yields an EMPTY model (every lookup misses -> static fallback)."""
        try:
            with open(path) as f:
                data = json.load(f)
            if data.get("bench") != "gru_backend_costs":
                raise ValueError("not a gru_backend_costs artifact")
            return cls.from_entries(data["entries"], source=str(path))
        except Exception as e:  # noqa: BLE001 - degrade, never break dispatch
            return cls({}, source=str(path),
                       error=f"{type(e).__name__}: {e}")

    def merged(self, entries, source: str = "") -> "CostModel":
        """A NEW model: this model's table with ``entries`` folded in.

        The online-recalibration API (``repro.serve.autotune``): served
        per-step timings come back as calibration rows and REPLACE any
        existing measured point at the same (family, backend, op, depth,
        hidden, batch) — fresher measurements win; batches never measured
        before extend the curve. Malformed rows and non-finite or
        non-positive latencies are skipped (a ManualClock serving run
        measures dt == 0, which must never poison the table with
        "free" backends).

        Pure: ``self`` is untouched. Install the result via
        :func:`set_cost_model`, which bumps the cost epoch and evicts the
        executable cache — the epoch is part of every cache key, so plans
        priced under the old table are unreachable afterwards (see
        docs/runtime.md, "Recalibration and cost epochs").
        """
        table = {k: list(v) for k, v in self._table.items()}
        for e in entries:
            try:
                key = (str(e.get("family", "gru")), str(e["backend"]),
                       str(e.get("op", "decode")),
                       int(e["depth"]), int(e["hidden_dim"]))
                batch = int(e["batch"])
                us = float(e["p50_us"])
            except (KeyError, TypeError, ValueError):
                continue
            if batch < 1 or not math.isfinite(us) or us <= 0.0:
                continue
            pts = table.setdefault(key, [])
            pts[:] = [(b, c) for (b, c) in pts if b != batch]
            pts.append((batch, us))
            pts.sort()
        return CostModel(table,
                         source=source or (f"{self.source}+online"
                                           if self.source else "<online>"))

    def batch_points(self, backend: str, op: str = "decode", *, depth: int,
                     hidden: int, family: str = "gru") -> List[tuple]:
        """The raw measured ``(batch, p50_us)`` points of one curve,
        sorted by batch. This is the autotuner's view of the
        batch-latency curve: :meth:`lookup` clamps and interpolates,
        which would fabricate a flat marginal cost outside the measured
        range — wave-size selection needs to know where the measurements
        actually end."""
        return list(self._table.get((str(family), str(backend), str(op),
                                     int(depth), int(hidden)), ()))

    def lookup(self, backend: str, op: str, *, depth: int, batch: int,
               hidden: int, family: str = "gru") -> Optional[float]:
        pts = self._table.get((str(family), backend, op, int(depth),
                               int(hidden)))
        if not pts:
            return None
        if batch <= pts[0][0]:
            return pts[0][1]
        if batch >= pts[-1][0]:
            return pts[-1][1]
        for (b0, c0), (b1, c1) in zip(pts, pts[1:]):
            if b0 <= batch <= b1:
                return c0 + (batch - b0) / (b1 - b0) * (c1 - c0)
        return None  # pragma: no cover - unreachable on a sorted table


_COST_MODEL: Optional[CostModel] = None
_COST_MODEL_LOADED = False
_COST_EPOCH = 0  # part of the executable cache key: new model, new plans


def set_cost_model(model: Optional[CostModel]) -> None:
    """Install a calibration model (None re-arms the lazy default load).
    Bumps the cost epoch, so already-memoized executables are not reused
    with stale costs — and evicts them: keys from older epochs can never
    be returned again, so keeping them would only leak in a long-lived
    server that periodically reloads calibration."""
    global _COST_MODEL, _COST_MODEL_LOADED, _COST_EPOCH
    _COST_MODEL = model
    _COST_MODEL_LOADED = model is not None
    _COST_EPOCH += 1
    _EXEC_CACHE.clear()


def load_cost_model(path) -> CostModel:
    """Load ``path`` (tolerantly) and install it. Returns the model."""
    model = CostModel.load(path)
    set_cost_model(model)
    return model


def cost_epoch() -> int:
    """The current cost/gate epoch. Part of every executable cache key:
    :func:`set_cost_model` and :func:`set_quant_accuracy` bump it (and
    evict the cache), so executables priced under an older table or gate
    state are unreachable afterwards. Observability for the online
    recalibration loop (``repro.serve.autotune``) and its tests."""
    return _COST_EPOCH


def cost_model() -> CostModel:
    """The active calibration model. On first use, loads
    ``$REPRO_GRU_COSTS`` (default ``./BENCH_backend_costs.json``) if
    present; otherwise an empty model (pure static dispatch)."""
    global _COST_MODEL, _COST_MODEL_LOADED
    if not _COST_MODEL_LOADED:
        path = os.environ.get("REPRO_GRU_COSTS", "BENCH_backend_costs.json")
        _COST_MODEL = (CostModel.load(path) if os.path.exists(path)
                       else CostModel({}, source=path))
        _COST_MODEL_LOADED = True
    return _COST_MODEL


# ---------------------------------------------------------------------------
# quant accuracy gate (the q8 backends' dispatch-eligibility record)
# ---------------------------------------------------------------------------

class QuantAccuracy:
    """The recorded result of the q8 accuracy harness
    (``python -m repro.quant.accuracy`` -> ``BENCH_quant_accuracy.json``):
    max/mean logit error vs the f32 oracle and classification parity on
    the jet-tagging eval set. Gates q8 auto-dispatch: only a loaded,
    error-free artifact with ``passed: true`` opens the gate — a missing,
    corrupt, or failing artifact means ``auto`` never selects a ``*_q8``
    backend (exact-name pins still work: explicit opt-in)."""

    def __init__(self, data: Optional[dict] = None, source: str = "",
                 error: Optional[str] = None):
        self.data = dict(data or {})
        self.source = source
        self.error = error

    @property
    def passed(self) -> bool:
        return self.error is None and bool(self.data.get("passed"))

    @classmethod
    def load(cls, path) -> "QuantAccuracy":
        """Tolerant load: a missing, unreadable, or schema-mismatched file
        yields a CLOSED gate (q8 stays pin-only), never an exception."""
        try:
            with open(path) as f:
                data = json.load(f)
            if data.get("bench") != "gru_quant_accuracy":
                raise ValueError("not a gru_quant_accuracy artifact")
            return cls(data, source=str(path))
        except Exception as e:  # noqa: BLE001 - degrade, never break dispatch
            return cls({}, source=str(path), error=f"{type(e).__name__}: {e}")


_QUANT_ACC: Optional[QuantAccuracy] = None
_QUANT_ACC_LOADED = False


def set_quant_accuracy(report: Optional[QuantAccuracy]) -> None:
    """Install an accuracy report (None re-arms the lazy default load).
    Bumps the cost epoch like :func:`set_cost_model`: gate flips change
    which backends are legal, so memoized executables must not outlive
    them."""
    global _QUANT_ACC, _QUANT_ACC_LOADED, _COST_EPOCH
    _QUANT_ACC = report
    _QUANT_ACC_LOADED = report is not None
    _COST_EPOCH += 1
    _EXEC_CACHE.clear()


def load_quant_accuracy(path) -> QuantAccuracy:
    """Load ``path`` (tolerantly) and install it. Returns the report."""
    report = QuantAccuracy.load(path)
    set_quant_accuracy(report)
    return report


def quant_accuracy() -> QuantAccuracy:
    """The active accuracy report. On first use, loads
    ``$REPRO_GRU_QUANT_ACC`` (default ``./BENCH_quant_accuracy.json``) if
    present; otherwise a closed gate."""
    global _QUANT_ACC, _QUANT_ACC_LOADED
    if not _QUANT_ACC_LOADED:
        path = os.environ.get("REPRO_GRU_QUANT_ACC",
                              "BENCH_quant_accuracy.json")
        _QUANT_ACC = (QuantAccuracy.load(path) if os.path.exists(path)
                      else QuantAccuracy({}, source=path,
                                         error="missing artifact"))
        _QUANT_ACC_LOADED = True
    return _QUANT_ACC


def quant_gate_open() -> bool:
    """True when the recorded accuracy artifact admits q8 auto-dispatch."""
    return quant_accuracy().passed


def backend_dtype(name: Optional[str]) -> str:
    """The numeric format a backend's recurrent matvecs run in — what a
    server reports as its served dtype."""
    return "int8" if name and name.endswith("_q8") else "float32"


# ---------------------------------------------------------------------------
# built-in backends: xla scan + sharded shard_map programs
# ---------------------------------------------------------------------------

def _xla_sequence(sp, h0s, xs, *, cfg, return_all, mask, placement):
    return gru_core.gru_stack_sequence_xla(sp.cells, h0s, xs, cfg=cfg,
                                           return_all=return_all, mask=mask)


def _xla_decode(sp, hs, x, *, cfg, placement):
    return gru_core.gru_stack_decode_xla(sp.cells, hs, x, cfg=cfg)


def _sharded_sequence(sp, h0s, xs, *, cfg, return_all, mask, placement,
                      step_impl: str = "xla"):
    """The shard_map sequence program; ``step_impl="pallas"`` is the
    `pallas_sharded` backend — same placed weight views, same collectives,
    per-shard step bodies swapped for the Pallas shard kernels
    (bitwise-equal to `sharded` at identical shard shapes)."""
    from repro.core import rowparallel
    sp = prepare(sp, cfg, placement, want_stacked=False)
    out = rowparallel.gru_stack_sequence_sharded_prepared(
        sp.placed, h0s, xs, mesh=placement.mesh, cfg=cfg,
        axis=placement.axis, return_all=return_all, mask=mask,
        step_impl=step_impl)
    if return_all:
        return out
    return out, None


def _sharded_decode(sp, hs, x, *, cfg, placement, step_impl: str = "xla"):
    from repro.core import rowparallel
    sp = prepare(sp, cfg, placement, want_stacked=False)
    return rowparallel.gru_stack_decode_sharded_prepared(
        sp.placed, hs, x, mesh=placement.mesh, cfg=cfg, axis=placement.axis,
        step_impl=step_impl)


_pallas_sharded_sequence = functools.partial(_sharded_sequence,
                                             step_impl="pallas")
_pallas_sharded_decode = functools.partial(_sharded_decode,
                                           step_impl="pallas")


register_backend(BackendSpec(
    name="xla",
    caps=Capabilities(supports_mask=True, supports_hetero_dims=True,
                      supports_mesh=False, return_all=True, decode=True,
                      sequence=True),
    cost=30,
    sequence_fn=_xla_sequence, decode_fn=_xla_decode))

register_backend(BackendSpec(
    name="sharded",
    caps=Capabilities(supports_mask=True, supports_hetero_dims=True,
                      supports_mesh=True, return_all=True, decode=False,
                      sequence=True),
    cost=5,
    sequence_fn=_sharded_sequence, decode_fn=None))

register_backend(BackendSpec(
    name="pallas_sharded",
    caps=Capabilities(supports_mask=True, supports_hetero_dims=True,
                      supports_mesh=True, return_all=True, decode=True,
                      sequence=True),
    # statically the PREFERRED mesh sequence backend (cost 4 < sharded's
    # 5): between the same collectives, the per-shard compute runs as
    # fused whole-block kernels instead of an XLA op soup. Its decode is
    # per-op dispreferred (decode_cost) for the same reason sharded_decode
    # is: one recurrent step is latency-bound and its collectives usually
    # dominate, so replicated decode wins unless a calibration measures
    # the kernel-in-shard_map step faster at this shape.
    cost=4, decode_cost=190,
    sequence_fn=_pallas_sharded_sequence, decode_fn=_pallas_sharded_decode))

register_backend(BackendSpec(
    name="sharded_decode",
    caps=Capabilities(supports_mask=False, supports_hetero_dims=True,
                      supports_mesh=True, return_all=False, decode=True,
                      sequence=False),
    # statically DISpreferred: one recurrent step is latency-bound and its
    # per-step collectives usually dominate — replicated decode wins unless
    # a calibration file MEASURES the sharded step faster at this shape.
    cost=200,
    sequence_fn=None, decode_fn=_sharded_decode))


# ---------------------------------------------------------------------------
# compile(): capability filtering + (measured | static) cost choice
# ---------------------------------------------------------------------------

class NoCapableBackend(ValueError):
    """No registered backend can legally serve the requested call."""


@dataclasses.dataclass(frozen=True, eq=False)
class GRUExecutable:
    """A compiled GRU workload: resolved placement + backends + jit-stable
    callables.

    ``sequence(params, h0s, xs, *, return_all=False, mask=None)`` returns
    ``(per-layer finals, last-layer states | None)``; ``prefill`` is the
    finals-only view of the same backend; ``decode(params, hs, x)`` returns
    the per-layer new states. ``params`` may be any layout ``prepare``
    accepts — pass ``executable.prepare(params)`` output on hot paths so
    the traced calls are pure compute against placement-resident weights.
    ``cost_source`` records whether backend choice came from measured
    calibration (``"measured"``) or the static table (``"static"``).
    """
    cfg: GRUConfig
    batch: Optional[int]
    seq: Optional[int]
    masked: bool
    placement: Placement
    mode: str
    sequence_backend: Optional[str]
    decode_backend: Optional[str]
    mask_exact: bool
    cost_source: str = "static"
    sequence: Callable = dataclasses.field(repr=False, default=None)
    prefill: Callable = dataclasses.field(repr=False, default=None)
    decode: Callable = dataclasses.field(repr=False, default=None)

    @property
    def mesh(self):
        return self.placement.mesh

    def prepare(self, params) -> StackParams:
        """Placement-resident params for THIS executable: device placement
        and weight stacking happen now, never inside the traced calls."""
        fam = cell_families.cfg_family(self.cfg)
        names = {self.sequence_backend, self.decode_backend}
        needs_mesh = any(s is not None and s.caps.supports_mesh
                         for s in (_REGISTRY.get((fam, n))
                                   for n in names if n))
        return prepare(params, self.cfg,
                       self.placement if needs_mesh else None,
                       want_stacked="pallas_fused" in names,
                       want_quant=any(n and n.endswith("_q8")
                                      for n in names))

    def describe(self) -> dict:
        return {"sequence_backend": self.sequence_backend,
                "decode_backend": self.decode_backend,
                "masked": self.masked, "mask_exact": self.mask_exact,
                "mesh": self.placement.mesh is not None,
                "axis": self.placement.axis, "mode": self.mode,
                "batch": self.batch, "seq": self.seq,
                "cost_source": self.cost_source}


def _hetero(cfg: GRUConfig) -> bool:
    dims = cfg.resolved_layer_dims
    return any(d != dims[0] for d in dims)


def _legal(spec: BackendSpec, *, op: str, masked: bool, hetero: bool,
           mesh, need_return_all: bool = False,
           cfg: Optional[GRUConfig] = None) -> bool:
    c = spec.caps
    if spec.name.endswith("_q8"):
        # the q8 datapath changes numerics: candidate only when the config
        # asked for it AND the accuracy artifact passed — or under an
        # exact-name pin (explicit opt-in bypasses both gates).
        if getattr(cfg, "backend", None) != spec.name:
            if getattr(cfg, "quant", "") != "int8" or not quant_gate_open():
                return False
    if op == "decode":
        if not c.decode or spec.decode_fn is None:
            return False
    else:
        if not c.sequence or spec.sequence_fn is None:
            return False
        if masked and not c.supports_mask:
            return False
        if need_return_all and not c.return_all:
            return False
    if hetero and not c.supports_hetero_dims:
        return False
    if c.supports_mesh and mesh is None:
        return False                      # shard_map backends need a mesh
    return True


# Static costs at or above this line mark a backend "measured-only": it is
# DEFINED to lose dispatch unless a calibration measures it faster, so a
# cost model that does not cover it (e.g. a q8 calibration that only ran
# the decode op) does not force the whole selection back to the static
# table. Candidates below the line keep PR 5's all-or-nothing contract —
# measured µs and static preference ints are not comparable units.
UNCALIBRATED_GATE_COST = 100


def _measured_costs(legal, cfg: GRUConfig, *, op: str,
                    batch: Optional[int]) -> Optional[Dict[str, float]]:
    """Measured µs per candidate, or None when the model cannot cover the
    call (unknown batch, heterogeneous dims, or an uncovered candidate —
    except measured-only candidates (static cost >=
    :data:`UNCALIBRATED_GATE_COST`), which are tolerated as uncovered and
    simply lose: per-op calibrations, like a q8 decode-only run, must not
    degrade every OTHER backend's measured dispatch to static)."""
    if batch is None or _hetero(cfg):
        return None
    model = cost_model()
    if not len(model):
        return None
    dims = cfg.resolved_layer_dims
    fam = cell_families.cfg_family(cfg)
    out = {}
    covered = 0
    for s in legal:
        us = model.lookup(s.name, op, depth=len(dims), batch=batch,
                          hidden=dims[0], family=fam)
        if us is None:
            if s.static_cost(op) >= UNCALIBRATED_GATE_COST:
                out[s.name] = float("inf")   # measured-only, unmeasured here
                continue
            return None
        covered += 1
        out[s.name] = us
    if not covered:
        return None                          # nothing actually measured
    return out


def _rank(spec: BackendSpec, cfg: GRUConfig, *, op: str, mesh,
          measured: Optional[float]) -> tuple:
    """Selection key, lexicographic: platform legality > mesh request
    (sequence ops: a provided mesh is an explicit ask for shard_map) >
    ``cfg.backend`` preference (family or exact name) > cost (measured µs
    when available, else the static table) > name (determinism)."""
    plat = 0
    if spec.name.startswith("pallas") and jax.default_backend() not in (
            "cpu", "tpu"):
        # the Pallas kernels target TPU (pltpu VMEM scratch) and run
        # interpret-mode on CPU; on any other platform they cannot lower,
        # so dispatch must never pick them over the XLA scan.
        plat = 1
    mesh_rank = 0
    if mesh is not None and op != "decode":
        mesh_rank = 0 if spec.caps.supports_mesh else 1
    pref = getattr(cfg, "backend", "xla")
    fam = 1
    if pref == spec.name:
        fam = 0                          # exact backend-name pin
    elif pref == "xla" and spec.name == "xla":
        fam = 0
    elif pref == "pallas" and spec.name.startswith("pallas"):
        fam = 0
    cost = float(spec.static_cost(op)) if measured is None else measured
    return (plat, mesh_rank, fam, cost, spec.name)


def _select(op: str, cfg: GRUConfig, *, masked: bool, placement: Placement,
            batch: Optional[int] = None,
            need_return_all: bool = False):
    """-> (winning spec | None, "measured" | "static"). Candidates are
    the requested family's backends only — families never cross."""
    hetero = _hetero(cfg)
    mesh = placement.mesh
    fam = cell_families.cfg_family(cfg)
    legal = [s for s in _REGISTRY.values()
             if s.family == fam
             and _legal(s, op=op, masked=masked, hetero=hetero, mesh=mesh,
                        need_return_all=need_return_all, cfg=cfg)]
    if not legal:
        return None, "static"
    measured = _measured_costs(legal, cfg, op=op, batch=batch)
    spec = min(legal, key=lambda s: _rank(
        s, cfg, op=op, mesh=mesh,
        measured=None if measured is None else measured[s.name]))
    return spec, ("measured" if measured is not None else "static")


_EXEC_CACHE: Dict[tuple, GRUExecutable] = {}


def compile(cfg: GRUConfig, *, batch: Optional[int] = None,
            seq: Optional[int] = None, placement=None, mask: bool = False,
            mode: str = "serve") -> GRUExecutable:
    """Ahead-of-time resolve: the fastest legal backend(s) for a GRU
    workload at these shapes, on this placement.

    ``placement``: a :class:`Placement`, a raw mesh (wrapped with the
    default axis), or None (host). ``mask`` declares whether calls will
    carry a (B, T) length mask (the array itself is a run-time argument).
    ``mode``: ``"prefill"`` / ``"sequence"`` require a sequence backend,
    ``"decode"`` a decode backend, ``"serve"`` both. Executables are
    memoized — the same key (cfg, shapes, placement, cost epoch) returns
    the SAME object, so its callables are stable across calls and jit
    caches keyed on them never retrace; distinct placements (e.g. two
    different meshes) compile distinct executables.

    ``cfg.family`` selects the cell family's backend namespace; an
    unregistered family raises the typed
    :class:`~repro.core.cells.UnknownCellFamily` (never a silent
    degrade to another family's backends).
    """
    _ensure_backends()
    cell_families.get_family(cell_families.cfg_family(cfg))  # typed check
    pl_ = _as_placement(placement)
    masked = bool(mask)
    key = (cfg, batch, seq, pl_, masked, mode, _COST_EPOCH)
    hit = _EXEC_CACHE.get(key)
    if hit is not None:
        return hit

    seq_spec, seq_src = _select("sequence", cfg, masked=masked,
                                placement=pl_, batch=batch)
    # a finals-only backend may win the primary selection; return_all=True
    # calls then fall through to the cheapest fully-capable backend instead
    # of failing inside the backend (the silent-capability-gap failure mode
    # this module exists to eliminate). Both specs are fixed at compile
    # time, so the callables stay jit-stable.
    if seq_spec is not None and seq_spec.caps.return_all:
        seq_spec_ra = seq_spec
    else:
        seq_spec_ra, _ = _select("sequence", cfg, masked=masked,
                                 placement=pl_, batch=batch,
                                 need_return_all=True)
    dec_spec, dec_src = _select("decode", cfg, masked=False, placement=pl_,
                                batch=batch)
    if mode in ("prefill", "sequence", "serve") and seq_spec is None:
        raise NoCapableBackend(
            f"no sequence backend for family="
            f"{cell_families.cfg_family(cfg)!r} cfg.backend={cfg.backend!r} "
            f"mask={mask} dims={cfg.resolved_layer_dims} mesh={pl_.mesh}")
    if mode in ("decode", "serve") and dec_spec is None:
        raise NoCapableBackend(
            f"no decode backend for family="
            f"{cell_families.cfg_family(cfg)!r} cfg.backend={cfg.backend!r} "
            f"dims={cfg.resolved_layer_dims}")

    def run_sequence(params, h0s, xs, *, return_all=False, mask=None):
        if mask is not None and not masked:
            raise ValueError("executable was compiled with mask=False; "
                             "re-compile with mask=True to pass a length "
                             "mask")
        spec = seq_spec if not return_all else seq_spec_ra
        if spec is None:
            raise NoCapableBackend(
                f"no return_all-capable sequence backend for "
                f"cfg.backend={cfg.backend!r} mask={mask is not None} "
                f"dims={cfg.resolved_layer_dims} mesh={pl_.mesh}")
        sp = prepare(params, cfg,
                     pl_ if spec.caps.supports_mesh else None,
                     want_stacked=spec.name == "pallas_fused",
                     want_quant=spec.name.endswith("_q8"))
        return spec.sequence_fn(sp, tuple(h0s), xs, cfg=cfg,
                                return_all=return_all, mask=mask,
                                placement=pl_)

    def run_prefill(params, h0s, xs, *, mask=None):
        return run_sequence(params, h0s, xs, mask=mask)[0]

    def run_decode(params, hs, x):
        sp = prepare(params, cfg,
                     pl_ if dec_spec.caps.supports_mesh else None,
                     want_stacked=dec_spec.name == "pallas_fused",
                     want_quant=dec_spec.name.endswith("_q8"))
        return dec_spec.decode_fn(sp, tuple(hs), x, cfg=cfg, placement=pl_)

    relevant = ([seq_src] if mode in ("prefill", "sequence") else
                [dec_src] if mode == "decode" else [seq_src, dec_src])
    exe = GRUExecutable(
        cfg=cfg, batch=batch, seq=seq, masked=masked, placement=pl_,
        mode=mode,
        sequence_backend=seq_spec.name if seq_spec else None,
        decode_backend=dec_spec.name if dec_spec else None,
        mask_exact=seq_spec.caps.mask_exact if seq_spec else True,
        cost_source="measured" if "measured" in relevant else "static",
        sequence=run_sequence, prefill=run_prefill,
        decode=run_decode if dec_spec else None)
    _EXEC_CACHE[key] = exe
    return exe


def clear_cache() -> None:
    """Drop all memoized executables (tests; not needed in serving)."""
    _EXEC_CACHE.clear()


# ---------------------------------------------------------------------------
# compile-and-run conveniences (the legacy entry points shim onto these)
# ---------------------------------------------------------------------------

def sequence(params, h0s, xs, *, cfg: GRUConfig, return_all: bool = False,
             mask=None, mesh=None):
    """Run a depth-L stack over xs (B,T,X) with the compiled backend.
    Returns (per-layer finals, last-layer states | None)."""
    exe = compile(cfg, batch=xs.shape[0] if xs.ndim >= 3 else None,
                  seq=xs.shape[-2], placement=mesh, mask=mask is not None,
                  mode="sequence")
    return exe.sequence(params, h0s, xs, return_all=return_all, mask=mask)


def decode(params, hs, x, *, cfg: GRUConfig, mesh=None):
    """One serve step through the stack with the compiled backend.
    Returns the per-layer new hidden states."""
    exe = compile(cfg, batch=x.shape[0], placement=mesh, mode="decode")
    return exe.decode(params, hs, x)


# ---------------------------------------------------------------------------
# deprecated one-shot surface: plan() / ExecPlan
# ---------------------------------------------------------------------------

def plan(cfg: GRUConfig, *, batch: Optional[int] = None,
         seq: Optional[int] = None, mesh=None, mask: bool = False,
         mode: str = "serve") -> GRUExecutable:
    """DEPRECATED one-shot resolve — thin shim over :func:`compile` (the
    two-stage compile/execute API). Returns the SAME memoized executable
    ``compile`` would, so results are bitwise-identical; warns once per
    process."""
    gru_core._warn_deprecated("runtime.plan")
    return compile(cfg, batch=batch, seq=seq, placement=mesh, mask=mask,
                   mode=mode)


def __getattr__(name: str):
    if name == "ExecPlan":
        # deprecated class name: plans ARE executables now
        gru_core._warn_deprecated("runtime.ExecPlan")
        return GRUExecutable
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
