"""The sLSTM cell family: scalar-gated recurrence with exponential gates
and a per-step stabilizer (xLSTM, Beck et al. 2024 — see SNIPPETS.md §3).

Second registered :class:`repro.core.cells.CellFamily` — the proof that the
paper's workload-distribution machinery (decoupled ``W.x`` GEMM, fused
recurrent path, capability dispatch, prepare()-placed weights) is not
GRU-specific. The cell keeps the repo's dense per-layer layout — ``w``
``(X, 4H)``, ``u`` ``(H, 4H)``, ``b`` ``(4H,)``, gate order ``[z, i, f, o]``
— so the same stacking/normalization helpers apply; the per-head
block-diagonal recurrence of ``repro.models.xlstm`` is a model-level
refinement, not part of the family contract.

Gate math (fp32, all backends and the oracle):

    z, i, f, o = split(W x + U h + b, 4)        # 2 matvecs/step, fused gates
    logf  = log_sigmoid(f)
    m'    = max(logf + m, i)                     # stabilizer state
    c'    = exp(logf + m - m') * c + exp(i - m') * tanh(z)
    n'    = exp(logf + m - m') * n + exp(i - m')
    h'    = sigmoid(o) * c' / max(n', 1e-6)

Per-layer state is FOUR ``(B, H)`` leaves ``(c, n, m, h)``; a depth-L
stack's flat runtime state is ``(c0, n0, m0, h0, c1, ...)`` (see
``repro.core.cells``). The stabilizer ``m`` is genuinely recurrent — it is
carried per step exactly like ``h``, in VMEM scratch for the fused Pallas
kernels (:mod:`repro.kernels.slstm_cell`).

This module owns the family registration, the parameter specs, the
XLA-scan fallback backend (``(slstm, xla)``) and the dense fp32 oracle.
The fused Pallas backend registers from ``repro.kernels.slstm_cell.ops``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import GRUConfig
from repro.core import cells as cells_registry
from repro.core.gru import stack_cell_params
from repro.core.params import Spec

STATE_LEAVES = 4                      # (c, n, m, h) per layer
M_INIT = -1e30                        # stabilizer init: first step's f_ = 0


# ---------------------------------------------------------------------------
# parameter specs + state layout
# ---------------------------------------------------------------------------

def slstm_cell_specs(input_dim: int, hidden_dim: int) -> dict:
    """One sLSTM layer. Gate stacking order along the last axis:
    [z, i, f, o]."""
    return {
        "w": Spec((input_dim, 4 * hidden_dim), ("rnn_in", "gates")),
        "u": Spec((hidden_dim, 4 * hidden_dim), ("hidden", "gates"),
                  init="recurrent"),
        "b": Spec((4 * hidden_dim,), ("gates",), init="zeros"),
    }


def slstm_stack_specs(cfg: GRUConfig) -> tuple:
    """Per-layer cell specs for a depth-L stack, layer 0 first."""
    return tuple(
        slstm_cell_specs(cfg.layer_input_dim(l), h)
        for l, h in enumerate(cfg.resolved_layer_dims)
    )


def stack_state0(cfg: GRUConfig, batch: int, dtype=jnp.float32) -> tuple:
    """Flat initial state, layer-major: (c, n, m, h) per layer."""
    out = []
    for h in cfg.resolved_layer_dims:
        out += [jnp.zeros((batch, h), dtype), jnp.zeros((batch, h), dtype),
                jnp.full((batch, h), M_INIT, dtype),
                jnp.zeros((batch, h), dtype)]
    return tuple(out)


def group_states(state: Sequence[jax.Array], num_layers: int) -> tuple:
    """Flat (4L,) tuple -> per-layer ((c, n, m, h), ...) groups."""
    state = tuple(state)
    assert len(state) == STATE_LEAVES * num_layers, (len(state), num_layers)
    return tuple(state[STATE_LEAVES * l:STATE_LEAVES * (l + 1)]
                 for l in range(num_layers))


def flatten_states(groups) -> tuple:
    """Per-layer ((c, n, m, h), ...) groups -> flat (4L,) tuple."""
    return tuple(leaf for g in groups for leaf in g)


# ---------------------------------------------------------------------------
# gate math (fp32)
# ---------------------------------------------------------------------------

def slstm_gate_math(c, n, m, h, xp, u, b):
    """One cell update. c/n/m/h: (B,H); xp: (B,4H) precomputed W.x;
    u: (H,4H); b broadcastable (4H,). Returns the new (c, n, m, h)."""
    H = h.shape[-1]
    g = xp + h @ u + b                                   # (B, 4H) fused gates
    z, i = g[..., :H], g[..., H:2 * H]
    f, o = g[..., 2 * H:3 * H], g[..., 3 * H:]
    logf = jax.nn.log_sigmoid(f)
    m_new = jnp.maximum(logf + m, i)
    i_ = jnp.exp(i - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c_new = f_ * c + i_ * jnp.tanh(z)
    n_new = f_ * n + i_
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1e-6)
    return c_new, n_new, m_new, h_new


def _f32_cell(cell: dict) -> tuple:
    return (cell["w"].astype(jnp.float32), cell["u"].astype(jnp.float32),
            cell["b"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# XLA-scan backend (the slstm family's fallback, serves any shape)
# ---------------------------------------------------------------------------

def _layer_sequence_xla(cell: dict, group: tuple, xs: jax.Array, *,
                        return_all: bool, mask: Optional[jax.Array]):
    """One layer over xs (..., T, X): decoupled W.x GEMM + lax.scan over
    the recurrent path. Returns ((c,n,m,h) finals, (B,T,H) h states|None).
    ``mask`` (B,T): False steps freeze all four state leaves (select, not
    perturb — live steps stay bitwise-identical to unpadded)."""
    w, u, b = _f32_cell(cell)
    xp = xs.astype(jnp.float32) @ w                      # (B,T,4H) decoupled
    xp_t = jnp.moveaxis(xp, -2, 0)                       # time-major (T,B,4H)
    c0, n0, m0, h0 = (leaf.astype(jnp.float32) for leaf in group)

    if mask is None:
        def step(carry, xp_step):
            new = slstm_gate_math(*carry, xp_step, u, b)
            return new, (new[3] if return_all else None)
        xs_scan = xp_t
    else:
        mask_t = jnp.moveaxis(mask, -1, 0) != 0          # (T,B) bool

        def step(carry, inp):
            xp_step, keep = inp
            new = slstm_gate_math(*carry, xp_step, u, b)
            new = tuple(jnp.where(keep[:, None], a, old)
                        for a, old in zip(new, carry))
            return new, (new[3] if return_all else None)
        xs_scan = (xp_t, mask_t)

    finals, hs = jax.lax.scan(step, (c0, n0, m0, h0), xs_scan)
    if return_all:
        return finals, jnp.moveaxis(hs, 0, -2)           # (B,T,H)
    return finals, None


def slstm_stack_sequence_xla(params, state0: Sequence[jax.Array],
                             xs: jax.Array, *, cfg: GRUConfig,
                             return_all: bool = False,
                             mask: Optional[jax.Array] = None):
    """Depth-L sLSTM stack over xs (B,T,X), layer-by-layer (each layer
    hoists its input GEMM over the lower layer's full hidden sequence).
    ``state0``: flat (4L,) tuple. Returns (flat finals, last layer's
    (B,T,H) h sequence | None). One shared mask freezes every layer's
    state at padded steps (exact, same argument as the GRU stack)."""
    cells = stack_cell_params(params, cfg)
    L = len(cells)
    groups = group_states(state0, L)
    finals, cur, hs = [], xs, None
    for l in range(L):
        last = l == L - 1
        fin, hs = _layer_sequence_xla(cells[l], groups[l], cur,
                                      return_all=(not last) or return_all,
                                      mask=mask)
        finals.append(fin)
        if not last:
            cur = hs
    return flatten_states(finals), (hs if return_all else None)


def slstm_stack_decode_xla(params, state: Sequence[jax.Array], x: jax.Array,
                           *, cfg: GRUConfig) -> tuple:
    """One serve step through the stack: layer ``l`` consumes layer
    ``l-1``'s NEW hidden state. ``state``: flat (4L,); returns the flat
    new state."""
    cells = stack_cell_params(params, cfg)
    groups = group_states(state, len(cells))
    out, cur = [], x
    for cell, group in zip(cells, groups):
        w, u, b = _f32_cell(cell)
        xp = cur.astype(jnp.float32) @ w                 # (B,4H)
        c, n, m, h = (leaf.astype(jnp.float32) for leaf in group)
        new = slstm_gate_math(c, n, m, h, xp, u, b)
        out.append(new)
        cur = new[3]
    return flatten_states(out)


# pure-jnp dense oracle used by every slstm test ----------------------------

def slstm_stack_reference(params, state0: Sequence[jax.Array], xs: jax.Array,
                          return_all: bool = False,
                          mask: Optional[jax.Array] = None):
    """Dense fp32 step-by-step oracle (python time loop, no scan, no
    decoupled GEMM). Returns (flat finals, last layer's (B,T,H) | None)."""
    cells = stack_cell_params(params)
    L = len(cells)
    wub = [_f32_cell(c) for c in cells]
    states = [list(leaf.astype(jnp.float32) for leaf in g)
              for g in group_states(state0, L)]
    out = []
    for t in range(xs.shape[-2]):
        cur = xs[..., t, :].astype(jnp.float32)
        keep = None if mask is None else mask[..., t, None] != 0
        for l in range(L):
            w, u, b = wub[l]
            new = slstm_gate_math(*states[l], cur @ w, u, b)
            if keep is not None:
                new = tuple(jnp.where(keep, a, old)
                            for a, old in zip(new, states[l]))
            states[l] = list(new)
            cur = new[3]
        if return_all:
            out.append(states[-1][3])
    hs = jnp.stack(out, axis=-2) if return_all else None
    return flatten_states(tuple(tuple(s) for s in states)), hs


# ---------------------------------------------------------------------------
# registration: the family + its XLA fallback backend
# ---------------------------------------------------------------------------

def _slstm_family() -> cells_registry.CellFamily:
    def stacked_views(cells):
        from repro.kernels.slstm_cell import ops as slstm_ops
        return slstm_ops.prepare_stacked_cells(cells)

    def reference(cells, state0, xs, *, return_all=False, mask=None):
        return slstm_stack_reference(cells, tuple(state0), xs,
                                     return_all=return_all, mask=mask)

    return cells_registry.CellFamily(
        name="slstm",
        gates=4,
        state_leaves=STATE_LEAVES,
        state_names=("c", "n", "m", "h"),
        h_leaf=3,
        cell_specs=slstm_cell_specs,
        stack_specs=slstm_stack_specs,
        init_state=stack_state0,
        normalize=stack_cell_params,
        reference=reference,
        stacked_views=stacked_views,
        supports_quant=False,          # no q8 views for the exp-gate path yet
        supports_placement=False,      # no shard_map backends registered
    )


cells_registry.register_family(_slstm_family())

_REGISTERED = False


def register_runtime_backends() -> None:
    """Idempotently register the ``(slstm, xla)`` fallback with the
    executor. Called by ``runtime._ensure_backends()`` on first use."""
    global _REGISTERED
    if _REGISTERED:
        return
    from repro.core import runtime

    def xla_seq(sp, state0, xs, *, cfg, return_all, mask, placement):
        return slstm_stack_sequence_xla(sp.cells, tuple(state0), xs, cfg=cfg,
                                        return_all=return_all, mask=mask)

    def xla_dec(sp, state, x, *, cfg, placement):
        return slstm_stack_decode_xla(sp.cells, tuple(state), x, cfg=cfg)

    runtime.register_backend(runtime.BackendSpec(
        family="slstm",
        name="xla",
        caps=runtime.Capabilities(supports_mask=True,
                                  supports_hetero_dims=True,
                                  supports_mesh=False, return_all=True,
                                  decode=True, sequence=True),
        cost=30,
        sequence_fn=xla_seq, decode_fn=xla_dec))
    _REGISTERED = True


__all__ = [
    "STATE_LEAVES", "M_INIT", "slstm_cell_specs", "slstm_stack_specs",
    "stack_state0", "group_states", "flatten_states", "slstm_gate_math",
    "slstm_stack_sequence_xla", "slstm_stack_decode_xla",
    "slstm_stack_reference", "register_runtime_backends",
]
