"""Cell-family registry: the protocol that opens the executor to non-GRU
recurrences.

The paper's workload-distribution scheme (row-parallel matvecs, fused
per-step compute, latency-first dispatch) is not GRU-specific — the same
structure serves any gated recurrence. This module is the seam: a
:class:`CellFamily` describes everything the executor
(:mod:`repro.core.runtime`) needs to compile/prepare/serve one recurrence
family — parameter specs, state layout, step/reference math, and which
prepare()-time weight views exist — and backends register against a
``(family, backend)`` key instead of assuming GRU. Adding a family (mLSTM,
SSM, ConvGRU, ...) is a registration, not a fork.

State convention: a stack's runtime state is a FLAT tuple of per-layer
leaves, layer-major — ``state_leaves`` arrays per layer, each ``(B, H)``,
with ``h_leaf`` indexing the readout hidden state within a layer's group.
GRU has one leaf per layer (``h``); sLSTM has four (``c, n, m, h`` — cell,
normalizer, exponential-gate stabilizer, hidden). A flat tuple of same-rank
arrays keeps every executor signature (``sequence_fn(sp, state, xs, ...)``),
the serving engine's slot-scatter, and the model cache specs identical
across families.

Families self-register on import of their home module;
:func:`ensure_families` imports the in-tree ones so lookups never depend on
import order. :func:`get_family` raises the typed :class:`UnknownCellFamily`
for anything unregistered — serving surfaces route through it so an unknown
``cfg.family`` fails loudly instead of silently degrading.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

__all__ = [
    "CellFamily", "UnknownCellFamily", "register_family", "get_family",
    "is_cell_family", "families", "ensure_families", "cfg_family",
]


class UnknownCellFamily(KeyError):
    """``cfg.family`` names no registered cell family (typed: serving
    surfaces catch/raise this instead of silently degrading)."""

    def __init__(self, name: str, known=()):
        super().__init__(name)
        self.family = name
        self.known = tuple(sorted(known))

    def __str__(self) -> str:
        return (f"unknown cell family {self.family!r}; registered families: "
                f"{list(self.known)}")


@dataclasses.dataclass(frozen=True)
class CellFamily:
    """One recurrence family, as the executor sees it.

    ``gates``: gate columns per hidden unit — each layer's ``w`` is
    ``(X, gates*H)``, ``u`` is ``(H, gates*H)``, ``b`` is ``(gates*H,)``
    (3 for GRU's z/r/h, 4 for sLSTM's z/i/f/o).
    ``state_leaves``/``state_names``/``h_leaf``: the flat per-layer state
    layout (see module docstring).
    ``cell_specs(input_dim, hidden_dim)`` / ``stack_specs(cfg)``: parameter
    pytree specs (:class:`repro.core.params.Spec`).
    ``init_state(cfg, batch, dtype)``: the flat initial-state tuple.
    ``normalize(params, cfg)``: any accepted param layout -> per-layer
    ``({"w","u","b"}, ...)`` cells tuple.
    ``reference(cells, state0, xs, *, return_all, mask)``: the dense fp32
    oracle — ``(flat finals, last-layer h sequence | None)``. Every
    backend registered under this family is tested against it.
    ``stacked_views(cells)``: the fused kernels' prepare()-time weight
    stacks (None: no fused backend registered).
    ``supports_quant`` / ``supports_placement``: whether prepare() may
    build int8 weight views / mesh-sharded weight views for this family
    (GRU-only today; a capability of the family, not of one backend).
    """
    name: str
    gates: int
    state_leaves: int
    state_names: tuple
    h_leaf: int
    cell_specs: Callable = dataclasses.field(repr=False, default=None)
    stack_specs: Callable = dataclasses.field(repr=False, default=None)
    init_state: Callable = dataclasses.field(repr=False, default=None)
    normalize: Callable = dataclasses.field(repr=False, default=None)
    reference: Callable = dataclasses.field(repr=False, default=None)
    stacked_views: Optional[Callable] = dataclasses.field(repr=False,
                                                          default=None)
    supports_quant: bool = False
    supports_placement: bool = False

    def state0(self, cfg, batch: int, dtype=None):
        """Flat initial-state tuple for a depth-L stack (layer-major)."""
        if dtype is None:
            return self.init_state(cfg, batch)
        return self.init_state(cfg, batch, dtype)


_FAMILIES: Dict[str, CellFamily] = {}


def register_family(family: CellFamily) -> None:
    _FAMILIES[family.name] = family


def ensure_families() -> None:
    """Import the in-tree families so registration never depends on import
    order (mirrors ``runtime._ensure_backends`` for backends)."""
    if "slstm" not in _FAMILIES:
        from repro.core import slstm  # noqa: F401  (registers on import)


def families() -> Dict[str, CellFamily]:
    """Snapshot of the registry (name -> family), for introspection/tests."""
    ensure_families()
    return dict(_FAMILIES)


def get_family(name: str) -> CellFamily:
    ensure_families()
    fam = _FAMILIES.get(name)
    if fam is None:
        raise UnknownCellFamily(name, known=_FAMILIES)
    return fam


def is_cell_family(name) -> bool:
    """True when ``name`` is a registered recurrence family (i.e. the
    executor can compile it and the engine serves it through the
    bucketed-prefill/fixed-slot decode wave path)."""
    ensure_families()
    return name in _FAMILIES


def cfg_family(cfg) -> str:
    """The family a config compiles under (missing/empty field -> "gru",
    the pre-registry default — old configs keep exactly their behavior)."""
    return getattr(cfg, "family", "gru") or "gru"


# ---------------------------------------------------------------------------
# the GRU family: the paper's cell, registered like any other
# ---------------------------------------------------------------------------

def _gru_family() -> CellFamily:
    from repro.core import gru as gru_core

    def stacked_views(cells):
        from repro.kernels.gru_sequence import ops as seq_ops
        return seq_ops.prepare_stacked_cells(cells)

    def reference(cells, state0, xs, *, return_all=False, mask=None):
        return gru_core.gru_stack_reference(cells, tuple(state0), xs,
                                            return_all=return_all, mask=mask)

    return CellFamily(
        name="gru",
        gates=3,
        state_leaves=1,
        state_names=("h",),
        h_leaf=0,
        cell_specs=gru_core.gru_cell_specs,
        stack_specs=gru_core.gru_stack_specs,
        init_state=gru_core.stack_h0,
        normalize=gru_core.stack_cell_params,
        reference=reference,
        stacked_views=stacked_views,
        supports_quant=True,
        supports_placement=True,
    )


register_family(_gru_family())
