"""The paper's GRU, TPU-adapted: row-wise vs cascade matvec, decoupled Wx,
fused vs unfused gate aggregation.

Gate math (paper eq. 1, "v1"/Cho variant):

    z = sigmoid(Wz x + Uz h + bz)
    r = sigmoid(Wr x + Ur h + br)
    h~ = tanh(Wh x + Uh (r*h) + bh)
    h' = (1-z)*h + z*h~

Structural modes (all numerically equal to the dense oracle; they differ in
the *shape of the computation*, which is what the paper studies):

* ``matvec_mode="rowwise"`` — output-stationary: the weight matrix is
  partitioned by output rows; every block consumes the full vector and emits
  complete outputs (no cross-block reduction). TPU analogue of the paper's
  row-wise AIE tiling; lowers to a parallel map over row blocks.
* ``matvec_mode="cascade"`` — contraction-stationary baseline: the matrix is
  partitioned by columns and partial sums accumulate sequentially across
  blocks (the AIE cascade-stream pipeline); lowers to ``lax.scan``.
* ``matvec_mode="dense"`` — plain ``x @ w`` oracle.

``fused_gates=True`` is the hybrid-aggregation analogue: gate matvecs are
batched into stacked matmuls and the bias+activation+Hadamard epilogue is
applied without materializing per-gate intermediates (2 matmuls/step).
``False`` is the unfused baseline (3 separate matvecs + separate adds).

``decoupled_wx=True`` hoists the input projection out of the recurrence:
``Xp = xs @ W`` runs as one MXU-shaped GEMM over all timesteps before the
scan — the paper's free-running ``W.x`` tiles that prefetch ahead of the
recurrent path.

``variant="v3"`` is a *beyond-paper* option (cuDNN-style gate math,
``h~ = tanh(Wh x + r*(Uh h + bh))``) that makes all three U matvecs
fusable into ONE matmul per step, shortening the recurrent critical path.

Deep stacks (beyond the paper's single validated layer): ``gru_stack_*``
run ``cfg.resolved_num_layers`` cells, layer ``l`` consuming layer
``l-1``'s hidden sequence. Layer 0 keeps the decoupled ``W.x`` hoisting;
deeper layers hoist their own input GEMM over the full lower-layer
sequence (layer-by-layer execution), so every layer's recurrent path stays
matvec-only. Per-layer ``matvec_mode`` overrides
(``cfg.layer_matvec_modes``) let row-wise and cascade layers mix in one
stack — the paper's hybrid AIE-PL split, generalized per layer. With
``backend="pallas"`` and uniform hidden sizes the whole stack lowers to
ONE fused pallas_call (see ``repro.kernels.gru_sequence``).

Backend DISPATCH lives in ``repro.core.runtime`` (the capability-driven
executor): this module keeps the gate math, the parameter specs, the
XLA-scan backend implementations (``gru_sequence_xla`` /
``gru_stack_sequence_xla`` / ``gru_stack_decode_xla``) and the dense
oracles. The historical entry points (``gru_sequence``,
``gru_stack_sequence``, ``gru_stack_decode_step``, ``gru_decode_step``)
remain as deprecated shims over the executor — bitwise-equal, warning
once per process.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import GRUConfig
from repro.core.params import Spec


# ---------------------------------------------------------------------------
# deprecation bookkeeping for the legacy entry points (now executor shims)
# ---------------------------------------------------------------------------

_DEPRECATION_WARNED: set = set()


def _warn_deprecated(old: str) -> None:
    """One DeprecationWarning per entry point per process: the legacy GRU
    entry points still work (and stay bitwise-equal to the executor) but
    new code should go through ``repro.core.runtime.compile()``."""
    if old in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(old)
    warnings.warn(
        f"{old} is a deprecated entry point; use "
        "repro.core.runtime.compile() -> GRUExecutable (capability-"
        "dispatched executor, two-stage compile/execute) instead.",
        DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def gru_cell_specs(input_dim: int, hidden_dim: int) -> dict:
    """One GRU layer. Gate stacking order along the last axis: [z, r, h]."""
    return {
        "w": Spec((input_dim, 3 * hidden_dim), ("rnn_in", "gates")),
        "u": Spec((hidden_dim, 3 * hidden_dim), ("hidden", "gates"), init="recurrent"),
        "b": Spec((3 * hidden_dim,), ("gates",), init="zeros"),
    }


def gru_stack_specs(cfg: GRUConfig) -> tuple:
    """Per-layer cell specs for a depth-L stack, layer 0 first."""
    return tuple(
        gru_cell_specs(cfg.layer_input_dim(l), h)
        for l, h in enumerate(cfg.resolved_layer_dims)
    )


def layer_config(cfg: GRUConfig, layer: int) -> GRUConfig:
    """Specialize a stack config to one layer (depth-1 view)."""
    return dataclasses.replace(
        cfg,
        input_dim=cfg.layer_input_dim(layer),
        hidden_dim=cfg.resolved_layer_dims[layer],
        matvec_mode=cfg.layer_matvec_mode(layer),
        num_layers=1, layer_dims=(), layer_matvec_modes=())


def stack_cell_params(params, cfg: Optional[GRUConfig] = None) -> tuple:
    """Normalize any accepted param layout to a tuple of per-layer cells.

    Accepts {"cells": (...)} (deep model), {"cell": {...}} (seed depth-1
    layout, kept for compatibility), a bare cell dict, or a sequence."""
    if isinstance(params, dict):
        if "cells" in params:
            return tuple(params["cells"])
        if "cell" in params:
            return (params["cell"],)
        return (params,)                      # bare {w,u,b}
    return tuple(params)


def gru_classifier_specs(cfg: GRUConfig) -> dict:
    """The paper's jet-tagging model: GRU stack + linear classifier head.

    Depth 1 keeps the seed's ``{"cell": ...}`` layout (checkpoint/example
    compatibility); deeper stacks use ``{"cells": (layer0, layer1, ...)}``.
    """
    head_in = cfg.resolved_layer_dims[-1]
    head = {
        "w": Spec((head_in, cfg.num_classes), ("hidden", None)),
        "b": Spec((cfg.num_classes,), (None,), init="zeros"),
    }
    if cfg.resolved_num_layers == 1:
        return {"cell": gru_cell_specs(cfg.input_dim, head_in), "head": head}
    return {"cells": gru_stack_specs(cfg), "head": head}


# ---------------------------------------------------------------------------
# structural matvec modes
# ---------------------------------------------------------------------------

def _row_blocks(n: int, blk: int) -> int:
    assert n % blk == 0, f"output dim {n} not divisible by row block {blk}"
    return n // blk


def matvec(x: jax.Array, w: jax.Array, mode: str = "dense", block: int = 0) -> jax.Array:
    """``x @ w`` with an explicit structural decomposition.

    x: (..., K), w: (K, N) -> (..., N).
    ``block`` = rows-per-block (rowwise) or contraction chunk (cascade);
    0 picks N//4 (rowwise, >=1) or K//4 (cascade, >=1).
    """
    K, N = w.shape
    if mode == "dense":
        return x @ w
    if mode == "rowwise":
        blk = block or max(N // 4, 1)
        while N % blk:
            blk -= 1
        nb = _row_blocks(N, blk)
        # (nb, K, blk): each block holds whole rows; every block sees the full
        # vector x and emits finished outputs. lax.map keeps the block
        # structure visible in HLO (parallel, no cross-block reduction).
        wb = jnp.moveaxis(w.reshape(K, nb, blk), 1, 0)
        yb = jax.lax.map(lambda wi: x @ wi, wb)          # (nb, ..., blk)
        return jnp.moveaxis(yb, 0, -2).reshape(*x.shape[:-1], N)
    if mode == "cascade":
        blk = block or max(K // 4, 1)
        while K % blk:
            blk -= 1
        kb = K // blk
        xs = x.reshape(*x.shape[:-1], kb, blk)
        ws = w.reshape(kb, blk, N)
        # sequential accumulation across contraction blocks = cascade stream.
        def body(carry, operand):
            xi, wi = operand
            return carry + xi @ wi, None
        x_first = jnp.moveaxis(xs, -2, 0)                # (kb, ..., blk)
        init = jnp.zeros((*x.shape[:-1], N), _acc_dtype(x.dtype))
        out, _ = jax.lax.scan(body, init, (x_first, ws))
        return out.astype(x.dtype)
    raise ValueError(f"unknown matvec mode {mode!r}")


def _acc_dtype(dt):
    return jnp.float32 if dt in (jnp.bfloat16, jnp.float16) else dt


# ---------------------------------------------------------------------------
# single step
# ---------------------------------------------------------------------------

def input_projection(params: dict, xs: jax.Array, cfg: GRUConfig) -> jax.Array:
    """The decoupled ``W.x`` path: one GEMM over however many timesteps are
    given (MXU-shaped; runs off the recurrent critical path)."""
    return matvec(xs, params["w"], cfg.matvec_mode, cfg.row_block)


def gru_step(params: dict, h: jax.Array, x: Optional[jax.Array] = None,
             x_proj: Optional[jax.Array] = None, *, cfg: GRUConfig) -> jax.Array:
    """One recurrent step. Pass ``x_proj`` (precomputed Wx, shape (..., 3H))
    when decoupled, else raw ``x``."""
    H = params["u"].shape[0]
    if x_proj is None:
        x_proj = input_projection(params, x, cfg)
    u, b = params["u"], params["b"]
    mode, blk = cfg.matvec_mode, cfg.row_block
    xz, xr, xh = x_proj[..., :H], x_proj[..., H:2 * H], x_proj[..., 2 * H:]

    if cfg.variant == "v3":
        # beyond-paper: single stacked U matvec per step (cuDNN gate math).
        uh_all = matvec(h, u, mode, blk) + b
        z = jax.nn.sigmoid(xz + uh_all[..., :H])
        r = jax.nn.sigmoid(xr + uh_all[..., H:2 * H])
        h_tilde = jnp.tanh(xh + r * uh_all[..., 2 * H:])
    elif cfg.fused_gates:
        # paper's hybrid aggregation: phase 1 fuses z,r (one (H,2H) matmul +
        # epilogue), phase 2 the candidate (one (H,H) matmul + epilogue).
        zr = matvec(h, u[:, :2 * H], mode, blk) + b[: 2 * H]
        z = jax.nn.sigmoid(xz + zr[..., :H])
        r = jax.nn.sigmoid(xr + zr[..., H:])
        h_tilde = jnp.tanh(xh + matvec(r * h, u[:, 2 * H:], mode, blk) + b[2 * H:])
    else:
        # unfused baseline: three separate matvecs, materialized per-gate
        # intermediates (the pure-AIE aggregator path).
        z = jax.nn.sigmoid(xz + matvec(h, u[:, :H], mode, blk) + b[:H])
        r = jax.nn.sigmoid(xr + matvec(h, u[:, H:2 * H], mode, blk) + b[H:2 * H])
        h_tilde = jnp.tanh(xh + matvec(r * h, u[:, 2 * H:], mode, blk) + b[2 * H:])
    return (1.0 - z) * h + z * h_tilde


# ---------------------------------------------------------------------------
# sequence
# ---------------------------------------------------------------------------

def gru_sequence_xla(params: dict, h0: jax.Array, xs: jax.Array, *,
                     cfg: GRUConfig, return_all: bool = False,
                     mask: Optional[jax.Array] = None):
    """The XLA-scan backend implementation (no dispatch): run the
    recurrence over ``xs`` (..., T, X), time axis = -2.

    Respects ``cfg.decoupled_wx`` (hoisted input GEMM) and ``cfg.unroll``
    (short-sequence latency mode). ``mask`` (B, T) bool, optional:
    timesteps where it is False leave the hidden state untouched —
    left-padded (bucketed) batches produce bitwise the same final state as
    their unpadded prompts, since GRU biases make zero *inputs*
    non-neutral.
    """
    m_t = None if mask is None else jnp.moveaxis(mask, -1, 0)  # (T, B)
    step = functools.partial(gru_step, params, cfg=cfg)

    def gated(h, h2, mt):
        return h2 if mt is None else jnp.where(mt[..., None], h2, h)

    if cfg.decoupled_wx:
        xp = input_projection(params, xs, cfg)           # (..., T, 3H) one GEMM
        xp_t = jnp.moveaxis(xp, -2, 0)

        def body(h, op):
            xpt, mt = op
            h2 = gated(h, step(h, x_proj=xpt), mt)
            return h2, (h2 if return_all else None)
        hT, hs = jax.lax.scan(body, h0, (xp_t, m_t), unroll=cfg.unroll)
    else:
        xs_t = jnp.moveaxis(xs, -2, 0)

        def body(h, op):
            xt, mt = op
            h2 = gated(h, step(h, x=xt), mt)
            return h2, (h2 if return_all else None)
        hT, hs = jax.lax.scan(body, h0, (xs_t, m_t), unroll=cfg.unroll)
    if return_all:
        return hT, jnp.moveaxis(hs, 0, -2)
    return hT, None


def gru_sequence(params: dict, h0: jax.Array, xs: jax.Array, *, cfg: GRUConfig,
                 return_all: bool = False, mask: Optional[jax.Array] = None):
    """DEPRECATED single-cell entry point — thin shim over the executor
    (``repro.core.runtime``), which capability-dispatches ``cfg.backend``
    to the XLA scan or the Pallas kernels (masked calls included)."""
    _warn_deprecated("gru_sequence")
    from repro.core import runtime
    lcfg = cfg if cfg.resolved_num_layers == 1 else layer_config(cfg, 0)
    finals, states = runtime.sequence((params,), (h0,), xs, cfg=lcfg,
                                      return_all=return_all, mask=mask)
    return finals[0], states


# ---------------------------------------------------------------------------
# deep stacks
# ---------------------------------------------------------------------------

def stack_h0(cfg: GRUConfig, batch: int, dtype=jnp.float32) -> tuple:
    """Zero initial hidden state per layer."""
    return tuple(jnp.zeros((batch, h), dtype) for h in cfg.resolved_layer_dims)


def gru_stack_sequence_xla(params: Sequence[dict], h0s: Sequence[jax.Array],
                           xs: jax.Array, *, cfg: GRUConfig,
                           return_all: bool = False,
                           mask: Optional[jax.Array] = None):
    """The XLA-scan stack backend (no dispatch): run a depth-L stack over
    ``xs`` (..., T, X), time axis = -2, layer-by-layer.

    ``params``/``h0s`` are per-layer sequences (layer 0 first). Returns
    ``(finals, all_states)`` where ``finals`` is the tuple of per-layer
    final hidden states and ``all_states`` is the LAST layer's full
    hidden sequence (or None). Every layer hoists its input GEMM over the
    lower layer's full sequence (layer 0: the paper's decoupled ``W.x``),
    so the recurrent path of each layer is matvec-only. Depth 1 is exactly
    ``gru_sequence_xla``.

    ``mask`` (B, T) bool, optional: False steps freeze EVERY layer's state
    (one shared mask is exact — during frozen steps upper layers ignore
    their input, so the real steps see exactly the unpadded computation).
    """
    params = stack_cell_params(params, cfg)
    L = len(params)
    finals = []
    cur = xs
    hs = None
    for l in range(L):
        lcfg = layer_config(cfg, l)
        last = l == L - 1
        hT, hs = gru_sequence_xla(params[l], h0s[l], cur, cfg=lcfg,
                                  return_all=(not last) or return_all,
                                  mask=mask)
        finals.append(hT)
        if not last:
            cur = hs
    return tuple(finals), (hs if return_all else None)


def gru_stack_sequence(params: Sequence[dict], h0s: Sequence[jax.Array],
                       xs: jax.Array, *, cfg: GRUConfig,
                       return_all: bool = False,
                       mask: Optional[jax.Array] = None):
    """DEPRECATED stack entry point — thin shim over the executor, which
    dispatches to the XLA scan, the fused Pallas stack kernel (uniform
    dims; masked calls stream the mask in-kernel, no XLA fallback), or the
    per-layer Pallas chain (heterogeneous dims)."""
    _warn_deprecated("gru_stack_sequence")
    from repro.core import runtime
    return runtime.sequence(params, tuple(h0s), xs, cfg=cfg,
                            return_all=return_all, mask=mask)


def gru_stack_decode_xla(params: Sequence[dict], hs: Sequence[jax.Array],
                         x: jax.Array, *, cfg: GRUConfig) -> tuple:
    """The XLA decode backend (no dispatch): one serve step through the
    whole stack via layer-by-layer structural-mode matvecs. Layer ``l``
    consumes layer ``l-1``'s NEW hidden state (same-timestep threading as
    the sequence path). Returns the tuple of per-layer new hidden states."""
    params = stack_cell_params(params, cfg)
    new_hs = []
    cur = x
    for l in range(len(params)):
        h2 = gru_step(params[l], hs[l], x=cur, cfg=layer_config(cfg, l))
        new_hs.append(h2)
        cur = h2
    return tuple(new_hs)


def gru_stack_decode_step(params: Sequence[dict], hs: Sequence[jax.Array],
                          x: jax.Array, *, cfg: GRUConfig,
                          impl: Optional[str] = None) -> tuple:
    """DEPRECATED decode entry point — thin shim over the executor.

    ``impl``: "pallas" / "xla" override ``cfg.backend`` as the dispatch
    preference (kept for compatibility); None follows ``cfg.backend``.
    Under the executor a "pallas" preference with heterogeneous layer
    sizes now runs the per-layer Pallas chain instead of silently taking
    the XLA path. A dict ``params`` may carry precomputed
    ``"stacked_cells"`` (see ``runtime.prepare``) so the fused path does
    no per-step weight restacking.
    """
    _warn_deprecated("gru_stack_decode_step")
    from repro.core import runtime
    if impl is not None and impl != cfg.backend:
        cfg = dataclasses.replace(cfg, backend=impl)
    return runtime.decode(params, tuple(hs), x, cfg=cfg)


def gru_stack_reference(params: Sequence[dict], h0s: Sequence[jax.Array],
                        xs: jax.Array, return_all: bool = False,
                        mask: Optional[jax.Array] = None):
    """Dense fp32 layer-by-layer oracle for the stack (depth-1 ==
    ``gru_reference``). Returns (per-layer finals, last-layer states|None)."""
    params = stack_cell_params(params)
    finals = []
    cur = xs
    hs = None
    for l, p in enumerate(params):
        last = l == len(params) - 1
        hT, hs = gru_reference(p, h0s[l], cur,
                               return_all=(not last) or return_all,
                               mask=mask)
        finals.append(hT)
        if not last:
            cur = hs
    return tuple(finals), (hs if return_all else None)


def gru_classify(params: dict, xs: jax.Array, *, cfg: GRUConfig) -> jax.Array:
    """Paper's jet-tagging forward pass: xs (B, T, X) -> logits (B, C).
    Routed through the executor (``repro.core.runtime``)."""
    from repro.core import runtime
    B = xs.shape[0]
    cells = stack_cell_params(params, cfg)
    h0s = stack_h0(cfg, B, xs.dtype)
    finals, _ = runtime.sequence(cells, h0s, xs, cfg=cfg)
    return finals[-1] @ params["head"]["w"] + params["head"]["b"]


def gru_decode_step(params: dict, h: jax.Array, x: jax.Array, *, cfg: GRUConfig) -> jax.Array:
    """DEPRECATED single-cell serve step — executor shim (batch can be 1)."""
    _warn_deprecated("gru_decode_step")
    from repro.core import runtime
    cell = params["cell"] if "cell" in params else params
    lcfg = cfg if cfg.resolved_num_layers == 1 else layer_config(cfg, 0)
    return runtime.decode((cell,), (h,), x, cfg=lcfg)[0]


# pure-jnp dense oracle used by every test --------------------------------

def gru_reference(params: dict, h0: jax.Array, xs: jax.Array,
                  return_all: bool = False,
                  mask: Optional[jax.Array] = None):
    """Dense, unfused, fp32 oracle (no structural modes, no scan tricks).
    ``mask`` (B, T): False steps leave h untouched (padding semantics)."""
    w = params["w"].astype(jnp.float32)
    u = params["u"].astype(jnp.float32)
    b = params["b"].astype(jnp.float32)
    H = u.shape[0]
    h = h0.astype(jnp.float32)
    out = []
    for t in range(xs.shape[-2]):
        x = xs[..., t, :].astype(jnp.float32)
        z = jax.nn.sigmoid(x @ w[:, :H] + h @ u[:, :H] + b[:H])
        r = jax.nn.sigmoid(x @ w[:, H:2 * H] + h @ u[:, H:2 * H] + b[H:2 * H])
        ht = jnp.tanh(x @ w[:, 2 * H:] + (r * h) @ u[:, 2 * H:] + b[2 * H:])
        h2 = (1 - z) * h + z * ht
        h = h2 if mask is None else jnp.where(mask[..., t, None], h2, h)
        if return_all:
            out.append(h)
    if return_all:
        return h, jnp.stack(out, axis=-2)
    return h, None
