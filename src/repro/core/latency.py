"""Analytical latency / roofline model (TPU v5e-like target).

Two consumers:

1. ``launch/roofline.py`` — turns the dry-run's compiled ``cost_analysis()``
   + HLO-parsed collective bytes into the three roofline terms.
2. ``benchmarks/fig3_latency.py`` — the paper's latency study re-derived for
   TPU: per-step GRU latency vs hidden/input size, rowwise vs cascade,
   fused vs unfused (the AIE tile-count model's analogue, §2 of DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class Hardware:
    """Per-chip v5e-like numbers used throughout (assignment constants)."""
    name: str = "tpu-v5e-like"
    peak_flops_bf16: float = 197e12      # FLOP/s
    peak_flops_fp32: float = 197e12 / 4  # MXU fp32 ~ 1/4 bf16
    hbm_bw: float = 819e9                # B/s
    ici_bw: float = 50e9                 # B/s per link
    vmem_bytes: int = 128 * 1024 * 1024  # v5e ~128 MiB VMEM
    vmem_bw: float = 819e9 * 20          # VMEM is ~an order faster than HBM
    launch_overhead_s: float = 2e-6      # per dispatched program


V5E = Hardware()


@dataclass(frozen=True)
class RooflineTerms:
    """The three-term model: each term is the time (s) if that resource were
    the only constraint; the max is the roofline-optimal step time."""
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "bound": self.bound}


def roofline(flops: float, hbm_bytes: float, collective_bytes: float,
             chips: int = 1, hw: Hardware = V5E, dtype: str = "bfloat16") -> RooflineTerms:
    """Aggregate-workload roofline: inputs are WHOLE-PROGRAM totals; each term
    divides by the chip count (the assignment's formulas)."""
    peak = hw.peak_flops_bf16 if dtype in ("bfloat16", "bf16") else hw.peak_flops_fp32
    return RooflineTerms(
        compute_s=flops / (chips * peak),
        memory_s=hbm_bytes / (chips * hw.hbm_bw),
        collective_s=collective_bytes / (chips * hw.ici_bw),
    )


# ---------------------------------------------------------------------------
# GRU per-step analytical model (the paper's latency study, TPU-translated)
# ---------------------------------------------------------------------------

def gru_step_model(hidden: int, input_dim: int, *, batch: int = 1,
                   fused_gates: bool = True, decoupled_wx: bool = True,
                   variant: str = "v1", row_shards: int = 1,
                   dtype_bytes: int = 4, weights_resident: bool = True,
                   hw: Hardware = V5E) -> RooflineTerms:
    """Latency terms for ONE recurrent step (the paper's Fig-3 axis).

    * ``row_shards`` — the paper's row-wise parallelization degree (AIE tiles
      -> TPU chips/row-blocks). Output rows of U split ``row_shards`` ways;
      each shard emits finished outputs; aggregation = all-gather of h'
      (paper: interface-tile broadcast + PL reassembly).
    * ``weights_resident`` — paper's "row reuse": after the first pass the
      vector/weights live in local memory; U streams from VMEM not HBM.
    * ``decoupled_wx`` — W.x is prefetched off the critical path, so its
      FLOPs/bytes drop off the per-step latency (the Fig-3 plateau in X).
    """
    H, X, B = hidden, input_dim, batch
    # FLOPs on the recurrent critical path (per shard): U matvecs are 2*H*H
    # MACs each; elementwise gates ~ 10*H.
    u_flops = 2 * 3 * H * (H // row_shards) * B
    x_flops = 0 if decoupled_wx else 2 * 3 * H * (X // max(row_shards, 1)) * B
    ew_flops = 12 * H * B
    # one matmul dispatch per phase: v3 = 1 phase, fused v1 = 2, unfused = 3
    phases = 1 if variant == "v3" else (2 if fused_gates else 3)
    flops = u_flops + x_flops + ew_flops

    # Bytes: U rows for this shard (+W if not decoupled) + h vector + epilogue
    u_bytes = 3 * H * (H // row_shards) * dtype_bytes
    w_bytes = 0 if decoupled_wx else 3 * H * X * dtype_bytes
    act_bytes = (4 * H * B) * dtype_bytes          # h in, h' out, gates traffic
    mem_bw = hw.vmem_bw if weights_resident else hw.hbm_bw
    memory_s = (u_bytes + w_bytes) / mem_bw + act_bytes / hw.hbm_bw

    # Aggregation: all-gather of the sharded h' (paper's reassembly path).
    coll_bytes = 0.0
    if row_shards > 1:
        coll_bytes = (row_shards - 1) / row_shards * H * B * dtype_bytes
    peak = hw.peak_flops_bf16 if dtype_bytes == 2 else hw.peak_flops_fp32
    return RooflineTerms(
        compute_s=flops / peak + phases * hw.launch_overhead_s,
        memory_s=memory_s,
        collective_s=coll_bytes / hw.ici_bw + (1e-6 if row_shards > 1 else 0.0),
    )


def gru_tile_cost(hidden: int) -> int:
    """The paper's AIE tile-count model: 3 tiles x 3 gates x H rows + 1."""
    return 3 * hidden * 3 + 1


def model_flops(n_active_params: int, tokens: int, training: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference forward."""
    return (6.0 if training else 2.0) * n_active_params * tokens
