"""Parameter-spec system: declare-once shapes + logical axes.

Every model declares its parameters as a nested dict of :class:`Spec` leaves.
From that single declaration we derive

* ``init_params``     — materialized arrays (deterministic per-path RNG),
* ``abstract_params`` — ``jax.ShapeDtypeStruct`` stand-ins (dry-run: no
  allocation ever happens for the full-size configs),
* ``logical_axes``    — a same-structure tree of logical-axis-name tuples,
  consumed by ``repro.distributed.sharding`` to build ``NamedSharding``s.

This is the single source of truth that lets the multi-pod dry-run lower
``train_step`` for a 235B-param MoE on a CPU host without touching memory.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary (params). Activations use the ``act_*`` names.
# The mapping to physical mesh axes lives in repro.distributed.sharding.
PARAM_AXES = (
    "layers",      # scan-over-layers stacking axis (never sharded)
    "vocab", "embed", "heads", "kv_heads", "head_dim", "mlp",
    "experts", "expert_mlp",
    "hidden", "rnn_in", "gates",       # recurrent cells (the paper's rows)
    "state", "conv", "dt",             # SSM
    "frames", "patches", "vis_embed",  # modality stubs
    # activation/cache logical axes (inputs, KV caches, recurrent states)
    "batch", "act_seq", "act_embed", "act_heads", "act_kv_heads",
    "act_mlp", "act_experts", "act_gates", "act_hidden",
    "act_kv_seq",  # KV-cache capacity dim (flash-decode style sharding)
    "act_seq_tp",  # sequence dim force-sharded over model (SP attention
                   # fallback when head counts don't divide the TP axis)
    "podwise",     # per-pod local state (error-feedback residuals)
)


def _canon_dtype(dtype) -> jnp.dtype:
    return jnp.dtype({"bf16": "bfloat16", "fp32": "float32", "fp16": "float16"}.get(dtype, dtype))


@dataclass(frozen=True)
class Spec:
    """Declaration of one parameter tensor."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"        # fan_in | normal | zeros | ones | embed | recurrent
    scale: float = 1.0
    dtype: Optional[str] = None  # None -> model param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)
        for a in self.axes:
            assert a is None or a in PARAM_AXES, f"unknown logical axis {a!r}"


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _path_seed(path_s: str) -> int:
    return int.from_bytes(hashlib.sha256(path_s.encode()).digest()[:4], "little")


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) >= 2:
        return shape[-2]
    return shape[-1]


def _init_one(spec: Spec, key, path_s: str, param_dtype: str):
    dtype = _canon_dtype(spec.dtype or param_dtype)
    k = jax.random.fold_in(key, _path_seed(path_s))
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
    if spec.init == "embed":
        return (spec.scale * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
    if spec.init == "fan_in":
        std = spec.scale / np.sqrt(_fan_in(shape))
        return (std * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
    if spec.init == "recurrent":
        # orthogonal-ish init for recurrent matrices: scaled normal is fine at
        # these sizes; exact orthogonality is not load-bearing for the system.
        std = spec.scale / np.sqrt(shape[-1])
        return (std * jax.random.normal(k, shape, jnp.float32)).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(specs, key, param_dtype: str = "float32"):
    """Materialize a spec tree into arrays (deterministic per-path)."""
    def f(path, spec):
        return _init_one(spec, key, _path_str(path), param_dtype)
    return jax.tree_util.tree_map_with_path(f, specs, is_leaf=is_spec)


def abstract_params(specs, param_dtype: str = "float32"):
    """ShapeDtypeStruct tree — dry-run stand-in, zero allocation."""
    def f(spec):
        return jax.ShapeDtypeStruct(spec.shape, _canon_dtype(spec.dtype or param_dtype))
    return jax.tree_util.tree_map(f, specs, is_leaf=is_spec)


def logical_axes(specs):
    """Same-structure tree of logical axis tuples."""
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_bytes(specs, param_dtype: str = "float32") -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(specs, is_leaf=is_spec):
        total += int(np.prod(leaf.shape)) * _canon_dtype(leaf.dtype or param_dtype).itemsize
    return total


def param_count(specs) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(specs, is_leaf=is_spec))


def stack_specs(spec_tree, n: int):
    """Prepend a scanned ``layers`` axis of size n to every Spec in the tree."""
    def f(s: Spec) -> Spec:
        return Spec((n,) + tuple(s.shape), ("layers",) + tuple(s.axes),
                    init=s.init, scale=s.scale, dtype=s.dtype)
    return jax.tree_util.tree_map(f, spec_tree, is_leaf=is_spec)


def cast_tree(tree, dtype):
    dt = _canon_dtype(dtype)
    return jax.tree_util.tree_map(
        lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


# ---------------------------------------------------------------------------
# int8 GRU weight quantization (the q8 datapath's placement-stage half)
# ---------------------------------------------------------------------------
#
# The paper's AIE datapath is fixed-point: each vector lane MACs int8 weight
# ROWS against the incoming activation vector. The TPU/CPU translation keeps
# that per-row layout literally: a (K, 3H) gate matrix is stored TRANSPOSED,
# (3H, K) int8, one contiguous weight row per output element, quantized
# symmetrically per output row (``scale_j = max|row_j| / 127``).
#
# Activations need no calibration at all: a GRU hidden state is a convex
# combination of its initial state and tanh outputs, so with ``|h0| <= 1``
# every ``h`` (and ``r*h``) stays in (-1, 1) — a FIXED activation scale of
# 127 is exact-range. That is what makes the q8 datapath a pure
# placement-stage transform: the execute path contains no reduce_max or
# dynamic rescale anywhere, only the in-kernel ``round(h*127)``.
#
# Dequant is one multiply folded next to the bias add: an int32 accumulator
# ``acc = h_q . u_q_row`` represents ``(h*127) . (row/scale_j)``, so
# ``float = acc * (scale_j / 127)`` — ``eff_j = scale_j / 127`` is
# precomputed here, at prepare() time, like the gate-major reshapes.

ACT_SCALE = 127.0   # fixed activation quantization scale (h in (-1,1))


def quantize_rows_int8(w) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-output-channel int8 quantization of a (K, N) matrix.

    Returns ``(q, eff)``: ``q`` is the TRANSPOSED (N, K) int8 matrix (one
    contiguous row per output channel — the paper's per-lane row layout,
    and the layout whose int8 reduction vectorizes), ``eff`` the (N,) f32
    dequant scale per output channel with the fixed activation scale
    already folded in (``max|col| / 127 / 127``).
    """
    wt = jnp.asarray(w, jnp.float32).T                     # (N, K) row-major
    scale = jnp.max(jnp.abs(wt), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)               # all-zero rows
    q = jnp.round(wt / scale).astype(jnp.int8)
    return q, (scale[:, 0] / ACT_SCALE).astype(jnp.float32)


@jax.tree_util.register_pytree_node_class
@dataclass
class QuantStackParams:
    """The q8 datapath's placement-resident weight views.

    ``cells``: per-layer ``{"u_q" (3H,H) int8, "u_eff" (3H,)}`` — every
    layer's recurrent matrix, usable at any ``layer_dims`` (the chain
    backend's working set). ``stacked``: the fused kernels' whole-stack
    views (``{"u_q" (L,3H,H), "u_eff" (L,3H), "wd_q", "wd_eff", "b"}``,
    deep-layer input projections int8 too) — ``None`` for heterogeneous
    stacks, exactly like ``StackParams.stacked``.
    """
    cells: tuple
    stacked: Optional[dict] = None

    def tree_flatten(self):
        return (self.cells, self.stacked), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def quantize_gru_cells(cells) -> QuantStackParams:
    """One-time quantization of a GRU stack's recurrent weights (and, for
    uniform stacks, the fused kernels' stacked views). Runs at prepare()
    time — scale computation, rounding, and int8 casting are placement
    costs, never part of a traced execute call (jaxpr-asserted by the test
    suite)."""
    cells = tuple(cells)
    per_layer = []
    for c in cells:
        u_q, u_eff = quantize_rows_int8(c["u"])
        per_layer.append({"u_q": u_q, "u_eff": u_eff})
    dims = tuple(c["u"].shape[0] for c in cells)
    stacked = None
    if all(d == dims[0] for d in dims):
        L, H = len(cells), dims[0]
        u_q = jnp.stack([p["u_q"] for p in per_layer], 0)          # (L,3H,H)
        u_eff = jnp.stack([p["u_eff"] for p in per_layer], 0)      # (L,3H)
        if L > 1:
            wd = [quantize_rows_int8(c["w"]) for c in cells[1:]]
            wd_q = jnp.stack([q for q, _ in wd], 0)                # (L-1,3H,H)
            wd_eff = jnp.stack([e for _, e in wd], 0)              # (L-1,3H)
        else:
            wd_q = jnp.zeros((1, 3 * H, 1), jnp.int8)
            wd_eff = jnp.zeros((1, 3 * H), jnp.float32)
        b = jnp.stack([jnp.asarray(c["b"], jnp.float32) for c in cells], 0)
        stacked = {"u_q": u_q, "u_eff": u_eff, "wd_q": wd_q,
                   "wd_eff": wd_eff, "b": b}
    return QuantStackParams(cells=tuple(per_layer), stacked=stacked)
