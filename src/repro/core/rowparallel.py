"""The paper's parallelization study as explicit shard_map programs.

Row-wise (the paper's scheme, output-stationary):
    U's OUTPUT rows are sharded across the mesh axis. Every shard receives
    the full vector (the broadcast), emits FINISHED outputs for its rows,
    and the next step's full vector is reassembled with an ALL-GATHER —
    the paper's interface-tile aggregation. There is never a partial-sum
    reduction.

Cascade (the paper's baseline, contraction-stationary):
    U's CONTRACTION dim is sharded; every shard MACs its slice of the
    vector against its column block and partial sums are combined with a
    PSUM — the AIE cascade-stream reduction pipeline.

GRU specifics (Fig. 1b): with paper gate math (v1), the candidate gate
needs the full ``r * h`` vector, so the row-wise step takes TWO
aggregations per step (after z,r and after h'). The beyond-paper ``v3``
gate variant fuses all U matvecs and needs ONE — this halves the
per-step collective latency and is one of the §Perf hillclimbs.

Deep stacks (``gru_stack_sequence_sharded``): every layer's U output rows
shard on the SAME mesh axis, and the step's TRAILING all-gather does
double duty — the gathered full ``h'`` that closes layer ``l``'s step is
exactly the replicated input the next layer's (row-sharded) input GEMM
needs. So stacking layers adds ZERO extra broadcast collectives on the
row-wise path: per step it is still one (v3) or two (v1) gathers per
layer, and the layer boundary is collective-free. Cascade layers keep
their hidden state sharded through the whole sequence and pay ONE
all-gather per layer (amortized over all T steps) to republish their
output sequence for the layer above. The two modes compose freely
per layer (``cfg.layer_matvec_modes``).

Kernel-fused shard bodies: the shard_map programs here are parametric in
their per-shard STEP implementation (``_STEP_IMPLS``). ``"xla"`` scans
plain ops (the ``sharded`` / ``sharded_decode`` backends); ``"pallas"``
invokes the shard-shaped Pallas kernels of ``repro.kernels.gru_sequence``
between the SAME collectives (the ``pallas_sharded`` backend) — the
repro's two parallel axes finally combined: the paper's row-parallel
workload distribution across the mesh, with each shard's per-tile compute
fused into whole-block kernels, the way AIE4ML nests per-tile kernels
under a global dataflow partition.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import GRUConfig
from repro.core.gru import stack_cell_params


# ---------------------------------------------------------------------------
# plain matvec (benchmark E4 building block)
# ---------------------------------------------------------------------------

def rowparallel_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                       axis: str = "model") -> jax.Array:
    """y = x @ w with w's OUTPUT dim sharded; all-gather of finished outputs."""
    def f(x_full, w_shard):
        y_shard = x_full @ w_shard
        return jax.lax.all_gather(y_shard, axis, axis=y_shard.ndim - 1,
                                  tiled=True)
    return shard_map(f, mesh=mesh, in_specs=(P(), P(None, axis)),
                     out_specs=P(), check_vma=False)(x, w)


def colparallel_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                       axis: str = "model") -> jax.Array:
    """y = x @ w with the CONTRACTION dim sharded; psum of partial sums."""
    def f(x_shard, w_shard):
        return jax.lax.psum(x_shard @ w_shard, axis)
    return shard_map(f, mesh=mesh, in_specs=(P(None, axis), P(axis, None)),
                     out_specs=P(), check_vma=False)(x, w)


# ---------------------------------------------------------------------------
# row-parallel GRU step / sequence (the paper's full scheme)
# ---------------------------------------------------------------------------

def _rowwise_step(h_full, xp_shard, u_shard, b_shard, shard_idx, *,
                  axis: str, n: int, variant: str):
    """One GRU step on one shard. h_full: (B,H) replicated; u_shard:
    (H, 3H/n) output rows of all three gates; xp/b sharded to match.
    Returns the all-gathered full h'."""
    B, H = h_full.shape
    Hl = H // n
    h32 = h_full.astype(jnp.float32)
    xz = xp_shard[..., :Hl]
    xr = xp_shard[..., Hl:2 * Hl]
    xh = xp_shard[..., 2 * Hl:]
    uz = u_shard[:, :Hl]
    ur = u_shard[:, Hl:2 * Hl]
    uh = u_shard[:, 2 * Hl:]
    bz, br, bh = b_shard[:Hl], b_shard[Hl:2 * Hl], b_shard[2 * Hl:]
    h_local = jax.lax.dynamic_slice_in_dim(h32, shard_idx * Hl, Hl, axis=1)

    if variant == "v3":
        # ONE U matvec, no mid-step aggregation (beyond-paper)
        z = jax.nn.sigmoid(xz + h32 @ uz + bz)
        r = jax.nn.sigmoid(xr + h32 @ ur + br)
        ht = jnp.tanh(xh + r * (h32 @ uh + bh))
        h_new_local = (1 - z) * h_local + z * ht
        return jax.lax.all_gather(h_new_local, axis, axis=1, tiled=True)

    # paper math: phase 1 -> aggregate r*h -> phase 2 -> aggregate h'
    z = jax.nn.sigmoid(xz + h32 @ uz + bz)
    r = jax.nn.sigmoid(xr + h32 @ ur + br)
    rh_local = r * h_local
    rh_full = jax.lax.all_gather(rh_local, axis, axis=1, tiled=True)  # agg #1
    ht = jnp.tanh(xh + rh_full @ uh + bh)
    h_new_local = (1 - z) * h_local + z * ht
    return jax.lax.all_gather(h_new_local, axis, axis=1, tiled=True)  # agg #2


def _rowwise_step_pallas(h_full, xp_shard, u_shard, b_shard, shard_idx, *,
                         axis: str, n: int, variant: str):
    """`_rowwise_step` with the per-shard compute in Pallas kernels (the
    ``pallas_sharded`` backend's step): same signature, same collectives in
    the same places — v3 runs ONE shard kernel then the trailing gather,
    v1 splits at the mid-step ``r*h`` aggregation into the z/r kernel and
    the candidate kernel. The kernel bodies repeat the XLA expressions op
    for op, so results are bitwise-equal to `_rowwise_step` at the same
    shard shapes (interpret mode on CPU)."""
    from repro.kernels import on_cpu
    from repro.kernels.gru_sequence import kernel as shard_kernels
    B, H = h_full.shape
    Hl = H // n
    h32 = h_full.astype(jnp.float32)
    h_local = jax.lax.dynamic_slice_in_dim(h32, shard_idx * Hl, Hl, axis=1)
    interp = on_cpu()

    if variant == "v3":
        h_new_local = shard_kernels.gru_rowwise_shard_step(
            h32, h_local, xp_shard, u_shard, b_shard, interpret=interp)
        return jax.lax.all_gather(h_new_local, axis, axis=1, tiled=True)

    # paper math: z/r kernel -> aggregate r*h -> candidate kernel -> agg h'
    z, rh_local = shard_kernels.gru_rowwise_shard_zr(
        h32, h_local, xp_shard[..., :2 * Hl], u_shard[:, :2 * Hl],
        b_shard[:2 * Hl], interpret=interp)
    rh_full = jax.lax.all_gather(rh_local, axis, axis=1, tiled=True)  # agg #1
    h_new_local = shard_kernels.gru_rowwise_shard_candidate(
        rh_full, h_local, z, xp_shard[..., 2 * Hl:], u_shard[:, 2 * Hl:],
        b_shard[2 * Hl:], interpret=interp)
    return jax.lax.all_gather(h_new_local, axis, axis=1, tiled=True)  # agg #2


def _cascade_step(h_shard, xp_full, u_rows, b_full, *, axis: str, variant: str):
    """Contraction-parallel step: h sharded (B,H/n), u_rows (H/n,3H) this
    shard's contraction slice; partial sums psum'd; h' kept sharded."""
    B, Hl = h_shard.shape
    H = xp_full.shape[-1] // 3
    h32 = h_shard.astype(jnp.float32)
    idx = jax.lax.axis_index(axis)
    if variant == "v3":
        g = jax.lax.psum(h32 @ u_rows, axis) + b_full         # (B,3H) psum #1
        z = jax.nn.sigmoid(xp_full[..., :H] + g[..., :H])
        r = jax.nn.sigmoid(xp_full[..., H:2 * H] + g[..., H:2 * H])
        ht = jnp.tanh(xp_full[..., 2 * H:] + r * g[..., 2 * H:])
    else:
        zr = jax.lax.psum(h32 @ u_rows[:, :2 * H], axis) + b_full[:2 * H]  # psum #1
        z = jax.nn.sigmoid(xp_full[..., :H] + zr[..., :H])
        r = jax.nn.sigmoid(xp_full[..., H:2 * H] + zr[..., H:])
        rh_shard = jax.lax.dynamic_slice_in_dim(r, idx * Hl, Hl, 1) * h32
        ht_p = jax.lax.psum(rh_shard @ u_rows[:, 2 * H:], axis)           # psum #2
        ht = jnp.tanh(xp_full[..., 2 * H:] + ht_p + b_full[2 * H:])
    z_l = jax.lax.dynamic_slice_in_dim(z, idx * Hl, Hl, 1)
    ht_l = jax.lax.dynamic_slice_in_dim(ht, idx * Hl, Hl, 1)
    return (1 - z_l) * h32 + z_l * ht_l


def _cascade_step_pallas(h_shard, xp_full, u_rows, b_full, *, axis: str,
                         variant: str):
    """`_cascade_step` with the per-shard compute in Pallas kernels: the
    partial-product matvec(s) and the gate epilogues run in-kernel, the
    psum(s) between them stay where the XLA step has them. The epilogues
    work on the LOCAL gate slices (the XLA step computes full-width gates
    then slices; both phases are elementwise, so slicing first is
    bitwise-identical)."""
    from repro.kernels import on_cpu
    from repro.kernels.gru_sequence import kernel as shard_kernels
    B, Hl = h_shard.shape
    H = xp_full.shape[-1] // 3
    h32 = h_shard.astype(jnp.float32)
    idx = jax.lax.axis_index(axis)
    interp = on_cpu()

    def local_gates(a, gates):
        """This shard's (B, gates*Hl) slice of stacked (B, gates*H) gates."""
        return jnp.concatenate(
            [jax.lax.dynamic_slice_in_dim(a, g * H + idx * Hl, Hl, 1)
             for g in range(gates)], axis=1)

    if variant == "v3":
        g = jax.lax.psum(shard_kernels.gru_shard_matvec(
            h32, u_rows, interpret=interp), axis) + b_full       # psum #1
        return shard_kernels.gru_cascade_shard_gates(
            local_gates(g, 3), local_gates(xp_full, 3), h32, interpret=interp)

    zr = jax.lax.psum(shard_kernels.gru_shard_matvec(
        h32, u_rows[:, :2 * H], interpret=interp), axis) + b_full[:2 * H]
    z_l, ht_p = shard_kernels.gru_cascade_shard_zr(
        local_gates(zr, 2), local_gates(xp_full, 2), h32,
        u_rows[:, 2 * H:], interpret=interp)
    ht_p = jax.lax.psum(ht_p, axis)                              # psum #2
    ht_in = (jax.lax.dynamic_slice_in_dim(xp_full, 2 * H + idx * Hl, Hl, 1)
             + jax.lax.dynamic_slice_in_dim(ht_p, idx * Hl, Hl, 1)
             + jax.lax.dynamic_slice_in_dim(b_full, 2 * H + idx * Hl, Hl, 0))
    return shard_kernels.gru_cascade_shard_update(z_l, ht_in, h32,
                                                  interpret=interp)


# step_impl -> (rowwise step, cascade step): the shard bodies of the
# sharded backends are IMPL-parametric — "xla" scans plain ops (`sharded` /
# `sharded_decode`), "pallas" invokes the shard kernels between the same
# collectives (`pallas_sharded`).
_STEP_IMPLS = {"xla": (_rowwise_step, _cascade_step),
               "pallas": (_rowwise_step_pallas, _cascade_step_pallas)}


def gru_sequence_sharded(params: dict, h0: jax.Array, xs: jax.Array, *,
                         mesh: Mesh, cfg: GRUConfig, axis: str = "model"):
    """Run the recurrence with the paper's scheme (cfg.matvec_mode) across
    ``axis``. Returns final h (B,H) replicated. Requires H % axis_size == 0.

    The decoupled input projection runs OUTSIDE the shard_map as one sharded
    GEMM (output rows sharded for rowwise; replicated for cascade)."""
    n = mesh.shape[axis]
    B, T, X = xs.shape
    H = h0.shape[-1]
    assert H % n == 0 and 3 * H % n == 0

    w, u, b = params["w"], params["u"], params["b"]
    # gate-major reshaped views so each shard gets rows of ALL THREE gates
    u3 = u.reshape(H, 3, H)     # (H, gate, H) -> shard last dim
    w3 = w.reshape(X, 3, H)
    b3 = b.reshape(3, H)

    if cfg.matvec_mode == "rowwise":
        def f(xs_l, h0_full, w_sh, u_sh, b_sh):
            # decoupled Wx on the shard's rows: (B,T,3,H/n)
            xp = jnp.einsum("btx,xgh->btgh", xs_l, w_sh)
            xp = xp.reshape(B, T, -1)
            u_flat = u_sh.reshape(H, -1)
            b_flat = b_sh.reshape(-1)
            idx = jax.lax.axis_index(axis)
            step = functools.partial(_rowwise_step, axis=axis, n=n,
                                     variant=cfg.variant)

            def body(h, xp_t):
                return step(h, xp_t, u_flat, b_flat, idx), None
            hT, _ = jax.lax.scan(body, h0_full.astype(jnp.float32),
                                 jnp.moveaxis(xp, 1, 0))
            return hT

        return shard_map(
            f, mesh=mesh,
            in_specs=(P(), P(), P(None, None, axis), P(None, None, axis),
                      P(None, axis)),
            out_specs=P(), check_vma=False,
        )(xs, h0, w3, u3, b3)

    # cascade: contraction sharded; xs and Wx replicated
    def f(xs_full, h0_full, u_rows, b_full):
        xp = jnp.einsum("btx,xh->bth", xs_full, w.reshape(X, 3 * H))
        idx = jax.lax.axis_index(axis)
        Hl = H // n
        h_shard = jax.lax.dynamic_slice_in_dim(
            h0_full.astype(jnp.float32), idx * Hl, Hl, 1)
        step = functools.partial(_cascade_step, axis=axis, variant=cfg.variant)

        def body(h_l, xp_t):
            return step(h_l, xp_t, u_rows, b_full), None
        hT_l, _ = jax.lax.scan(body, h_shard, jnp.moveaxis(xp, 1, 0))
        return jax.lax.all_gather(hT_l, axis, axis=1, tiled=True)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(), P(), P(axis, None), P()),
        out_specs=P(), check_vma=False,
    )(xs, h0, u.reshape(H, 3 * H), b)


# ---------------------------------------------------------------------------
# deep stacks: per-layer row sharding with collective reuse
# ---------------------------------------------------------------------------

def _layer_view(cell: dict, mode: str) -> dict:
    """One layer's shard_map-ready weight views (no placement yet).

    rowwise: gate-major reshapes so each shard owns rows of ALL THREE
    gates; cascade: the raw cell (contraction dim sharded by spec)."""
    H = cell["u"].shape[0]
    if mode == "rowwise":
        Xl = cell["w"].shape[0]
        return {"w3": cell["w"].reshape(Xl, 3, H),
                "u3": cell["u"].reshape(H, 3, H),
                "b3": cell["b"].reshape(3, H)}
    return {"w": cell["w"], "u": cell["u"], "b": cell["b"]}


def _layer_spec(mode: str, axis: str) -> dict:
    if mode == "rowwise":
        return {"w3": P(None, None, axis), "u3": P(None, None, axis),
                "b3": P(None, axis)}
    return {"w": P(), "u": P(axis, None), "b": P()}


def sharded_layer_specs(cfg: GRUConfig, num_layers: int,
                        axis: str = "model") -> tuple:
    """Per-layer PartitionSpec dicts matching ``prepare_sharded_layers``."""
    return tuple(_layer_spec(cfg.layer_matvec_mode(l), axis)
                 for l in range(num_layers))


def prepare_sharded_layers(cells, cfg: GRUConfig, *, mesh: Mesh,
                           axis: str = "model") -> tuple:
    """ONE-time weight placement for the sharded backends: the gate-major
    reshapes AND the ``device_put`` onto the mesh both happen here, so a
    traced execute call against the result is pure compute (no
    ``device_put`` of weight arrays in its jaxpr — asserted by tests).
    This is what ``runtime.prepare(params, cfg, placement)`` calls for a
    mesh placement; the per-call compat paths run it inside the call
    (where the ``device_put`` is traced), which is exactly the per-call
    placement cost the compile/execute split removes."""
    cells = tuple(cells)
    n = mesh.shape[axis]
    placed = []
    for l, c in enumerate(cells):
        H = c["u"].shape[0]
        assert H % n == 0 and 3 * H % n == 0, (H, n)
        mode = cfg.layer_matvec_mode(l)
        view = _layer_view(c, mode)
        spec = _layer_spec(mode, axis)
        placed.append({k: jax.device_put(v, NamedSharding(mesh, spec[k]))
                       for k, v in view.items()})
    return tuple(placed)


def _layer_dims(layer_args) -> list:
    """Hidden size per layer, read off the prepared views."""
    return [(a["u3"].shape[0] if "u3" in a else a["u"].shape[0])
            for a in layer_args]


def gru_stack_sequence_sharded_impl(params, h0s, xs, *, mesh: Mesh,
                                    cfg: GRUConfig, axis: str = "model",
                                    return_all: bool = False, mask=None):
    """Depth-L stack with every layer's U output rows (rowwise) or
    contraction dim (cascade) sharded on the SAME mesh axis, inside ONE
    shard_map. Returns the tuple of per-layer final h, replicated; with
    ``return_all=True`` returns ``(finals, last_layer_states (B,T,H))`` so
    sharded prefill can emit the full sequence without a second pass — a
    rowwise last layer's states are already replicated by the step's
    trailing all-gather (zero extra collectives), a cascade last layer
    republishes its sequence with ONE amortized gather, exactly like the
    inner layers. This is the executor's ``sharded`` backend
    (``repro.core.runtime``).

    ``mask`` (B, T) bool, optional: replicated across the mesh and scanned
    next to the input projections; False steps freeze every layer's
    (local) hidden state AFTER the step's collectives, so the gating adds
    zero communication and bucketed left-padded prompts stay
    bitwise-identical to their unpadded originals on every shard.

    The latency play (rowwise layers): the trailing all-gather that closes
    each step already replicates the full ``h'``, which is precisely the
    broadcast the next layer's row-sharded input GEMM needs — one
    collective does double duty, so layer boundaries cost no extra
    communication. Cascade layers run the whole sequence with sharded
    hidden state and republish their output sequence with a single
    all-gather amortized over all T steps. Modes mix freely per layer
    (``cfg.layer_matvec_modes``); requires ``H_l % axis_size == 0``.

    Compat path: builds the gate-major views and places them PER CALL
    (``prepare_sharded_layers``); hot paths should prepare once via
    ``runtime.prepare(params, cfg, placement)`` and go through
    ``gru_stack_sequence_sharded_prepared``.
    """
    cells = stack_cell_params(params, cfg)
    layer_args = prepare_sharded_layers(cells, cfg, mesh=mesh, axis=axis)
    return gru_stack_sequence_sharded_prepared(
        layer_args, h0s, xs, mesh=mesh, cfg=cfg, axis=axis,
        return_all=return_all, mask=mask)


def gru_stack_sequence_sharded_prepared(layer_args, h0s, xs, *, mesh: Mesh,
                                        cfg: GRUConfig, axis: str = "model",
                                        return_all: bool = False, mask=None,
                                        step_impl: str = "xla"):
    """The execute stage of the sharded sequence backends: ONE shard_map
    over PRE-PLACED per-layer weight views (``prepare_sharded_layers``
    output, i.e. ``StackParams.placed``). Contains no gate-major restacking
    and no ``device_put`` — placement already happened at prepare time.

    ``step_impl`` selects the per-shard step bodies: ``"xla"`` (the
    ``sharded`` backend — plain ops in the scan) or ``"pallas"`` (the
    ``pallas_sharded`` backend — the shard kernels of
    ``repro.kernels.gru_sequence`` between the SAME collectives, bitwise-
    equal at the same shard shapes). Everything else — the layer loop, the
    gather-reuse across layer boundaries, the mask gating, return_all —
    is shared."""
    n = mesh.shape[axis]
    B, T, X = xs.shape
    L = len(layer_args)
    modes = [cfg.layer_matvec_mode(l) for l in range(L)]
    dims = _layer_dims(layer_args)
    for H in dims:
        assert H % n == 0 and 3 * H % n == 0, (H, n)
    layer_specs = sharded_layer_specs(cfg, L, axis)
    rowwise_step, cascade_step = _STEP_IMPLS[step_impl]

    def f(xs_full, h0s_full, largs, *margs):
        idx = jax.lax.axis_index(axis)
        cur = xs_full.astype(jnp.float32)          # (B,T,·) replicated
        # (T, B) replicated mask, scanned alongside the projections; None
        # keeps the unmasked trace byte-identical to the historical one.
        m_t = None if not margs else jnp.moveaxis(margs[0], 1, 0)
        finals = []
        all_states = None
        for l in range(L):
            H, a = dims[l], largs[l]
            last = l == L - 1
            # inner layers thread their full sequence up; the last layer
            # emits it only when the caller asked for return_all
            emit = (not last) or return_all
            if modes[l] == "rowwise":
                xp = jnp.einsum("btx,xgh->btgh", cur, a["w3"]).reshape(B, T, -1)
                u_flat = a["u3"].reshape(H, -1)
                b_flat = a["b3"].reshape(-1)
                step = functools.partial(rowwise_step, axis=axis, n=n,
                                         variant=cfg.variant)

                def body(h, op, step=step, u=u_flat, b=b_flat, emit=emit):
                    if m_t is None:
                        h2 = step(h, op, u, b, idx)
                    else:
                        xp_t, mt = op
                        # gate AFTER the trailing gather: replicated select,
                        # no extra collectives; live rows keep exact bits.
                        h2 = jnp.where(mt[:, None], step(h, xp_t, u, b, idx),
                                       h)
                    return h2, (h2 if emit else None)  # carry == full h
                ops_ = (jnp.moveaxis(xp, 1, 0) if m_t is None
                        else (jnp.moveaxis(xp, 1, 0), m_t))
                hT, hs = jax.lax.scan(body, h0s_full[l].astype(jnp.float32),
                                      ops_)
                if emit:
                    seq = jnp.moveaxis(hs, 0, 1)   # already replicated: reuse
                    if not last:
                        cur = seq
                    else:
                        all_states = seq
            else:
                xp = jnp.einsum("btx,xh->bth", cur, a["w"].astype(jnp.float32))
                Hl = H // n
                h_shard = jax.lax.dynamic_slice_in_dim(
                    h0s_full[l].astype(jnp.float32), idx * Hl, Hl, 1)
                step = functools.partial(cascade_step, axis=axis,
                                         variant=cfg.variant)

                def body(h_l, op, step=step, u=a["u"], b=a["b"], emit=emit):
                    if m_t is None:
                        h2 = step(h_l, op, u, b)
                    else:
                        xp_t, mt = op
                        # the carry is the (B, H/n) LOCAL shard; the (B,)
                        # mask broadcasts over it on every device alike.
                        h2 = jnp.where(mt[:, None], step(h_l, xp_t, u, b),
                                       h_l)
                    return h2, (h2 if emit else None)
                ops_ = (jnp.moveaxis(xp, 1, 0) if m_t is None
                        else (jnp.moveaxis(xp, 1, 0), m_t))
                hT_l, hs_l = jax.lax.scan(body, h_shard, ops_)
                if emit:
                    # ONE gather republishes the whole output sequence
                    hs = jax.lax.all_gather(hs_l, axis, axis=2, tiled=True)
                    seq = jnp.moveaxis(hs, 0, 1)
                    hT = seq[:, -1]
                    if not last:
                        cur = seq
                    else:
                        all_states = seq
                else:
                    hT = jax.lax.all_gather(hT_l, axis, axis=1, tiled=True)
            finals.append(hT)
        if return_all:
            return tuple(finals), all_states
        return tuple(finals)

    out_specs = tuple(P() for _ in range(L))
    if return_all:
        out_specs = (out_specs, P())
    margs = () if mask is None else (mask,)
    mspecs = () if mask is None else (P(),)
    return shard_map(
        f, mesh=mesh,
        in_specs=(P(), tuple(P() for _ in range(L)), tuple(layer_specs))
        + mspecs,
        out_specs=out_specs, check_vma=False,
    )(xs, tuple(h0s), tuple(layer_args), *margs)


# ---------------------------------------------------------------------------
# sharded decode: ONE persistent shard_map step over pre-sharded weights
# ---------------------------------------------------------------------------

def gru_stack_decode_sharded_prepared(layer_args, hs, x, *, mesh: Mesh,
                                      cfg: GRUConfig, axis: str = "model",
                                      step_impl: str = "xla"):
    """One serve step through the whole stack inside ONE shard_map, against
    pre-placed weights (the executor's ``sharded_decode`` backend;
    ``step_impl="pallas"`` swaps the per-shard bodies for the shard
    kernels — the ``pallas_sharded`` decode path, bitwise-equal at the
    same shard shapes).

    ``hs``: per-layer (B, H) replicated states; ``x``: (B, X) the new
    token's features. Returns the per-layer new states, replicated — the
    same cache layout the replicated decode backends use, so the serving
    engine can switch backends without converting state.

    Per layer it is exactly one sequence step of the matching mode:
    rowwise shards compute their xp rows + finished output rows and the
    step's trailing all-gather republishes ``h'`` — which is again the
    replicated input the next layer's row-sharded input GEMM needs, so
    layer boundaries add zero collectives; cascade layers psum partial
    sums and pay one gather to republish their (single-step) output.
    """
    n = mesh.shape[axis]
    L = len(layer_args)
    modes = [cfg.layer_matvec_mode(l) for l in range(L)]
    dims = _layer_dims(layer_args)
    for H in dims:
        assert H % n == 0 and 3 * H % n == 0, (H, n)
    layer_specs = sharded_layer_specs(cfg, L, axis)
    rowwise_step, cascade_step = _STEP_IMPLS[step_impl]

    def f(x_full, hs_full, largs):
        idx = jax.lax.axis_index(axis)
        cur = x_full.astype(jnp.float32)               # (B, ·) replicated
        outs = []
        for l in range(L):
            H, a = dims[l], largs[l]
            if modes[l] == "rowwise":
                B = cur.shape[0]
                xp = jnp.einsum("bx,xgh->bgh", cur,
                                a["w3"].astype(jnp.float32)).reshape(B, -1)
                h2 = rowwise_step(hs_full[l].astype(jnp.float32), xp,
                                  a["u3"].reshape(H, -1),
                                  a["b3"].reshape(-1), idx,
                                  axis=axis, n=n, variant=cfg.variant)
            else:
                xp = cur @ a["w"].astype(jnp.float32)  # (B, 3H) replicated
                Hl = H // n
                h_shard = jax.lax.dynamic_slice_in_dim(
                    hs_full[l].astype(jnp.float32), idx * Hl, Hl, 1)
                h2_l = cascade_step(h_shard, xp, a["u"], a["b"],
                                    axis=axis, variant=cfg.variant)
                h2 = jax.lax.all_gather(h2_l, axis, axis=1, tiled=True)
            outs.append(h2)
            cur = h2                                   # same-token threading
        return tuple(outs)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(), tuple(P() for _ in range(L)), tuple(layer_specs)),
        out_specs=tuple(P() for _ in range(L)), check_vma=False,
    )(x, tuple(hs), tuple(layer_args))


def gru_stack_decode_sharded_impl(params, hs, x, *, mesh: Mesh,
                                  cfg: GRUConfig, axis: str = "model"):
    """Compat decode wrapper: per-call weight placement + the prepared
    step. Hot paths prepare once (``runtime.prepare``) instead."""
    cells = stack_cell_params(params, cfg)
    layer_args = prepare_sharded_layers(cells, cfg, mesh=mesh, axis=axis)
    return gru_stack_decode_sharded_prepared(layer_args, hs, x, mesh=mesh,
                                             cfg=cfg, axis=axis)


def gru_stack_sequence_sharded(params, h0s, xs, *, mesh: Mesh, cfg: GRUConfig,
                               axis: str = "model", return_all: bool = False,
                               mask=None):
    """DEPRECATED entry point — use ``repro.core.runtime.compile(cfg,
    placement=...)``, which dispatches sequence work to this shard_map
    program whenever a mesh is supplied. Kept as a thin, bitwise-equal
    shim."""
    from repro.core.gru import _warn_deprecated
    _warn_deprecated("gru_stack_sequence_sharded")
    return gru_stack_sequence_sharded_impl(params, h0s, xs, mesh=mesh,
                                           cfg=cfg, axis=axis,
                                           return_all=return_all, mask=mask)
