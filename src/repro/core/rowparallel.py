"""The paper's parallelization study as explicit shard_map programs.

Row-wise (the paper's scheme, output-stationary):
    U's OUTPUT rows are sharded across the mesh axis. Every shard receives
    the full vector (the broadcast), emits FINISHED outputs for its rows,
    and the next step's full vector is reassembled with an ALL-GATHER —
    the paper's interface-tile aggregation. There is never a partial-sum
    reduction.

Cascade (the paper's baseline, contraction-stationary):
    U's CONTRACTION dim is sharded; every shard MACs its slice of the
    vector against its column block and partial sums are combined with a
    PSUM — the AIE cascade-stream reduction pipeline.

GRU specifics (Fig. 1b): with paper gate math (v1), the candidate gate
needs the full ``r * h`` vector, so the row-wise step takes TWO
aggregations per step (after z,r and after h'). The beyond-paper ``v3``
gate variant fuses all U matvecs and needs ONE — this halves the
per-step collective latency and is one of the §Perf hillclimbs.

Deep stacks (``gru_stack_sequence_sharded``): every layer's U output rows
shard on the SAME mesh axis, and the step's TRAILING all-gather does
double duty — the gathered full ``h'`` that closes layer ``l``'s step is
exactly the replicated input the next layer's (row-sharded) input GEMM
needs. So stacking layers adds ZERO extra broadcast collectives on the
row-wise path: per step it is still one (v3) or two (v1) gathers per
layer, and the layer boundary is collective-free. Cascade layers keep
their hidden state sharded through the whole sequence and pay ONE
all-gather per layer (amortized over all T steps) to republish their
output sequence for the layer above. The two modes compose freely
per layer (``cfg.layer_matvec_modes``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import GRUConfig
from repro.core.gru import stack_cell_params


# ---------------------------------------------------------------------------
# plain matvec (benchmark E4 building block)
# ---------------------------------------------------------------------------

def rowparallel_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                       axis: str = "model") -> jax.Array:
    """y = x @ w with w's OUTPUT dim sharded; all-gather of finished outputs."""
    def f(x_full, w_shard):
        y_shard = x_full @ w_shard
        return jax.lax.all_gather(y_shard, axis, axis=y_shard.ndim - 1,
                                  tiled=True)
    return shard_map(f, mesh=mesh, in_specs=(P(), P(None, axis)),
                     out_specs=P(), check_vma=False)(x, w)


def colparallel_matmul(x: jax.Array, w: jax.Array, mesh: Mesh,
                       axis: str = "model") -> jax.Array:
    """y = x @ w with the CONTRACTION dim sharded; psum of partial sums."""
    def f(x_shard, w_shard):
        return jax.lax.psum(x_shard @ w_shard, axis)
    return shard_map(f, mesh=mesh, in_specs=(P(None, axis), P(axis, None)),
                     out_specs=P(), check_vma=False)(x, w)


# ---------------------------------------------------------------------------
# row-parallel GRU step / sequence (the paper's full scheme)
# ---------------------------------------------------------------------------

def _rowwise_step(h_full, xp_shard, u_shard, b_shard, shard_idx, *,
                  axis: str, n: int, variant: str):
    """One GRU step on one shard. h_full: (B,H) replicated; u_shard:
    (H, 3H/n) output rows of all three gates; xp/b sharded to match.
    Returns the all-gathered full h'."""
    B, H = h_full.shape
    Hl = H // n
    h32 = h_full.astype(jnp.float32)
    xz = xp_shard[..., :Hl]
    xr = xp_shard[..., Hl:2 * Hl]
    xh = xp_shard[..., 2 * Hl:]
    uz = u_shard[:, :Hl]
    ur = u_shard[:, Hl:2 * Hl]
    uh = u_shard[:, 2 * Hl:]
    bz, br, bh = b_shard[:Hl], b_shard[Hl:2 * Hl], b_shard[2 * Hl:]
    h_local = jax.lax.dynamic_slice_in_dim(h32, shard_idx * Hl, Hl, axis=1)

    if variant == "v3":
        # ONE U matvec, no mid-step aggregation (beyond-paper)
        z = jax.nn.sigmoid(xz + h32 @ uz + bz)
        r = jax.nn.sigmoid(xr + h32 @ ur + br)
        ht = jnp.tanh(xh + r * (h32 @ uh + bh))
        h_new_local = (1 - z) * h_local + z * ht
        return jax.lax.all_gather(h_new_local, axis, axis=1, tiled=True)

    # paper math: phase 1 -> aggregate r*h -> phase 2 -> aggregate h'
    z = jax.nn.sigmoid(xz + h32 @ uz + bz)
    r = jax.nn.sigmoid(xr + h32 @ ur + br)
    rh_local = r * h_local
    rh_full = jax.lax.all_gather(rh_local, axis, axis=1, tiled=True)  # agg #1
    ht = jnp.tanh(xh + rh_full @ uh + bh)
    h_new_local = (1 - z) * h_local + z * ht
    return jax.lax.all_gather(h_new_local, axis, axis=1, tiled=True)  # agg #2


def _cascade_step(h_shard, xp_full, u_rows, b_full, *, axis: str, variant: str):
    """Contraction-parallel step: h sharded (B,H/n), u_rows (H/n,3H) this
    shard's contraction slice; partial sums psum'd; h' kept sharded."""
    B, Hl = h_shard.shape
    H = xp_full.shape[-1] // 3
    h32 = h_shard.astype(jnp.float32)
    idx = jax.lax.axis_index(axis)
    if variant == "v3":
        g = jax.lax.psum(h32 @ u_rows, axis) + b_full         # (B,3H) psum #1
        z = jax.nn.sigmoid(xp_full[..., :H] + g[..., :H])
        r = jax.nn.sigmoid(xp_full[..., H:2 * H] + g[..., H:2 * H])
        ht = jnp.tanh(xp_full[..., 2 * H:] + r * g[..., 2 * H:])
    else:
        zr = jax.lax.psum(h32 @ u_rows[:, :2 * H], axis) + b_full[:2 * H]  # psum #1
        z = jax.nn.sigmoid(xp_full[..., :H] + zr[..., :H])
        r = jax.nn.sigmoid(xp_full[..., H:2 * H] + zr[..., H:])
        rh_shard = jax.lax.dynamic_slice_in_dim(r, idx * Hl, Hl, 1) * h32
        ht_p = jax.lax.psum(rh_shard @ u_rows[:, 2 * H:], axis)           # psum #2
        ht = jnp.tanh(xp_full[..., 2 * H:] + ht_p + b_full[2 * H:])
    z_l = jax.lax.dynamic_slice_in_dim(z, idx * Hl, Hl, 1)
    ht_l = jax.lax.dynamic_slice_in_dim(ht, idx * Hl, Hl, 1)
    return (1 - z_l) * h32 + z_l * ht_l


def gru_sequence_sharded(params: dict, h0: jax.Array, xs: jax.Array, *,
                         mesh: Mesh, cfg: GRUConfig, axis: str = "model"):
    """Run the recurrence with the paper's scheme (cfg.matvec_mode) across
    ``axis``. Returns final h (B,H) replicated. Requires H % axis_size == 0.

    The decoupled input projection runs OUTSIDE the shard_map as one sharded
    GEMM (output rows sharded for rowwise; replicated for cascade)."""
    n = mesh.shape[axis]
    B, T, X = xs.shape
    H = h0.shape[-1]
    assert H % n == 0 and 3 * H % n == 0

    w, u, b = params["w"], params["u"], params["b"]
    # gate-major reshaped views so each shard gets rows of ALL THREE gates
    u3 = u.reshape(H, 3, H)     # (H, gate, H) -> shard last dim
    w3 = w.reshape(X, 3, H)
    b3 = b.reshape(3, H)

    if cfg.matvec_mode == "rowwise":
        def f(xs_l, h0_full, w_sh, u_sh, b_sh):
            # decoupled Wx on the shard's rows: (B,T,3,H/n)
            xp = jnp.einsum("btx,xgh->btgh", xs_l, w_sh)
            xp = xp.reshape(B, T, -1)
            u_flat = u_sh.reshape(H, -1)
            b_flat = b_sh.reshape(-1)
            idx = jax.lax.axis_index(axis)
            step = functools.partial(_rowwise_step, axis=axis, n=n,
                                     variant=cfg.variant)

            def body(h, xp_t):
                return step(h, xp_t, u_flat, b_flat, idx), None
            hT, _ = jax.lax.scan(body, h0_full.astype(jnp.float32),
                                 jnp.moveaxis(xp, 1, 0))
            return hT

        return shard_map(
            f, mesh=mesh,
            in_specs=(P(), P(), P(None, None, axis), P(None, None, axis),
                      P(None, axis)),
            out_specs=P(), check_vma=False,
        )(xs, h0, w3, u3, b3)

    # cascade: contraction sharded; xs and Wx replicated
    def f(xs_full, h0_full, u_rows, b_full):
        xp = jnp.einsum("btx,xh->bth", xs_full, w.reshape(X, 3 * H))
        idx = jax.lax.axis_index(axis)
        Hl = H // n
        h_shard = jax.lax.dynamic_slice_in_dim(
            h0_full.astype(jnp.float32), idx * Hl, Hl, 1)
        step = functools.partial(_cascade_step, axis=axis, variant=cfg.variant)

        def body(h_l, xp_t):
            return step(h_l, xp_t, u_rows, b_full), None
        hT_l, _ = jax.lax.scan(body, h_shard, jnp.moveaxis(xp, 1, 0))
        return jax.lax.all_gather(hT_l, axis, axis=1, tiled=True)

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(), P(), P(axis, None), P()),
        out_specs=P(), check_vma=False,
    )(xs, h0, u.reshape(H, 3 * H), b)


# ---------------------------------------------------------------------------
# deep stacks: per-layer row sharding with collective reuse
# ---------------------------------------------------------------------------

def gru_stack_sequence_sharded_impl(params, h0s, xs, *, mesh: Mesh,
                                    cfg: GRUConfig, axis: str = "model",
                                    return_all: bool = False, mask=None):
    """Depth-L stack with every layer's U output rows (rowwise) or
    contraction dim (cascade) sharded on the SAME mesh axis, inside ONE
    shard_map. Returns the tuple of per-layer final h, replicated; with
    ``return_all=True`` returns ``(finals, last_layer_states (B,T,H))`` so
    sharded prefill can emit the full sequence without a second pass — a
    rowwise last layer's states are already replicated by the step's
    trailing all-gather (zero extra collectives), a cascade last layer
    republishes its sequence with ONE amortized gather, exactly like the
    inner layers. This is the executor's ``sharded`` backend
    (``repro.core.runtime``).

    ``mask`` (B, T) bool, optional: replicated across the mesh and scanned
    next to the input projections; False steps freeze every layer's
    (local) hidden state AFTER the step's collectives, so the gating adds
    zero communication and bucketed left-padded prompts stay
    bitwise-identical to their unpadded originals on every shard.

    The latency play (rowwise layers): the trailing all-gather that closes
    each step already replicates the full ``h'``, which is precisely the
    broadcast the next layer's row-sharded input GEMM needs — one
    collective does double duty, so layer boundaries cost no extra
    communication. Cascade layers run the whole sequence with sharded
    hidden state and republish their output sequence with a single
    all-gather amortized over all T steps. Modes mix freely per layer
    (``cfg.layer_matvec_modes``); requires ``H_l % axis_size == 0``.
    """
    n = mesh.shape[axis]
    B, T, X = xs.shape
    cells = stack_cell_params(params, cfg)
    L = len(cells)
    modes = [cfg.layer_matvec_mode(l) for l in range(L)]
    dims = [c["u"].shape[0] for c in cells]
    for H in dims:
        assert H % n == 0 and 3 * H % n == 0, (H, n)

    layer_args, layer_specs = [], []
    for c, mode in zip(cells, modes):
        Xl = c["w"].shape[0]
        H = c["u"].shape[0]
        if mode == "rowwise":
            # gate-major views: each shard owns rows of ALL THREE gates
            layer_args.append({"w3": c["w"].reshape(Xl, 3, H),
                               "u3": c["u"].reshape(H, 3, H),
                               "b3": c["b"].reshape(3, H)})
            layer_specs.append({"w3": P(None, None, axis),
                                "u3": P(None, None, axis),
                                "b3": P(None, axis)})
        else:  # cascade: contraction sharded, everything else replicated
            layer_args.append({"w": c["w"], "u": c["u"], "b": c["b"]})
            layer_specs.append({"w": P(), "u": P(axis, None), "b": P()})

    def f(xs_full, h0s_full, largs, *margs):
        idx = jax.lax.axis_index(axis)
        cur = xs_full.astype(jnp.float32)          # (B,T,·) replicated
        # (T, B) replicated mask, scanned alongside the projections; None
        # keeps the unmasked trace byte-identical to the historical one.
        m_t = None if not margs else jnp.moveaxis(margs[0], 1, 0)
        finals = []
        all_states = None
        for l in range(L):
            H, a = dims[l], largs[l]
            last = l == L - 1
            # inner layers thread their full sequence up; the last layer
            # emits it only when the caller asked for return_all
            emit = (not last) or return_all
            if modes[l] == "rowwise":
                xp = jnp.einsum("btx,xgh->btgh", cur, a["w3"]).reshape(B, T, -1)
                u_flat = a["u3"].reshape(H, -1)
                b_flat = a["b3"].reshape(-1)
                step = functools.partial(_rowwise_step, axis=axis, n=n,
                                         variant=cfg.variant)

                def body(h, op, step=step, u=u_flat, b=b_flat, emit=emit):
                    if m_t is None:
                        h2 = step(h, op, u, b, idx)
                    else:
                        xp_t, mt = op
                        # gate AFTER the trailing gather: replicated select,
                        # no extra collectives; live rows keep exact bits.
                        h2 = jnp.where(mt[:, None], step(h, xp_t, u, b, idx),
                                       h)
                    return h2, (h2 if emit else None)  # carry == full h
                ops_ = (jnp.moveaxis(xp, 1, 0) if m_t is None
                        else (jnp.moveaxis(xp, 1, 0), m_t))
                hT, hs = jax.lax.scan(body, h0s_full[l].astype(jnp.float32),
                                      ops_)
                if emit:
                    seq = jnp.moveaxis(hs, 0, 1)   # already replicated: reuse
                    if not last:
                        cur = seq
                    else:
                        all_states = seq
            else:
                xp = jnp.einsum("btx,xh->bth", cur, a["w"].astype(jnp.float32))
                Hl = H // n
                h_shard = jax.lax.dynamic_slice_in_dim(
                    h0s_full[l].astype(jnp.float32), idx * Hl, Hl, 1)
                step = functools.partial(_cascade_step, axis=axis,
                                         variant=cfg.variant)

                def body(h_l, op, step=step, u=a["u"], b=a["b"], emit=emit):
                    if m_t is None:
                        h2 = step(h_l, op, u, b)
                    else:
                        xp_t, mt = op
                        # the carry is the (B, H/n) LOCAL shard; the (B,)
                        # mask broadcasts over it on every device alike.
                        h2 = jnp.where(mt[:, None], step(h_l, xp_t, u, b),
                                       h_l)
                    return h2, (h2 if emit else None)
                ops_ = (jnp.moveaxis(xp, 1, 0) if m_t is None
                        else (jnp.moveaxis(xp, 1, 0), m_t))
                hT_l, hs_l = jax.lax.scan(body, h_shard, ops_)
                if emit:
                    # ONE gather republishes the whole output sequence
                    hs = jax.lax.all_gather(hs_l, axis, axis=2, tiled=True)
                    seq = jnp.moveaxis(hs, 0, 1)
                    hT = seq[:, -1]
                    if not last:
                        cur = seq
                    else:
                        all_states = seq
                else:
                    hT = jax.lax.all_gather(hT_l, axis, axis=1, tiled=True)
            finals.append(hT)
        if return_all:
            return tuple(finals), all_states
        return tuple(finals)

    out_specs = tuple(P() for _ in range(L))
    if return_all:
        out_specs = (out_specs, P())
    margs = () if mask is None else (mask,)
    mspecs = () if mask is None else (P(),)
    return shard_map(
        f, mesh=mesh,
        in_specs=(P(), tuple(P() for _ in range(L)), tuple(layer_specs))
        + mspecs,
        out_specs=out_specs, check_vma=False,
    )(xs, tuple(h0s), tuple(layer_args), *margs)


def gru_stack_sequence_sharded(params, h0s, xs, *, mesh: Mesh, cfg: GRUConfig,
                               axis: str = "model", return_all: bool = False,
                               mask=None):
    """DEPRECATED entry point — use ``repro.core.runtime.plan(cfg,
    mesh=...)``, which dispatches sequence work to this shard_map program
    whenever a mesh is supplied. Kept as a thin, bitwise-equal shim."""
    from repro.core.gru import _warn_deprecated
    _warn_deprecated("gru_stack_sequence_sharded")
    return gru_stack_sequence_sharded_impl(params, h0s, xs, mesh=mesh,
                                           cfg=cfg, axis=axis,
                                           return_all=return_all, mask=mask)
