"""q8 accuracy gate: measure the int8 datapath against the f32 oracle.

The q8 backends change numerics, so they are not allowed into ``auto``
dispatch on speed alone: this harness quantifies the damage on the
paper's jet-tagging task and RECORDS it — the written artifact
(``BENCH_quant_accuracy.json``) is what opens the dispatch gate
(``repro.core.runtime.quant_gate_open``). No artifact, a stale/failed
one, or one from a different bench ⇒ the q8 backends stay
pin-only. That is the intended lifecycle: **calibrate accuracy first,
then let the cost model route to int8** — never the other way round.

Protocol: train the jet-tagging classifier (short teacher-aligned run on
the synthetic stream — enough to open real logit margins; parity on an
untrained net is vacuous because near-tied logits flip argmax on noise),
then compare class logits of every q8 backend against the f32 oracle on
a held-out eval set:

* ``max_abs_logit_err`` / ``mean_abs_logit_err`` — logit error bounds,
* ``argmax_match``     — raw top-1 agreement over the whole eval set,
* ``argmax_match_confident`` — classification parity over the example
  eval set: examples whose f32 top-2 logit gap is at least ``tie_eps``.
  Below that margin the oracle's own argmax is a coin flip under ANY
  numerical perturbation (a different f32 reduction order included), so
  a disagreement there measures the tie, not the datapath. Ties are
  counted and reported (``ties``), never silently dropped.
* ``passed``           — confident-set parity == 1.0 for every measured
  backend AND max logit error within ``--bound``.

    PYTHONPATH=src python -m repro.quant.accuracy [--smoke] \
        [--json BENCH_quant_accuracy.json] [--bound 0.05] [--depth L]

CSV: name,value,detail
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config
from repro.core import gru as gru_core
from repro.core.params import init_params
from repro.data.pipeline import SyntheticStream
from repro.models import gru_lm

Q8_BACKENDS = ("pallas_fused_q8", "pallas_chain_q8")


def _train(mcfg, batch: int, steps: int, lr: float, seed: int = 0):
    """Short SGD run on the synthetic jet stream (linear-teacher labels:
    learnable, so logit margins open within a few hundred steps)."""
    params = init_params(gru_lm.lm_specs(mcfg), jax.random.key(seed))
    params = {"head": params["head"],
              **{k: params[k] for k in ("cell", "cells") if k in params}}
    stream = SyntheticStream(mcfg, ShapeConfig(
        "quant_train", seq_len=mcfg.gru.seq_len, global_batch=batch,
        kind="train"))

    @jax.jit
    def step(p, feats, labels):
        def loss(p):
            l, _ = gru_lm.loss_fn(p, mcfg, {"features": feats,
                                            "labels": labels})
            return l
        l, g = jax.value_and_grad(loss)(p)
        return jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g), l

    last = float("nan")
    for i in range(steps):
        b = stream.batch_at(i)
        params, l = step(params, jnp.asarray(b["features"]),
                         jnp.asarray(b["labels"]))
        last = float(l)
    return params, last


def _eval_logits(params, gcfg, xs):
    """Class logits (B, C) under the datapath ``gcfg`` resolves to."""
    return np.asarray(gru_core.gru_classify(params, xs, cfg=gcfg))


def run(arch: str = "gru-jet", depth: int = None, hidden: int = None,
        train_steps: int = 300, train_batch: int = 64, lr: float = 0.05,
        eval_batches: int = 8, eval_batch: int = 64, bound: float = 0.05,
        tie_eps: float = 0.02, backends=Q8_BACKENDS,
        json_path: str = "BENCH_quant_accuracy.json",
        csv: bool = True) -> dict:
    mcfg = get_config(arch)
    gcfg = mcfg.gru
    if depth:
        gcfg = dataclasses.replace(gcfg, num_layers=depth)
    if hidden:
        gcfg = dataclasses.replace(gcfg, hidden_dim=hidden)
    mcfg = mcfg.replace(gru=gcfg)

    params, final_loss = _train(mcfg, train_batch, train_steps, lr)

    # held-out eval batches: a different stream seed than training
    stream = SyntheticStream(mcfg, ShapeConfig(
        "quant_eval", seq_len=gcfg.seq_len, global_batch=eval_batch,
        kind="prefill"))
    feats = [jnp.asarray(stream.batch_at(10_000 + i)["features"])
             for i in range(eval_batches)]

    f32_cfg = dataclasses.replace(gcfg, backend="xla")
    oracle = [_eval_logits(params, f32_cfg, xs) for xs in feats]
    # f32 top-2 logit gap per example: the confidence of the oracle's own
    # decision. Examples under tie_eps are ties, reported separately.
    top2 = [np.sort(ref, axis=-1)[:, -2:] for ref in oracle]
    confident = [(t[:, 1] - t[:, 0]) >= tie_eps for t in top2]

    per_backend, all_pass = {}, True
    for name in backends:
        qcfg = dataclasses.replace(gcfg, backend=name)  # exact pin: legal
        errs, agree, agree_conf = [], [], []
        for xs, ref, conf in zip(feats, oracle, confident):
            got = _eval_logits(params, qcfg, xs)
            errs.append(np.abs(got - ref))
            same = got.argmax(-1) == ref.argmax(-1)
            agree.append(same)
            agree_conf.append(same[conf])
        err = np.concatenate([e.ravel() for e in errs])
        agree = np.concatenate(agree)
        agree_conf = np.concatenate(agree_conf)
        m = {"max_abs_logit_err": round(float(err.max()), 6),
             "mean_abs_logit_err": round(float(err.mean()), 6),
             "argmax_match": round(float(agree.mean()), 6),
             "argmax_match_confident": round(float(agree_conf.mean()), 6),
             "examples": int(agree.size),
             "ties": int(agree.size - agree_conf.size)}
        m["passed"] = (m["argmax_match_confident"] == 1.0
                       and m["max_abs_logit_err"] <= bound)
        all_pass = all_pass and m["passed"]
        per_backend[name] = m
        if csv:
            print(f"quant_acc_{name},{m['max_abs_logit_err']:.6f},"
                  f"argmax_match={m['argmax_match']:.4f};"
                  f"confident={m['argmax_match_confident']:.4f}"
                  f"({m['ties']}ties);"
                  f"mean={m['mean_abs_logit_err']:.6f}")

    out = {"bench": "gru_quant_accuracy", "schema": 1,
           "device": jax.default_backend(), "arch": arch,
           "config": {"depth": gcfg.resolved_num_layers,
                      "hidden": gcfg.hidden_dim,
                      "input_dim": gcfg.input_dim,
                      "seq_len": gcfg.seq_len, "variant": gcfg.variant},
           "train_steps": train_steps, "final_loss": round(final_loss, 4),
           "bound": bound, "tie_eps": tie_eps,
           "backends": per_backend, "passed": all_pass}
    with open(json_path, "w") as f:
        json.dump(out, f, indent=2)
    if csv:
        print(f"quant_acc_passed,{int(all_pass)},"
              f"bound={bound};artifact={json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced run for CI (still writes the artifact)")
    ap.add_argument("--arch", default="gru-jet")
    ap.add_argument("--depth", type=int, default=None,
                    help="override stack depth (default: the arch's)")
    ap.add_argument("--hidden", type=int, default=None)
    ap.add_argument("--train-steps", type=int, default=None)
    ap.add_argument("--eval-batches", type=int, default=None)
    ap.add_argument("--bound", type=float, default=0.05,
                    help="max |logit error| allowed for passed=true")
    ap.add_argument("--tie-eps", type=float, default=0.02,
                    help="f32 top-2 logit gap under which an example "
                         "counts as a tie (reported, excluded from the "
                         "parity bar)")
    ap.add_argument("--json", default="BENCH_quant_accuracy.json")
    args = ap.parse_args()
    if args.smoke:
        run(arch=args.arch, depth=args.depth, hidden=args.hidden,
            train_steps=args.train_steps or 80, train_batch=32,
            eval_batches=args.eval_batches or 2, eval_batch=32,
            bound=args.bound, tie_eps=args.tie_eps, json_path=args.json)
    else:
        run(arch=args.arch, depth=args.depth, hidden=args.hidden,
            train_steps=args.train_steps or 300,
            eval_batches=args.eval_batches or 8,
            bound=args.bound, tie_eps=args.tie_eps, json_path=args.json)
