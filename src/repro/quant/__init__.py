"""Quantized-datapath tooling: the q8 accuracy gate harness."""
