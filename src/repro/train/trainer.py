"""Training loop core: jitted train_step with microbatch accumulation,
AdamW, and an explicit cross-pod DP mode with compressed gradient exchange.

Two step builders:

* ``make_train_step`` — pure-GSPMD: batch sharded over (pod, data); XLA
  derives every collective. This is the dry-run / production default.
* ``make_pod_train_step`` — the multi-pod distributed-optimization path:
  ``shard_map(axis_names={"pod"})`` makes the pod axis MANUAL (data/model
  stay auto inside), each pod computes local gradients, and the cross-pod
  exchange goes through ``repro.distributed.compression`` (int8+error
  feedback / bf16) — the slow-link-aware design for 1000+ node meshes.

State is a plain dict so checkpointing/resharding is tree surgery:
{"params", "opt": {"mu","nu"}, "step", optional "ef"}.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.params import Spec, abstract_params, init_params, is_spec
from repro.distributed import compression
from repro.distributed.sharding import ShardCtx, param_shardings, resolve_pspec
from repro.models import api as mapi
from repro.optim import adamw


def state_specs(model_cfg: ModelConfig, train_cfg: TrainConfig,
                with_ef: bool = False, n_pods: int = 1) -> dict:
    A = mapi.get_api(model_cfg)
    pspecs = A.specs(model_cfg)
    s = {
        "params": pspecs,
        "opt": adamw.opt_specs(pspecs, train_cfg.opt_dtype),
        "step": Spec((), (), init="zeros", dtype="int32"),
    }
    if with_ef:
        def f(sp: Spec) -> Spec:
            return Spec((n_pods,) + tuple(sp.shape), ("podwise",) + tuple(sp.axes),
                        init="zeros", dtype="float32")
        s["ef"] = jax.tree_util.tree_map(f, pspecs, is_leaf=is_spec)
    return s


def init_state(model_cfg: ModelConfig, train_cfg: TrainConfig, seed: int = 0,
               with_ef: bool = False, n_pods: int = 1) -> dict:
    specs = state_specs(model_cfg, train_cfg, with_ef, n_pods)
    return init_params(specs, jax.random.key(seed), model_cfg.param_dtype)


def _micro_grads(loss_fn, params, batch, micro: int):
    """Gradient accumulation over ``micro`` microbatches via lax.scan."""
    if micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return grads, loss, metrics

    def split(x):
        return x.reshape((micro, x.shape[0] // micro) + x.shape[1:])
    mb = jax.tree_util.tree_map(split, batch)

    def body(acc, one):
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, one)
        acc = jax.tree_util.tree_map(jnp.add, acc, g)
        return acc, (loss, metrics)

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    grads, (losses, metricses) = jax.lax.scan(body, zeros, mb, length=micro)
    grads = jax.tree_util.tree_map(lambda g: (g / micro).astype(jnp.float32), grads)
    metrics = jax.tree_util.tree_map(lambda m: m.mean(), metricses)
    return grads, losses.mean(), metrics


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig,
                    ctx: ShardCtx):
    """Pure-GSPMD step: (state, batch) -> (state, metrics)."""
    A = mapi.get_api(model_cfg)

    def loss_fn(params, batch):
        return A.loss_fn(params, model_cfg, batch, ctx)

    def step_fn(state, batch):
        grads, loss, metrics = _micro_grads(loss_fn, state["params"], batch,
                                            train_cfg.microbatches)
        params2, opt2, om = adamw.adamw_update(
            state["params"], grads, state["opt"], state["step"], train_cfg)
        new_state = {"params": params2, "opt": opt2, "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss, **om)
        return new_state, metrics

    return step_fn


def make_pod_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig,
                        ctx: ShardCtx):
    """Explicit-DP over the pod axis with compressed gradient all-reduce.

    Requires a mesh with a "pod" axis. Gradients are computed per pod
    (auto-sharded over data/model inside), exchanged with
    ``train_cfg.grad_compression``, then the (replicated) optimizer update
    runs inside the same shard_map.
    """
    mesh = ctx.mesh
    assert mesh is not None and "pod" in mesh.axis_names
    A = mapi.get_api(model_cfg)
    method = train_cfg.grad_compression
    use_ef = method == "int8_ef"
    # inside the pod-manual region, constraints may only touch auto axes.
    # Old jax (no jax.shard_map) crashes XLA on sharding constraints inside
    # a partial-manual region (IsManualSubgroup check); constraints are
    # hints, so drop them there and keep the collectives identical.
    if hasattr(jax, "shard_map"):
        inner_ctx = ShardCtx(mesh=mesh, profile=ctx.profile,
                             manual=ctx.manual + ("pod",))
    else:
        inner_ctx = ShardCtx(mesh=None, profile=ctx.profile)

    def loss_fn(params, batch):
        return A.loss_fn(params, model_cfg, batch, inner_ctx)

    def local_fn(state, batch, reduce: bool = True):
        ef = None
        if use_ef:
            ef = jax.tree_util.tree_map(lambda e: e[0], state["ef"])
        grads, loss, metrics = _micro_grads(loss_fn, state["params"], batch,
                                            train_cfg.microbatches)
        if reduce:
            grads, ef2 = compression.pod_allreduce_mean(grads, method, "pod", ef)
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(m, "pod"), metrics)
        else:                       # structure probe (outside shard_map)
            ef2 = ef
        params2, opt2, om = adamw.adamw_update(
            state["params"], grads, state["opt"], state["step"], train_cfg)
        new_state = {"params": params2, "opt": opt2, "step": state["step"] + 1}
        if use_ef:
            new_state["ef"] = jax.tree_util.tree_map(lambda e: e[None], ef2)
        return new_state, dict(metrics, loss=loss, **om)

    # state replicated over pod except EF (pod-local); batch sharded over pod
    def _state_spec(s):
        if not use_ef:
            return jax.tree_util.tree_map(lambda _: P(), s)
        out = {k: jax.tree_util.tree_map(lambda _: P(), v)
               for k, v in s.items() if k != "ef"}
        out["ef"] = jax.tree_util.tree_map(lambda _: P("pod"), s["ef"])
        return out

    def step_fn(state, batch):
        batch_specs = jax.tree_util.tree_map(lambda _: P("pod"), batch)
        st_specs = _state_spec(state)
        # metrics dict structure is data-dependent; derive out_specs from a
        # collective-free probe (psum can't trace outside the shard_map)
        met_shape = jax.eval_shape(
            lambda s, b: local_fn(s, b, reduce=False)[1], state, batch)
        met_specs = jax.tree_util.tree_map(lambda _: P(), met_shape)
        return shard_map(
            local_fn, mesh=mesh, axis_names={"pod"},
            in_specs=(st_specs, batch_specs),
            out_specs=(st_specs, met_specs),
            check_vma=False,
        )(state, batch)

    return step_fn


def jit_train_step(step_fn, model_cfg: ModelConfig, train_cfg: TrainConfig,
                   ctx: ShardCtx, batch_specs_tree, with_ef=False, n_pods=1):
    """jit with in/out shardings derived from the spec trees."""
    if ctx.mesh is None:
        return jax.jit(step_fn, donate_argnums=(0,))
    sspecs = state_specs(model_cfg, train_cfg, with_ef, n_pods)
    state_sh = param_shardings(sspecs, ctx)
    batch_sh = jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, resolve_pspec(s.axes, s.shape, ctx)),
        batch_specs_tree, is_leaf=is_spec)
    return jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                   donate_argnums=(0,))
