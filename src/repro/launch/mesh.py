"""Production mesh definitions (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods =
    512 chips (pod, data, model); the pod axis carries DP (+ optional PP
    and compressed gradient exchange).

    When the process exposes more devices than the mesh needs (the dry-run
    forces 512 host devices and then builds the 256-chip single-pod mesh),
    the first N devices are used."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devs = jax.devices()
    assert len(devs) >= need, (len(devs), need)
    return compat.make_mesh(shape, axes, devices=devs[:need])


def make_host_mesh(shape, axes):
    """Small host-device mesh for tests/examples (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set before jax init)."""
    return compat.make_mesh(shape, axes)
