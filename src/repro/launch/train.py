"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gru-jet --steps 300
    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
        --steps 50 --batch 8 --seq 64 --checkpoint-dir /tmp/ck --resume

Builds the data pipeline, jitted train step (optionally over a host-device
mesh), async checkpointing, and the straggler monitor; resumes from the
latest committed checkpoint when --resume is given.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ShapeConfig, TrainConfig, get_config, get_smoke_config
from repro.data.pipeline import PipelineConfig, SyntheticStream
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.distributed.sharding import ShardCtx
from repro.train import trainer


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced same-family config (CPU-sized)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--checkpoint-dir", default="")
    p.add_argument("--checkpoint-every", type=int, default=100)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "gru":
        args.seq = cfg.gru.seq_len
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
                       total_steps=args.steps, microbatches=args.microbatches,
                       checkpoint_every=args.checkpoint_every, seed=args.seed)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    stream = SyntheticStream(cfg, shape, PipelineConfig(seed=args.seed))
    ctx = ShardCtx()

    state = trainer.init_state(cfg, tcfg, seed=args.seed)
    step_fn = jax.jit(trainer.make_train_step(cfg, tcfg, ctx),
                      donate_argnums=(0,))

    mgr = None
    start = 0
    if args.checkpoint_dir:
        mgr = CheckpointManager(args.checkpoint_dir, keep=3)
        if args.resume and mgr.latest_step() is not None:
            state = mgr.restore(state)
            start = int(np.asarray(state["step"]))
            print(f"resumed from step {start}")

    strag = StragglerMonitor()
    t_begin = time.time()
    for s in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(s).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        strag.record("host0", time.time() - t0)
        if s % args.log_every == 0 or s == args.steps - 1:
            extra = ""
            if "acc" in metrics:
                extra = f" acc={float(metrics['acc']):.3f}"
            print(f"step {s:5d} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e}{extra} "
                  f"({time.time()-t0:.2f}s)", flush=True)
        if mgr and (s + 1) % tcfg.checkpoint_every == 0:
            mgr.save_async(state, s + 1)
    if mgr:
        mgr.save(state, args.steps)
        mgr.wait()
    print(f"done: {args.steps - start} steps in {time.time()-t_begin:.1f}s; "
          f"final loss {loss:.4f}")
    return state


if __name__ == "__main__":
    main()
