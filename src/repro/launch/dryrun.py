import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost/collective analysis.

For each cell the lowered program is the REAL step the system runs:
  train_*    -> jitted train_step (fwd+bwd+AdamW, microbatchable)
  prefill_*  -> jitted prefill (full forward + cache build)
  decode_* / long_* -> jitted serve_step (one token against a full cache)

Inputs are ShapeDtypeStructs built from the same Spec trees the runtime
uses — no allocation ever happens for the full-size configs. Results land
in experiments/dryrun/<arch>__<shape>__<mesh>.json (resumable: existing
files are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ALL_ARCHS, TrainConfig, get_config
from repro.configs.shapes import GRU_SHAPES, SHAPES, shape_skip_reason
from repro.core.params import abstract_params
from repro.distributed.sharding import ShardCtx, param_shardings
from repro.launch.hloparse import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import api as mapi
from repro.train import trainer

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _shapes_for(arch: str):
    return GRU_SHAPES if arch == "gru-jet" else SHAPES


def build_lowerable(arch: str, shape_name: str, ctx: ShardCtx,
                    profile: str = "default", param_dtype: str | None = None):
    """Returns (jitted_fn, abstract_args tuple)."""
    cfg = get_config(arch)
    if param_dtype:
        cfg = cfg.replace(param_dtype=param_dtype)
    shape = _shapes_for(arch)[shape_name]
    A = mapi.get_api(cfg)
    bspecs = mapi.input_specs(cfg, shape)
    batch_abs = abstract_params(bspecs, "float32")
    batch_sh = param_shardings(bspecs, ctx)

    if shape.kind == "train":
        tcfg = TrainConfig()
        sspecs = trainer.state_specs(cfg, tcfg)
        state_abs = abstract_params(sspecs, cfg.param_dtype)
        state_sh = param_shardings(sspecs, ctx)
        step = trainer.make_train_step(cfg, tcfg, ctx)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        return fn, (state_abs, batch_abs), cfg, shape

    pspecs = A.specs(cfg)
    params_abs = abstract_params(pspecs, cfg.param_dtype)
    params_sh = param_shardings(pspecs, ctx)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return A.prefill(params, cfg, batch, ctx)
        fn = jax.jit(prefill_fn, in_shardings=(params_sh, batch_sh))
        return fn, (params_abs, batch_abs), cfg, shape

    # decode: abstract cache with capacity = context length
    cspecs = A.cache_specs(cfg, shape.global_batch, shape.seq_len)
    cache_abs = abstract_params(cspecs, cfg.param_dtype)
    cache_sh = param_shardings(cspecs, ctx)
    tok_abs = jax.tree_util.tree_leaves(batch_abs)[0]

    def decode_fn(params, cache, tok):
        return A.decode_step(params, cfg, cache, tok, ctx)

    tok_sh = jax.tree_util.tree_leaves(batch_sh)[0]
    fn = jax.jit(decode_fn, in_shardings=(params_sh, cache_sh, tok_sh),
                 donate_argnums=(1,))
    return fn, (params_abs, cache_abs, tok_abs), cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             profile: str = "default", param_dtype: str | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = get_config(arch)
    shape = _shapes_for(arch)[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "profile": profile, "kind": shape.kind,
           "seq_len": shape.seq_len, "global_batch": shape.global_batch,
           "status": "ok"}
    skip = shape_skip_reason(cfg, shape) if arch != "gru-jet" else None
    if skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ShardCtx(mesh=mesh, profile=profile)
    t0 = time.time()
    fn, args, cfg, shape = build_lowerable(arch, shape_name, ctx, profile,
                                           param_dtype)
    lowered = fn.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 1)
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_hbm_per_device": int(ma.argument_size_in_bytes
                                       + ma.output_size_in_bytes
                                       + ma.temp_size_in_bytes
                                       - ma.alias_size_in_bytes),
        }
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    stats = parse_collectives(txt)
    rec["cost"] = {
        # trip-count-weighted per-device numbers from the HLO analyzer
        # (XLA-CPU cost_analysis counts while bodies once — see hloparse)
        "flops": stats.flops,
        "hbm_bytes": stats.hbm_bytes,
        "xla_flops_unweighted": float(ca.get("flops", 0.0)),
        "xla_bytes_unweighted": float(ca.get("bytes accessed", 0.0)),
    }
    rec["collectives"] = {
        "per_device_bytes": stats.total_coll_bytes,
        "by_kind_bytes": dict(stats.coll_bytes),
        "counts": {k: int(v) for k, v in stats.coll_counts.items()},
        "unknown_trip_loops": stats.unknown_trip_loops,
    }
    rec["hlo_chars"] = len(txt)
    print(compiled.memory_analysis())
    return rec


def cell_path(arch, shape_name, multi_pod, profile="default"):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = "" if profile == "default" else f"__{profile}"
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod-only", action="store_true")
    p.add_argument("--single-pod-only", action="store_true")
    p.add_argument("--profile", default="default")
    p.add_argument("--param-dtype", default=None)
    p.add_argument("--force", action="store_true")
    args = p.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    cells = []
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        shapes = [args.shape] if args.shape else list(_shapes_for(arch))
        for s in shapes:
            for mp in meshes:
                cells.append((arch, s, mp))

    failures = 0
    for arch, s, mp in cells:
        path = cell_path(arch, s, mp, args.profile)
        if os.path.exists(path) and not args.force:
            print(f"skip (cached) {path}")
            continue
        label = f"{arch} x {s} x {'2x16x16' if mp else '16x16'}"
        print(f"=== {label} ===", flush=True)
        try:
            rec = run_cell(arch, s, mp, args.profile, args.param_dtype)
        except Exception as e:
            failures += 1
            rec = {"arch": arch, "shape": s,
                   "mesh": "pod2x16x16" if mp else "pod16x16",
                   "profile": args.profile, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
            print(f"FAILED {label}: {e}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            print(f"ok in {rec['compile_s']}s  flops={rec['cost']['flops']:.3g} "
                  f"coll={rec['collectives']['per_device_bytes']:.3g}B", flush=True)
    print(f"done, failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
