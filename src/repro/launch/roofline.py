"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh):

    compute term    = HLO_FLOPs / (chips x peak)     [per-dev flops / peak]
    memory term     = HLO_bytes / (chips x HBM_bw)   [per-dev bytes / bw]
    collective term = collective_bytes / (chips x link_bw)

The dry-run stores PER-DEVICE numbers (post-SPMD partition shapes), so each
term is simply per-device quantity / per-device rate; the assignment's
global formulas are algebraically identical (global = per-device x chips).

MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (inference),
with N_active counted from the Spec trees (MoE experts scaled by top_k/E).
``roofline fraction`` = time the step WOULD take if it ran exactly at the
dominant-resource roofline vs the useful-model-FLOPs time — the headline
perf score.

Usage: python -m repro.launch.roofline [--md experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

from repro.configs.base import TrainConfig, get_config
from repro.core.latency import V5E
from repro.core.params import Spec, is_spec
from repro.models import api as mapi

import jax

DRY_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def active_params(arch: str) -> float:
    """N_active from the Spec trees (exact; MoE experts scaled k/E)."""
    cfg = get_config(arch)
    specs = mapi.get_api(cfg).specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec)[0]
    total = 0.0
    for path, spec in flat:
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        n = float(np.prod(spec.shape))
        if cfg.moe is not None and ("/moe/w" in keys or keys.endswith("moe/wg")
                                    or "/moe/" in keys and path[-1].key in ("wg", "wu", "wd")):
            n *= cfg.moe.top_k / max(cfg.moe.num_experts, 1)
        total += n
    return total


def model_flops_for(rec: dict) -> float:
    n_act = active_params(rec["arch"])
    B, S = rec["global_batch"], rec["seq_len"]
    if rec["kind"] == "train":
        return 6.0 * n_act * B * S
    if rec["kind"] == "prefill":
        return 2.0 * n_act * B * S
    return 2.0 * n_act * B           # decode: one token per sequence


def terms_for(rec: dict, hw=V5E) -> dict:
    chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    cfg = get_config(rec["arch"])
    peak = hw.peak_flops_bf16 if cfg.dtype == "bfloat16" else hw.peak_flops_fp32
    f_dev = rec["cost"]["flops"]
    b_dev = rec["cost"]["hbm_bytes"]
    c_dev = rec["collectives"]["per_device_bytes"]
    compute_s = f_dev / peak
    memory_s = b_dev / hw.hbm_bw
    coll_s = c_dev / hw.ici_bw
    total_s = max(compute_s, memory_s, coll_s)
    mf = model_flops_for(rec)
    useful_s = mf / (chips * peak)
    out = {
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "bound": max(
            (("compute", compute_s), ("memory", memory_s),
             ("collective", coll_s)), key=lambda kv: kv[1])[0],
        "model_flops": mf,
        "hlo_flops_global": f_dev * chips,
        "useful_ratio": mf / max(f_dev * chips, 1.0),
        "roofline_fraction": useful_s / max(total_s, 1e-30),
        "step_s": total_s,
    }
    return out


_ADVICE = {
    "compute": ("cut redundant HLO FLOPs (remat policy, fused attention, "
                "dedup matmuls) or move to bf16 MXU-shaped dots"),
    "memory": ("keep hot intermediates in VMEM (Pallas fusion of attention/"
               "cell epilogues), bf16 params/optimizer, bigger fusion scopes"),
    "collective": ("reshard to cut per-layer all-gathers (SP profile), "
                   "overlap collectives with compute, compress cross-pod "
                   "gradient traffic"),
}


def load_records():
    recs = []
    for p in sorted(glob.glob(os.path.join(DRY_DIR, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def make_table(recs, md_path=None):
    lines = []
    hdr = ("| arch | shape | mesh | chips | compute s | memory s | coll s | "
           "bound | MODEL/HLO | roofline frac |")
    lines.append(hdr)
    lines.append("|" + "---|" * 10)
    rows = []
    for rec in recs:
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                         f"- | - | - | - | SKIP | - | - |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                         f"- | - | - | - | ERROR | - | - |")
            continue
        t = terms_for(rec)
        rows.append((rec, t))
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | {t['chips']} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} | **{t['bound']}** "
            f"| {t['useful_ratio']:.3f} | {t['roofline_fraction']:.3f} |")
    table = "\n".join(lines)
    if md_path:
        notes = ["", "### Per-cell bottleneck advice", ""]
        for rec, t in rows:
            notes.append(f"- **{rec['arch']} x {rec['shape']} x {rec['mesh']}**"
                         f" ({t['bound']}-bound): {_ADVICE[t['bound']]}")
        with open(md_path, "w") as f:
            f.write("# Roofline (derived from the multi-pod dry-run)\n\n"
                    + table + "\n" + "\n".join(notes) + "\n")
    return table, rows


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--md", default=os.path.join(DRY_DIR, "..", "roofline.md"))
    args = p.parse_args()
    recs = load_records()
    table, rows = make_table(recs, args.md)
    print(table)
    print(f"\n{len(rows)} ok cells; table written to {args.md}")


if __name__ == "__main__":
    main()
