"""Trip-count-weighted accounting over post-SPMD optimized HLO text.

XLA-CPU's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE
(verified: a 10-iteration scanned matmul reports the flops of one), so
scan-over-layers programs would be undercounted ~L-fold. This module
re-derives the three roofline inputs from the HLO text itself:

* ``flops``      — 2 * prod(output dims) * prod(contraction dims) for every
  ``dot``, recursing through fusion/control-flow computations and
  multiplying by ``known_trip_count`` on while loops.
* ``hbm_bytes``  — operand + output bytes of every top-level instruction
  (post-fusion boundaries = HBM traffic); fusion-internal instructions are
  NOT counted (they live in registers/VMEM); while bodies count per trip.
* ``collectives`` — operand bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, per kind, trip-weighted.

Operand shapes are resolved through a per-computation symbol table
(instruction name -> result shape), since this dialect prints operands
untyped (``dot(%x.1, %w.1)``).

All shapes in ``compiled.as_text()`` are PER-DEVICE (the SPMD partition),
so every number here is per-chip; the roofline layer converts to the
assignment's global formulas.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "copy-start",
             "copy-done"}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
# out-shape may be a tuple containing /*index=N*/ comments — match lazily up
# to the first " op(" token.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_CALLED_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COMP_DEF_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")


def _shapes_in(s: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(s):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _operand_names(args_str: str) -> List[str]:
    """Names inside op( ... ) at nesting depth 0, attrs stripped. Operands
    may be typed (``dot(f32[128,128]{1,0} %x, ...)`` in older dialects), so
    commas inside brackets/braces must not split."""
    out, depth, cur = [], 0, []
    for ch in args_str:
        if ch in "([{":
            depth += 1
            cur.append(ch)
        elif ch in ")]}":
            if ch == ")" and depth == 0:
                break
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    names = []
    for tok in out:
        m = re.search(r"%?([\w.\-]+)\s*$", tok)
        if m:
            names.append(m.group(1))
    return names


@dataclass
class Account:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "Account", mult: float = 1.0, with_bytes: bool = True):
        self.flops += other.flops * mult
        if with_bytes:
            self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))


@dataclass
class _Comp:
    symtab: Dict[str, int]                    # name -> result bytes
    instrs: List["_Instr"] = field(default_factory=list)
    params: Dict[int, str] = field(default_factory=dict)   # index -> name


@dataclass
class _Instr:
    op: str
    out_bytes: int
    operands: List[str]
    dot_flops: float
    calls: List[Tuple[str, int, str]]         # (callee, trip, kind)
    coll_kind: Optional[str] = None
    is_root: bool = False
    name: str = ""


def _split_lines(hlo_text: str):
    """Yield (comp_name, header_params_str, instr_lines) per computation."""
    name, params, lines = None, "", []
    for raw in hlo_text.splitlines():
        mdef = _COMP_DEF_RE.match(raw)
        if mdef and "{" in raw and "=" not in raw.split("(")[0]:
            if name is not None:
                yield name, params, lines
            name, params, lines = mdef.group(1), mdef.group(2), []
            continue
        if name is None:
            continue
        if raw.strip().startswith("}"):
            yield name, params, lines
            name, params, lines = None, "", []
            continue
        lines.append(raw)
    if name is not None:
        yield name, params, lines


def _parse(hlo_text: str) -> Dict[str, _Comp]:
    blocks = list(_split_lines(hlo_text))
    # pass 1: symbol tables (name -> shapes), per computation + global fallback
    shapes_local: Dict[str, Dict[str, list]] = {}
    shapes_global: Dict[str, list] = {}
    for cname, params, lines in blocks:
        tab: Dict[str, list] = {}
        for pdecl in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\))|[^,()]+)",
                                 params):
            sh = _shapes_in(pdecl.group(2))
            tab[pdecl.group(1)] = sh
            shapes_global.setdefault(pdecl.group(1), sh)
        for raw in lines:
            m = _INSTR_RE.match(raw)
            if not m:
                continue
            iname, out_shape_str = m.group(1), m.group(2)
            sh = _shapes_in(out_shape_str)
            tab[iname] = sh
            shapes_global.setdefault(iname, sh)
        shapes_local[cname] = tab

    def lookup(cname: str, oname: str) -> list:
        tab = shapes_local.get(cname, {})
        if oname in tab:
            return tab[oname]
        return shapes_global.get(oname, [])

    # pass 2: instruction accounting
    comps: Dict[str, _Comp] = {}
    for cname, params, lines in blocks:
        comp = _Comp(symtab={k: _bytes_of(v)
                             for k, v in shapes_local[cname].items()})
        comps[cname] = comp
        for raw in lines:
            m = _INSTR_RE.match(raw)
            if not m:
                continue
            name, out_shape_str, op, rest = m.groups()
            if op.endswith("-done"):
                continue
            opn = op[:-6] if op.endswith("-start") else op
            out_shapes = _shapes_in(out_shape_str)
            out_bytes = _bytes_of(out_shapes)
            operands = _operand_names(rest)
            if opn == "parameter":
                mi = re.match(r"\s*(\d+)", rest)
                if mi:
                    comp.params[int(mi.group(1))] = name

            dot_flops = 0.0
            if opn == "dot" and operands:
                mc = _LHS_CONTRACT_RE.search(raw)
                lhs_shapes = lookup(cname, operands[0])
                if mc and lhs_shapes:
                    lhs_dims = lhs_shapes[0][1]
                    contract = 1
                    for d in (int(x) for x in mc.group(1).split(",") if x):
                        if d < len(lhs_dims):
                            contract *= lhs_dims[d]
                    out_elems = 1
                    for _, dims in out_shapes:
                        for d in dims:
                            out_elems *= d
                    dot_flops = 2.0 * out_elems * contract

            calls: List[Tuple[str, int, str]] = []
            trip = 1
            mt = _TRIP_RE.search(raw)
            if mt:
                trip = int(mt.group(1))
            kind = "while" if opn == "while" else ("call" if opn in (
                "call", "conditional", "custom-call", "async-start") else "fusion")
            for callee in _CALLED_RE.findall(raw):
                calls.append((callee, trip if opn == "while" else 1, kind))
            mb = _BRANCHES_RE.search(raw)
            if mb:
                for callee in mb.group(1).split(","):
                    callee = callee.strip().lstrip("%")
                    if callee:
                        calls.append((callee, 1, "call"))

            coll_kind = opn if opn in COLLECTIVES else None
            comp.instrs.append(_Instr(opn, out_bytes, operands, dot_flops,
                                      calls, coll_kind,
                                      is_root=raw.lstrip().startswith("ROOT"),
                                      name=name))
    return comps


# ---------------------------------------------------------------------------
# slice-aware fusion I/O: a fusion that reads a parameter only through
# dynamic-slice/gather touches the SLICE, not the whole operand (the stacked
# scan-over-layers tensors would otherwise be counted L times over); a fusion
# whose root is dynamic-update-slice writes the UPDATE, not the whole buffer.
# ---------------------------------------------------------------------------

_SLICE_READ_OPS = {"dynamic-slice", "gather", "slice"}


def _fusion_io(comps: Dict[str, _Comp], fused_name: str,
               operand_full_bytes: List[int]):
    """Effective (read_bytes, write_bytes_or_None) of one fusion call."""
    comp = comps.get(fused_name)
    if comp is None:
        return sum(operand_full_bytes), None
    # alias map: index-remapping / elementwise-1:1 ops are free inside a
    # fusion — a slice of a convert of a param touches only the slice.
    _PASS = ("bitcast", "copy", "convert", "reshape", "transpose", "tuple",
             "get-tuple-element")
    alias: Dict[str, str] = {}

    def root_of(nm: str) -> str:
        seen = []
        while nm in alias and nm not in seen:
            seen.append(nm)
            nm = alias[nm]
        return nm

    for ins in comp.instrs:
        if ins.op in _PASS and ins.operands:
            alias[ins.name] = ins.operands[0]

    # per-param access: max over (alias-resolved) uses; slice-like uses
    # count the slice, direct uses count the full tensor.
    access: Dict[str, int] = {}
    dus_writes = 0
    has_dus_root = False
    name_of_param = set(comp.params.values())
    for ins in comp.instrs:
        if ins.op in _PASS:
            continue
        for pos, o in enumerate(ins.operands):
            o = root_of(o)
            if o not in name_of_param:
                continue
            if ins.op in _SLICE_READ_OPS:
                use = ins.out_bytes
            elif ins.op == "dynamic-update-slice" and pos == 0:
                # in-place update: reads/writes only the update window
                use = comp.symtab.get(ins.operands[1], 0) if len(ins.operands) > 1 else ins.out_bytes
            else:
                use = comp.symtab.get(o, 0)
            access[o] = max(access.get(o, 0), use)
        if ins.is_root and ins.op == "dynamic-update-slice":
            has_dus_root = True
            dus_writes = (comp.symtab.get(ins.operands[1], 0)
                          if len(ins.operands) > 1 else ins.out_bytes)
    # a root that is a pass-through of a DUS still writes only the window
    if not has_dus_root:
        for ins in comp.instrs:
            if ins.is_root and ins.op in _PASS and ins.operands:
                src = root_of(ins.operands[0])
                for ins2 in comp.instrs:
                    if ins2.name == src and ins2.op == "dynamic-update-slice":
                        has_dus_root = True
                        dus_writes = (comp.symtab.get(ins2.operands[1], 0)
                                      if len(ins2.operands) > 1 else ins2.out_bytes)
    # read bytes: map params by index order to caller operands, capped
    reads = 0
    for idx, full in enumerate(operand_full_bytes):
        pname = comp.params.get(idx)
        if pname is None:
            reads += full
        else:
            reads += min(access.get(pname, 0), full)
    return reads, (dus_writes if has_dus_root else None)


def analyze(hlo_text: str) -> Account:
    comps = _parse(hlo_text)
    memo: Dict[Tuple[str, bool], Account] = {}

    def resolve(cname: str, count_bytes: bool, seen=()) -> Account:
        key = (cname, count_bytes)
        if key in memo:
            return memo[key]
        acc = Account()
        comp = comps.get(cname)
        if comp is None or cname in seen:
            return acc
        for ins in comp.instrs:
            acc.flops += ins.dot_flops
            operand_full = [comp.symtab.get(o, 0) for o in ins.operands]
            operand_bytes = sum(operand_full)
            # plain calls are byte-transparent: traffic is accounted inside
            # the callee, where fusion slice-awareness applies (some dialects
            # wrap slice fusions in a call; counting the call site would
            # charge the FULL operand per loop trip)
            if count_bytes and ins.op not in _FREE_OPS and ins.op != "call":
                out_b, in_b = ins.out_bytes, operand_bytes
                if ins.op == "fusion":
                    fused = next((c for c, _, k in ins.calls if k == "fusion"),
                                 None)
                    if fused is not None:
                        in_b, dus_w = _fusion_io(comps, fused, operand_full)
                        if dus_w is not None:
                            out_b = dus_w
                elif ins.op in _SLICE_READ_OPS:
                    in_b = ins.out_bytes          # touch the slice, not the src
                elif ins.op == "dynamic-update-slice":
                    upd = (comp.symtab.get(ins.operands[1], 0)
                           if len(ins.operands) > 1 else ins.out_bytes)
                    in_b, out_b = upd, upd
                acc.hbm_bytes += out_b + in_b
            if ins.coll_kind:
                cb = operand_bytes or ins.out_bytes
                acc.coll_bytes[ins.coll_kind] = (
                    acc.coll_bytes.get(ins.coll_kind, 0.0) + cb)
                acc.coll_counts[ins.coll_kind] = (
                    acc.coll_counts.get(ins.coll_kind, 0) + 1)
            for callee, trip, kind in ins.calls:
                transparent = kind == "while" or ins.op == "call"
                sub = resolve(callee, count_bytes and transparent,
                              seen + (cname,))
                if kind == "while" and trip == 1 and sub.flops > 0:
                    acc.unknown_trip_loops += 1
                acc.add(sub, mult=trip,
                        with_bytes=(transparent and count_bytes))
        memo[key] = acc
        return acc

    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    if m and m.group(1) in comps:
        return resolve(m.group(1), True)
    total = Account()
    for c in comps:
        total.add(resolve(c, True))
    return total


# helper kept for dryrun.py
def parse_collectives(hlo_text: str) -> Account:
    return analyze(hlo_text)
