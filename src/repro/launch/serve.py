"""Serving driver: batched requests through the ServeEngine, or — with
``--replicas N`` (N > 1, cell families only) — through the fault-tolerant
FleetRouter (``repro.serve.fleet``). Cell-family archs (gru-jet,
slstm-jet, ...) serve feature-vector waves; which family a config runs is
resolved through the ``repro.core.cells`` registry, never hardcoded.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 4 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch gru-jet --smoke \
        --replicas 2 --inject-faults --requests 8

GRU waves run bucketed continuous batching: ``--slots`` bounds the live
batch (defaults to ``--requests``); give MORE requests than slots to
exercise mid-wave admit/retire. ``--gru-backend`` sets the executor
preference (``repro.core.runtime``): ``pallas`` serves through the fused
persistent stack kernel (one pallas_call per step), ``auto`` lets the
plan pick the cheapest legal backend. The resolved prefill/decode
backends are printed with the latency stats.

``--async`` serves through the asyncio front-end
(``repro.serve.async_frontend``): one client coroutine per request over a
FleetRouter — solo (``--replicas 1``) or fleet — with token streams
bitwise-identical to the synchronous path.

Fleet mode: ``--routing`` picks depth-aware vs static round-robin
dispatch; ``--inject-faults`` runs a seeded kill/restore + slow schedule
under a deterministic ManualClock (virtual time, zero sleeps) and prints
the fleet's fault accounting — the CLI face of ``docs/serving.md``.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.core import cells as cell_families
from repro.core.params import init_params
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--slots", type=int, default=0,
                   help="decode batch slots (0 = --requests); requests "
                        "beyond this queue and admit as slots free up (gru)")
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--vary-prompt", action="store_true",
                   help="gru: ragged prompt lengths (exercises buckets+mask)")
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--gru-backend",
                   choices=("xla", "pallas", "auto", "pallas_fused",
                            "pallas_chain", "sharded", "pallas_sharded",
                            "sharded_decode", "pallas_fused_q8",
                            "pallas_chain_q8"),
                   default=None,
                   help="executor backend preference (pallas = fused "
                        "kernel family; an exact name pins that backend — "
                        "the mesh-requiring ones [sharded, pallas_sharded, "
                        "sharded_decode] need a sharded launch and fall "
                        "through otherwise; the *_q8 pins serve the int8 "
                        "datapath regardless of the accuracy gate [explicit "
                        "opt-in]; auto = cheapest legal backend "
                        "— measured per-shape costs when "
                        "BENCH_backend_costs.json is loaded, the static "
                        "table otherwise, with the q8 backends eligible "
                        "only when BENCH_quant_accuracy.json records a "
                        "pass)")
    p.add_argument("--bucket-min", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--replicas", type=int, default=1,
                   help="gru: serve through a FleetRouter with this many "
                        "engine replicas (admission control, depth routing, "
                        "retry/hedging; see docs/serving.md)")
    p.add_argument("--inject-faults", action="store_true",
                   help="fleet: run a seeded kill/restore+slow schedule "
                        "under a deterministic virtual clock and print the "
                        "fault accounting (requires --replicas > 1)")
    p.add_argument("--routing", choices=("depth", "static"), default="depth",
                   help="fleet dispatch policy: measured queue-depth scoring "
                        "vs static round-robin")
    p.add_argument("--async", dest="use_async", action="store_true",
                   help="serve through the asyncio front-end "
                        "(repro.serve.async_frontend): one client coroutine "
                        "per request over a FleetRouter — works solo "
                        "(--replicas 1) and fleet; token streams are "
                        "bitwise-identical to the synchronous path "
                        "(cell families only; see docs/serving.md)")
    p.add_argument("--autotune", action="store_true",
                   help="attach an online AutoTuner (repro.serve.autotune): "
                        "wave size from the measured batch-latency curve, "
                        "prompt-bucket ladder from observed length "
                        "quantiles, served step timings folded back into "
                        "the CostModel — retuned only at wave boundaries; "
                        "prints the applied decisions (fleet mode tunes "
                        "each replica independently)")
    args = p.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    is_cell = cell_families.is_cell_family(cfg.family)
    if args.gru_backend and is_cell:
        cfg = cfg.replace(gru=dataclasses.replace(cfg.gru,
                                                  backend=args.gru_backend))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(args.seed),
                         cfg.param_dtype)
    rng = np.random.default_rng(args.seed)
    if is_cell:
        # cell-family (gru/slstm/...) feature-vector waves: prompts are
        # (S, X) float windows
        def plen():
            return (int(rng.integers(1, args.prompt_len + 1))
                    if args.vary_prompt else args.prompt_len)
        reqs = [Request(prompt=rng.normal(size=(plen(), cfg.gru.input_dim))
                        .astype(np.float32),
                        max_new_tokens=args.max_new)
                for _ in range(args.requests)]
    else:
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                            size=args.prompt_len)
                        .astype(np.int32),
                        max_new_tokens=args.max_new)
                for _ in range(args.requests)]
    if args.replicas > 1 or args.use_async:
        if not is_cell:
            p.error("--async/--replicas>1 serve through the FleetRouter, "
                    "which is cell-family only")
        # --async with --replicas 1 is the solo path through the same
        # front-end: one replica behind the asyncio transport
        return _serve_fleet(cfg, params, reqs, args)
    tuner = None
    if args.autotune:
        from repro.serve.autotune import AutoTuner
        tuner = AutoTuner()
    engine = ServeEngine(cfg, params, ShardCtx(),
                         max_batch=args.slots or args.requests,
                         bucket_min=args.bucket_min, tuner=tuner)
    done = engine.generate(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: {len(r.out)} tokens -> {r.out[:8]}...")
    stats = engine.latency_stats()
    print(f"decode latency: mean={stats['mean_s']*1e3:.2f}ms "
          f"p50={stats['p50_s']*1e3:.2f}ms p90={stats['p90_s']*1e3:.2f}ms "
          f"p99={stats['p99_s']*1e3:.2f}ms ({stats['steps']} steps); "
          f"prefill mean={stats['prefill_mean_s']*1e3:.2f}ms "
          f"({stats['prefills']} prefills, "
          f"{len(engine._prefill_jit)} bucket jits)")
    if is_cell:
        pf = sorted(set(engine.prefill_backends))
        steps = stats.get("decode_backend_steps", {})
        attributed = ",".join(f"{k}:{v}" for k, v in sorted(steps.items()))
        print(f"executor: prefill={'/'.join(pf) or '-'} "
              f"decode={engine.decode_backend} "
              f"dtype={stats.get('served_dtype')} "
              f"decode_steps=[{attributed or '-'}]")
    if args.autotune:
        _print_autotune(stats["autotune"])
    return done


def _print_autotune(at: dict) -> None:
    ladder = at.get("bucket_ladder")
    print(f"autotune: wave_size={at['wave_size']} "
          f"bucket_ladder={ladder or 'pow2'} "
          f"retunes={at.get('retunes', 0)} "
          f"prompts_observed={at.get('prompts_observed', 0)}")
    for d in at.get("decisions", ()):
        print(f"  [{d['kind']}] {d['from']} -> {d['to']} "
              f"({d['measurement'].get('rule', '')})")


def _serve_fleet(cfg, params, reqs, args):
    """Fleet mode: N supervised replicas behind one generate() call.
    ``--inject-faults`` runs the whole thing in deterministic virtual time
    (ManualClock) against a seeded kill/restore+slow schedule."""
    from repro.distributed.fault_tolerance import ManualClock
    from repro.serve.fleet import FaultInjector, FleetConfig, FleetRouter

    names = [f"replica{i}" for i in range(args.replicas)]
    clock = injector = None
    if args.inject_faults:
        clock = ManualClock()
        injector = FaultInjector.seeded(args.seed, names, horizon_s=0.6)
        print(f"fault schedule (seed {args.seed}): "
              + "; ".join(f"t={e.t:.3f} {e.kind} {e.replica}"
                          + (f" x{e.factor:g}" if e.kind == "slow" else "")
                          for e in injector._events))
    router = FleetRouter(cfg, params, replicas=args.replicas,
                         max_batch=args.slots or max(2, args.requests // 2),
                         bucket_min=args.bucket_min, clock=clock,
                         config=FleetConfig(routing=args.routing),
                         injector=injector, autotune=args.autotune)
    if args.use_async:
        from repro.serve.async_frontend import run_clients
        done = run_clients(router, reqs)
        print(f"async front-end: {len(reqs)} concurrent client coroutines "
              f"over {args.replicas} replica(s)")
    else:
        done = router.generate(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: {len(r.out)} tokens -> {r.out[:8]}...")
    s = router.stats()
    print(f"fleet: {args.replicas} replicas routing={s['routing']} "
          f"completed={s['completed']}/{s['submitted']} "
          f"failed={s['failed']} shed={s['shed'] or '{}'} "
          f"retries={s['retries']} hedges={s['hedges']} "
          f"kills={s['kills']} restores={s['restores']}")
    for name, rs in s["replicas"].items():
        line = (f"  {name}: alive={rs['alive']} restarts={rs['restarts']} "
                f"steps={rs['steps']} requests={rs['requests']}")
        if args.autotune:
            line += (f" wave_size={rs['wave_size']} "
                     f"bucket_ladder={rs['bucket_ladder'] or 'pow2'} "
                     f"retunes={rs['retunes']}")
        print(line)
    return done


if __name__ == "__main__":
    main()
