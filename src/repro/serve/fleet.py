"""Fault-tolerant multi-replica serving fleet: async front-door router
with admission control, retry/hedging, and deterministic fault injection.

The paper's contract is a per-request latency budget on the sequential GRU
decode path. One :class:`~repro.serve.engine.ServeEngine` keeps that budget
per kernel; this module keeps it per request while replicas crash, straggle
and recover. A :class:`FleetRouter` owns N engine replicas (possibly on
distinct placements) behind one ``submit()/generate()`` surface — the
runtime device-dispatch idiom: one user-facing call, replica chosen per
request at runtime.

Architecture (one cooperative scheduler, zero wall-clock sleeps):

* **Bounded admission** — ``submit`` raises a typed
  :class:`FleetRejected` (``reason="queue_full"`` /
  ``"deadline_infeasible"``) instead of queueing unboundedly; queued
  requests whose deadline lapses before dispatch are shed with
  ``reason="deadline"``.
* **Routing** — per request, by prompt bucket + measured per-replica
  queue depth: expected drain time = outstanding decode tokens x the
  replica's expected step time (the engine's own recent measured steps
  when available, else the CostModel's measured row for its resolved
  decode backend, else a nominal constant), plus a penalty for replicas
  that would have to compile the prompt's prefill bucket cold.
  ``routing="static"`` (round-robin) is kept as the benchmark's A/B arm.
* **Supervision** — every ``tick()``: live replicas beat a
  :class:`~repro.distributed.fault_tolerance.HeartbeatMonitor`; a replica
  that misses ``heartbeat_timeout_s`` of beats is declared dead and its
  in-flight requests are requeued with exponential backoff under a retry
  budget (re-dispatched from scratch — decode is deterministic, so a
  retried stream is bitwise the fault-free stream). Step times feed a
  :class:`~repro.distributed.fault_tolerance.StragglerMonitor`; a
  straggler's in-flight requests get a hedged duplicate dispatch on the
  fastest non-straggler, first finisher wins, the loser's lane is
  cancelled. A restored replica re-enters the rotation warm:
  :meth:`FleetReplica.restart` rebuilds its engine, which re-runs
  ``prepare()`` against the replica's placement.
* **Cancellation** — :meth:`FleetRouter.cancel` propagates a client
  disconnect end to end: the ticket is tombstoned out of the queue (an
  O(1) status flip; ``_dispatch_queued`` lazily skips and drops
  non-queued entries, so the admission deque is never scanned — large
  queues are the normal case under the async front-end), every live
  flight's wave lane is freed (``gru_wave_cancel``) including any hedged
  duplicate, and the ticket lands in ``status="cancelled"``
  (``reason="client_disconnect"``) — never counted as completed or
  failed.
* **Deadlines** — enforced end to end, not just at admission: a queued
  ticket whose deadline lapses is shed before dispatch, and an IN-FLIGHT
  ticket past its budget is shed mid-decode — its wave lanes (hedges
  included) are cancelled so no replica keeps spending decode steps on a
  request that can only be returned late. Both count a ``"deadline"``
  shed.
* **Async transport** — :class:`repro.serve.async_frontend.AsyncFleetClient`
  wraps this router in an asyncio front-end: a background scheduler task
  owns the ``tick()`` loop (each tick runs on a single worker thread so
  the jit-bound ``gru_wave_step`` never stalls the event loop), clients
  get per-token async streams, and coroutine cancellation wires client
  disconnects into :meth:`cancel`. The router itself stays a
  single-threaded cooperative scheduler — the front-end serializes every
  router call through that one worker thread.
* **Autotuning** (``autotune=True``) — one
  :class:`~repro.serve.autotune.AutoTuner` per replica closes the loop
  from that replica's measured serving back into its engine's wave size
  and bucket ladder, and folds served step timings into the shared
  CostModel — which the depth-routing prior (``_step_cost_s``) reads
  live, so routing estimates refresh with recalibration. See
  ``docs/serving.md`` ("Autotuning").
* **Fault injection** — a :class:`FaultInjector` holds a schedule of
  kill / restore / slow / delay events against the router's injectable
  clock. Under a ``ManualClock`` the router itself advances virtual time
  ``tick_s`` per tick, so every failure path runs deterministically in
  tier-1 tests; under a ``SystemClock`` the same schedule drives a live
  load test (``benchmarks/serve_fleet.py``).

Simulated-time semantics (``ManualClock``): a replica with
``slow_factor=f`` executes one decode step every f ticks (a straggler is
genuinely slower, so hedges genuinely win) and records ``tick_s * f`` as
its step time. Under a real clock the fleet is single-process, so
``slow``/``delay`` events inflate the *recorded* step signal (detection
and mitigation are real; the slowdown itself is simulated). Virtual time
advances ``tick_s`` per *service* tick only: ``generate()``'s
backpressure pump — ticks spent merely waiting for a queue slot — runs
``tick(advance_time=False)``, so waiting for admission never counts as
service time against queued tickets' deadlines or retry backoffs (the
clock still moves when a pump tick can make no progress at all, e.g.
every replica dead awaiting a scheduled restore — genuine waiting).

See ``docs/serving.md`` for the failure-mode table mapping each event to
its detection signal, mitigation, and covering test.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import cells
from repro.distributed.fault_tolerance import (Clock, HeartbeatMonitor,
                                               ManualClock, StragglerMonitor,
                                               SystemClock)
from repro.distributed.sharding import ShardCtx
from repro.serve.autotune import AutoTuneConfig, AutoTuner
from repro.serve.engine import Request, ServeEngine


def _pct(xs: List[float], q: float) -> float:
    """Percentile that refuses to invent numbers: empty history is NaN,
    never 0.0 — a replica/arm that served nothing must not report a
    perfect p99 (which could silently pass a latency-ratio CI gate)."""
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def _mean(xs: List[float]) -> float:
    return float(np.mean(np.asarray(xs))) if xs else float("nan")


class FleetRejected(RuntimeError):
    """Typed admission rejection: load is shed with a reason, never by
    silent unbounded queueing. ``reason`` is one of ``"queue_full"``,
    ``"deadline_infeasible"``, ``"deadline"``."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"{reason}: {detail}" if detail else reason)
        self.reason = reason


@dataclass
class FaultEvent:
    """One scheduled fault: fires when the router clock reaches ``t``."""
    t: float
    kind: str                        # "kill" | "restore" | "slow" | "delay"
    replica: str
    factor: float = 1.0              # slow: service-time multiplier
    delay_s: float = 0.0             # delay: one-off added service time


class FaultInjector:
    """Deterministic fault schedule, drained against the router's clock.

    Events are applied at the first tick whose clock time reaches
    ``event.t`` — with a ``ManualClock`` that instant is exact and
    reproducible, so tests exercise kill/restore/straggle paths without a
    single wall-clock sleep.
    """

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self._events = sorted(events, key=lambda e: (e.t, e.replica, e.kind))
        self._i = 0
        self.applied: List[FaultEvent] = []

    def __len__(self) -> int:
        return len(self._events) - self._i

    def due(self, now: float) -> List[FaultEvent]:
        out = []
        while self._i < len(self._events) and self._events[self._i].t <= now:
            out.append(self._events[self._i])
            self._i += 1
        self.applied.extend(out)
        return out

    @classmethod
    def seeded(cls, seed: int, replica_names: Sequence[str],
               horizon_s: float, kill_prob: float = 0.6,
               slow_prob: float = 0.4, slow_factor: float = 6.0,
               t0: float = 0.0) -> "FaultInjector":
        """A reproducible random schedule: each replica independently gets
        a kill->restore window (prob ``kill_prob``) and/or a slow window
        (prob ``slow_prob``) inside ``[t0 + 10%, t0 + 90%]`` of the
        horizon. Every kill is paired with a restore, so a seeded schedule
        can stall the fleet but never strand it."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for name in replica_names:
            if rng.random() < kill_prob:
                t_kill = t0 + horizon_s * rng.uniform(0.1, 0.5)
                t_back = t_kill + horizon_s * rng.uniform(0.15, 0.4)
                events.append(FaultEvent(t=t_kill, kind="kill", replica=name))
                events.append(FaultEvent(t=t_back, kind="restore",
                                         replica=name))
            if rng.random() < slow_prob:
                t_slow = t0 + horizon_s * rng.uniform(0.1, 0.6)
                t_fast = t_slow + horizon_s * rng.uniform(0.1, 0.3)
                events.append(FaultEvent(t=t_slow, kind="slow", replica=name,
                                         factor=slow_factor))
                events.append(FaultEvent(t=t_fast, kind="slow", replica=name,
                                         factor=1.0))
        return cls(events)


@dataclass
class FleetConfig:
    """Router policy knobs (all timing in clock seconds)."""
    queue_limit: int = 64            # bound on outstanding (queued+in-flight)
    retry_budget: int = 3            # re-dispatches after replica death
    backoff_base_s: float = 0.02     # retry n waits base * 2^(n-1)
    heartbeat_timeout_s: float = 0.25
    straggler_factor: float = 3.0
    straggler_window: int = 8
    hedge: bool = True               # duplicate-dispatch straggler requests
    routing: str = "depth"           # "depth" (measured) | "static" (RR)
    tick_s: float = 0.01             # virtual seconds per tick (ManualClock)
    nominal_step_s: float = 1e-3     # expected step time with no signal
    bucket_penalty_s: float = 0.05   # routing cost of a cold prefill bucket


# identity semantics: tickets live in queues/lists that are searched with
# ``in``/``remove`` — field-wise dataclass eq would compare the numpy
# prompt arrays inside Request (ambiguous truth value)
@dataclass(eq=False)
class FleetTicket:
    """One admitted request's lifecycle in the fleet. ``id`` is the
    router-assigned request id — the handle a client passes back to
    :meth:`FleetRouter.cancel` on disconnect."""
    request: Request
    t_submit: float
    id: int = -1
    deadline_s: Optional[float] = None    # relative to t_submit
    status: str = "queued"   # queued|inflight|done|shed|failed|cancelled
    reason: Optional[str] = None
    retries: int = 0
    hedged: bool = False
    not_before: float = 0.0          # backoff gate (clock time)
    t_first_dispatch: Optional[float] = None
    t_done: Optional[float] = None
    replicas: List[str] = field(default_factory=list)   # dispatch history
    flights: List["_Flight"] = field(default_factory=list)

    @property
    def outstanding(self) -> bool:
        return self.status in ("queued", "inflight")


@dataclass(eq=False)                     # identity, same as FleetTicket
class _Flight:
    """One dispatch attempt: a fresh clone of the ticket's request served
    by one replica (retries and hedges each get their own flight, so a
    half-decoded attempt never leaks partial output into the result)."""
    ticket: FleetTicket
    replica: "FleetReplica"
    clone: Request
    hedge: bool = False


class FleetReplica:
    """One supervised engine replica. ``build_engine`` rebuilds it from
    scratch on restart — ``ServeEngine.__init__`` re-runs ``prepare()``
    against the replica's placement, so a recovered replica re-enters the
    rotation with weights placed (warm), not on the request hot path."""

    def __init__(self, name: str, build_engine: Callable[[], ServeEngine]):
        self.name = name
        self._build = build_engine
        self.engine = build_engine()
        self.alive = True
        self.slow_factor = 1.0
        self.pending_delay_s = 0.0
        self.restarts = 0
        self.steps = 0
        self.flights: List[_Flight] = []
        self._sim_credit = 0.0       # ManualClock: fractional step budget

    def kill(self) -> None:
        """Simulated crash: stops beating and stepping; wave state is lost
        (the rebuilt engine starts empty, like a restarted process)."""
        self.alive = False

    def restart(self) -> None:
        """Re-enter the rotation warm: fresh engine, prepare() re-run."""
        self.engine = self._build()
        self.alive = True
        self.slow_factor = 1.0
        self.pending_delay_s = 0.0
        self._sim_credit = 0.0
        self.flights = []
        self.restarts += 1


class FleetRouter:
    """Async front-door for N ServeEngine replicas: bounded admission,
    depth-aware routing, retry/hedging, fault supervision.

    ``submit()`` is the async surface: it enqueues and returns a
    :class:`FleetTicket` immediately (or raises :class:`FleetRejected`);
    ``tick()`` advances the whole fleet one scheduler round;
    ``run_until_done()`` pumps ticks until nothing is outstanding;
    ``generate(requests)`` is the one-call convenience wrapper.
    """

    def __init__(self, cfg, params, *, replicas: int = 2,
                 ctxs: Optional[Sequence[ShardCtx]] = None,
                 max_batch: int = 4, bucket_min: int = 8,
                 clock: Optional[Clock] = None,
                 config: FleetConfig = FleetConfig(),
                 injector: Optional[FaultInjector] = None,
                 autotune: bool = False,
                 tuner_config: Optional[AutoTuneConfig] = None):
        if not cells.is_cell_family(cfg.family):
            raise NotImplementedError("the fleet serves registered cell "
                                      "families (stepwise waves: "
                                      f"{sorted(cells.families())}); "
                                      "use ServeEngine directly for LM "
                                      "batches")
        self.cfg = cfg
        self.config = config
        self.clock = clock or SystemClock()
        self.injector = injector
        self.max_batch = max_batch
        # autotune=True attaches one AutoTuner PER REPLICA (each replica's
        # engine tunes to its own observed traffic; the recalibration
        # dimension feeds the shared process-wide CostModel, which the
        # routing prior _step_cost_s reads live). A restarted replica gets
        # a fresh tuner, consistent with its empty jit caches.
        self.autotune = bool(autotune)
        ctxs = list(ctxs) if ctxs is not None else [ShardCtx()] * replicas
        assert len(ctxs) == replicas

        def _builder(ctx):
            def build():
                tuner = (AutoTuner(tuner_config or AutoTuneConfig())
                         if self.autotune else None)
                return ServeEngine(cfg, params, ctx, max_batch=max_batch,
                                   bucket_min=bucket_min, clock=self.clock,
                                   tuner=tuner)
            return build

        self.replicas = [FleetReplica(f"replica{i}", _builder(ctx))
                         for i, ctx in enumerate(ctxs)]
        self._by_name = {r.name: r for r in self.replicas}
        self.heartbeats = HeartbeatMonitor(
            timeout_s=config.heartbeat_timeout_s, clock=self.clock)
        self.stragglers = StragglerMonitor(
            factor=config.straggler_factor, window=config.straggler_window,
            clock=self.clock)
        for r in self.replicas:
            self.heartbeats.beat(r.name)
        self.tickets: List[FleetTicket] = []
        self._by_id: Dict[int, FleetTicket] = {}
        self._next_id = 0
        self._queue: deque = deque()
        self._deadlined: List[FleetTicket] = []  # outstanding w/ deadline_s
        self._outstanding = 0
        self._rr = -1                # static round-robin cursor
        self.ticks = 0
        self.counters: Dict[str, int] = {
            "submitted": 0, "completed": 0, "failed": 0, "retries": 0,
            "cancelled": 0, "hedges": 0, "hedges_cancelled": 0, "kills": 0,
            "restores": 0}
        self.sheds: Dict[str, int] = {}
        self._e2e: List[float] = []
        self._queue_waits: List[float] = []

    # -- admission -----------------------------------------------------------

    def submit(self, request: Request,
               deadline_s: Optional[float] = None) -> FleetTicket:
        """Admit one request (non-blocking). Raises :class:`FleetRejected`
        when the outstanding set is at ``queue_limit`` or a requested
        deadline cannot be met even on the least-loaded replica."""
        now = self.clock.now()
        if self._outstanding >= self.config.queue_limit:
            self.sheds["queue_full"] = self.sheds.get("queue_full", 0) + 1
            raise FleetRejected("queue_full",
                                f"{self._outstanding} outstanding >= "
                                f"limit {self.config.queue_limit}")
        if deadline_s is not None:
            est = self._estimated_service_s(request)
            if est > deadline_s:
                self.sheds["deadline_infeasible"] = (
                    self.sheds.get("deadline_infeasible", 0) + 1)
                raise FleetRejected(
                    "deadline_infeasible",
                    f"estimated {est:.4f}s > deadline {deadline_s:.4f}s")
        if request.t_submit is None:
            request.t_submit = now
        t = FleetTicket(request=request, t_submit=now, id=self._next_id,
                        deadline_s=deadline_s)
        self._next_id += 1
        self._by_id[t.id] = t
        self.tickets.append(t)
        self._queue.append(t)
        if deadline_s is not None:
            self._deadlined.append(t)
        self._outstanding += 1
        self.counters["submitted"] += 1
        return t

    def cancel(self, handle) -> bool:
        """Client-disconnect propagation: drop an outstanding request
        everywhere it lives — the bounded queue, the owning replica's
        wave lane (:meth:`ServeEngine.gru_wave_cancel`), AND any hedged
        duplicate still racing on another replica. ``handle`` may be the
        :class:`FleetTicket`, its integer ``id``, or the original
        :class:`Request`. Returns False when the ticket is not
        outstanding (already done / shed / failed / cancelled): a
        disconnect after completion is a no-op — the result already
        landed in ``request.out``.

        A still-queued ticket is TOMBSTONED, not removed: the status flip
        to ``"cancelled"`` is O(1) and ``_dispatch_queued`` drops the
        stale deque entry on its next pass (it already pops everything
        each tick and skips non-queued tickets). The old
        ``t in self._queue`` / ``remove`` pair scanned the whole deque
        per cancel — O(queue_limit) per disconnect, which the async
        front-end turns into the common case."""
        t = self._find_ticket(handle)
        if t is None or not t.outstanding:
            return False
        self._release_flights(t)
        t.status = "cancelled"
        t.reason = "client_disconnect"
        t.t_done = self.clock.now()
        self._outstanding -= 1
        self.counters["cancelled"] += 1
        return True

    def _release_flights(self, t: FleetTicket) -> None:
        """Free every live lane a ticket holds (cancel + deadline-shed
        path): the wave lane releases immediately — a dead replica's
        engine is about to be rebuilt anyway, so a failed wave-cancel
        there is fine — and cancelled hedges are counted."""
        for fl in list(t.flights):
            fl.replica.engine.gru_wave_cancel(fl.clone)
            if fl in fl.replica.flights:
                fl.replica.flights.remove(fl)
            t.flights.remove(fl)
            if fl.hedge:
                self.counters["hedges_cancelled"] += 1

    def _find_ticket(self, handle) -> Optional[FleetTicket]:
        if isinstance(handle, FleetTicket):
            return handle
        if isinstance(handle, (int, np.integer)):
            return self._by_id.get(int(handle))
        for t in reversed(self.tickets):     # a Request: newest wins
            if t.request is handle:
                return t
        return None

    def generate(self, requests: Sequence[Request],
                 deadline_s: Optional[float] = None) -> List[Request]:
        """One-call surface: admit everything (pumping ticks while the
        bounded queue is full, i.e. backpressure instead of rejection) and
        serve to completion. Per-request results land in ``request.out``
        exactly as with a single engine."""
        tickets = []
        for r in requests:
            pumped = 0
            # a full queue is backpressure here, not overload: pump the
            # scheduler until a slot frees instead of shedding own work.
            # Waiting for admission is NOT service time: these ticks run
            # with advance_time=False, so the caller's already-queued
            # tickets don't burn deadline budget (and backoff gates don't
            # expire) merely because the caller is still submitting. When
            # a pump tick performs no decode step at all (every replica
            # dead/gated — the fleet genuinely cannot progress without
            # time moving), the clock advances normally so scheduled
            # restores and retry backoffs can fire.
            while self._outstanding >= self.config.queue_limit:
                if self.tick(advance_time=False) == 0 and isinstance(
                        self.clock, ManualClock):
                    self.clock.advance(self.config.tick_s)
                pumped += 1
                if pumped > 200_000:
                    raise RuntimeError(
                        "fleet queue never drained during generate()")
            tickets.append(self.submit(r, deadline_s=deadline_s))
        self.run_until_done()
        return list(requests)

    # -- scheduler -----------------------------------------------------------

    def run_until_done(self, max_ticks: int = 200_000) -> None:
        """Pump ``tick()`` until no ticket is outstanding. ``max_ticks``
        bounds broken schedules (e.g. a kill with no restore and no
        survivor) with a loud error instead of a hang."""
        n = 0
        while any(t.outstanding for t in self.tickets):
            self.tick()
            n += 1
            if n > max_ticks:
                raise RuntimeError(
                    f"fleet did not converge in {max_ticks} ticks: "
                    f"{sum(t.outstanding for t in self.tickets)} outstanding,"
                    f" alive={[r.name for r in self.replicas if r.alive]}")

    def tick(self, advance_time: bool = True) -> int:
        """One scheduler round: advance virtual time, apply due faults,
        beat/detect/requeue, shed lapsed deadlines, dispatch, step every
        live replica one decode step, hedge stragglers. Returns the
        number of decode steps performed this round.

        ``advance_time=False`` (the ``generate()`` admission pump) runs
        the full round without consuming virtual time under a ManualClock
        — waiting for a queue slot is not service time, so it must not
        age queued tickets' deadlines or expire retry backoffs. Under a
        SystemClock the flag is inert (real time is not ours to stop)."""
        self.ticks += 1
        if advance_time and isinstance(self.clock, ManualClock):
            self.clock.advance(self.config.tick_s)
        now = self.clock.now()
        if self.injector is not None:
            for ev in self.injector.due(now):
                self._apply_event(ev)
        for rep in self.replicas:
            if rep.alive:
                self.heartbeats.beat(rep.name)
        dead = set(self.heartbeats.dead_hosts())
        for rep in self.replicas:
            if rep.name in dead and rep.flights:
                self._on_replica_down(rep, now)
        self._shed_lapsed(now)
        self._dispatch_queued(now)
        stepped = 0
        for rep in self.replicas:
            stepped += self._step_replica(rep)
        if self.config.hedge:
            self._hedge_stragglers(now)
        return stepped

    def _apply_event(self, ev: FaultEvent) -> None:
        rep = self._by_name[ev.replica]
        if ev.kind == "kill":
            if rep.alive:
                rep.kill()
                self.counters["kills"] += 1
        elif ev.kind == "restore":
            if not rep.alive:
                rep.restart()
                self.heartbeats.beat(rep.name)   # back in the rotation
                self.counters["restores"] += 1
        elif ev.kind == "slow":
            rep.slow_factor = float(ev.factor)
        elif ev.kind == "delay":
            rep.pending_delay_s += float(ev.delay_s)
        else:
            raise ValueError(f"unknown fault kind: {ev.kind!r}")

    def _on_replica_down(self, rep: FleetReplica, now: float) -> None:
        """Requeue a dead replica's in-flight requests: each surviving
        ticket re-enters the queue from scratch with exponential backoff,
        up to the retry budget. A ticket whose hedge is still live on
        another replica just loses this flight."""
        for fl in rep.flights:
            t = fl.ticket
            if fl in t.flights:
                t.flights.remove(fl)
            if t.status != "inflight":
                continue
            if any(f.replica.alive for f in t.flights):
                continue                         # hedge still racing
            t.retries += 1
            if t.retries > self.config.retry_budget:
                t.status = "failed"
                t.reason = "retry_budget"
                self._outstanding -= 1
                self.counters["failed"] += 1
                continue
            t.status = "queued"
            t.not_before = now + (self.config.backoff_base_s
                                  * 2 ** (t.retries - 1))
            self._queue.append(t)
            self.counters["retries"] += 1
        rep.flights = []

    def _shed_lapsed(self, now: float) -> None:
        """End-to-end deadline enforcement: shed every outstanding ticket
        whose submit->now age exceeds its deadline — queued tickets are
        tombstoned out of the deque (lazy drop in ``_dispatch_queued``),
        and IN-FLIGHT tickets have their wave lanes (hedges included)
        cancelled so no replica keeps spending decode steps on a request
        that can only be returned late. Only tickets submitted with a
        deadline live on ``_deadlined`` (resolved ones are pruned here),
        so this never scans the admission deque or the full ticket
        history."""
        if not self._deadlined:
            return
        still: List[FleetTicket] = []
        for t in self._deadlined:
            if not t.outstanding:
                continue                     # resolved some other way
            if now - t.t_submit > t.deadline_s:
                self._release_flights(t)     # no-op for queued tickets
                t.status = "shed"
                t.reason = "deadline"
                t.t_done = now
                self._outstanding -= 1
                self.sheds["deadline"] = self.sheds.get("deadline", 0) + 1
            else:
                still.append(t)
        self._deadlined = still

    def _dispatch_queued(self, now: float) -> None:
        alive = [r for r in self.replicas if r.alive]
        if not alive:
            return
        held = []
        while self._queue:
            t = self._queue.popleft()
            if t.status != "queued":
                continue                     # tombstone (cancelled/shed):
                                             # lazily dropped here, never
                                             # scanned out of the deque
            if t.not_before > now:
                held.append(t)                   # backoff not elapsed
                continue
            self._dispatch(t, self._route(t, alive), now)
        self._queue.extend(held)

    def _dispatch(self, t: FleetTicket, rep: FleetReplica, now: float,
                  hedge: bool = False) -> None:
        r = t.request
        clone = Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                        eos_id=r.eos_id, stream=r.stream)
        fl = _Flight(ticket=t, replica=rep, clone=clone, hedge=hedge)
        t.flights.append(fl)
        rep.flights.append(fl)
        t.status = "inflight"
        t.replicas.append(rep.name)
        if t.t_first_dispatch is None:
            t.t_first_dispatch = now
            self._queue_waits.append(now - t.t_submit)
        rep.engine.gru_wave_enqueue([clone])

    def _step_replica(self, rep: FleetReplica) -> int:
        """Advance one replica one decode step; returns 1 if it stepped
        (the tick's service-progress signal), 0 otherwise."""
        if not rep.alive or rep.engine.gru_wave_active() == 0:
            return 0
        sim = isinstance(self.clock, ManualClock)
        if sim and rep.slow_factor > 1.0:
            # a straggler genuinely runs fewer steps per unit virtual time
            rep._sim_credit += 1.0 / rep.slow_factor
            if rep._sim_credit < 1.0:
                return 0
            rep._sim_credit -= 1.0
        t0 = self.clock.now()
        finished = rep.engine.gru_wave_step()
        measured = self.clock.now() - t0
        if sim:
            dt = self.config.tick_s * rep.slow_factor + rep.pending_delay_s
        else:
            dt = measured * rep.slow_factor + rep.pending_delay_s
        rep.pending_delay_s = 0.0
        rep.steps += 1
        self.stragglers.record(rep.name, dt)
        for clone in finished:
            for fl in list(rep.flights):
                if fl.clone is clone:
                    self._resolve(fl)
                    break
        return 1

    def _resolve(self, fl: _Flight) -> None:
        """First finisher wins the ticket: copy the clone's stream into the
        user's request and cancel every other flight (hedge losers)."""
        t = fl.ticket
        fl.replica.flights.remove(fl)
        if fl in t.flights:
            t.flights.remove(fl)
        if t.status != "inflight":
            return                               # already resolved/shed
        t.request.out = list(fl.clone.out)
        t.request.done = True
        t.request.t_finish = fl.clone.t_finish
        t.status = "done"
        t.t_done = self.clock.now()
        self._outstanding -= 1
        self.counters["completed"] += 1
        self._e2e.append(t.t_done - t.t_submit)
        for other in list(t.flights):
            other.replica.engine.gru_wave_cancel(other.clone)
            if other in other.replica.flights:
                other.replica.flights.remove(other)
            t.flights.remove(other)
            self.counters["hedges_cancelled"] += 1

    def _hedge_stragglers(self, now: float) -> None:
        strag = set(self.stragglers.stragglers())
        if not strag:
            return
        fast = [r for r in self.replicas
                if r.alive and r.name not in strag]
        if not fast:
            return
        for rep in self.replicas:
            if rep.name not in strag:
                continue
            for fl in list(rep.flights):
                t = fl.ticket
                if t.hedged or t.status != "inflight" or len(t.flights) > 1:
                    continue
                target = min(fast, key=lambda r: self._expected_wait_s(r))
                t.hedged = True
                self.counters["hedges"] += 1
                self._dispatch(t, target, now, hedge=True)

    # -- routing -------------------------------------------------------------

    def _route(self, t: FleetTicket, alive: List[FleetReplica]
               ) -> FleetReplica:
        if self.config.routing == "static":
            self._rr = (self._rr + 1) % len(alive)
            return alive[self._rr]
        S = int(np.asarray(t.request.prompt).reshape(
            -1, self.cfg.gru.input_dim).shape[0])

        def score(rep: FleetReplica) -> float:
            s = self._expected_wait_s(rep)
            if not rep.engine.bucket_warm(S):
                s += self.config.bucket_penalty_s
            return s

        return min(alive, key=score)

    def _expected_wait_s(self, rep: FleetReplica) -> float:
        """Expected time for this replica to drain its outstanding work:
        decode tokens owed x expected step time / slots. Step time comes
        from the replica's own recent measured steps, else the CostModel's
        measured row for the resolved decode backend, else nominal."""
        _, tokens = rep.engine.gru_work_remaining()
        return (tokens / max(1, self.max_batch)) * self._step_cost_s(rep)

    def _step_cost_s(self, rep: FleetReplica) -> float:
        recent = rep.engine.step_times[-self.config.straggler_window:]
        med = float(np.median(recent)) if recent else 0.0
        if med > 0.0:
            return med * rep.slow_factor
        step = self.config.nominal_step_s
        try:                          # the CostModel's measured rows
            from repro.core import runtime
            g = self.cfg.gru
            exe = runtime.compile(g, batch=self.max_batch, mode="decode",
                                  placement=rep.engine.ctx.mesh)
            us = runtime.cost_model().lookup(
                exe.decode_backend, "decode", depth=g.num_layers,
                batch=self.max_batch, hidden=g.hidden_dim,
                family=cells.cfg_family(g))
            if us is not None:
                step = us * 1e-6
        except Exception:             # routing must never take a fleet down
            pass
        return step * rep.slow_factor

    def _estimated_service_s(self, request: Request) -> float:
        """Admission-time completion estimate on the least-loaded replica
        (queue drain + the request's own decode tokens)."""
        alive = [r for r in self.replicas if r.alive]
        if not alive:
            return float("inf")
        return min(self._expected_wait_s(r)
                   + max(1, request.max_new_tokens) * self._step_cost_s(r)
                   for r in alive)

    # -- stats ---------------------------------------------------------------

    def stats(self) -> dict:
        """Fleet-level accounting + per-replica engine latency stats. The
        e2e percentiles here include fleet queueing, retries and hedging —
        the honest per-request numbers the paper's deadline is judged by.
        A fleet that completed nothing reports NaN percentiles, never a
        fake-perfect 0.0 (see ``_pct``) — consumers must check
        ``completed`` before trusting the tails."""
        per_replica = {}
        for rep in self.replicas:
            ls = rep.engine.latency_stats()
            at = ls["autotune"]
            per_replica[rep.name] = {
                "alive": rep.alive, "restarts": rep.restarts,
                "steps": rep.steps, "slow_factor": rep.slow_factor,
                "decode_p50_s": ls["p50_s"], "decode_p99_s": ls["p99_s"],
                "queue_wait_p99_s": ls["queue_wait_p99_s"],
                "requests": ls["requests"],
                # tuned shape summary (full decision records stay on the
                # engine: latency_stats()["autotune"]["decisions"])
                "wave_size": at["wave_size"],
                "bucket_ladder": at["bucket_ladder"],
                "retunes": at.get("retunes", 0)}
        return {**self.counters,
                "shed": dict(self.sheds),
                "outstanding": self._outstanding,
                "ticks": self.ticks,
                "routing": self.config.routing,
                "autotune": self.autotune,
                "e2e_mean_s": _mean(self._e2e),
                "e2e_p50_s": _pct(self._e2e, 50),
                "e2e_p99_s": _pct(self._e2e, 99),
                "queue_wait_p50_s": _pct(self._queue_waits, 50),
                "queue_wait_p99_s": _pct(self._queue_waits, 99),
                "replicas": per_replica}
