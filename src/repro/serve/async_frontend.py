"""Asyncio front-end for the serving fleet: concurrent clients over the
cooperative :class:`~repro.serve.fleet.FleetRouter` scheduler.

The router is a deterministic single-threaded scheduler — ``submit()``
enqueues, ``tick()`` advances the whole fleet one round — and until now
every caller had to pump ``tick()`` itself (the ROADMAP's open "real
async transport" item). :class:`AsyncFleetClient` closes that gap: it
owns the tick loop in ONE background asyncio task and exposes the
coroutine surface actual concurrent clients need:

* **``submit()`` / ``generate()`` coroutines** — any number of client
  coroutines submit concurrently; typed admission control surfaces
  naturally (``FleetRejected`` raises into the awaiting client; with
  ``wait=True`` a full queue becomes async backpressure instead).
* **Per-token streaming** — ``async for tok in handle`` yields tokens as
  the fleet decodes them, not only at completion. Mid-flight tokens are
  read from the ticket's live flights; because greedy decode is
  deterministic, every flight (retries and hedges included) produces the
  same prefix, so the stream can follow whichever flight is furthest
  ahead and never emits a token the final result won't contain.
* **A thread off-ramp for the jit-bound step** — each ``tick()`` (which
  runs the blocking ``gru_wave_step`` on every live replica) executes on
  a dedicated single worker thread via ``run_in_executor``, so the event
  loop never stalls on device compute. EVERY router call (submit /
  cancel / tick) is serialized through that same one-worker executor:
  the router stays the single-threaded scheduler it was designed to be,
  and no locks are added to its hot path.
* **Client-disconnect propagation** — cancelling the consuming task (or
  abandoning the token stream) routes into
  :meth:`FleetRouter.cancel`: the ticket leaves the queue, its wave
  lanes and hedged duplicates are freed, and ``cancelled`` is counted —
  exactly the synchronous cancellation semantics, driven by
  ``asyncio.CancelledError``.
* **Graceful drain/shutdown** — ``async with`` (or ``aclose()``) stops
  accepting new work, pumps the scheduler until nothing is outstanding,
  then stops the tick task and joins the worker thread.

Determinism: the front-end adds no timing of its own. All fleet timing
still flows through the router's injectable Clock — under a
``ManualClock`` the scheduler task ticks back-to-back with
``asyncio.sleep(0)`` yields only (zero wall-clock sleeps, tier-1 safe),
and the deterministic FaultInjector matrix runs unchanged under the
async loop; under a ``SystemClock``, ``tick_interval_s`` optionally
paces the loop. When the fleet has no outstanding work the scheduler
parks on an event (no polling) until a submit, disconnect, or close
wakes it.

Token streams are bitwise-identical to the synchronous path: the router
mechanics are untouched, and per-request greedy decode does not depend
on admission interleaving (asserted in ``tests/test_serve_async.py``).

See ``docs/serving.md`` ("Async front-end") and
``examples/serve_async.py`` for the N-concurrent-clients shape.
"""
from __future__ import annotations

import asyncio
from concurrent.futures import ThreadPoolExecutor
from typing import AsyncIterator, List, Optional, Sequence

from repro.distributed.fault_tolerance import ManualClock
from repro.serve.engine import Request
from repro.serve.fleet import FleetRejected, FleetRouter, FleetTicket

_DONE = object()                     # end-of-stream sentinel


class AsyncTicket:
    """One client's handle on an admitted request: the underlying
    :class:`FleetTicket` plus an async token stream. Single consumer:
    iterate it (``async for tok in handle``) or ``await handle.result()``
    to drain to completion. Dropping the iterator mid-stream (task
    cancellation, ``break`` + close) counts as a client disconnect and
    cancels the request fleet-wide."""

    def __init__(self, client: "AsyncFleetClient", ticket: FleetTicket):
        self._client = client
        self.ticket = ticket
        self.request = ticket.request
        self._q: asyncio.Queue = asyncio.Queue()
        self._emitted = 0            # tokens already pushed to the stream

    @property
    def id(self) -> int:
        return self.ticket.id

    @property
    def status(self) -> str:
        return self.ticket.status

    def __aiter__(self) -> AsyncIterator[int]:
        return self._tokens()

    async def _tokens(self) -> AsyncIterator[int]:
        try:
            while True:
                item = await self._q.get()
                if item is _DONE:
                    return
                if isinstance(item, BaseException):
                    raise item
                yield item
        except (asyncio.CancelledError, GeneratorExit):
            # the consumer went away mid-stream: a client disconnect.
            # No awaits here (we are unwinding a cancelled frame) — just
            # hand the ticket to the scheduler task, which cancels it
            # through the router before its next tick.
            self._client._abandon(self)
            raise

    async def result(self) -> Request:
        """Drain the stream and return the completed request (tokens in
        ``request.out``). Raises :class:`FleetRejected` if the ticket was
        shed (lapsed deadline) or failed (retry budget) mid-flight."""
        async for _ in self:
            pass
        return self.request


class AsyncFleetClient:
    """Asyncio transport over one :class:`FleetRouter`. Use as an async
    context manager::

        async with AsyncFleetClient(router) as client:
            handle = await client.submit(req)          # or client.generate
            async for tok in handle: ...

    ``tick_interval_s`` paces the scheduler under a real clock (ignored
    under ``ManualClock``, where ticks ARE virtual time and run
    back-to-back). ``max_stall_ticks`` bounds a fleet that stops making
    progress (e.g. a kill with no restore and no survivor) with a loud
    error into every live stream instead of a silent hang — the async
    analogue of ``run_until_done(max_ticks=...)``."""

    def __init__(self, router: FleetRouter, *, tick_interval_s: float = 0.0,
                 max_stall_ticks: int = 200_000):
        self.router = router
        self.tick_interval_s = float(tick_interval_s)
        self.max_stall_ticks = int(max_stall_ticks)
        # ONE worker: every router call serializes through this thread,
        # which is what keeps the lockless router sound under asyncio
        self._exec = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="fleet-tick")
        self._streams: dict = {}             # ticket id -> AsyncTicket
        self._abandoned: List[FleetTicket] = []
        self._task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._idle: Optional[asyncio.Event] = None
        self._slot_free: Optional[asyncio.Event] = None
        self._accepting = True
        self._closed = False

    # -- lifecycle -----------------------------------------------------------

    async def __aenter__(self) -> "AsyncFleetClient":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose(drain=exc == (None, None, None))

    async def start(self) -> None:
        """Start the background scheduler task (idempotent)."""
        if self._task is not None:
            return
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._idle.set()
        self._slot_free = asyncio.Event()
        self._task = asyncio.get_running_loop().create_task(
            self._scheduler(), name="fleet-scheduler")

    async def drain(self) -> None:
        """Wait until the fleet has nothing outstanding (queued or
        in-flight). New submits are still accepted — this is a barrier,
        not a shutdown."""
        if self._task is None:
            return
        self._wake.set()
        await self._idle.wait()

    async def aclose(self, drain: bool = True) -> None:
        """Graceful shutdown: refuse new submits, optionally drain every
        outstanding request to completion, then stop the scheduler task
        and join the tick worker thread. ``drain=False`` abandons
        outstanding work (their streams end with an error)."""
        self._accepting = False
        if self._task is None:
            self._exec.shutdown(wait=True)
            return
        if drain:
            await self.drain()
        self._closed = True
        self._wake.set()
        try:
            await self._task
        finally:
            self._task = None
            self._exec.shutdown(wait=True)
        if not drain:
            self._broadcast(FleetRejected(
                "shutdown", "client closed without draining"))

    # -- client surface ------------------------------------------------------

    async def submit(self, request: Request,
                     deadline_s: Optional[float] = None,
                     wait: bool = True) -> AsyncTicket:
        """Admit one request; returns the :class:`AsyncTicket` stream
        handle. ``FleetRejected`` raises into the caller exactly as the
        sync ``submit`` does; with ``wait=True`` (default) a full queue
        is treated as backpressure — the coroutine parks until a slot
        frees (completions/cancellations signal it) and retries, never
        busy-spins. ``deadline_infeasible`` always raises."""
        if not self._accepting:
            raise RuntimeError("AsyncFleetClient is closing")
        await self.start()
        loop = asyncio.get_running_loop()
        while True:
            fut = loop.run_in_executor(
                self._exec, self.router.submit, request, deadline_s)
            try:
                ticket = await asyncio.shield(fut)
                break
            except asyncio.CancelledError:
                # the client disconnected DURING admission: the executor
                # call cannot be recalled, so if it landed, hand the
                # ticket straight to the scheduler for cancellation —
                # the fleet must not serve a ghost with no consumer
                def _cleanup(f):
                    if not f.cancelled() and f.exception() is None:
                        self._abandoned.append(f.result())
                        if self._wake is not None:
                            self._wake.set()
                fut.add_done_callback(_cleanup)
                raise
            except FleetRejected as e:
                if not wait or e.reason != "queue_full":
                    raise
                self._slot_free.clear()
                self._wake.set()         # keep the scheduler serving
                await self._slot_free.wait()
        handle = AsyncTicket(self, ticket)
        self._streams[ticket.id] = handle
        self._idle.clear()
        self._wake.set()
        return handle

    async def generate(self, request: Request,
                       deadline_s: Optional[float] = None) -> Request:
        """Submit + drain: returns the completed request (tokens in
        ``request.out``). Cancelling the awaiting task mid-stream
        propagates a client disconnect into :meth:`FleetRouter.cancel`."""
        handle = await self.submit(request, deadline_s=deadline_s)
        return await handle.result()

    async def cancel(self, handle: AsyncTicket) -> bool:
        """Explicitly cancel an outstanding request (the programmatic
        face of a disconnect). The handle's stream ends early; returns
        what :meth:`FleetRouter.cancel` returned."""
        loop = asyncio.get_running_loop()
        ok = await loop.run_in_executor(
            self._exec, self.router.cancel, handle.ticket)
        self._wake.set()
        return bool(ok)

    def _abandon(self, handle: AsyncTicket) -> None:
        """Mid-stream consumer disappearance (task cancelled, iterator
        closed). Synchronous on purpose — called while unwinding a
        cancelled frame — the scheduler task performs the actual
        ``router.cancel`` before its next tick."""
        self._streams.pop(handle.ticket.id, None)
        self._abandoned.append(handle.ticket)
        if self._wake is not None:
            self._wake.set()

    # -- the scheduler task --------------------------------------------------

    def _progress_sig(self) -> tuple:
        c = self.router.counters
        return (c["completed"], c["failed"], c["cancelled"],
                sum(self.router.sheds.values()), self.router._outstanding)

    async def _scheduler(self) -> None:
        """The one owner of the router's tick loop. Each round: flush
        pending disconnects into ``router.cancel``, run one ``tick()``
        on the worker thread, publish freshly decoded tokens to every
        live stream, signal freed queue slots, then yield. Parks (no
        polling) whenever nothing is outstanding."""
        loop = asyncio.get_running_loop()
        manual = isinstance(self.router.clock, ManualClock)
        sig, stalled = self._progress_sig(), 0
        while True:
            while self._abandoned:
                t = self._abandoned.pop()
                await loop.run_in_executor(self._exec, self.router.cancel, t)
            if self.router._outstanding == 0:
                self._idle.set()
                self._slot_free.set()
                if self._closed:
                    return
                self._wake.clear()
                await self._wake.wait()
                continue
            self._idle.clear()
            await loop.run_in_executor(self._exec, self.router.tick)
            self._publish()
            if self.router._outstanding < self.router.config.queue_limit:
                self._slot_free.set()
            now_sig = self._progress_sig()
            stalled = 0 if now_sig != sig else stalled + 1
            sig = now_sig
            if stalled > self.max_stall_ticks:
                err = RuntimeError(
                    f"fleet made no progress in {self.max_stall_ticks} "
                    f"ticks: {self.router._outstanding} outstanding, alive="
                    f"{[r.name for r in self.router.replicas if r.alive]}")
                self._broadcast(err)
                self._idle.set()
                raise err
            if self.tick_interval_s > 0.0 and not manual:
                await asyncio.sleep(self.tick_interval_s)
            else:
                # yield so client coroutines can submit/consume between
                # ticks; never a wall-clock sleep under ManualClock
                await asyncio.sleep(0)

    def _publish(self) -> None:
        """Move freshly decoded tokens into each live stream. Runs on the
        event loop between executor calls, so it never races a tick.
        In-flight tokens come from the ticket's furthest-ahead flight
        (all flights of one ticket share a deterministic prefix); final
        status pushes the terminal sentinel or a typed error."""
        finished = []
        for tid, handle in self._streams.items():
            t = handle.ticket
            if t.status == "done":
                out = t.request.out
                for tok in out[handle._emitted:]:
                    handle._q.put_nowait(tok)
                handle._emitted = len(out)
                handle._q.put_nowait(_DONE)
                finished.append(tid)
            elif t.status in ("shed", "failed"):
                handle._q.put_nowait(FleetRejected(
                    t.reason or t.status,
                    f"request {tid} {t.status} mid-flight"))
                finished.append(tid)
            elif t.status == "cancelled":
                # disconnect already initiated client-side (or explicit
                # cancel): end the stream quietly, status says why
                handle._q.put_nowait(_DONE)
                finished.append(tid)
            elif t.flights:
                best = max((fl.clone.out for fl in t.flights), key=len)
                if len(best) > handle._emitted:
                    for tok in best[handle._emitted:]:
                        handle._q.put_nowait(tok)
                    handle._emitted = len(best)
        for tid in finished:
            self._streams.pop(tid, None)

    def _broadcast(self, err: BaseException) -> None:
        for handle in self._streams.values():
            handle._q.put_nowait(err)
        self._streams.clear()


def run_clients(router: FleetRouter, requests: Sequence[Request],
                deadline_s: Optional[float] = None) -> List[Request]:
    """Synchronous convenience: serve ``requests`` through the async
    front-end as N concurrent client coroutines (one per request) and
    return them completed — the async twin of ``FleetRouter.generate``,
    used by ``launch/serve.py --async``. Must not be called from inside
    a running event loop (it owns ``asyncio.run``)."""
    async def _main():
        async with AsyncFleetClient(router) as client:
            await asyncio.gather(
                *(client.generate(r, deadline_s=deadline_s)
                  for r in requests))

    asyncio.run(_main())
    return list(requests)
