"""Batched serving engine: prefill + decode loop over the model API.

Design point mirrors the paper: the figure of merit is PER-STEP LATENCY of
the sequential decode path (batch can be 1); throughput comes from batching
aligned requests. Requests are left-aligned into fixed slots, prefilled
once, then decoded lockstep with per-slot finish masking (EOS or budget);
the step function is jitted once per (batch, prompt_len) bucket.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi


@dataclass
class Request:
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                 # -1 = never
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, ctx: ShardCtx = ShardCtx(),
                 max_batch: int = 8):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx
        self.max_batch = max_batch
        self.api = mapi.get_api(cfg)
        self._prefill_jit = {}
        self._decode_jit = None
        self.step_times: List[float] = []

    def _get_decode(self):
        if self._decode_jit is None:
            def fn(params, cache, tok):
                return self.api.decode_step(params, self.cfg, cache, tok, self.ctx)
            self._decode_jit = jax.jit(fn, donate_argnums=(1,))
        return self._decode_jit

    def _get_prefill(self, S: int):
        if S not in self._prefill_jit:
            def fn(params, batch):
                return self.api.prefill(params, self.cfg, batch, self.ctx)
            self._prefill_jit[S] = jax.jit(fn)
        return self._prefill_jit[S]

    def generate(self, requests: Sequence[Request]) -> List[Request]:
        """Serve a wave of requests (padded/aligned batch)."""
        reqs = list(requests)
        assert len(reqs) <= self.max_batch
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad alignment
        if self.cfg.family in ("audio", "vlm", "gru"):
            raise NotImplementedError("wave serving is LM-only; use the "
                                      "model API directly for other families")
        prefill = self._get_prefill(S)
        logits, cache = prefill(self.params, {"tokens": jnp.asarray(toks)})
        decode = self._get_decode()
        max_new = max(r.max_new_tokens for r in reqs)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        finished = np.zeros(B, bool)
        for _ in range(max_new):
            t0 = time.perf_counter()
            logits, cache = decode(self.params, cache, next_tok)
            logits.block_until_ready()
            self.step_times.append(time.perf_counter() - t0)
            tok_np = np.asarray(next_tok)
            for i, r in enumerate(reqs):
                if not finished[i]:
                    r.out.append(int(tok_np[i]))
                    if (int(tok_np[i]) == r.eos_id
                            or len(r.out) >= r.max_new_tokens):
                        finished[i] = True
                        r.done = True
            if finished.all():
                break
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for r in reqs:
            r.done = True
        return reqs

    def latency_stats(self) -> Dict[str, float]:
        ts = np.array(self.step_times[1:] or [0.0])     # drop compile step
        return {"mean_s": float(ts.mean()), "p50_s": float(np.percentile(ts, 50)),
                "p99_s": float(np.percentile(ts, 99)), "steps": len(ts)}
