"""Batched serving engine: bucketed prefill + continuous-batching decode.

Design point mirrors the paper: the figure of merit is PER-STEP LATENCY of
the sequential decode path (batch can be 1); throughput comes from batching
aligned requests WITHOUT ever paying a recompile on the hot path.

Compile-once discipline (the ROADMAP's re-jit item):

* **Prompt-length buckets** — prompts are left-padded up to the next
  power of two (>= ``bucket_min``), so prefill jits once per bucket, not
  once per distinct prompt length. Padding is made semantics-exact by a
  per-slot length mask threaded to the recurrent core (False timesteps
  freeze the hidden state), so a bucketed prompt yields bitwise the same
  state as its unpadded original.
* **Fixed batch slots** (GRU waves) — the batch axis is always padded to
  ``max_batch`` slots (empty slots carry zero features and are masked
  out), so BOTH prefill and decode see one static batch shape: the decode
  step compiles exactly once per engine lifetime.
* **Keyed decode cache** — ``_get_decode`` is keyed by the decode input's
  batch shape (the donated-cache jit used to be keyed on nothing, so a
  wave with a different batch size silently retraced against it).

Continuous batching (GRU waves): ``generate`` accepts MORE requests than
``max_batch``. The overflow queues; whenever slots' requests finish
(EOS or budget), the slots are retired mid-wave and queued requests are
admitted into them. ALL requests admitted at one step share ONE bucketed
prefill (batch padded to the slot shape, so no new compilation) whose
rows are scattered into the freed slots of the live wave cache in one
device-side update — when several slots free simultaneously the admit
cost stays one prefill, not one per request. Finished streams therefore
free capacity immediately instead of padding the wave to the slowest
request.

Autotuning: an attached :class:`repro.serve.autotune.AutoTuner` closes
the loop from measured serving back into these knobs — wave size from
the measured batch-latency curve, the prompt-bucket ladder from the
observed length distribution (``bucket_ladder`` replaces the power-of-
two rule in ``_bucket_for``), and online CostModel recalibration. All
retuning happens at WAVE BOUNDARIES only (``_maybe_retune``): a tuning
decision may invalidate the jit caches (``_invalidate_jits``), which
must never happen under a live wave — the compile-once discipline holds
mid-wave by construction. Decisions are reported in
``latency_stats()["autotune"]``; see docs/serving.md ("Autotuning").

GRU execution dispatches through the executor (``repro.core.runtime``)
via its compile/execute API: params are prepared ONCE against the ctx's
placement (weight stacking and — under a mesh — device placement happen
at engine construction, never on the hot path), and the engine records
the compiled executable's chosen backend per prefill
(``prefill_backends``) and PER DECODE STEP (``decode_backends``, aligned
with ``step_times``). Decode attribution is keyed by the decode jit each
step ran under and frozen at that jit's trace time (the trace embeds the
backend; later cost-model changes don't retrace it), so ``latency_stats``
attributes every step to the backend that ACTUALLY ran — including when
continuous-batching admits change the decode key — rather than the one
resolved once at wave start. The attribution strings are executor
backend names, the ``pallas_sharded`` family (fused shard kernels inside
the shard_map, selectable per shape once a calibration measures the
sharded step faster) included.

Cell families: the wave path is not GRU-specific — ``generate`` routes
EVERY registered cell family (``repro.core.cells``: gru, slstm, ...)
through the same bucketed-prefill/fixed-slot machinery; the family's flat
state tuple flows leaf-by-leaf through the cache scatter, so sLSTM's
four-leaf (c, n, m, h) state rides the exact slot plumbing GRU's one-leaf
state does. A ``cfg.family`` that is neither a registered cell family nor
a known LM family raises the typed ``UnknownCellFamily`` instead of
silently degrading to the token path.

The cell families (the paper's own models) serve FEATURE VECTORS instead
of tokens: a request's ``prompt`` is a float (S, X) feature window, and each
decode step pushes one more feature vector (the request's ``stream`` if
provided, else free-running on the last observed features) and emits the
running class prediction. Per step that is exactly one pass through the
depth-L recurrence — with ``cfg.gru.backend == "pallas"`` a single fused
pallas_call (see ``repro.kernels.gru_sequence``) — the paper's latency
figure of merit, measured by ``latency_stats`` (p50/p99 tail bounds, not
just means: the paper's constraint is a deadline).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cells as cell_families
from repro.core.cells import UnknownCellFamily
from repro.distributed.fault_tolerance import Clock, SystemClock
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi


@dataclass
class Request:
    prompt: np.ndarray               # (S,) int32 tokens | (S, X) float features
    max_new_tokens: int = 16
    eos_id: int = -1                 # -1 = never
    stream: Optional[np.ndarray] = None  # gru: (>=max_new, X) decode features
    out: List[int] = field(default_factory=list)
    done: bool = False
    # request-lifecycle timestamps (engine clock), for queue-wait and
    # end-to-end latency accounting; t_submit may be pre-stamped by a
    # front-door router so the wait includes fleet-level queueing
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None
    t_finish: Optional[float] = None


def _pct(xs, q: float) -> float:
    """Percentile with honest empties: no history -> NaN, never 0.0 (an
    engine that served nothing must not report a perfect p99 — a 0.0
    there can silently pass ratio-based CI gates)."""
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else float("nan")


def _mean(xs) -> float:
    return float(np.mean(np.asarray(xs))) if len(xs) else float("nan")


def bucket_len(S: int, minimum: int = 8) -> int:
    """Next power of two >= max(S, minimum): the prefill jit key."""
    b = max(minimum, 1)
    while b < S:
        b *= 2
    return b


@dataclass
class _Slot:
    """One live decode lane of a GRU wave."""
    req: Request
    last_feat: np.ndarray            # free-running fallback feature vector
    step: int = 0                    # per-request decode step (stream index)


@dataclass
class _GruWave:
    """Resumable continuous-batching state: the wave a stepwise caller
    (``gru_wave_step``) advances one decode step at a time."""
    slots: List[Optional[_Slot]]
    nxt: np.ndarray                  # (max_batch, X) next-feature staging
    key: tuple                       # decode jit key (max_batch, X)
    pending: deque = field(default_factory=deque)
    cache: Optional[dict] = None     # None until the first admit prefills


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, ctx: ShardCtx = ShardCtx(),
                 max_batch: int = 8, bucket_min: int = 8,
                 clock: Optional[Clock] = None, tuner=None):
        self.cfg = cfg
        self.ctx = ctx
        self.max_batch = max_batch
        self.bucket_min = bucket_min
        self.clock = clock or SystemClock()
        # optional feedback loop (repro.serve.autotune.AutoTuner): observes
        # prompts + warm step timings and retunes wave size / bucket ladder
        # / cost rows — only ever applied at wave boundaries (_maybe_retune)
        self.tuner = tuner
        # autotuned prefill ladder: None = the static power-of-two ladder;
        # else a small fixed ascending tuple of bucket lengths (jit keys)
        self.bucket_ladder: Optional[tuple] = None
        self.api = mapi.get_api(cfg)
        prep = getattr(self.api, "prepare_params", None)
        self.params = prep(params, cfg, ctx) if prep else params
        self._prefill_jit = {}           # keyed by prompt-length bucket
        self._decode_jit = {}            # keyed by decode batch shape
        self._decode_plan_backends = {}  # backend traced into each decode
                                         # jit (frozen at trace time)
        self._decode_warm = set()        # keys whose compile step has passed
        self._prefill_plan_backends = {} # backend traced into each prefill
                                         # bucket jit (frozen at trace time)
        self._prefill_cold = set()       # post-retune buckets whose first
                                         # (compile) timing is excluded
        self._scatter_jit = {}           # keyed by admit-batch size
        self._jit_gen = 0                # bumped per _invalidate_jits call
        self._wave: Optional[_GruWave] = None
        self.step_times: List[float] = []
        self.prefill_times: List[float] = []
        self.prefill_backends: List[str] = []   # executor choice per prefill
        self.decode_backend: Optional[str] = None    # latest resolved
        self.decode_backends: List[str] = []    # per recorded step (aligned
                                                # with step_times)
        self.queue_waits: List[float] = []      # per request: submit -> admit
        self.e2e_times: List[float] = []        # per request: submit -> finish

    # -- jit caches ---------------------------------------------------------

    def _get_decode(self, batch_shape: tuple):
        """Decode step jit, keyed by the new-input batch shape. The cache is
        donated, so an unkeyed entry reused at a different batch shape would
        silently retrace; the key makes the compile-once contract checkable
        (see test_serve_engine_decode_cache_keyed_by_batch)."""
        if batch_shape not in self._decode_jit:
            def fn(params, cache, tok):
                return self.api.decode_step(params, self.cfg, cache, tok, self.ctx)
            self._decode_jit[batch_shape] = jax.jit(fn, donate_argnums=(1,))
        return self._decode_jit[batch_shape]

    def _get_prefill(self, S: int):
        if S not in self._prefill_jit:
            def fn(params, batch):
                return self.api.prefill(params, self.cfg, batch, self.ctx)
            self._prefill_jit[S] = jax.jit(fn)
            if self._jit_gen > 0:
                # a jit (re)created after a mid-serve retune: its first
                # call recompiles, and that compile is a tuning cost —
                # excluded from the percentiles exactly like the
                # per-decode-jit rule (_record_prefill). First-EVER bucket
                # compiles (gen 0) stay included: cold-start is part of
                # the prefill story.
                self._prefill_cold.add(S)
        return self._prefill_jit[S]

    def _get_scatter(self, k: int):
        """Admit-k cache scatter: copy rows 0..k-1 of a freshly prefilled
        cache into the ``k`` freed slots of the live wave cache
        (device-side, one trace per admit-batch size k <= max_batch)."""
        if k not in self._scatter_jit:
            def fn(cache, fresh, slots_):
                return {"h": tuple(h.at[slots_].set(f[:k]) for h, f in
                                   zip(cache["h"], fresh["h"])),
                        "pos": cache["pos"]}
            self._scatter_jit[k] = jax.jit(fn)
        return self._scatter_jit[k]

    # -- LM waves -----------------------------------------------------------

    def generate(self, requests: Sequence[Request]) -> List[Request]:
        """Serve a wave of requests. Cell-family waves (gru, slstm, any
        registered recurrence) run bucketed continuous batching and accept
        any number of requests; LM waves are a single padded/aligned batch
        of at most ``max_batch``. An unregistered family raises
        :class:`UnknownCellFamily` — never a silent fall-through to the
        token path."""
        reqs = list(requests)
        if cell_families.is_cell_family(self.cfg.family):
            return self._generate_gru(reqs)
        if self.cfg.family in ("audio", "vlm"):
            raise NotImplementedError("wave serving is LM/cell-family-only; "
                                      "use the model API directly for other "
                                      "families")
        if self.cfg.family not in mapi._FAMS:
            raise UnknownCellFamily(self.cfg.family,
                                    known=cell_families.families())
        assert len(reqs) <= self.max_batch
        B = len(reqs)
        now = self.clock.now()
        for r in reqs:
            if r.t_submit is None:
                r.t_submit = now
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad alignment
        prefill = self._get_prefill(S)
        t0 = self.clock.now()
        logits, cache = prefill(self.params, {"tokens": jnp.asarray(toks)})
        logits.block_until_ready()
        self._record_prefill(S, self.clock.now() - t0)
        now = self.clock.now()
        for r in reqs:
            r.t_admit = now
            self.queue_waits.append(now - r.t_submit)
        max_new = max(r.max_new_tokens for r in reqs)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        key = tuple(next_tok.shape)
        decode = self._get_decode(key)
        finished = np.zeros(B, bool)
        for _ in range(max_new):
            t0 = self.clock.now()
            logits, cache = decode(self.params, cache, next_tok)
            logits.block_until_ready()
            self._record_step(key, self.clock.now() - t0)
            tok_np = np.asarray(next_tok)
            for i, r in enumerate(reqs):
                if not finished[i]:
                    r.out.append(int(tok_np[i]))
                    if (int(tok_np[i]) == r.eos_id
                            or len(r.out) >= r.max_new_tokens):
                        finished[i] = True
                        self._finish(r)
            if finished.all():
                break
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for r in reqs:
            if not r.done:
                self._finish(r)
        return reqs

    def _finish(self, r: Request) -> None:
        """Mark a request complete and record its end-to-end latency."""
        r.done = True
        r.t_finish = self.clock.now()
        if r.t_submit is not None:
            self.e2e_times.append(r.t_finish - r.t_submit)

    # -- GRU waves: bucketed continuous batching ----------------------------

    def _gru_prefill_batch(self, prompts: List[np.ndarray], Sb: int):
        """Left-pad prompts into the FIXED (max_batch, Sb, X) slot shape with
        an exactness mask; rows beyond len(prompts) are empty (fully
        masked)."""
        X = self.cfg.gru.input_dim
        Bs = self.max_batch
        feats = np.zeros((Bs, Sb, X), np.float32)
        mask = np.zeros((Bs, Sb), bool)
        for i, p in enumerate(prompts):
            feats[i, Sb - p.shape[0]:] = p
            mask[i, Sb - p.shape[0]:] = True
        return feats, mask

    def _bucket_for(self, S: int) -> int:
        """The prefill bucket (jit key) a prompt of length ``S`` pads to:
        the autotuned quantile ladder when one is installed (smallest rung
        >= S; prompts above the top rung double from it, so the key space
        stays a small fixed set), else the static power-of-two ladder."""
        if self.bucket_ladder:
            for b in self.bucket_ladder:
                if S <= b:
                    return b
            return bucket_len(S, minimum=self.bucket_ladder[-1] * 2)
        return bucket_len(S, self.bucket_min)

    def _prefill_backend_for(self, Sb: int) -> Optional[str]:
        """The executor backend the prefill jit for bucket ``Sb`` traced
        with — resolved once at first use and frozen, mirroring
        ``_decode_backend_for``: the jitted prefill embeds the backend
        chosen in its trace-time cost epoch, so attribution must not
        follow later cost-model changes (a retune that DOES change the
        resolution also invalidates the jits, clearing this map)."""
        if Sb not in self._prefill_plan_backends:
            compiler = getattr(self.api, "executable", None)
            # mirrors the compile key gru_lm.prefill resolves for this
            # call: the engine always sends the slot-shaped batch WITH a
            # mask, so (batch, seq, masked=True) is the key the model uses
            self._prefill_plan_backends[Sb] = (
                None if compiler is None
                else compiler(self.cfg, batch=self.max_batch, seq=Sb,
                              masked=True, mode="prefill",
                              mesh=self.ctx.mesh).sequence_backend)
        return self._prefill_plan_backends[Sb]

    def _record_prefill(self, Sb: int, dt: float) -> None:
        """Record one prefill latency. A bucket's first-EVER compile is
        included (cold-start is part of the prefill story), but a jit
        (re)created after a retune invalidation has its first (compile)
        call excluded — same rule as the per-decode-jit exclusion, so
        mid-serve retunes can't poison the steady-state percentiles."""
        if Sb in self._prefill_cold:
            self._prefill_cold.discard(Sb)
            return
        self.prefill_times.append(dt)

    def _gru_prefill(self, prompts: List[np.ndarray]):
        """One bucketed prefill of up to max_batch prompts; returns cache."""
        Sb = self._bucket_for(max(p.shape[0] for p in prompts))
        feats, mask = self._gru_prefill_batch(prompts, Sb)
        backend = self._prefill_backend_for(Sb)
        if backend is not None:          # record the executor's choice
            self.prefill_backends.append(backend)
        prefill = self._get_prefill(Sb)
        t0 = self.clock.now()
        logits, cache = prefill(self.params, {"features": jnp.asarray(feats),
                                              "mask": jnp.asarray(mask)})
        logits.block_until_ready()
        self._record_prefill(Sb, self.clock.now() - t0)
        return cache

    def _make_slot(self, r: Request) -> _Slot:
        X = self.cfg.gru.input_dim
        p = np.asarray(r.prompt, np.float32).reshape(-1, X)
        return _Slot(req=r, last_feat=p[-1])

    def _generate_gru(self, reqs: List[Request]) -> List[Request]:
        if not reqs:
            return []
        self.gru_wave_begin(reqs)
        while self.gru_wave_active():
            self.gru_wave_step()
        self._wave = None
        for r in reqs:
            if not r.done:                              # pragma: no cover
                r.done = True
        return reqs

    # -- stepwise wave API (the fleet router's drive surface) ---------------
    #
    # ``generate`` is a closed loop: begin + step-until-idle. A front-door
    # router (``repro.serve.fleet``) needs finer control — advance each
    # replica ONE decode step per scheduler tick, enqueue new requests into
    # a live wave, and cancel a lane (hedging first-wins, retry-on-death) —
    # so the continuous-batching loop is exposed as begin/enqueue/step/
    # cancel. All four preserve the compile-once discipline: the same
    # bucketed prefills, the same fixed-slot decode jit.

    def gru_wave_begin(self, requests: Sequence[Request] = ()) -> None:
        """Start a fresh continuous-batching wave (cell families only).
        A wave boundary: the attached tuner (if any) may retune here,
        before any slot shape is traced for this wave."""
        if not cell_families.is_cell_family(self.cfg.family):
            raise UnknownCellFamily(self.cfg.family,
                                    known=cell_families.families())
        self._maybe_retune()
        X = self.cfg.gru.input_dim
        Bs = self.max_batch
        self._wave = _GruWave(slots=[None] * Bs,
                              nxt=np.zeros((Bs, X), np.float32),
                              key=(Bs, X))
        self.gru_wave_enqueue(requests)

    def gru_wave_enqueue(self, requests: Sequence[Request]) -> None:
        """Queue requests into the live wave (FIFO admission; they enter
        slots as capacity frees). Starts a wave if none is live."""
        if self._wave is None:
            self.gru_wave_begin(())
        now = self.clock.now()
        X = self.cfg.gru.input_dim
        for r in requests:
            if r.t_submit is None:
                r.t_submit = now
            if self.tuner is not None:
                self.tuner.observe_prompt(
                    np.asarray(r.prompt).reshape(-1, X).shape[0])
            self._wave.pending.append(r)

    def gru_wave_active(self) -> int:
        """Live lanes + queued requests still owed work by this wave."""
        w = self._wave
        if w is None:
            return 0
        return sum(s is not None for s in w.slots) + len(w.pending)

    def gru_work_remaining(self) -> tuple:
        """(requests, decode tokens) still owed — the router's measured
        queue-depth signal for expected-service-time routing."""
        w = self._wave
        if w is None:
            return 0, 0
        toks = sum(max(1, s.req.max_new_tokens - len(s.req.out))
                   for s in w.slots if s is not None)
        toks += sum(max(1, r.max_new_tokens) for r in w.pending)
        return self.gru_wave_active(), toks

    def bucket_warm(self, prompt_len: int) -> bool:
        """Whether this engine has already compiled the prefill bucket a
        prompt of ``prompt_len`` lands in (router bucket-affinity)."""
        return self._bucket_for(prompt_len) in self._prefill_jit

    # -- autotune surface (repro.serve.autotune) ----------------------------
    #
    # The tuner never mutates the engine directly: it calls these
    # boundary-safe mutators from maybe_retune(), which the engine itself
    # only invokes between waves (_maybe_retune). That split is what keeps
    # the no-mid-wave-retrace invariant enforceable in one place.

    def _maybe_retune(self) -> None:
        """Run the attached tuner if (and only if) no wave work is live —
        a retune may invalidate every jit cache, which must never happen
        under a wave mid-decode (the donated decode cache and the frozen
        backend attribution both assume trace stability for the wave's
        lifetime)."""
        if self.tuner is None:
            return
        if self._wave is not None and self.gru_wave_active() > 0:
            return
        self.tuner.maybe_retune(self)

    def _invalidate_jits(self) -> None:
        """Drop every shape-dependent jit (prefill buckets, decode steps,
        admit scatters) plus the frozen backend attributions, so the next
        call re-traces against the CURRENT wave size and cost epoch.
        Only wave-boundary retunes call this. The warm/cold markers reset
        with the jits: each re-created jit's first (compile) step is
        excluded from the percentiles again (_record_step /
        _record_prefill)."""
        self._prefill_jit.clear()
        self._decode_jit.clear()
        self._scatter_jit.clear()
        self._decode_plan_backends.clear()
        self._prefill_plan_backends.clear()
        self._decode_warm.clear()
        self._prefill_cold.clear()
        self._jit_gen += 1

    def apply_wave_size(self, n: int) -> None:
        """Resize the decode slot count (tuner decision). Every jit here
        is batch-shaped — prefill pads to ``max_batch`` rows, decode and
        scatter trace the slot axis — so the caches are invalidated; a
        drained wave object is dropped so the next enqueue builds slots
        at the new size. Callable only between waves (enforced by
        _maybe_retune being the sole caller path)."""
        n = int(n)
        if n < 1 or n == self.max_batch:
            return
        self.max_batch = n
        self._invalidate_jits()
        if self._wave is not None and self.gru_wave_active() == 0:
            self._wave = None

    def apply_bucket_ladder(self, ladder) -> None:
        """Install an autotuned prefill-bucket ladder (ascending lengths;
        empty/None restores the power-of-two ladder). Existing bucket
        jits stay valid — old buckets simply stop being chosen for new
        admits, and identical rungs keep hitting their compiled jits —
        but the generation marker bumps: NEW bucket jits born from this
        retune compile mid-serve, and their first call is excluded from
        the percentiles like any other post-retune jit (_get_prefill)."""
        ladder = tuple(int(b) for b in (ladder or ()))
        ladder = ladder or None
        if ladder != self.bucket_ladder:
            self.bucket_ladder = ladder
            self._jit_gen += 1

    def refresh_executables(self) -> bool:
        """After a cost-model epoch bump: re-resolve the executor choice
        for every live jit key and invalidate ONLY if some resolution
        changed. The live jits froze their trace-time backend, so when
        the refreshed table confirms those choices a recalibration costs
        zero retraces; when it disagrees, serving the now-known-slower
        backend would be worse than one boundary recompile."""
        compiler = getattr(self.api, "executable", None)
        if compiler is None:
            return False
        changed = False
        for key, frozen in self._decode_plan_backends.items():
            fresh = compiler(self.cfg, batch=key[0], mode="decode",
                             mesh=self.ctx.mesh).decode_backend
            if fresh != frozen:
                changed = True
                break
        if not changed:
            for Sb, frozen in self._prefill_plan_backends.items():
                fresh = compiler(self.cfg, batch=self.max_batch, seq=Sb,
                                 masked=True, mode="prefill",
                                 mesh=self.ctx.mesh).sequence_backend
                if fresh != frozen:
                    changed = True
                    break
        if changed:
            self._invalidate_jits()
        return changed

    def gru_wave_cancel(self, request: Request) -> bool:
        """Drop a request from the live wave (queued or mid-decode): the
        fleet's first-wins hedge cancellation and retry requeue both land
        here. The lane frees immediately; the stale cache row is inert
        (masked slots' outputs are never read). Returns False if the
        request is not in this wave (e.g. it just finished)."""
        w = self._wave
        if w is None:
            return False
        for i, r in enumerate(w.pending):
            if r is request:
                del w.pending[i]
                return True
        for j, s in enumerate(w.slots):
            if s is not None and s.req is request:
                w.slots[j] = None
                return True
        return False

    def gru_wave_step(self) -> List[Request]:
        """Advance the wave ONE decode step: admit queued requests into
        every empty slot (ALL admits share ONE bucketed prefill + one
        scatter), run one fused decode step over the fixed slots, retire
        finished lanes. Returns the requests that finished this step."""
        w = self._wave
        if w is None:
            return []
        X = self.cfg.gru.input_dim
        empty = [j for j, s in enumerate(w.slots) if s is None]
        if empty and w.pending:
            k = min(len(empty), len(w.pending))
            admits = [self._make_slot(w.pending.popleft()) for _ in range(k)]
            now = self.clock.now()
            for s in admits:
                s.req.t_admit = now
                if s.req.t_submit is not None:
                    self.queue_waits.append(now - s.req.t_submit)
            fresh = self._gru_prefill(
                [np.asarray(s.req.prompt, np.float32).reshape(-1, X)
                 for s in admits])
            if w.cache is None:
                # first cohort: the prefilled cache IS the wave cache (row
                # i belongs to slot i; surplus rows are fully masked)
                w.cache = fresh
            else:
                w.cache = self._get_scatter(k)(
                    w.cache, fresh, jnp.asarray(empty[:k], jnp.int32))
            for j, s in zip(empty[:k], admits):
                w.slots[j] = s
        if not any(s is not None for s in w.slots):
            return []
        for j, s in enumerate(w.slots):
            if s is None:
                w.nxt[j] = 0.0
                continue
            r = s.req
            w.nxt[j] = (r.stream[s.step] if r.stream is not None
                        and s.step < len(r.stream) else s.last_feat)
        # attribution is frozen per decode-jit key AT TRACE TIME
        # (_decode_backend_for): the jitted step embeds whichever backend
        # the executor resolved when it first traced, and later cost-model
        # epoch bumps do NOT retrace it — so a fresh compile() mid-wave
        # could only MIS-attribute. Steps are recorded under the key they
        # ran with; if admits ever change the decode key (live-batch
        # resizing), the new key resolves its own backend on first use.
        decode = self._get_decode(w.key)
        t0 = self.clock.now()
        logits, w.cache = decode(self.params, w.cache, jnp.asarray(w.nxt))
        logits.block_until_ready()
        self._record_step(w.key, self.clock.now() - t0,
                          self._decode_backend_for(w.key))
        cls = np.asarray(jnp.argmax(logits, -1))
        finished = []
        for j, s in enumerate(w.slots):
            if s is None:
                continue
            r = s.req
            r.out.append(int(cls[j]))
            s.step += 1
            if (int(cls[j]) == r.eos_id
                    or len(r.out) >= r.max_new_tokens):
                self._finish(r)
                w.slots[j] = None                       # retire mid-wave
                finished.append(r)
        if not w.pending and all(s is None for s in w.slots):
            # the wave just drained: a boundary. The tuner may retune now
            # (possibly invalidating jits / resizing slots) — the next
            # enqueue starts a fresh wave against the new configuration.
            self._maybe_retune()
        return finished

    # -- stats --------------------------------------------------------------

    def _decode_backend_for(self, key: tuple) -> Optional[str]:
        """The executor backend the decode jit for ``key`` traced with —
        resolved ONCE per key at first use (i.e. at trace time, in the
        same cost-model epoch) and frozen thereafter, because the jitted
        step itself never retraces on epoch bumps. This is what makes
        ``decode_backends`` attribution reflect the backend that ACTUALLY
        ran, not whatever a fresh compile() would pick today. Also tracks
        the latest choice on ``self.decode_backend``."""
        if key not in self._decode_plan_backends:
            compiler = getattr(self.api, "executable", None)
            self._decode_plan_backends[key] = (
                None if compiler is None
                else compiler(self.cfg, batch=key[0], mode="decode",
                              mesh=self.ctx.mesh).decode_backend)
        backend = self._decode_plan_backends[key]
        if backend is not None:
            self.decode_backend = backend
        return backend

    def _record_step(self, key: tuple, dt: float,
                     backend: Optional[str] = None) -> None:
        """Record one decode-step latency, excluding each decode jit's
        FIRST call (its compile) so the tail percentiles reflect steady
        state, not compilation — per key, since every batch shape compiles
        separately. ``backend`` attributes the step to the executor
        backend that actually ran it (``decode_backends`` stays aligned
        with ``step_times``)."""
        if key in self._decode_warm:
            self.step_times.append(dt)
            self.decode_backends.append(backend)
            if self.tuner is not None and backend is not None:
                # warm steps only: compile steps must not become cost rows
                g = self.cfg.gru
                self.tuner.observe_step(
                    dt, batch=key[0], backend=backend,
                    depth=g.resolved_num_layers,
                    hidden=g.resolved_layer_dims[0],
                    family=cell_families.cfg_family(g))
        else:
            self._decode_warm.add(key)

    def latency_stats(self) -> Dict[str, float]:
        """Per-step decode latency distribution (tail-bound view: the
        paper's constraint is a deadline, not an average) plus prefill
        timings and ``decode_backend_steps`` (recorded steps per executor
        backend — attribution follows the backend each step's decode jit
        actually traced with). Compile
        steps are excluded per decode-jit key at record time; prefill
        timings INCLUDE each bucket's compile (cold-start cost is part of
        the prefill story). Empty histories report NaN, never 0.0 — an
        engine that served nothing has no percentiles (``steps`` /
        ``requests`` / ``prefills`` say how much history backs each
        number)."""
        ts = self.step_times
        pf = self.prefill_times
        qw = self.queue_waits
        ee = self.e2e_times
        per_backend: Dict[str, int] = {}
        for b in self.decode_backends:
            if b is not None:
                per_backend[b] = per_backend.get(b, 0) + 1
        from repro.core import runtime
        # the autotune decision trail: current tuned shape + every applied
        # decision with the measurement that justified it (always present;
        # enabled=False for untuned engines, so consumers need no getattr)
        autotune = {"enabled": self.tuner is not None,
                    "wave_size": self.max_batch,
                    "bucket_ladder": (list(self.bucket_ladder)
                                      if self.bucket_ladder else None)}
        if self.tuner is not None:
            autotune.update(self.tuner.stats())
        return {"decode_backend_steps": per_backend,
                "autotune": autotune,
                # per-REQUEST latencies (engine clock): queue wait is
                # submit -> slot admission, e2e is submit -> finish — the
                # router's depth-aware routing signal and the fleet
                # benchmark's honest p99 (per-step decode percentiles alone
                # hide queueing delay entirely)
                "requests": len(self.e2e_times),
                "queue_wait_mean_s": _mean(qw),
                "queue_wait_p50_s": _pct(qw, 50),
                "queue_wait_p99_s": _pct(qw, 99),
                "e2e_mean_s": _mean(ee),
                "e2e_p50_s": _pct(ee, 50),
                "e2e_p99_s": _pct(ee, 99),
                # the datapath precision the latest resolved decode backend
                # serves (int8 for the *_q8 backends, float32 otherwise)
                "served_dtype": runtime.backend_dtype(self.decode_backend),
                "mean_s": _mean(ts),
                "p50_s": _pct(ts, 50),
                "p90_s": _pct(ts, 90),
                "p99_s": _pct(ts, 99),
                "max_s": float(max(ts)) if ts else float("nan"),
                "steps": len(ts),
                "prefill_mean_s": _mean(pf),
                "prefill_p99_s": _pct(pf, 99),
                "prefills": len(self.prefill_times)}
