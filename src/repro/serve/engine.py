"""Batched serving engine: prefill + decode loop over the model API.

Design point mirrors the paper: the figure of merit is PER-STEP LATENCY of
the sequential decode path (batch can be 1); throughput comes from batching
aligned requests. Requests are left-aligned into fixed slots, prefilled
once, then decoded lockstep with per-slot finish masking (EOS or budget);
the step function is jitted once per (batch, prompt_len) bucket.

The GRU family (the paper's own model) serves FEATURE VECTORS instead of
tokens: a request's ``prompt`` is a float (S, X) feature window, prefilled
through the whole recurrent stack, and each decode step pushes one more
feature vector (the request's ``stream`` if provided, else free-running on
the last observed features) and emits the running class prediction. Per
step that is exactly one pass through the depth-L recurrence — the paper's
latency figure of merit, measured by ``latency_stats``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi


@dataclass
class Request:
    prompt: np.ndarray               # (S,) int32 tokens | (S, X) float features
    max_new_tokens: int = 16
    eos_id: int = -1                 # -1 = never
    stream: Optional[np.ndarray] = None  # gru: (>=max_new, X) decode features
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, ctx: ShardCtx = ShardCtx(),
                 max_batch: int = 8):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx
        self.max_batch = max_batch
        self.api = mapi.get_api(cfg)
        self._prefill_jit = {}
        self._decode_jit = None
        self.step_times: List[float] = []

    def _get_decode(self):
        if self._decode_jit is None:
            def fn(params, cache, tok):
                return self.api.decode_step(params, self.cfg, cache, tok, self.ctx)
            self._decode_jit = jax.jit(fn, donate_argnums=(1,))
        return self._decode_jit

    def _get_prefill(self, S: int):
        if S not in self._prefill_jit:
            def fn(params, batch):
                return self.api.prefill(params, self.cfg, batch, self.ctx)
            self._prefill_jit[S] = jax.jit(fn)
        return self._prefill_jit[S]

    def generate(self, requests: Sequence[Request]) -> List[Request]:
        """Serve a wave of requests (padded/aligned batch)."""
        reqs = list(requests)
        assert len(reqs) <= self.max_batch
        if self.cfg.family == "gru":
            return self._generate_gru(reqs)
        if self.cfg.family in ("audio", "vlm"):
            raise NotImplementedError("wave serving is LM/GRU-only; use the "
                                      "model API directly for other families")
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad alignment
        prefill = self._get_prefill(S)
        logits, cache = prefill(self.params, {"tokens": jnp.asarray(toks)})
        decode = self._get_decode()
        max_new = max(r.max_new_tokens for r in reqs)
        next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        finished = np.zeros(B, bool)
        for _ in range(max_new):
            t0 = time.perf_counter()
            logits, cache = decode(self.params, cache, next_tok)
            logits.block_until_ready()
            self.step_times.append(time.perf_counter() - t0)
            tok_np = np.asarray(next_tok)
            for i, r in enumerate(reqs):
                if not finished[i]:
                    r.out.append(int(tok_np[i]))
                    if (int(tok_np[i]) == r.eos_id
                            or len(r.out) >= r.max_new_tokens):
                        finished[i] = True
                        r.done = True
            if finished.all():
                break
            next_tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for r in reqs:
            r.done = True
        return reqs

    def _generate_gru(self, reqs: List[Request]) -> List[Request]:
        """Feature-vector wave serving for the paper's recurrent family.

        Prompts are (S_i, X) float windows, left-padded with zeros and
        prefilled through the stack once; every decode step feeds the next
        (B, X) feature slab (request ``stream`` when given, else the last
        prompt vector, free-running) and records the argmax class."""
        X = self.cfg.gru.input_dim
        B = len(reqs)
        prompts = [np.asarray(r.prompt, np.float32).reshape(-1, X)
                   for r in reqs]
        S = max(p.shape[0] for p in prompts)
        feats = np.zeros((B, S, X), np.float32)
        for i, p in enumerate(prompts):
            feats[i, S - p.shape[0]:] = p               # left-pad alignment
        prefill = self._get_prefill(S)
        logits, cache = prefill(self.params, {"features": jnp.asarray(feats)})
        decode = self._get_decode()
        max_new = max(r.max_new_tokens for r in reqs)
        finished = np.zeros(B, bool)
        for step in range(max_new):
            nxt = np.stack([
                r.stream[step] if r.stream is not None
                and step < len(r.stream) else prompts[i][-1]
                for i, r in enumerate(reqs)]).astype(np.float32)
            t0 = time.perf_counter()
            logits, cache = decode(self.params, cache, jnp.asarray(nxt))
            logits.block_until_ready()
            self.step_times.append(time.perf_counter() - t0)
            cls = np.asarray(jnp.argmax(logits, -1))
            for i, r in enumerate(reqs):
                if not finished[i]:
                    r.out.append(int(cls[i]))
                    if (int(cls[i]) == r.eos_id
                            or len(r.out) >= r.max_new_tokens):
                        finished[i] = True
                        r.done = True
            if finished.all():
                break
        for r in reqs:
            r.done = True
        return reqs

    def latency_stats(self) -> Dict[str, float]:
        ts = np.array(self.step_times[1:] or [0.0])     # drop compile step
        return {"mean_s": float(ts.mean()), "p50_s": float(np.percentile(ts, 50)),
                "p99_s": float(np.percentile(ts, 99)), "steps": len(ts)}
