"""Online autotuner: close the loop from measured step timings back to
engine configuration.

The paper's central claim is that a latency-constrained recurrent design
must derive its workload distribution from the hardware's MEASURED
behavior, not static heuristics. The runtime already does this forward —
calibration artifacts drive backend dispatch through the CostModel
(``repro.core.runtime``) — but the ServeEngine's own shape knobs were
still operator-chosen constants. This module is the system's first
feedback loop: it flows measurements BACKWARD, from serving into
configuration, along three dimensions:

* **Wave size** — the engine's decode slot count, chosen from the
  measured batch-latency curve: the largest batch whose MARGINAL cost of
  one more slot (``step(B) - step(B-1)``) stays under
  ``marginal_frac x step(1)``. Adding slots is nearly free while the
  kernel is latency-bound (the per-step collectives/launch dominate) and
  stops being free once the batch axis saturates the fabric — exactly
  the rows-per-lane tradeoff the paper tunes on the AIE. Measured points
  come from :meth:`CostModel.batch_points` at the served
  ``(family, depth, H)`` for the engine's resolved decode backend; with
  fewer than two measured batches there is no curve and the static
  default stands.
* **Prompt-bucket ladder** — prefill jit keys chosen from the OBSERVED
  prompt-length distribution: quantile boundaries (default p50/p75/p90/
  max) replace the power-of-two ladder. Still jit-stable: a retune
  installs a small FIXED set of bucket lengths; prompts above the top
  rung extend by doubling, so the jit-key space stays bounded.
* **Online recalibration** — served per-step timings (the same numbers
  ``latency_stats()`` reports) fold back into the CostModel as fresh
  measured rows via :meth:`CostModel.merged` + :func:`set_cost_model`,
  which bumps the cost epoch. The fleet's routing priors
  (``FleetRouter._step_cost_s``) read the refreshed table on their next
  lookup automatically. The engine re-traces only if the refreshed table
  actually CHANGES a resolved backend (``refresh_executables``) — a
  recalibration that confirms the current choice costs zero retraces.

Throttling and the no-mid-wave-retrace invariant: the tuner never acts
on its own. The engine calls :meth:`AutoTuner.maybe_retune` only at WAVE
BOUNDARIES (``ServeEngine._maybe_retune``: no live lanes, no pending
work), so a tuning decision can invalidate jit caches without ever
retracing under a live wave — the engine's compile-once discipline
holds mid-wave by construction, and the tests assert it by jit count.

Every applied change appends a JSON-serializable decision record to
``AutoTuner.decisions`` — ``{"kind", "t", "from", "to", "measurement"}``
with the measurement that justified it — surfaced through
``latency_stats()["autotune"]`` and the fleet benchmark's
``BENCH_autotune_decisions.json`` artifact.

Determinism: all timing comes from the engine's injected Clock; under a
plain ``ManualClock`` measured step dts are 0.0 and are ignored
(``observe_step`` drops non-positive dts; ``CostModel.merged`` skips
non-positive rows), so tier-1 tests drive the loop with synthetic cost
tables and auto-advancing clocks — zero sleeps, zero flakes.

To pin static behavior, simply don't attach a tuner (the default), or
disable dimensions per :class:`AutoTuneConfig` flag. An exact
``cfg.backend`` name pin is never overridden by recalibration — pins
bypass cost selection entirely (see docs/runtime.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import cells as cell_families
from repro.core import runtime


@dataclasses.dataclass(frozen=True)
class AutoTuneConfig:
    """Tuning policy knobs (all latencies in µs, matching CostModel rows).

    ``marginal_frac``: a slot is worth adding while the marginal step
    cost of adding it stays under this fraction of the single-lane step
    cost. ``step_budget_us`` optionally caps the absolute per-step
    latency (the paper's deadline translated to a wave-size bound).
    ``ladder_quantiles`` are the observed-prompt-length quantiles that
    become bucket boundaries (the top one should be 1.0 so the ladder
    covers the longest observed prompt). ``recal_min_steps`` throttles
    recalibration: fold timings back only once this many fresh warm
    steps have accumulated since the last fold.
    """
    tune_wave_size: bool = True
    tune_buckets: bool = True
    recalibrate: bool = True
    wave_floor: int = 1
    wave_cap: int = 16
    marginal_frac: float = 0.5
    step_budget_us: Optional[float] = None
    ladder_quantiles: Tuple[float, ...] = (0.5, 0.75, 0.9, 1.0)
    ladder_min_prompts: int = 8
    ladder_max_prompts: int = 4096       # observation window (newest kept)
    recal_min_steps: int = 32


class AutoTuner:
    """The feedback loop's state: observations in, decisions out.

    One tuner per engine (the fleet builds one per replica). The engine
    feeds it observations on the hot path (cheap appends, no jax calls):
    ``observe_prompt`` per enqueued request, ``observe_step`` per warm
    recorded decode step. At wave boundaries the engine hands itself to
    ``maybe_retune``, which evaluates each enabled dimension against the
    accumulated measurements and applies what changed through the
    engine's own boundary-safe mutators (``apply_wave_size``,
    ``apply_bucket_ladder``, ``refresh_executables``).
    """

    def __init__(self, config: AutoTuneConfig = AutoTuneConfig()):
        self.config = config
        self.prompt_lens: List[int] = []
        # fresh warm-step samples since the last recalibration fold,
        # grouped by the CostModel row they will become
        self._fresh: Dict[tuple, List[float]] = {}
        self._fresh_n = 0
        self.decisions: List[dict] = []
        self.retunes = 0                 # boundary evaluations that applied
                                         # at least one change

    # -- observation hooks (called by the engine on the hot path) -----------

    def observe_prompt(self, length: int) -> None:
        self.prompt_lens.append(int(length))
        if len(self.prompt_lens) > self.config.ladder_max_prompts:
            del self.prompt_lens[:-self.config.ladder_max_prompts]

    def observe_step(self, dt_s: float, *, batch: int, backend: Optional[str],
                     depth: int, hidden: int, family: str = "gru") -> None:
        """One warm decode-step timing. Non-positive dts are ignored (a
        plain ManualClock measures 0.0 between now() calls — folding that
        into the table would price the backend as free)."""
        if backend is None or dt_s <= 0.0:
            return
        key = (str(family), str(backend), int(depth), int(hidden),
               int(batch))
        self._fresh.setdefault(key, []).append(float(dt_s))
        self._fresh_n += 1

    # -- the retune entry point (wave boundaries only) ----------------------

    def maybe_retune(self, engine) -> List[dict]:
        """Evaluate every enabled dimension; apply and record what
        changed. MUST be called at a wave boundary only — the engine
        enforces that (``ServeEngine._maybe_retune``), which is what
        keeps jit invalidation from ever retracing under a live wave.
        Recalibration runs first so the wave-size rule reads the freshest
        curve. Returns the decision records applied this call."""
        applied: List[dict] = []
        now = engine.clock.now()
        if self.config.recalibrate:
            d = self._recalibrate(engine, now)
            if d is not None:
                applied.append(d)
        if self.config.tune_wave_size:
            d = self._tune_wave_size(engine, now)
            if d is not None:
                applied.append(d)
        if self.config.tune_buckets:
            d = self._tune_buckets(engine, now)
            if d is not None:
                applied.append(d)
        if applied:
            self.retunes += 1
            self.decisions.extend(applied)
        return applied

    # -- dimension 1: wave size from the measured batch-latency curve -------

    def _tune_wave_size(self, engine, now: float) -> Optional[dict]:
        g = engine.cfg.gru
        fam = cell_families.cfg_family(g)
        depth = g.resolved_num_layers
        hidden = g.resolved_layer_dims[0]
        exe = runtime.compile(g, batch=engine.max_batch, mode="decode",
                              placement=engine.ctx.mesh)
        backend = exe.decode_backend
        model = runtime.cost_model()
        pts = model.batch_points(backend, "decode", depth=depth,
                                 hidden=hidden, family=fam)
        if len(pts) < 2:
            return None              # no measured curve: static default wins

        def cost(b: int) -> float:
            return model.lookup(backend, "decode", depth=depth, batch=b,
                                hidden=hidden, family=fam)

        cap = max(1, min(self.config.wave_cap, pts[-1][0]))
        floor = max(1, self.config.wave_floor)
        solo = cost(1)
        margin = self.config.marginal_frac * solo
        best = floor
        prev = cost(best)
        for b in range(floor + 1, cap + 1):
            c = cost(b)
            if self.config.step_budget_us is not None \
                    and c > self.config.step_budget_us:
                break
            if c - prev > margin:
                break
            best, prev = b, c
        if best == engine.max_batch:
            return None
        decision = {
            "kind": "wave_size", "t": float(now),
            "from": int(engine.max_batch), "to": int(best),
            "measurement": {
                "family": fam, "backend": backend, "depth": int(depth),
                "hidden": int(hidden),
                "curve_us": [[int(b), float(cost(b))]
                             for b in range(1, cap + 1)],
                "solo_us": float(solo),
                "marginal_cap_us": float(margin),
                "step_budget_us": self.config.step_budget_us,
                "rule": (f"largest B<=cap with step(B)-step(B-1) <= "
                         f"{self.config.marginal_frac:g} x step(1)")}}
        engine.apply_wave_size(best)
        return decision

    # -- dimension 2: bucket ladder from observed prompt lengths ------------

    def _tune_buckets(self, engine, now: float) -> Optional[dict]:
        lens = self.prompt_lens
        if len(lens) < self.config.ladder_min_prompts:
            return None
        arr = np.asarray(lens, np.int64)
        qs = self.config.ladder_quantiles
        # method="higher": every rung is an actually-observed length, so
        # quantile prompts pad by zero timesteps
        rungs = np.quantile(arr, qs, method="higher")
        ladder = tuple(sorted({max(1, int(r)) for r in rungs}))
        if ladder == (engine.bucket_ladder or ()):
            return None
        decision = {
            "kind": "bucket_ladder", "t": float(now),
            "from": (list(engine.bucket_ladder) if engine.bucket_ladder
                     else f"pow2(min={engine.bucket_min})"),
            "to": list(ladder),
            "measurement": {
                "prompts": int(arr.size),
                "quantiles": [float(q) for q in qs],
                "len_p50": int(np.percentile(arr, 50)),
                "len_max": int(arr.max()),
                "rule": "observed prompt-length quantiles become the "
                        "prefill jit keys (longer prompts double from "
                        "the top rung)"}}
        engine.apply_bucket_ladder(ladder)
        return decision

    # -- dimension 3: fold served timings back into the CostModel -----------

    def _recalibrate(self, engine, now: float) -> Optional[dict]:
        if self._fresh_n < self.config.recal_min_steps:
            return None
        g = engine.cfg.gru
        entries = []
        for (fam, backend, depth, hidden, batch), dts in self._fresh.items():
            entries.append({"family": fam, "backend": backend,
                            "op": "decode", "depth": depth,
                            "hidden_dim": hidden, "batch": batch,
                            "p50_us": float(np.percentile(dts, 50) * 1e6),
                            "steps": len(dts)})
        samples, self._fresh, self._fresh_n = self._fresh_n, {}, 0
        if not entries:
            return None
        epoch_from = runtime.cost_epoch()
        runtime.set_cost_model(runtime.cost_model().merged(
            entries, source="<autotune>"))
        # re-trace only when the refreshed table changes a resolution the
        # engine's live jits froze at trace time; same choice = zero cost
        rebuilt = engine.refresh_executables()
        return {
            "kind": "recalibrate", "t": float(now),
            "from": epoch_from, "to": runtime.cost_epoch(),
            "rebuilt_jits": bool(rebuilt),
            "measurement": {
                "steps_folded": samples,
                "entries": entries,
                "decode_backend": engine.decode_backend,
                "rule": (f"fold p50 of >= {self.config.recal_min_steps} "
                         "fresh warm steps into the CostModel "
                         "(set_cost_model epoch bump)")}}

    # -- surface for latency_stats() ----------------------------------------

    def stats(self) -> dict:
        return {"retunes": self.retunes,
                "prompts_observed": len(self.prompt_lens),
                "fresh_steps": self._fresh_n,
                "decisions": [dict(d) for d in self.decisions]}
