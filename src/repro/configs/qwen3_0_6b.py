"""qwen3-0.6b [dense]: 28L d_model=1024 16H (kv=8) d_ff=3072 vocab=151936,
qk-norm, head_dim 128, tied embeddings [hf:Qwen/Qwen3 family; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, head_dim=16, vocab_size=256, remat=False)
