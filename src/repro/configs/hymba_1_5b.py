"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — PARALLEL attention + Mamba heads per layer, sliding-window
attention except 3 global layers (first/middle/last) [arXiv:2411.13676; hf].
Meta-tokens omitted (DESIGN.md §Arch-applicability)."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=1),
)

SMOKE = CONFIG.replace(num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=256, sliding_window=8,
                       global_attn_layers=(0, 2, 5),
                       ssm=SSMConfig(state_dim=4, conv_width=4, expand=1),
                       remat=False)
