"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (kv=4) d_ff=1536
vocab=151936, 128 routed experts top-8, qk-norm, head_dim 128
[hf:Qwen/Qwen3-30B-A3B family; hf]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_expert=1536,
                  norm_topk_prob=True),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
    head_dim=16, vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=48, norm_topk_prob=True),
    remat=False)
