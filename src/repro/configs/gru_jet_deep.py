"""Deep jet-tagging stack (``gru-jet-deep``): beyond-paper depth scaling.

Three GRU layers of H=32 over the paper's 5-feature input, with a MIXED
per-layer parallelization — the paper's hybrid AIE-PL split generalized to
whole layers: the input-adjacent layers run the row-wise scheme (gather
aggregation), the middle layer the cascade baseline (psum). Serves as the
registered example for ``GRUConfig.num_layers``/``layer_matvec_modes`` and
as the depth-sweep anchor in ``benchmarks/rowwise_vs_cascade.py``.
"""
from repro.configs.base import GRUConfig, ModelConfig

CONFIG = ModelConfig(
    name="gru-jet-deep",
    family="gru",
    num_layers=3,
    d_model=32,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=5,
    gru=GRUConfig(input_dim=5, hidden_dim=32, num_classes=5, seq_len=20,
                  num_layers=3,
                  layer_matvec_modes=("rowwise", "cascade", "rowwise"),
                  fused_gates=True, decoupled_wx=True),
    dtype="float32",          # fp32 end-to-end, like the paper's AIE path
    param_dtype="float32",
    scan_layers=False,
    remat=False,
)

SMOKE = CONFIG  # already CPU-sized
