"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared (shared ffn 5632)
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. Experts padded 60->64 for EP divisibility
(DESIGN.md §Arch-applicability)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408, shared_d_ff=5632,
                  norm_topk_prob=False),
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=96,
    vocab_size=256,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, shared_d_ff=64,
                  norm_topk_prob=False),
    remat=False)
