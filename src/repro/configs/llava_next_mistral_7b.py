"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (kv=8) d_ff=14336
vocab=32000 — mistral-7b backbone; anyres vision tiling STUB (input_specs
provides precomputed patch embeddings, base tile 576 x 1024)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from repro.configs.base import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    vision=VisionStubConfig(num_patches=576, embed_dim=1024),
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=256,
                       vision=VisionStubConfig(num_patches=8, embed_dim=32),
                       remat=False)
