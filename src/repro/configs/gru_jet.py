"""The paper's own model: jet-tagging GRU (H=20, X=5, 5 classes, T=20).

Numerically validated configuration from the paper (§5: "we numerically
tested the H = 20 and X = 5 with a GRU trained in a jet tagging dataset").
Full fp32, batch 1 at serve time — the latency-measurement regime.
"""
from repro.configs.base import GRUConfig, ModelConfig

CONFIG = ModelConfig(
    name="gru-jet",
    family="gru",
    num_layers=1,
    d_model=20,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=5,
    gru=GRUConfig(input_dim=5, hidden_dim=20, num_classes=5, seq_len=20,
                  matvec_mode="rowwise", fused_gates=True, decoupled_wx=True),
    dtype="float32",          # the paper is fp32 end-to-end (AIE native fp32)
    param_dtype="float32",
    scan_layers=False,
    remat=False,
)

# scaled-up variant used by the latency sweeps (H up to 32 like Table 1)
def scaled(hidden: int = 32, input_dim: int = 32, **kw) -> ModelConfig:
    return CONFIG.replace(gru=GRUConfig(
        input_dim=input_dim, hidden_dim=hidden, num_classes=5, seq_len=20,
        **kw))

SMOKE = CONFIG  # already CPU-sized
