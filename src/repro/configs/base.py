"""Config system: model architecture, shapes, mesh, and training configs.

Every assigned architecture is a `ModelConfig` in its own module under
`repro.configs`; the registry maps ``--arch <id>`` to it. Shape cells
(train_4k / prefill_32k / decode_32k / long_500k) live in `shapes.py`.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden size
    shared_d_ff: int = 0             # 0 = no shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001   # load-balance aux loss
    norm_topk_prob: bool = True      # renormalize top-k weights (qwen3 style)
    tp_mode: str = "gather"          # expert TP: "gather" (weight-gathered
                                     # EP, §Perf H2) | "psum" (baseline)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (used by hymba's parallel SSM heads)."""
    state_dim: int = 16
    conv_width: int = 4
    dt_rank: int = 0                 # 0 -> ceil(d_model/16)
    expand: int = 1                  # inner expansion of the ssm path


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_layers: Tuple[int, ...] = ()   # layer indices that are sLSTM blocks
    proj_factor: float = 2.0             # mLSTM up-projection factor
    conv_width: int = 4


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (whisper). Frontend is a stub:
    input_specs() provides precomputed frame embeddings."""
    num_layers: int
    num_frames: int = 1500           # whisper: 30 s of audio after conv frontend


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM modality frontend stub: precomputed patch embeddings are inputs."""
    num_patches: int = 576           # base-resolution tile (anyres tiles stubbed)
    embed_dim: int = 1024            # pre-projection CLIP dim


@dataclass(frozen=True)
class GRUConfig:
    """The paper's own model family (core contribution).

    Depth: the paper validates one layer (H=20), but the row-wise scheme is
    per-matvec and composes across layers. ``num_layers``/``layer_dims``
    describe a stack: layer 0 consumes ``input_dim``; layer ``l`` consumes
    the previous layer's hidden size. ``layer_matvec_modes`` optionally
    overrides ``matvec_mode`` per layer (the paper's hybrid AIE-PL split,
    generalized: row-wise and cascade layers can be mixed in one stack).
    All depth-1 defaults reproduce the original single-cell behavior.

    ``family`` names the cell recurrence this stack runs
    (``repro.core.cells`` registry): ``"gru"`` (default, the paper's
    cell) or ``"slstm"`` (exponential-gated xLSTM cell, 4 gate columns
    per hidden unit). The shape fields describe the stack identically for
    every family; the executor keys its backend registry, cost rows and
    prepare()-time weight views by ``(family, backend)``.
    """
    input_dim: int = 5
    hidden_dim: int = 20
    num_classes: int = 5
    seq_len: int = 20
    matvec_mode: str = "rowwise"     # "rowwise" | "cascade" | "dense"
    fused_gates: bool = True         # hybrid fused aggregation vs unfused
    decoupled_wx: bool = True        # hoist W.x out of the recurrence
    variant: str = "v1"              # "v1" (paper/Cho) | "v3" (beyond-paper fused-U)
    backend: str = "xla"             # executor preference: "xla"/"pallas"
                                     # pin a family, an exact backend name
                                     # (e.g. "pallas_chain") pins one
                                     # backend, "auto" = cheapest legal
                                     # (measured costs when calibrated;
                                     # see repro.core.runtime)
    row_block: int = 0               # rows per block (0 = auto)
    unroll: int = 1                  # scan unroll for short-seq latency mode
    quant: str = ""                  # "" (f32 everywhere) | "int8": make the
                                     # q8 backends (pallas_fused_q8 /
                                     # pallas_chain_q8) dispatch candidates —
                                     # selected by "auto" only when the quant
                                     # accuracy gate is open AND a calibration
                                     # measures them faster (exact backend-name
                                     # pins bypass the gate; see
                                     # repro.core.runtime)
    # --- deep stacks ---
    num_layers: int = 1              # stack depth (ignored if layer_dims set)
    layer_dims: Tuple[int, ...] = ()     # per-layer hidden sizes; () -> uniform
    layer_matvec_modes: Tuple[str, ...] = ()  # per-layer matvec_mode overrides
    # --- cell family (last field: keeps positional construction stable) ---
    family: str = "gru"              # cell recurrence: "gru" | "slstm"

    @property
    def resolved_num_layers(self) -> int:
        return len(self.layer_dims) if self.layer_dims else self.num_layers

    @property
    def resolved_layer_dims(self) -> Tuple[int, ...]:
        """Hidden size of every layer, layer 0 first."""
        if self.layer_dims:
            return tuple(self.layer_dims)
        return (self.hidden_dim,) * self.num_layers

    def layer_input_dim(self, layer: int) -> int:
        """Input width of ``layer``: raw features for layer 0, previous
        hidden size above it."""
        if layer == 0:
            return self.input_dim
        return self.resolved_layer_dims[layer - 1]

    def layer_matvec_mode(self, layer: int) -> str:
        if self.layer_matvec_modes:
            return self.layer_matvec_modes[layer]
        return self.matvec_mode


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm|gru|slstm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    # attention / block details
    qk_norm: bool = False
    qkv_bias: bool = False
    out_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10_000.0
    rope: bool = True
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    mlp: str = "swiglu"              # swiglu | gelu
    parallel_block: bool = False     # cohere-style attn ∥ mlp
    tie_embeddings: bool = False
    sliding_window: int = 0          # 0 = full attention
    global_attn_layers: Tuple[int, ...] = ()  # layers that ignore sliding_window
    logit_softcap: float = 0.0
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionStubConfig] = None
    gru: Optional[GRUConfig] = None
    # numerics
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # parameter dtype (dry-run may override)
    # scan-over-layers (compile-time discipline for deep stacks)
    scan_layers: bool = True
    remat: bool = True
    # attention implementation: xla_flash (chunked, compiles everywhere),
    # pallas (TPU target kernel), naive (oracle)
    attn_impl: str = "xla_flash"
    attn_chunk: int = 1024           # kv-chunk for xla_flash / pallas block

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_recurrent(self) -> bool:
        return self.family in ("ssm", "hybrid", "gru", "slstm")

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: recurrent/hybrid archs only."""
        return self.family in ("ssm", "hybrid", "gru", "slstm")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        attn = d * hd * n_q + 2 * d * hd * n_kv + hd * n_q * d
        if self.mlp == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        per_layer = attn + mlp + 2 * d
        if self.moe is not None:
            m = self.moe
            emlp = m.num_experts * 3 * d * m.d_expert + d * m.num_experts
            if m.shared_d_ff:
                emlp += 3 * d * m.shared_d_ff
            per_layer = attn + emlp + 2 * d
        total = self.num_layers * per_layer + self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        if self.encoder is not None:
            total += self.encoder.num_layers * (attn * 2 + mlp + 3 * d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        full_moe = m.num_experts * 3 * d * m.d_expert
        active_moe = m.top_k * 3 * d * m.d_expert
        return self.param_count() - self.num_layers * (full_moe - active_moe)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1            # gradient accumulation
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    grad_compression: str = "none"   # none | bf16 | bf16_ef (error feedback)
    opt_dtype: str = "float32"       # Adam moment dtype


_REGISTRY = {
    "gru-jet": "gru_jet",
    "gru-jet-deep": "gru_jet_deep",
    "slstm-jet": "slstm_jet",
    "xlstm-125m": "xlstm_125m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen3-0.6b": "qwen3_0_6b",
    "command-r-35b": "command_r_35b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2.5-3b": "qwen2_5_3b",
    "whisper-large-v3": "whisper_large_v3",
    "hymba-1.5b": "hymba_1_5b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ASSIGNED_ARCHS = [a for a in _REGISTRY
                  if not a.startswith(("gru-jet", "slstm-jet"))]
ALL_ARCHS = list(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    if arch not in _REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch]}")
    return mod.SMOKE
