"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517; unverified]. Alternating m/s pairs (1:1 ratio so
both cell types are exercised; the xLSTM paper sweeps ratios)."""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                      # blocks integrate their own projections
    vocab_size=50304,
    norm="rmsnorm",
    rope=False,
    xlstm=XLSTMConfig(slstm_layers=(1, 3, 5, 7, 9, 11), proj_factor=2.0,
                      conv_width=4),
)

SMOKE = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, vocab_size=128,
    remat=False)
