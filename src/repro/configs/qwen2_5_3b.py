"""qwen2.5-3b [dense]: 36L d_model=2048 16H (kv=2) d_ff=11008 vocab=151936,
QKV bias, tied embeddings [hf:Qwen/Qwen2.5 family; hf]. kv=2 does not
divide model=16 -> KV heads replicate on the TP axis (resolver drop)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=256, remat=False)
