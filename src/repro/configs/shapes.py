"""Assigned input-shape cells and the (arch x shape) matrix with skip rules."""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

from repro.configs.base import ALL_ARCHS, ASSIGNED_ARCHS, ModelConfig, ShapeConfig, get_config

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}

# The paper's own model gets its own cells (not part of the 40 assigned ones).
GRU_SHAPES = {
    "jet_t20": ShapeConfig("jet_t20", seq_len=20, global_batch=1, kind="decode"),
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "long_500k": SHAPES["long_500k"],
}


def shape_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Assignment rules: long_500k only for sub-quadratic archs; decode only
    for archs with a decode step (all assigned archs have one)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "full-attention arch: 500k decode is quadratic-cost; skipped per assignment"
    return None


def cells(include_gru: bool = True) -> Iterator[Tuple[str, ShapeConfig, Optional[str]]]:
    """Yield (arch, shape, skip_reason) for the full assigned matrix."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            yield arch, shape, shape_skip_reason(cfg, shape)
    if include_gru:
        cfg = get_config("gru-jet")
        for shape in GRU_SHAPES.values():
            yield "gru-jet", shape, None
