"""command-r-35b [dense]: 40L d_model=8192 64H (kv=8) d_ff=22528
vocab=256000 — parallel attn||mlp blocks, LayerNorm, no biases, tied
embeddings [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    norm="layernorm",
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=7_500_000.0,
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=160, vocab_size=256, remat=False)
