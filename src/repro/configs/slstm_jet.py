"""sLSTM twin of the paper's jet-tagging model (H=20, X=5, 5 classes, T=20).

Same shapes and serving regime as ``gru_jet``, with the cell family
switched to the exponential-gated sLSTM (``repro.core.slstm``): the
second registered recurrence, serving through the identical
compile/prepare/ServeEngine path. The per-layer weights are ``(X, 4H)`` /
``(H, 4H)`` instead of the GRU's ``3H`` gate columns.
"""
from repro.configs.base import GRUConfig, ModelConfig

CONFIG = ModelConfig(
    name="slstm-jet",
    family="slstm",
    num_layers=1,
    d_model=20,
    num_heads=1,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=5,
    gru=GRUConfig(family="slstm", input_dim=5, hidden_dim=20, num_classes=5,
                  seq_len=20, matvec_mode="rowwise", fused_gates=True,
                  decoupled_wx=True),
    dtype="float32",          # fp32 end-to-end, like the paper's GRU
    param_dtype="float32",
    scan_layers=False,
    remat=False,
)


# scaled-up variant used by the latency sweeps
def scaled(hidden: int = 32, input_dim: int = 32, **kw) -> ModelConfig:
    return CONFIG.replace(gru=GRUConfig(
        family="slstm", input_dim=input_dim, hidden_dim=hidden,
        num_classes=5, seq_len=20, **kw))


SMOKE = CONFIG  # already CPU-sized
