"""whisper-large-v3 [audio]: 32L(dec)+32L(enc) d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866 — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings, 1500 frames = 30 s) [arXiv:2212.04356;
unverified]. LayerNorm, GELU, sinusoidal positions, no RoPE."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    norm="layernorm",
    mlp="gelu",
    rope=False,
    tie_embeddings=True,
    encoder=EncoderConfig(num_layers=32, num_frames=1500),
)

SMOKE = CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                       d_ff=128, vocab_size=256,
                       encoder=EncoderConfig(num_layers=2, num_frames=16),
                       remat=False)
