"""AdamW from scratch (no optax in this environment): decoupled weight
decay, global-norm clipping, warmup+cosine schedule, optional low-precision
moments (bf16 ``nu``/``mu`` halves optimizer HBM — matters at 235B params).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.core.params import Spec, is_spec


def opt_specs(param_specs, dtype: str = "float32") -> dict:
    """Mirrored Spec trees for the Adam moments (dry-run abstract state)."""
    def f(s: Spec) -> Spec:
        return Spec(s.shape, s.axes, init="zeros", dtype=dtype)
    return {
        "mu": jax.tree_util.tree_map(f, param_specs, is_leaf=is_spec),
        "nu": jax.tree_util.tree_map(f, param_specs, is_leaf=is_spec),
    }


def init_opt_state(params, dtype: str = "float32") -> dict:
    dt = jnp.dtype(dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree_util.tree_map(z, params),
            "nu": jax.tree_util.tree_map(z, params)}


def lr_schedule(step: jax.Array, cfg: TrainConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.1 * cfg.learning_rate + 0.9 * cfg.learning_rate * 0.5 * (
        1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(params, grads, opt_state, step: jax.Array, cfg: TrainConfig):
    """One AdamW step. Returns (params', opt_state', metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_schedule(step, cfg)
    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        step_ = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step_ + cfg.weight_decay * p32)
        return (p_new.astype(p.dtype), mu32.astype(mu.dtype),
                nu32.astype(nu.dtype))

    flat_p, tree = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_nu = jax.tree_util.tree_leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    params2 = jax.tree_util.tree_unflatten(tree, [o[0] for o in out])
    mu2 = jax.tree_util.tree_unflatten(tree, [o[1] for o in out])
    nu2 = jax.tree_util.tree_unflatten(tree, [o[2] for o in out])
    return params2, {"mu": mu2, "nu": nu2}, {"lr": lr, "grad_norm": gn}
