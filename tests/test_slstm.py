"""sLSTM cell family, end to end: the second family the ``(family,
backend)`` registry serves.

Covers the acceptance surface of the cell-family subsystem:

* fused Pallas kernels (sequence + decode) against the raw-array oracle
  (``kernels/slstm_cell/ref.py``) and the model-layout reference
  (``repro.core.slstm.slstm_stack_reference``), depths 1-3, masked and
  unmasked;
* the XLA-scan fallback's bitwise mask-exactness contract;
* ``runtime.compile(cfg)`` with ``cfg.family="slstm"`` returning a working
  executable for both backends, with prepare() doing ALL weight placement
  (no ``device_put`` in the traced execute jaxpr);
* typed ``UnknownCellFamily`` from every serving surface;
* ServeEngine waves serving slstm through ``generate()`` with per-step
  backend attribution in ``latency_stats()``;
* the measured ``(family, backend)`` calibration round-trip
  (CostModel rows -> ``compile`` with ``cost_source == "measured"``);
* executable-cache keys: stable within a family, distinct across families.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GRUConfig, get_smoke_config
from repro.core import cells, runtime, slstm
from repro.core.params import init_params
from repro.kernels import on_cpu
from repro.kernels.slstm_cell import ops as sops
from repro.kernels.slstm_cell import ref as sref
from repro.kernels.slstm_cell.kernel import (slstm_stack_decode_kernel,
                                             slstm_stack_sequence_kernel)

TOL = dict(rtol=3e-5, atol=3e-6)
B, T, X, PAD = 2, 6, 5, 3


def _case(depth=2, H=16, backend="auto"):
    cfg = GRUConfig(input_dim=X, hidden_dim=H, num_layers=depth,
                    backend=backend, family="slstm")
    fam = cells.get_family("slstm")
    params = init_params({"cells": fam.stack_specs(cfg)}, jax.random.key(0))
    xs = jax.random.normal(jax.random.key(1), (B, T, X))
    return cfg, fam, params, xs, fam.state0(cfg, B)


def _mask():
    """Left-pad mask: first PAD steps of a T+PAD window are padding."""
    return jnp.broadcast_to(jnp.arange(T + PAD)[None, :] >= PAD, (B, T + PAD))


def _raw_arrays(params, xs):
    """Model-layout params -> the kernels' raw stacked-array interface."""
    stacked = sops.prepare_stacked_cells(params["cells"])
    xp_t = jnp.moveaxis(xs @ params["cells"][0]["w"], -2, 0)   # (T,B,4H)
    return stacked, xp_t


# ---------------------------------------------------------------------------
# kernel/ref triplet parity (raw-array interface)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 3])
@pytest.mark.parametrize("masked", [False, True])
def test_sequence_kernel_matches_ref(depth, masked):
    cfg, fam, params, xs, s0 = _case(depth)
    L = cfg.resolved_num_layers
    stacked, xp_t = _raw_arrays(params, xs)
    c0, n0, m0, h0 = sops._leaf_stacks(tuple(s0), L)
    mask_t = (jnp.ones((T, B), jnp.float32)
              .at[:2, 1].set(0.0) if masked else None)
    got = slstm_stack_sequence_kernel(
        c0, n0, m0, h0, xp_t, stacked["u"], stacked["w_deep"], stacked["b"],
        mask_t, interpret=on_cpu())
    if masked:
        # oracle with the same freeze: replay only the kept steps per row
        ref = sref.slstm_stack_sequence_ref(
            c0, n0, m0, h0, xp_t, stacked["u"], stacked["w_deep"],
            stacked["b"])
        # row 1 skipped steps 0-1: recompute its trajectory separately
        ref1 = sref.slstm_stack_sequence_ref(
            c0[:, 1:], n0[:, 1:], m0[:, 1:], h0[:, 1:], xp_t[2:, 1:],
            stacked["u"], stacked["w_deep"], stacked["b"])
        for g, r, r1 in zip(got[1:], ref[1:], ref1[1:]):
            np.testing.assert_allclose(np.asarray(g[:, 0]),
                                       np.asarray(r[:, 0]), **TOL)
            np.testing.assert_allclose(np.asarray(g[:, 1]),
                                       np.asarray(r1[:, 0]), **TOL)
        return
    ref = sref.slstm_stack_sequence_ref(
        c0, n0, m0, h0, xp_t, stacked["u"], stacked["w_deep"], stacked["b"])
    for g, r in zip(got, ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), **TOL)


@pytest.mark.parametrize("depth", [1, 3])
def test_decode_kernel_matches_ref(depth):
    cfg, fam, params, xs, s0 = _case(depth)
    L = cfg.resolved_num_layers
    stacked, xp_t = _raw_arrays(params, xs)
    c, n, m, h = sops._leaf_stacks(tuple(s0), L)
    got = slstm_stack_decode_kernel(c, n, m, h, xp_t[0], stacked["u"],
                                    stacked["w_deep"], stacked["b"],
                                    interpret=on_cpu())
    ref = sref.slstm_stack_decode_ref(c, n, m, h, xp_t[0], stacked["u"],
                                      stacked["w_deep"], stacked["b"])
    for g, r in zip(got, ref):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), **TOL)


# ---------------------------------------------------------------------------
# compiled executables: both backends vs the family reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "pallas_fused"])
@pytest.mark.parametrize("depth", [1, 2])
def test_compile_matches_family_reference(backend, depth):
    cfg, fam, params, xs, s0 = _case(depth, backend=backend)
    cell_p = fam.normalize(params, cfg)
    ref_f, ref_all = fam.reference(cell_p, s0, xs, return_all=True)
    p = runtime.compile(cfg, batch=B, seq=T, mode="prefill")
    assert p.sequence_backend == backend
    finals, alls = p.sequence(params, s0, xs, return_all=True)
    assert len(finals) == slstm.STATE_LEAVES * depth
    for a, b in zip(finals, ref_f):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
    np.testing.assert_allclose(np.asarray(alls), np.asarray(ref_all), **TOL)
    # decode: T single steps == the sequence finals
    pd = runtime.compile(cfg, batch=B, mode="decode")
    assert pd.decode_backend == backend
    st = s0
    for t in range(T):
        st = pd.decode(params, st, xs[:, t])
    for a, b in zip(st, ref_f):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("backend", ["xla", "pallas_fused"])
def test_mask_exact_bitwise(backend):
    """Where the executable claims mask_exact, left-padded+masked finals
    equal the unpadded run BITWISE — the engine's bucketing contract."""
    cfg, fam, params, xs, s0 = _case(2, backend=backend)
    xs_pad = jnp.pad(xs, ((0, 0), (PAD, 0), (0, 0)))
    p = runtime.compile(cfg, batch=B, seq=T + PAD, mask=True, mode="prefill")
    assert p.sequence_backend == backend and p.mask_exact
    fm, _ = p.sequence(params, s0, xs_pad, mask=_mask())
    un = runtime.compile(cfg, batch=B, seq=T, mode="prefill")
    fu, _ = un.sequence(params, s0, xs)
    for a, b in zip(fu, fm):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hetero_dims_fall_to_xla():
    """The fused kernel needs uniform VMEM blocks in the slstm namespace
    too: hetero layer_dims resolve to the hetero-capable xla backend."""
    cfg = GRUConfig(input_dim=X, layer_dims=(16, 8), backend="pallas_fused",
                    family="slstm")
    fam = cells.get_family("slstm")
    params = init_params({"cells": fam.stack_specs(cfg)}, jax.random.key(0))
    xs = jax.random.normal(jax.random.key(1), (B, T, X))
    s0 = fam.state0(cfg, B)
    p = runtime.compile(cfg, batch=B, seq=T, mode="prefill")
    assert p.sequence_backend == "xla"
    finals, _ = p.sequence(params, s0, xs)
    ref_f, _ = fam.reference(fam.normalize(params, cfg), s0, xs)
    for a, b in zip(finals, ref_f):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


# ---------------------------------------------------------------------------
# prepare(): all weight work ahead of the traced execute
# ---------------------------------------------------------------------------

def _prim_names(fn, *args):
    names = set()

    def walk(j):
        for e in j.eqns:
            names.add(e.primitive.name)
            for v in e.params.values():
                if hasattr(v, "jaxpr"):
                    walk(v.jaxpr)
    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return names


def test_prepare_no_device_put_in_execute_trace():
    cfg, fam, params, xs, s0 = _case(2, backend="pallas_fused")
    exe = runtime.compile(cfg, batch=B, seq=T, mode="prefill")
    sp = exe.prepare(params)
    assert sp.stacked is not None          # fused views built once
    n_seq = _prim_names(lambda p, h, x: exe.sequence(p, h, x), sp, s0, xs)
    assert "device_put" not in n_seq, sorted(n_seq)
    ed = runtime.compile(cfg, batch=B, mode="decode")
    n_dec = _prim_names(lambda p, h, x: ed.decode(p, h, x), sp, s0, xs[:, 0])
    assert "device_put" not in n_dec, sorted(n_dec)


def test_prepare_skips_unsupported_family_views():
    """prepare() consults the family's capability set: no int8 weight rows
    and no mesh placement for a family that registers neither."""
    cfg, fam, params, xs, s0 = _case(2, backend="auto")
    sp = runtime.prepare(params, dataclasses.replace(cfg, quant="int8"))
    assert sp.quant is None
    assert sp.placed is None
    assert sp.stacked is not None


# ---------------------------------------------------------------------------
# typed unknown-family error, registry namespaces, cache keys
# ---------------------------------------------------------------------------

def test_unknown_family_typed_error():
    with pytest.raises(cells.UnknownCellFamily) as ei:
        cells.get_family("convgru")
    assert ei.value.family == "convgru"
    assert "gru" in ei.value.known and "slstm" in ei.value.known
    assert isinstance(ei.value, KeyError)   # old except-KeyError code holds
    cfg = GRUConfig(input_dim=X, hidden_dim=16, family="convgru")
    with pytest.raises(cells.UnknownCellFamily):
        runtime.compile(cfg, batch=B, seq=T, mode="prefill")


def test_registry_namespaces_per_family():
    slstm_b = runtime.backends("slstm")
    assert set(slstm_b) == {"xla", "pallas_fused"}
    assert all(s.family == "slstm" for s in slstm_b.values())
    gru_b = runtime.backends("gru")
    assert {"xla", "pallas_fused", "pallas_chain"} <= set(gru_b)
    assert all(s.family == "gru" for s in gru_b.values())
    # default namespace is gru: pre-registry call sites see the same map
    assert set(runtime.backends()) == set(gru_b)


def test_exec_cache_keyed_by_family():
    """Memoized compiles: stable within a family, never shared across."""
    g = GRUConfig(input_dim=X, hidden_dim=16, num_layers=2, backend="xla")
    s = dataclasses.replace(g, family="slstm")
    eg = runtime.compile(g, batch=B, seq=T, mode="prefill")
    es = runtime.compile(s, batch=B, seq=T, mode="prefill")
    assert eg is not es
    assert eg is runtime.compile(g, batch=B, seq=T, mode="prefill")
    assert es is runtime.compile(s, batch=B, seq=T, mode="prefill")


# ---------------------------------------------------------------------------
# measured (family, backend) calibration round-trip
# ---------------------------------------------------------------------------

def test_family_calibration_roundtrip():
    """Measured slstm rows drive slstm dispatch (cost_source="measured")
    without leaking into gru dispatch, and vice versa."""
    entries = [{"family": "slstm", "backend": b, "op": op, "depth": 2,
                "batch": B, "hidden_dim": 16,
                "p50_us": 5.0 if b == "xla" else 50.0}
               for b in ("xla", "pallas_fused")
               for op in ("decode", "sequence")]
    try:
        runtime.set_cost_model(runtime.CostModel.from_entries(
            entries, source="<test: slstm rows>"))
        cfg = GRUConfig(input_dim=X, hidden_dim=16, num_layers=2,
                        backend="auto", family="slstm")
        exe = runtime.compile(cfg, batch=B, mode="decode")
        assert exe.cost_source == "measured"
        assert exe.decode_backend == "xla"   # the measured-cheap one
        # the same shapes under gru see NO slstm rows: static fallback
        gcfg = dataclasses.replace(cfg, family="gru")
        ge = runtime.compile(gcfg, batch=B, mode="decode")
        assert ge.cost_source == "static"
    finally:
        runtime.set_cost_model(runtime.CostModel({}, source="<tests: static>"))


# ---------------------------------------------------------------------------
# ServeEngine: slstm waves through generate()
# ---------------------------------------------------------------------------

def test_serve_engine_slstm_waves():
    from repro.distributed.sharding import ShardCtx
    from repro.models import api as mapi
    from repro.serve.engine import Request, ServeEngine

    cfg = get_smoke_config("slstm-jet")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.normal(size=(int(rng.integers(3, 13)),
                                            cfg.gru.input_dim))
                    .astype(np.float32), max_new_tokens=4)
            for _ in range(5)]
    eng = ServeEngine(cfg, params, ShardCtx(), max_batch=3)
    done = eng.generate(reqs)
    assert all(r.done and len(r.out) == 4 for r in done)
    stats = eng.latency_stats()
    # per-step attribution names an (slstm, ·) backend
    assert eng.decode_backend in ("xla", "pallas_fused")
    assert stats["decode_backend_steps"], stats
    assert set(stats["decode_backend_steps"]) <= {"xla", "pallas_fused"}
    assert sum(stats["decode_backend_steps"].values()) == stats["steps"]
    # decode-loop output equals the model API run on the same prompt
    logits, _ = A.prefill(eng.params, cfg,
                          {"features": jnp.asarray(reqs[0].prompt)[None]},
                          ShardCtx())
    assert done[0].out[0] == int(jnp.argmax(logits, -1)[0])


def test_serve_engine_unknown_family_raises():
    from repro.distributed.sharding import ShardCtx
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config("slstm-jet").replace(family="convgru")
    with pytest.raises(cells.UnknownCellFamily):
        ServeEngine(cfg, {}, ShardCtx())
