"""Optional-hypothesis shim: the real library when installed, otherwise
drop-in ``given``/``settings``/``st`` stand-ins that turn each property
test into a clean pytest skip instead of a collection error. Import via
``from _hyp import given, settings, st`` (tests/ is on sys.path under
pytest's rootdir-based import mode)."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """st.integers(...), st.floats(...), ... -> inert placeholders."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            # zero-arg wrapper: pytest must not see the original signature,
            # or it would demand fixtures for the hypothesis arguments
            def skipper():
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
