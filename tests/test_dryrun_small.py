"""Miniature dry-run on an 8-device host mesh: proves the lowering pipeline
(abstract state -> jit -> lower -> compile -> analyses) end to end without
the 512-device cost. The full production sweep is exercised by
``python -m repro.launch.dryrun --all`` (see EXPERIMENTS.md §Dry-run)."""
import pytest


def test_mini_dryrun_train_and_decode(multidev):
    multidev("""
import jax, jax.numpy as jnp
from repro import compat
from repro.configs.base import get_smoke_config, TrainConfig, ShapeConfig
from repro.core.params import abstract_params
from repro.distributed.sharding import ShardCtx, param_shardings
from repro.models import api as mapi
from repro.train import trainer
from repro.launch.hloparse import analyze

mesh = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
ctx = ShardCtx(mesh=mesh)

for arch in ["qwen3-0.6b", "qwen2-moe-a2.7b", "xlstm-125m", "hymba-1.5b"]:
    cfg = get_smoke_config(arch)
    A = mapi.get_api(cfg)
    tcfg = TrainConfig()
    shape = ShapeConfig("t", 32, 8, "train")
    bspecs = mapi.input_specs(cfg, shape)
    sspecs = trainer.state_specs(cfg, tcfg)
    fn = jax.jit(trainer.make_train_step(cfg, tcfg, ctx),
                 in_shardings=(param_shardings(sspecs, ctx),
                               param_shardings(bspecs, ctx)))
    lowered = fn.lower(abstract_params(sspecs, cfg.param_dtype),
                       abstract_params(bspecs, "float32"))
    compiled = lowered.compile()
    assert compiled.memory_analysis() is not None
    a = analyze(compiled.as_text())
    assert a.flops > 0, arch
    # decode path
    pspecs = A.specs(cfg)
    cspecs = A.cache_specs(cfg, 8, 64)
    tok = jax.ShapeDtypeStruct((8,), jnp.int32)
    dfn = jax.jit(lambda p, c, t: A.decode_step(p, cfg, c, t, ctx),
                  in_shardings=(param_shardings(pspecs, ctx),
                                param_shardings(cspecs, ctx), None))
    dcomp = dfn.lower(abstract_params(pspecs, cfg.param_dtype),
                      abstract_params(cspecs, cfg.param_dtype), tok).compile()
    assert dcomp.memory_analysis() is not None
    print("ok", arch)
print("PASS")
""", n_devices=8, timeout=560)
