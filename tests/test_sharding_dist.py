"""Sharding rules, row-parallel study, pipeline parallelism, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.params import Spec
from repro.distributed.sharding import (PROFILES, ShardCtx, resolve_pspec)


class _FakeMesh:
    def __init__(self, shape):
        self._shape = shape

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


def _ctx(profile="default", **mesh_shape):
    ctx = ShardCtx.__new__(ShardCtx)
    object.__setattr__(ctx, "mesh", _FakeMesh(mesh_shape or
                                              {"data": 16, "model": 16}))
    object.__setattr__(ctx, "profile", profile)
    return ctx


def test_divisibility_drop():
    ctx = _ctx()
    # kv_heads=8 cannot divide model=16 -> dropped; capacity picks model
    ps = resolve_pspec(("batch", "kv_heads", "act_kv_seq", None),
                       (128, 8, 32768, 128), ctx)
    assert ps[0] == ("data",) or ps[0] == "data"
    assert ps[1] is None
    assert ps[2] == "model"


def test_dedup_mesh_axes():
    ctx = _ctx()
    # both logical axes map to model; only the first wins
    ps = resolve_pspec(("heads", "mlp"), (32, 3200), ctx)
    assert ps[0] == "model" and (len(ps) < 2 or ps[1] is None)


def test_profiles_differ():
    d = dict(PROFILES["default"])
    sp = dict(PROFILES["sp"])
    ca = dict(PROFILES["cascade"])
    assert d["act_seq"] == () and sp["act_seq"] == ("model",)
    assert d["gates"] == ("model",) and ca["gates"] == ()
    assert ca["hidden"] == ("model",)


def test_multipod_batch_axes():
    ctx = _ctx(pod=2, data=16, model=16)
    ps = resolve_pspec(("batch", "act_seq"), (256, 4096), ctx)
    assert ps[0] == ("pod", "data")


def test_rowparallel_gru_all_modes(multidev):
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import GRUConfig
from repro.core import gru, rowparallel
from repro.core.params import init_params
mesh = compat.make_mesh((4,), ("model",))
H, X, B, T = 32, 8, 2, 9
params = init_params(gru.gru_cell_specs(X, H), jax.random.key(0))
xs = jax.random.normal(jax.random.key(1), (B, T, X))
h0 = jnp.zeros((B, H))
ref, _ = gru.gru_reference(params, h0, xs)
for mode in ["rowwise", "cascade"]:
    cfg = GRUConfig(input_dim=X, hidden_dim=H, matvec_mode=mode)
    out = rowparallel.gru_sequence_sharded(params, h0, xs, mesh=mesh, cfg=cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-6)
# v3 consistency between schemes
o3 = [rowparallel.gru_sequence_sharded(params, h0, xs, mesh=mesh,
        cfg=GRUConfig(input_dim=X, hidden_dim=H, matvec_mode=m, variant="v3"))
      for m in ("rowwise", "cascade")]
np.testing.assert_allclose(np.asarray(o3[0]), np.asarray(o3[1]), rtol=3e-5, atol=3e-6)
print("PASS")
""")


def test_rowwise_collectives_are_allgather_only(multidev):
    """The paper's claim, verified in HLO: row-wise aggregation is gathers,
    cascade is reductions."""
    multidev("""
import jax, jax.numpy as jnp, re
from repro.configs.base import GRUConfig
from repro.core import gru, rowparallel
from repro.core.params import init_params
mesh = jax.make_mesh((4,), ("model",))
H, X, B, T = 32, 8, 2, 4
params = init_params(gru.gru_cell_specs(X, H), jax.random.key(0))
xs = jax.random.normal(jax.random.key(1), (B, T, X))
h0 = jnp.zeros((B, H))
def hlo(mode, variant="v1"):
    cfg = GRUConfig(input_dim=X, hidden_dim=H, matvec_mode=mode, variant=variant)
    f = jax.jit(lambda p, h, x: rowparallel.gru_sequence_sharded(p, h, x, mesh=mesh, cfg=cfg))
    return f.lower(params, h0, xs).compile().as_text()
row = hlo("rowwise")
cas = hlo("cascade")
assert "all-gather" in row
assert "all-reduce" in cas
# v3 rowwise halves the gathers per step vs v1 (one agg instead of two)
from repro.launch.hloparse import analyze
a1 = analyze(hlo("rowwise", "v1"))
a3 = analyze(hlo("rowwise", "v3"))
ag1 = a1.coll_counts.get("all-gather", 0)
ag3 = a3.coll_counts.get("all-gather", 0)
assert ag3 < ag1, (ag1, ag3)
print("PASS")
""")


def test_pipeline_parallel(multidev):
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.distributed import pipeline as pp
def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])
sp = {"w": jax.random.normal(jax.random.key(2), (4, 16, 16)) * 0.5,
      "b": jnp.zeros((4, 16))}
mesh = jax.make_mesh((4,), ("pod",))
xs = jax.random.normal(jax.random.key(3), (8, 4, 16))
out_pp = pp.pipeline_apply(stage_fn, sp, xs, mesh=mesh, axis="pod")
out_seq = pp.sequential_reference(stage_fn, sp, xs)
np.testing.assert_allclose(np.asarray(out_pp), np.asarray(out_seq), rtol=1e-5, atol=1e-5)
print("PASS")
""")


def test_compression_int8_ef_unbiased(multidev):
    """Error feedback: repeated compression of a CONSTANT gradient converges
    to the true value (residual is carried, not lost)."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import pod_allreduce_mean
mesh = compat.make_mesh((2,), ("pod",))
g_true = {"w": jnp.array([0.301, -0.7004, 1e-4, 0.02])}
def run_once(ef):
    def f(g, e):
        out, e2 = pod_allreduce_mean(g, "int8_ef", "pod",
                                     {"w": e["w"][0]})
        return out, {"w": e2["w"][None]}
    return jax.jit(compat.shard_map(f, mesh=mesh, axis_names={"pod"},
        in_specs=(P(), P("pod")), out_specs=(P(), P("pod")),
        check_vma=False))(g_true, ef)
ef = {"w": jnp.zeros((2, 4))}
acc = np.zeros(4)
n = 12
for i in range(n):
    out, ef = run_once(ef)
    acc += np.asarray(out["w"])
mean_est = acc / n
np.testing.assert_allclose(mean_est, np.asarray(g_true["w"]), atol=2e-3)
print("PASS")
""", n_devices=2)
