"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import ref as fa_ref
from repro.kernels.flash_attn.kernel import flash_attention
from repro.kernels.gru_cell import ref as gc_ref
from repro.kernels.gru_cell.kernel import gru_step_blocked, gru_step_fused
from repro.kernels.gru_sequence import ref as gs_ref
from repro.kernels.gru_sequence.kernel import gru_sequence_kernel
from repro.kernels.rowwise_matvec import ops as mv_ops, ref as mv_ref


@pytest.mark.parametrize("B,K,N", [(1, 16, 32), (4, 96, 256), (8, 128, 128),
                                   (2, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rowwise_and_cascade_matmul(B, K, N, dtype):
    x = jax.random.normal(jax.random.key(0), (B, K)).astype(dtype)
    w = jax.random.normal(jax.random.key(1), (K, N)).astype(dtype)
    ref = mv_ref.matmul_ref(x, w)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(mv_ops.rowwise(x, w), np.float32),
                               np.asarray(ref), **tol)
    np.testing.assert_allclose(np.asarray(mv_ops.cascade(x, w), np.float32),
                               np.asarray(ref), **tol)


@pytest.mark.parametrize("B,H", [(1, 20), (2, 64), (3, 32)])
@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_gru_cell_fused(B, H, variant):
    ks = jax.random.split(jax.random.key(0), 4)
    h = jax.random.normal(ks[0], (B, H))
    xp = jax.random.normal(ks[1], (B, 3 * H))
    u = jax.random.normal(ks[2], (H, 3 * H)) / np.sqrt(H)
    b = jax.random.normal(ks[3], (3 * H,)) * 0.1
    ref = gc_ref.gru_step_ref(h, xp, u, b, variant=variant)
    out = gru_step_fused(h, xp, u, b, variant=variant, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("H,block", [(64, 32), (64, 16), (128, 64)])
def test_gru_cell_blocked(H, block):
    B = 2
    ks = jax.random.split(jax.random.key(1), 4)
    h = jax.random.normal(ks[0], (B, H))
    xp = jax.random.normal(ks[1], (B, 3 * H))
    u = jax.random.normal(ks[2], (H, 3 * H)) / np.sqrt(H)
    b = jax.random.normal(ks[3], (3 * H,)) * 0.1
    ref = gc_ref.gru_step_ref(h, xp, u, b)
    out = gru_step_blocked(h, xp, u, b, block_n=block, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,B,H", [(1, 1, 20), (7, 2, 64), (13, 3, 32)])
def test_gru_sequence_kernel(T, B, H):
    ks = jax.random.split(jax.random.key(2), 4)
    h0 = jax.random.normal(ks[0], (B, H))
    xp = jax.random.normal(ks[1], (T, B, 3 * H))
    u = jax.random.normal(ks[2], (H, 3 * H)) / np.sqrt(H)
    b = jax.random.normal(ks[3], (3 * H,)) * 0.1
    ref = gs_ref.gru_sequence_ref(h0, xp, u, b)
    out = gru_sequence_kernel(h0, xp, u, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("Hq,Hkv,S,D", [(4, 2, 70, 16), (2, 2, 64, 32),
                                        (8, 2, 33, 16)])
@pytest.mark.parametrize("window", [0, 17])
def test_flash_attention(Hq, Hkv, S, D, window):
    B = 1
    q = jax.random.normal(jax.random.key(3), (B, Hq, S, D))
    k = jax.random.normal(jax.random.key(4), (B, Hkv, S, D))
    v = jax.random.normal(jax.random.key(5), (B, Hkv, S, D))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    ref = fa_ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-5)


def test_flash_attention_bf16():
    B, Hq, Hkv, S, D = 1, 2, 1, 48, 16
    q = jax.random.normal(jax.random.key(6), (B, Hq, S, D), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(7), (B, Hkv, S, D), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(8), (B, Hkv, S, D), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                          interpret=True)
    ref = fa_ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)
