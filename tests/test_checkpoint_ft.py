"""Checkpointing + fault tolerance: atomicity, checksums, GC, elastic
restart with injected failures."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.distributed.fault_tolerance import (ElasticMeshManager,
                                               HeartbeatMonitor, ManualClock,
                                               StragglerMonitor, Supervisor,
                                               largest_feasible_mesh)


def _state(val=0.0):
    return {"params": {"w": jnp.full((4, 4), val), "b": jnp.zeros((4,))},
            "step": jnp.array(0, jnp.int32)}


def test_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [10, 20, 30]:
        mgr.save(_state(float(s)), s)
    assert mgr.all_steps() == [20, 30]          # keep-last-2
    restored = mgr.restore(_state(), step=30)
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]), 30.0)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(_state(1.0), 1)
    mgr.save_async(_state(2.0), 2)
    mgr.wait()
    assert mgr.latest_step() == 2


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(_state(5.0), 5)
    # flip the LAST 4 data bytes of the largest leaf file (stay inside the
    # array payload, past the .npy header)
    d = os.path.join(str(tmp_path), "step_00000005")
    fn = max((f for f in os.listdir(d) if f.endswith(".npy")),
             key=lambda f: os.path.getsize(os.path.join(d, f)))
    path = os.path.join(d, fn)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size - 4)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(_state(), step=5)


def test_partial_write_invisible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(_state(1.0), 1)
    # simulate a crash mid-write: directory without COMMITTED marker
    os.makedirs(os.path.join(str(tmp_path), "step_00000002"))
    assert mgr.latest_step() == 1


def test_elastic_mesh_shapes():
    assert largest_feasible_mesh(512, 16, prefer_pods=2) == (2, 16, 16)
    assert largest_feasible_mesh(256, 16) == (16, 16)
    # lose 16 devices out of 512 -> largest data multiple of model=16
    m = ElasticMeshManager(total_devices=512, model_parallel=16, pods=2)
    m.fail(range(16))
    assert m.current_shape() in ((2, 15, 16), (31, 16))
    m2 = ElasticMeshManager(total_devices=8, model_parallel=2)
    m2.fail([0, 1, 2])
    assert m2.current_shape() == (2, 2)


def test_monitors():
    """Monitors share ONE injectable clock: beats and liveness checks can
    no longer mix an injected `now` with time.monotonic() (the old
    per-call-override API allowed exactly that bug)."""
    clk = ManualClock()
    hb = HeartbeatMonitor(timeout_s=1.0, clock=clk)
    hb.beat("a")
    hb.beat("b")
    clk.advance(0.5)
    assert hb.dead_hosts() == []
    clk.advance(1.5)                        # t=2.0
    hb.beat("a")
    clk.advance(0.1)                        # t=2.1: b last beat at 0.0
    assert hb.dead_hosts() == ["b"]
    assert hb.alive_hosts() == ["a"]

    sm = StragglerMonitor(factor=2.0, clock=clk)
    for h, t in [("a", 1.0), ("b", 1.0), ("c", 5.0)]:
        for _ in range(4):
            sm.record(h, t)
    assert sm.stragglers() == ["c"]
    # time-horizon expiry: with max_age_s, stale slow samples stop flagging
    sm2 = StragglerMonitor(factor=2.0, max_age_s=10.0, clock=clk)
    for h, t in [("a", 1.0), ("b", 1.0), ("c", 5.0)]:
        for _ in range(4):
            sm2.record(h, t)
    assert sm2.stragglers() == ["c"]
    clk.advance(20.0)
    for _ in range(4):                       # c recovered; old samples aged out
        sm2.record("c", 1.0)
        sm2.record("a", 1.0)
        sm2.record("b", 1.0)
    assert sm2.stragglers() == []


def test_supervisor_survives_injected_failures(tmp_path):
    """End-to-end: train, crash at step 7 and 13, shrink mesh, restore from
    checkpoint, finish all steps with the loss still decreasing."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mesh_mgr = ElasticMeshManager(total_devices=8, model_parallel=2)
    trace = {"builds": []}

    def build(mesh_shape):
        trace["builds"].append(mesh_shape)
        # tiny quadratic model: state is a scalar parameter
        def step_fn(state, step):
            w = state["params"]["w"]
            g = 2 * (w - 3.0)
            w2 = w - 0.1 * g
            return ({"params": {"w": w2},
                     "step": state["step"] + 1},
                    {"loss": float((w2 - 3.0) ** 2)})

        state = {"params": {"w": jnp.array(0.0)}, "step": jnp.array(0)}

        def save_fn(state, step):
            mgr.save(state, step)

        def restore_fn(like):
            step = mgr.latest_step() or 0
            if step:
                st = mgr.restore(like, step=step)
            else:
                st = like
            return st, step
        return step_fn, state, save_fn, restore_fn

    sup = Supervisor(mesh_mgr, build, checkpoint_every=5)
    state, step, history = sup.run(
        20, inject={7: [0], 13: [1]})
    assert step == 20
    assert sup.restarts == 2
    assert len(trace["builds"]) == 3            # initial + 2 rebuilds
    assert trace["builds"][-1] == (3, 2)        # shrunk from (4,2)
    assert history[-1][1]["loss"] < history[0][1]["loss"]
