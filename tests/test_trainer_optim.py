"""Trainer + optimizer: AdamW math, microbatch equivalence, loss decrease,
pod-explicit DP with compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, TrainConfig, get_smoke_config
from repro.data.pipeline import SyntheticStream
from repro.distributed.sharding import ShardCtx
from repro.optim import adamw
from repro.train import trainer


def test_adamw_matches_manual():
    cfg = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=10,
                      weight_decay=0.0, grad_clip=1e9)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    opt = adamw.init_opt_state(p)
    p2, opt2, m = adamw.adamw_update(p, g, opt, jnp.array(0), cfg)
    # step 0: mu=0.1g*... bias-corrected step = g/|g| elementwise = 1
    lr0 = adamw.lr_schedule(jnp.array(0), cfg)
    expect = np.array([1.0, -2.0]) - float(lr0) * np.array([1.0, 1.0])
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-4)


def test_weight_decay_decoupled():
    cfg = TrainConfig(learning_rate=1e-2, warmup_steps=0, weight_decay=0.1,
                      grad_clip=1e9)
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([0.0])}
    opt = adamw.init_opt_state(p)
    p2, _, _ = adamw.adamw_update(p, g, opt, jnp.array(0), cfg)
    lr0 = float(adamw.lr_schedule(jnp.array(0), cfg))
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - lr0 * 0.1 * 1.0,
                               rtol=1e-5)


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                               rtol=1e-5)
    assert float(gn) > 30


def test_lr_schedule_shape():
    cfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_schedule(jnp.array(s), cfg)) for s in range(0, 100, 10)]
    assert lrs[0] < lrs[1]                      # warmup rises
    assert lrs[-1] < lrs[2]                     # cosine decays
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-9         # floor at 10%


def test_microbatch_equivalence():
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32",
                                                 param_dtype="float32")
    t1 = TrainConfig(microbatches=1, learning_rate=0.0, grad_clip=1e9)
    t2 = TrainConfig(microbatches=2, learning_rate=0.0, grad_clip=1e9)
    state1 = trainer.init_state(cfg, t1)
    state2 = jax.tree_util.tree_map(lambda x: x, state1)
    stream = SyntheticStream(cfg, ShapeConfig("t", 16, 4, "train"))
    batch = {k: jnp.asarray(v) for k, v in stream.batch_at(0).items()}
    from repro.models import api as mapi
    A = mapi.get_api(cfg)

    def grads(state, micro):
        def loss_fn(p, b):
            return A.loss_fn(p, cfg, b, ShardCtx())
        g, l, _ = trainer._micro_grads(loss_fn, state["params"], batch, micro)
        return g
    g1 = grads(state1, 1)
    g2 = grads(state2, 2)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-5)


def test_loss_decreases_small_lm():
    cfg = get_smoke_config("qwen3-0.6b")
    tcfg = TrainConfig(learning_rate=2e-3, warmup_steps=5, total_steps=60)
    state = trainer.init_state(cfg, tcfg)
    step = jax.jit(trainer.make_train_step(cfg, tcfg, ShardCtx()),
                   donate_argnums=(0,))
    stream = SyntheticStream(cfg, ShapeConfig("t", 32, 8, "train"))
    losses = []
    for s in range(40):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_pod_compressed_training(multidev):
    """Explicit pod-DP with int8+EF tracks uncompressed training."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import ShapeConfig, TrainConfig, get_smoke_config
from repro.data.pipeline import SyntheticStream
from repro.distributed.sharding import ShardCtx
from repro.train import trainer

cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32", param_dtype="float32")
mesh = compat.make_mesh((2, 2), ("pod", "data"))
ctx = ShardCtx(mesh=mesh)
stream = SyntheticStream(cfg, ShapeConfig("t", 16, 8, "train"))

losses = {}
for method in ["none", "int8_ef"]:
    tcfg = TrainConfig(learning_rate=2e-3, warmup_steps=2, total_steps=30,
                       grad_compression=method)
    with_ef = method == "int8_ef"
    state = trainer.init_state(cfg, tcfg, with_ef=with_ef, n_pods=2)
    step = jax.jit(trainer.make_pod_train_step(cfg, tcfg, ctx), donate_argnums=(0,))
    ls = []
    for s in range(15):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(s).items()}
        state, m = step(state, batch)
        ls.append(float(m["loss"]))
    losses[method] = ls
assert losses["none"][-1] < losses["none"][0] - 0.05
# compressed run also trains, and tracks the uncompressed trajectory
assert losses["int8_ef"][-1] < losses["int8_ef"][0] - 0.05
diff = abs(losses["int8_ef"][-1] - losses["none"][-1])
assert diff < 0.5, (losses["none"][-1], losses["int8_ef"][-1])
print("PASS")
""", n_devices=4)
