"""Core GRU: structural modes vs dense oracle + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import GRUConfig
from repro.core import gru
from repro.core.params import init_params


def _params(X, H, key=0):
    return init_params(gru.gru_cell_specs(X, H), jax.random.key(key))


@pytest.mark.parametrize("mode", ["dense", "rowwise", "cascade"])
@pytest.mark.parametrize("fused", [True, False])
def test_modes_match_oracle(mode, fused):
    X, H, B, T = 5, 20, 3, 11
    params = _params(X, H)
    xs = jax.random.normal(jax.random.key(1), (B, T, X))
    h0 = jnp.zeros((B, H))
    ref, ref_all = gru.gru_reference(params, h0, xs, return_all=True)
    for dec in [True, False]:
        cfg = GRUConfig(input_dim=X, hidden_dim=H, matvec_mode=mode,
                        fused_gates=fused, decoupled_wx=dec)
        h, alls = gru.gru_sequence(params, h0, xs, cfg=cfg, return_all=True)
        np.testing.assert_allclose(np.asarray(h), np.asarray(ref),
                                   rtol=3e-5, atol=3e-6)
        np.testing.assert_allclose(np.asarray(alls), np.asarray(ref_all),
                                   rtol=3e-5, atol=3e-6)


def test_pallas_backend_matches():
    X, H, B, T = 5, 20, 2, 9
    params = _params(X, H)
    xs = jax.random.normal(jax.random.key(2), (B, T, X))
    h0 = jnp.zeros((B, H))
    ref, _ = gru.gru_reference(params, h0, xs)
    cfg = GRUConfig(input_dim=X, hidden_dim=H, backend="pallas")
    h, _ = gru.gru_sequence(params, h0, xs, cfg=cfg)
    np.testing.assert_allclose(np.asarray(h), np.asarray(ref),
                               rtol=3e-5, atol=3e-6)


def test_unroll_matches_scan():
    X, H, B, T = 4, 16, 2, 8
    params = _params(X, H)
    xs = jax.random.normal(jax.random.key(3), (B, T, X))
    h0 = jnp.zeros((B, H))
    a, _ = gru.gru_sequence(params, h0, xs, cfg=GRUConfig(X, H, unroll=1))
    b, _ = gru.gru_sequence(params, h0, xs, cfg=GRUConfig(X, H, unroll=4))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 48), st.integers(1, 16), st.integers(1, 12),
       st.integers(0, 10_000))
def test_hidden_state_bounded(H, X, T, seed):
    """|h| <= 1 always: h is a convex combo of h_prev and tanh(...)."""
    params = _params(X, H, key=seed % 97)
    xs = 3.0 * jax.random.normal(jax.random.key(seed), (1, T, X))
    h0 = jnp.zeros((1, H))
    for variant in ["v1", "v3"]:
        cfg = GRUConfig(input_dim=X, hidden_dim=H, variant=variant)
        h, alls = gru.gru_sequence(params, h0, xs, cfg=cfg, return_all=True)
        assert np.all(np.abs(np.asarray(alls)) <= 1.0 + 1e-6)
        assert np.isfinite(np.asarray(alls)).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 32), st.integers(1, 8), st.integers(0, 10_000))
def test_rowwise_equals_cascade(H, X, seed):
    params = _params(X, H, key=seed % 89)
    xs = jax.random.normal(jax.random.key(seed), (2, 5, X))
    h0 = jax.random.normal(jax.random.key(seed + 1), (2, H)) * 0.5
    outs = []
    for mode in ["dense", "rowwise", "cascade"]:
        cfg = GRUConfig(input_dim=X, hidden_dim=H, matvec_mode=mode)
        h, _ = gru.gru_sequence(params, h0, xs, cfg=cfg)
        outs.append(np.asarray(h))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-5)


def test_zero_update_gate_keeps_state():
    """With b_z -> -inf, z -> 0 and h stays at h0 (gate semantics)."""
    X, H = 3, 8
    params = _params(X, H)
    params = dict(params)
    params["b"] = params["b"].at[:H].set(-30.0)   # z gate bias
    xs = jax.random.normal(jax.random.key(5), (1, 6, X))
    h0 = jax.random.normal(jax.random.key(6), (1, H)) * 0.3
    h, _ = gru.gru_sequence(params, h0, xs, cfg=GRUConfig(X, H))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h0), atol=1e-5)


def test_classifier_shapes_and_grads():
    from repro.configs.gru_jet import CONFIG
    params = init_params(gru.gru_classifier_specs(CONFIG.gru), jax.random.key(0))
    xs = jax.random.normal(jax.random.key(1), (4, 20, 5))
    logits = gru.gru_classify(params, xs, cfg=CONFIG.gru)
    assert logits.shape == (4, 5)

    def loss(p):
        return gru.gru_classify(p, xs, cfg=CONFIG.gru).sum()
    g = jax.grad(loss)(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
