"""The GRU executor (repro.core.runtime): dispatch matrix, prepare(),
deprecation shims, and executable metadata.

The dispatch-matrix suite is the redesign's contract: every
(mask on/off x depth 1-3 x hetero/uniform dims x mesh/none x
prefill/decode) combination must resolve to a backend and match
``gru_stack_reference`` to tolerance — bitwise (padded+masked vs
unpadded) wherever the executable claims ``mask_exact``. Compile/execute
(Placement, CostModel, executable caching) specifics live in
``test_gru_compile.py``.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.params import init_params

TOL = dict(rtol=3e-5, atol=3e-6)
DEC_TOL = dict(rtol=1e-4, atol=1e-5)


def _cfg(depth, hetero, backend="auto", **kw):
    if hetero:
        return GRUConfig(input_dim=5, layer_dims=(16, 8, 12)[:depth],
                         backend=backend, **kw)
    return GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth,
                     backend=backend, **kw)


def _data(cfg, B=2, T=6, key=1):
    xs = jax.random.normal(jax.random.key(key), (B, T, cfg.input_dim))
    return xs, gru.stack_h0(cfg, B)


def _padded(xs, P=3):
    B, T, _ = xs.shape
    xs_pad = jnp.pad(xs, ((0, 0), (P, 0), (0, 0)))
    mask = jnp.broadcast_to(jnp.arange(T + P)[None, :] >= P, (B, T + P))
    return xs_pad, mask


# ---------------------------------------------------------------------------
# dispatch matrix (single host); the mesh column runs in the multidev test
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("hetero", [False, True])
@pytest.mark.parametrize("masked", [False, True])
@pytest.mark.parametrize("mode", ["prefill", "decode"])
def test_dispatch_matrix(depth, hetero, masked, mode):
    cfg = _cfg(depth, hetero)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    xs, h0s = _data(cfg)
    ref, _ = gru.gru_stack_reference(params, h0s, xs)
    p = runtime.compile(cfg, batch=2, seq=6, mask=masked, mode=mode)
    if mode == "decode":
        assert p.decode_backend is not None
        hs = h0s
        for t in range(xs.shape[1]):
            hs = p.decode(params, hs, xs[:, t])
        for a, b in zip(hs, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       **DEC_TOL)
        return
    assert p.sequence_backend is not None
    if not masked:
        finals, _ = p.sequence(params, h0s, xs)
    else:
        xs_pad, mask = _padded(xs)
        finals, _ = p.sequence(params, h0s, xs_pad, mask=mask)
        if p.mask_exact:
            # the plan CLAIMS padding invariance: hold it to bitwise
            un = runtime.compile(cfg, batch=2, seq=6, mode=mode)
            f_un, _ = un.sequence(params, h0s, xs)
            for a, b in zip(f_un, finals):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(finals, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


def test_dispatch_matrix_mesh(multidev):
    """The mesh column of the matrix: sequence work dispatches to the
    kernel-fused shard_map backend ``pallas_sharded`` (statically cheaper
    than ``sharded``; mask and hetero dims included, both bitwise
    padding-invariant), with ``sharded`` still pinnable by exact name;
    decode under a mesh statically resolves to a replicated single-host
    backend, while the ``sharded_decode`` candidate (persistent shard_map
    step) is reference-exact and becomes selectable when a calibration
    measures it faster."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.params import init_params
mesh = jax.make_mesh((4,), ("model",))
placement = runtime.Placement(mesh=mesh)
X, B, T, P = 6, 2, 7, 3
xs = jax.random.normal(jax.random.key(1), (B, T, X))
xs_pad = jnp.pad(xs, ((0, 0), (P, 0), (0, 0)))
mask = jnp.broadcast_to(jnp.arange(T + P)[None, :] >= P, (B, T + P))
for dims in ((16, 16), (16, 8)):
    for masked in (False, True):
        cfg = GRUConfig(input_dim=X, layer_dims=dims, backend="auto",
                        layer_matvec_modes=("rowwise", "cascade"))
        params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
        h0s = gru.stack_h0(cfg, B)
        p = runtime.compile(cfg, batch=B, seq=T, placement=placement,
                            mask=masked, mode="prefill")
        assert p.sequence_backend == "pallas_sharded", p.sequence_backend
        import dataclasses
        pin = runtime.compile(dataclasses.replace(cfg, backend="sharded"),
                              batch=B, seq=T, placement=placement,
                              mask=masked, mode="prefill")
        assert pin.sequence_backend == "sharded", pin.sequence_backend
        if masked:
            finals, _ = p.sequence(params, h0s, xs_pad, mask=mask)
            un = runtime.compile(cfg, batch=B, seq=T, placement=placement,
                                 mode="prefill")
            f_un, _ = un.sequence(params, h0s, xs)
            for a, b in zip(f_un, finals):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            finals, _ = p.sequence(params, h0s, xs)
        ref, _ = gru.gru_stack_reference(params, h0s, xs)
        for a, b in zip(finals, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-5, atol=3e-6)
        # decode column: static costs keep decode replicated ...
        pd = runtime.compile(cfg, batch=B, placement=placement, mode="decode")
        assert pd.decode_backend in ("xla", "pallas_fused", "pallas_chain")
        # ... and the sharded_decode candidate is reference-exact (runs
        # the per-shape-calibratable persistent shard_map step, hetero
        # dims and mixed modes included)
        sp = runtime.prepare(params, cfg, placement, want_stacked=False)
        spec = runtime.backends()["sharded_decode"]
        hs = h0s
        for t in range(T):
            hs = spec.decode_fn(sp, tuple(hs), xs[:, t], cfg=cfg,
                                placement=placement)
        for a, b in zip(hs, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
# a calibration that measures the sharded step fastest flips the decode
# choice (per shape) — and the flipped executable matches the replicated
# one numerically
cfg = GRUConfig(input_dim=X, layer_dims=(16, 16), backend="auto",
                layer_matvec_modes=("rowwise", "cascade"))
params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
h0s = gru.stack_h0(cfg, B)
runtime.set_cost_model(runtime.CostModel.from_entries(
    [{"backend": b, "op": "decode", "depth": 2, "batch": B,
      "hidden_dim": 16, "p50_us": 5.0 if b == "sharded_decode" else 50.0}
     for b in ("xla", "pallas_fused", "pallas_chain", "sharded_decode",
               "pallas_sharded")]))
pd = runtime.compile(cfg, batch=B, placement=placement, mode="decode")
assert pd.decode_backend == "sharded_decode", pd.decode_backend
assert pd.cost_source == "measured"
got = pd.decode(params, h0s, xs[:, 0])
runtime.set_cost_model(None)
rep = runtime.compile(cfg, batch=B, placement=placement, mode="decode")
assert rep.decode_backend != "sharded_decode"
want = rep.decode(params, h0s, xs[:, 0])
for a, b in zip(got, want):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)
print("PASS")
""", timeout=560)


# ---------------------------------------------------------------------------
# plan semantics
# ---------------------------------------------------------------------------

def test_compile_picks_expected_backends():
    """Cost/preference dispatch: auto picks the fused kernel when legal,
    the chain for hetero dims; explicit prefs pin their family (or, for an
    exact backend name, that one backend); masked calls no longer push
    pallas configs onto the XLA scan."""
    u3 = _cfg(3, hetero=False)
    h3 = _cfg(3, hetero=True)
    assert runtime.compile(u3, mode="serve").sequence_backend == "pallas_fused"
    assert runtime.compile(u3, mode="serve").decode_backend == "pallas_fused"
    assert runtime.compile(h3, mode="serve").sequence_backend == "pallas_chain"
    assert runtime.compile(h3, mode="serve").decode_backend == "pallas_chain"
    assert runtime.compile(u3, mask=True,
                           mode="prefill").sequence_backend == "pallas_fused"
    x3 = _cfg(3, hetero=False, backend="xla")
    assert runtime.compile(x3, mode="serve").sequence_backend == "xla"
    p3 = _cfg(3, hetero=False, backend="pallas")
    assert runtime.compile(p3, mask=True,
                           mode="prefill").sequence_backend == "pallas_fused"
    # an exact backend name pins that backend, overriding cost order
    c3 = _cfg(3, hetero=False, backend="pallas_chain")
    assert runtime.compile(c3, mode="serve").decode_backend == "pallas_chain"
    # a pallas preference with hetero dims falls through to the chain
    # (historically: silent XLA decode / a raise) instead of erroring
    ph = _cfg(3, hetero=True, backend="pallas")
    assert runtime.compile(ph, mode="decode").decode_backend == "pallas_chain"


def test_compile_is_memoized_and_jit_stable():
    """The same compile key returns the SAME GRUExecutable object (stable
    callables -> jit caches keyed on them never retrace)."""
    cfg = _cfg(2, hetero=False)
    a = runtime.compile(cfg, batch=2, seq=6, mode="serve")
    b = runtime.compile(cfg, batch=2, seq=6, mode="serve")
    assert a is b and a.sequence is b.sequence and a.decode is b.decode
    params = runtime.prepare(
        init_params(gru.gru_stack_specs(cfg), jax.random.key(0)), cfg)
    xs, h0s = _data(cfg)
    f = jax.jit(lambda p, h, x: a.decode(p, h, x))
    f(params, h0s, xs[:, 0])
    f(params, h0s, xs[:, 1])
    assert f._cache_size() == 1


def test_plan_return_all_falls_through_to_capable_backend():
    """A finals-only backend may win the primary selection, but a
    return_all=True call must route to a fully-capable backend instead of
    failing inside the cheap one (enforced capability, not a doc note)."""
    cfg = _cfg(2, hetero=False)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    xs, h0s = _data(cfg)

    calls = []

    def finals_only(sp, h0s_, xs_, *, cfg, return_all, mask, placement):
        assert not return_all
        calls.append("finals_only")
        return gru.gru_stack_sequence_xla(sp.cells, h0s_, xs_, cfg=cfg,
                                          mask=mask)

    runtime.register_backend(runtime.BackendSpec(
        name="_test_finals_only",
        caps=runtime.Capabilities(supports_mask=True,
                                  supports_hetero_dims=True,
                                  return_all=False, decode=False,
                                  sequence=True),
        cost=-50, sequence_fn=finals_only))
    try:
        p = runtime.compile(cfg, batch=2, seq=6, mode="sequence")
        assert p.sequence_backend == "_test_finals_only"
        f1, s1 = p.sequence(params, h0s, xs)
        assert calls == ["finals_only"] and s1 is None
        f2, s2 = p.sequence(params, h0s, xs, return_all=True)
        assert calls == ["finals_only"]          # fell through, not reused
        assert s2 is not None
        for a, b in zip(f1, f2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
    finally:
        runtime._REGISTRY.pop("_test_finals_only", None)
        runtime.clear_cache()


def test_compile_capability_registry():
    """Every registered backend exposes the ISSUE's capability surface."""
    regs = runtime.backends()
    assert {"xla", "sharded", "pallas_fused", "pallas_chain",
            "sharded_decode", "pallas_sharded"} <= set(regs)
    for spec in regs.values():
        caps = spec.caps
        for field in ("supports_mask", "supports_hetero_dims",
                      "supports_mesh", "return_all", "decode", "sequence"):
            assert isinstance(getattr(caps, field), bool)
        assert isinstance(spec.cost, int)
    assert not regs["pallas_fused"].caps.supports_hetero_dims
    assert regs["pallas_chain"].caps.supports_hetero_dims
    assert regs["sharded"].caps.supports_mesh
    assert not regs["sharded"].caps.decode
    assert regs["sharded_decode"].caps.supports_mesh
    assert regs["sharded_decode"].caps.decode
    assert not regs["sharded_decode"].caps.sequence
    # pallas_sharded: the combined axes — full sequence+decode surface,
    # mesh-requiring, statically cheaper than sharded for sequence work
    # but per-op dispreferred for decode (the latency-bound step)
    psh = regs["pallas_sharded"]
    assert psh.caps.supports_mesh and psh.caps.decode and psh.caps.sequence
    assert psh.caps.supports_hetero_dims and psh.caps.supports_mask
    assert psh.cost < regs["sharded"].cost
    assert psh.static_cost("sequence") == psh.cost
    assert psh.static_cost("decode") > regs["pallas_fused"].cost
    assert psh.static_cost("decode") < regs["sharded_decode"].cost


# ---------------------------------------------------------------------------
# prepare(): one normalization to rule the three historical ones
# ---------------------------------------------------------------------------

def test_prepare_subsumes_param_layouts():
    cfg = _cfg(2, hetero=False)
    cells = tuple(init_params(gru.gru_stack_specs(cfg), jax.random.key(0)))
    layouts = [cells, list(cells), {"cells": cells}]
    sps = [runtime.prepare(p, cfg) for p in layouts]
    for sp in sps:
        assert isinstance(sp, runtime.StackParams)
        assert sp.dims == (16, 16)
        assert sp.stacked is not None            # uniform -> fused views
        np.testing.assert_array_equal(np.asarray(sp.stacked["u"]),
                                      np.asarray(sps[0].stacked["u"]))
    # StackParams passthrough is identity (hot paths re-prepare for free)
    assert runtime.prepare(sps[0], cfg) is sps[0]
    # a dict already carrying stacked_cells keeps them (no recompute)
    marked = {"cells": cells,
              "stacked_cells": {"u": sps[0].stacked["u"] + 1.0,
                                "w_deep": sps[0].stacked["w_deep"],
                                "b": sps[0].stacked["b"]}}
    assert runtime.prepare(marked, cfg).stacked is marked["stacked_cells"]
    # depth-1 seed layout and bare cells
    cfg1 = _cfg(1, hetero=False)
    cell = init_params(gru.gru_cell_specs(5, 16), jax.random.key(1))
    for layout in ({"cell": cell}, cell, (cell,)):
        sp = runtime.prepare(layout, cfg1)
        assert len(sp.cells) == 1 and sp.dims == (16,)
    # hetero stacks carry no fused views
    cfgh = _cfg(3, hetero=True)
    sph = runtime.prepare(
        tuple(init_params(gru.gru_stack_specs(cfgh), jax.random.key(2))),
        cfgh)
    assert sph.stacked is None and sph.dims == (16, 8, 12)


def test_prepare_is_a_pytree():
    """StackParams flows through jit/tree_map like any params pytree."""
    cfg = _cfg(2, hetero=False)
    sp = runtime.prepare(
        init_params(gru.gru_stack_specs(cfg), jax.random.key(0)), cfg)
    leaves = jax.tree_util.tree_leaves(sp)
    assert len(leaves) == 2 * 3 + 3              # 2 cells x {w,u,b} + stacked
    sp2 = jax.tree_util.tree_map(lambda x: x, sp)
    assert isinstance(sp2, runtime.StackParams)
    assert sp2.dims == sp.dims


# ---------------------------------------------------------------------------
# deprecation shims: warn once per process, bitwise-equal to the executor
# ---------------------------------------------------------------------------

def test_legacy_shims_warn_once_and_match_bitwise():
    cfg = _cfg(2, hetero=False)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    xs, h0s = _data(cfg)
    gru._DEPRECATION_WARNED.clear()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        old_f, old_all = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg,
                                                return_all=True)
        gru.gru_stack_sequence(params, h0s, xs, cfg=cfg)   # repeat: no new warn
        old_hs = gru.gru_stack_decode_step(params, h0s, xs[:, 0], cfg=cfg)
        old_1, _ = gru.gru_sequence(params[0], h0s[0], xs, cfg=cfg)
    deps = [str(x.message) for x in w
            if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 3, deps                  # one per entry point
    assert any("gru_stack_sequence" in m for m in deps)
    assert all("runtime" in m for m in deps)

    new_f, new_all = runtime.sequence(params, h0s, xs, cfg=cfg,
                                      return_all=True)
    for a, b in zip(old_f, new_f):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(old_all), np.asarray(new_all))
    new_hs = runtime.decode(params, h0s, xs[:, 0], cfg=cfg)
    for a, b in zip(old_hs, new_hs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    lcfg = gru.layer_config(cfg, 0)
    new_1, _ = runtime.sequence((params[0],), (h0s[0],), xs, cfg=lcfg)
    np.testing.assert_array_equal(np.asarray(old_1), np.asarray(new_1[0]))


def test_legacy_decode_impl_override_matches_executor():
    """impl="pallas"/"xla" on the legacy decode shim == an explicit
    backend preference on the executor, bitwise."""
    cfg = _cfg(3, hetero=False)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    xs, h0s = _data(cfg)
    for impl in ("xla", "pallas"):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            old = gru.gru_stack_decode_step(params, h0s, xs[:, 0], cfg=cfg,
                                            impl=impl)
        new = runtime.decode(params, h0s, xs[:, 0],
                             cfg=dataclasses.replace(cfg, backend=impl))
        for a, b in zip(old, new):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# masked fused kernels: the capability the redesign closes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [1, 3])
@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_masked_pallas_sequence_bitwise_vs_unpadded(depth, variant):
    """Bucketed (left-padded+masked) prefill through the FUSED Pallas
    kernels: bitwise the unpadded computation at the same batch shape
    (the bucketing contract), and per-row-correct for ragged lengths —
    closing the ROADMAP's masked-prefill fallback."""
    cfg = _cfg(depth, hetero=False, backend="pallas", variant=variant)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    xs, h0s = _data(cfg, B=2, T=5)
    p = runtime.compile(cfg, batch=2, seq=8, mask=True, mode="prefill")
    assert p.sequence_backend == "pallas_fused"
    un = runtime.compile(cfg, batch=2, seq=5, mode="prefill")
    f_un, _ = un.sequence(params, h0s, xs)
    # uniform left-pad: bitwise at the same batch shape
    xs_pad, mask = _padded(xs)
    f_pd, _ = p.sequence(params, h0s, xs_pad, mask=mask)
    for a, b in zip(f_un, f_pd):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ragged: row 0 keeps length 5, row 1 only 3, left-aligned into T=5;
    # rows match their solo (different-batch-shape) runs to fp tolerance
    lens = np.array([5, 3])
    xs_r = np.zeros((2, 5, 5), np.float32)
    xs_r[0] = np.asarray(xs[0])
    xs_r[1, 2:] = np.asarray(xs[1, :3])
    mask_r = jnp.asarray(np.arange(5)[None, :] >= (5 - lens)[:, None])
    f_r, states = p.sequence(params, h0s, jnp.asarray(xs_r), mask=mask_r,
                             return_all=True)
    solo = runtime.compile(cfg, batch=1, seq=5, mode="prefill")
    f0, _ = solo.sequence(params, tuple(h[:1] for h in h0s), xs[:1])
    f1, _ = solo.sequence(params, tuple(h[1:2] for h in h0s), xs[1:2, :3])
    for l in range(depth):
        np.testing.assert_allclose(np.asarray(f_r[l][0]),
                                   np.asarray(f0[l][0]),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(f_r[l][1]),
                                   np.asarray(f1[l][0]),
                                   rtol=1e-6, atol=1e-7)
    # the return_all stream carries the gated (frozen-then-live) states:
    # compare against the masked XLA backend (variant-aware oracle)
    xcfg = dataclasses.replace(cfg, backend="xla")
    px = runtime.compile(xcfg, batch=2, seq=5, mask=True, mode="prefill")
    _, states_x = px.sequence(params, h0s, jnp.asarray(xs_r), mask=mask_r,
                              return_all=True)
    np.testing.assert_allclose(np.asarray(states), np.asarray(states_x),
                               **TOL)
