"""q8 datapath: kernel parity, the no-quantize-in-execute jaxpr invariant,
accuracy-gated dispatch, cache keying, and the per-op CostModel tolerance.

Parity is asserted against the quantize-dequantize oracles at 1e-6: the
oracles keep quantized activations as integer-valued f32, so their f32
dots accumulate EXACTLY the kernels' int32 sums at test sizes — any
disagreement is a real kernel bug, not float noise.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _q8 import q8_stack_decode, q8_stack_finals
from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.params import (init_params, quantize_gru_cells,
                               quantize_rows_int8)

B, T, X, PAD = 2, 5, 5, 3
TOL = dict(rtol=1e-6, atol=1e-6)


@pytest.fixture(autouse=True)
def _restore_gates():
    """Leave every test with the suite's hermetic defaults (static costs,
    closed accuracy gate) no matter what it installed."""
    yield
    runtime.set_cost_model(runtime.CostModel({}, source="<tests: static>"))
    runtime.set_quant_accuracy(runtime.QuantAccuracy(
        {}, source="<tests: closed>"))


def _case(dims, backend, variant="v1"):
    cfg = GRUConfig(input_dim=X, layer_dims=dims, backend=backend,
                    variant=variant)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    cells = gru.stack_cell_params(params, cfg)
    return cfg, cells


def _data(seed=1):
    xs = jax.random.normal(jax.random.key(seed), (B, T, X))
    xs_pad = jnp.pad(xs, ((0, 0), (PAD, 0), (0, 0)))
    mask = jnp.broadcast_to(jnp.arange(T + PAD)[None, :] >= PAD,
                            (B, T + PAD))
    return xs, xs_pad, mask


# ---------------------------------------------------------------------------
# weight quantization (the prepare()-stage half)
# ---------------------------------------------------------------------------

def test_quantize_rows_int8_layout_and_roundtrip():
    w = jax.random.normal(jax.random.key(0), (12, 24))
    q, eff = quantize_rows_int8(w)
    assert q.shape == (24, 12) and q.dtype == jnp.int8     # transposed rows
    assert eff.shape == (24,) and eff.dtype == jnp.float32
    # per-row symmetric: dequant error bounded by half a quantization step
    deq = np.asarray(q, np.float32) * np.asarray(eff)[:, None] * 127.0
    step = np.abs(np.asarray(w).T).max(axis=1, keepdims=True) / 127.0
    assert (np.abs(deq - np.asarray(w).T) <= 0.5 * step + 1e-7).all()
    # all-zero rows quantize to zero with a finite scale
    q0, eff0 = quantize_rows_int8(jnp.zeros((4, 6)))
    assert not np.asarray(q0).any() and np.isfinite(np.asarray(eff0)).all()


def test_quant_views_shapes():
    _, cells = _case((8, 8, 8), "xla")
    q = quantize_gru_cells(cells)
    assert len(q.cells) == 3
    assert q.cells[0]["u_q"].shape == (24, 8)
    assert q.stacked["u_q"].shape == (3, 24, 8)
    assert q.stacked["wd_q"].shape == (2, 24, 8)
    _, hcells = _case((16, 8), "xla")
    assert quantize_gru_cells(hcells).stacked is None      # hetero: no stack


# ---------------------------------------------------------------------------
# kernel parity vs the quantize-dequantize oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["v1", "v3"])
@pytest.mark.parametrize("dims,backend", [
    ((16,), "pallas_fused_q8"),
    ((12, 12), "pallas_fused_q8"),
    ((8, 8, 8), "pallas_fused_q8"),
    ((8, 8, 8), "pallas_chain_q8"),
    ((16, 8), "pallas_chain_q8"),                          # hetero dims
])
def test_q8_sequence_parity(dims, backend, variant):
    cfg, cells = _case(dims, backend, variant)
    xs, _, _ = _data()
    h0s = gru.stack_h0(cfg, B)
    exe = runtime.compile(cfg, batch=B, seq=T, mode="sequence")
    assert exe.sequence_backend == backend                 # exact pin holds
    finals, _ = exe.sequence(cells, h0s, xs)
    ref = q8_stack_finals(backend, cells, h0s, xs, cfg)
    for a, b in zip(finals, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


@pytest.mark.parametrize("dims,backend", [
    ((12, 12), "pallas_fused_q8"),
    ((16, 8), "pallas_chain_q8"),
    ((16,), "pallas_fused_q8"),
])
def test_q8_decode_parity(dims, backend):
    cfg, cells = _case(dims, backend)
    xs, _, _ = _data()
    hs = gru.stack_h0(cfg, B)
    exe = runtime.compile(cfg, batch=B, mode="decode")
    assert exe.decode_backend == backend
    for t in range(T):
        ref = q8_stack_decode(backend, cells, hs, xs[:, t], cfg)
        hs = exe.decode(cells, hs, xs[:, t])
        for a, b in zip(hs, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


@pytest.mark.parametrize("backend,dims", [
    ("pallas_fused_q8", (12, 12)), ("pallas_chain_q8", (16, 8))])
def test_q8_masked_prefill_bitwise(backend, dims):
    """Left-padded + masked prefill == unpadded, BITWISE: the q8 step is
    deterministic per step, so the where-freeze never perturbs it."""
    cfg, cells = _case(dims, backend)
    xs, xs_pad, mask = _data()
    h0s = gru.stack_h0(cfg, B)
    exe = runtime.compile(cfg, batch=B, seq=T + PAD, mask=True,
                          mode="prefill")
    assert exe.sequence_backend == backend and exe.mask_exact
    fm, _ = exe.sequence(cells, h0s, xs_pad, mask=mask)
    un = runtime.compile(cfg, batch=B, seq=T, mode="prefill")
    fu, _ = un.sequence(cells, h0s, xs)
    for a, b in zip(fm, fu):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# the jaxpr invariant: prepared execute contains NO quantize ops
# ---------------------------------------------------------------------------

_QUANT_PRIMS = {"round", "reduce_max"}    # the quantization signature ops


def _outer_prims(obj, out):
    """Collect primitive names reachable WITHOUT descending into
    pallas_call bodies (in-kernel activation rounding is the datapath
    itself; weight quantization outside a kernel is the bug)."""
    jaxpr = getattr(obj, "jaxpr", obj)
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        out.add(eqn.primitive.name)
        for v in jax.tree_util.tree_leaves(list(eqn.params.values())):
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                _outer_prims(v, out)
    return out


def _decode_prims(cfg, params):
    exe = runtime.compile(cfg, batch=B, mode="decode")
    hs = gru.stack_h0(cfg, B)
    x = jnp.ones((B, X))
    closed = jax.make_jaxpr(lambda p, h, xv: exe.decode(p, h, xv))(
        params, hs, x)
    return _outer_prims(closed, set())


@pytest.mark.parametrize("backend", ["pallas_fused_q8", "pallas_chain_q8"])
def test_prepared_execute_has_no_quantize_ops(backend):
    cfg, cells = _case((12, 12), backend)
    exe = runtime.compile(cfg, batch=B, mode="decode")
    sp = exe.prepare(cells)
    assert sp.quant is not None                 # int8 views built up front
    prims = _decode_prims(cfg, sp)
    assert not (prims & _QUANT_PRIMS), prims & _QUANT_PRIMS
    # control: tracing from RAW params quantizes inside the traced call —
    # the exact per-token cost prepare() exists to hoist out
    raw_prims = _decode_prims(cfg, cells)
    assert raw_prims & _QUANT_PRIMS


# ---------------------------------------------------------------------------
# executable-cache keying + accuracy-gated dispatch
# ---------------------------------------------------------------------------

def test_exec_cache_keys_on_quant_flag():
    base = GRUConfig(input_dim=X, layer_dims=(12, 12), backend="auto")
    a = runtime.compile(base, batch=B, mode="decode")
    b = runtime.compile(dataclasses.replace(base, quant="int8"),
                        batch=B, mode="decode")
    c = runtime.compile(base, batch=B, mode="decode")
    assert a is c                                # memoized per cfg
    assert a is not b                            # quant flag is in the key
    # gate flips bump the epoch: stale executables must not survive them
    runtime.set_quant_accuracy(runtime.QuantAccuracy(
        {"bench": "gru_quant_accuracy", "passed": True}, source="<t>"))
    assert runtime.compile(base, batch=B, mode="decode") is not a


def _measured(entries):
    return runtime.CostModel(
        {(b, "decode", 2, 12): [(B, us)] for b, us in entries.items()},
        source="<test>")


def test_accuracy_gate_roundtrip(tmp_path):
    """The dispatch-eligibility round-trip: q8 is auto-chosen ONLY when a
    PASSING artifact is loaded AND a calibration measures it faster."""
    cfg = GRUConfig(input_dim=X, layer_dims=(12, 12), backend="auto",
                    quant="int8")
    fast_q8 = _measured({"xla": 50.0, "pallas_fused": 40.0,
                         "pallas_chain": 60.0, "pallas_fused_q8": 4.0,
                         "pallas_chain_q8": 9.0})

    # closed gate (missing/failing artifact): q8 NEVER auto-chosen, even
    # with a calibration that says it wins
    for report in (runtime.QuantAccuracy({}, source="<missing>"),
                   runtime.QuantAccuracy({"bench": "gru_quant_accuracy",
                                          "passed": False}, source="<f>")):
        runtime.set_quant_accuracy(report)
        runtime.set_cost_model(fast_q8)
        exe = runtime.compile(cfg, batch=B, mode="decode")
        assert not exe.decode_backend.endswith("_q8"), exe.decode_backend

    # passing artifact from DISK: q8 becomes eligible and wins measured
    path = tmp_path / "BENCH_quant_accuracy.json"
    path.write_text(json.dumps({"bench": "gru_quant_accuracy",
                                "passed": True, "backends": {}}))
    report = runtime.load_quant_accuracy(path)
    assert report.passed and runtime.quant_gate_open()
    runtime.set_cost_model(fast_q8)
    exe = runtime.compile(cfg, batch=B, mode="decode")
    assert exe.decode_backend == "pallas_fused_q8"
    assert exe.cost_source == "measured"

    # open gate but NO calibration: static costs keep q8 dispreferred
    runtime.set_cost_model(runtime.CostModel({}, source="<static>"))
    exe = runtime.compile(cfg, batch=B, mode="decode")
    assert not exe.decode_backend.endswith("_q8")

    # wrong-bench artifact: tolerant load, closed gate
    bad = tmp_path / "other.json"
    bad.write_text(json.dumps({"bench": "gru_decode_step_latency"}))
    assert not runtime.load_quant_accuracy(bad).passed


def test_exact_pin_bypasses_gate():
    runtime.set_quant_accuracy(runtime.QuantAccuracy(
        {"bench": "gru_quant_accuracy", "passed": False}, source="<f>"))
    cfg = GRUConfig(input_dim=X, layer_dims=(12, 12),
                    backend="pallas_fused_q8")
    exe = runtime.compile(cfg, batch=B, mode="serve")
    assert exe.decode_backend == "pallas_fused_q8"
    assert exe.sequence_backend == "pallas_fused_q8"


def test_quant_flag_without_pin_runs_q8_numerics_only_when_gated():
    """cfg.quant="int8" + open gate + measured win: the AUTO choice runs
    the q8 numerics (output matches the q8 oracle, not the f32 one)."""
    runtime.set_quant_accuracy(runtime.QuantAccuracy(
        {"bench": "gru_quant_accuracy", "passed": True}, source="<t>"))
    runtime.set_cost_model(_measured(
        {"xla": 50.0, "pallas_fused": 40.0, "pallas_chain": 60.0,
         "pallas_fused_q8": 4.0, "pallas_chain_q8": 9.0}))
    cfg = GRUConfig(input_dim=X, layer_dims=(12, 12), backend="auto",
                    quant="int8")
    _, cells = _case((12, 12), "auto")
    exe = runtime.compile(cfg, batch=B, mode="decode")
    assert exe.decode_backend == "pallas_fused_q8"
    hs = gru.stack_h0(cfg, B)
    x = jnp.ones((B, X))
    got = exe.decode(cells, hs, x)
    ref = q8_stack_decode("pallas_fused_q8", cells, hs, x, cfg)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


# ---------------------------------------------------------------------------
# CostModel per-op tolerance (the satellite bugfix)
# ---------------------------------------------------------------------------

def test_partial_calibration_tolerates_measured_only_backends():
    """A calibration that does not cover a measured-only candidate (static
    cost >= UNCALIBRATED_GATE_COST, e.g. a q8 row missing for this shape)
    must NOT collapse the whole selection back to the static table."""
    runtime.set_quant_accuracy(runtime.QuantAccuracy(
        {"bench": "gru_quant_accuracy", "passed": True}, source="<t>"))
    cfg = GRUConfig(input_dim=X, layer_dims=(12, 12), backend="auto",
                    quant="int8")
    # q8 candidates legal but UNmeasured; sub-gate candidates all covered
    runtime.set_cost_model(_measured(
        {"xla": 9.0, "pallas_fused": 3.0, "pallas_chain": 8.0}))
    exe = runtime.compile(cfg, batch=B, mode="decode")
    assert exe.cost_source == "measured"         # not degraded to static
    assert exe.decode_backend == "pallas_fused"  # unmeasured q8 loses

    # the inverse hole — a q8 decode-ONLY calibration (its backend name
    # registered for both ops but measured for one) leaves a sub-gate
    # candidate uncovered: all-or-nothing still applies there
    runtime.set_cost_model(_measured({"pallas_fused_q8": 4.0}))
    exe = runtime.compile(cfg, batch=B, mode="decode")
    assert exe.cost_source == "static"
    assert not exe.decode_backend.endswith("_q8")


def test_serve_reports_dtype():
    assert runtime.backend_dtype("pallas_fused_q8") == "int8"
    assert runtime.backend_dtype("pallas_fused") == "float32"
    assert runtime.backend_dtype(None) == "float32"
