"""The compile/execute split (repro.core.runtime): GRUExecutable caching,
Placement-resident prepare(), the measured CostModel, and the legacy
``plan()``/``ExecPlan`` shims.

Acceptance contract of the redesign:

* ``compile()`` is memoized by (cfg, shapes, placement, cost epoch) —
  identical keys return the SAME object (jit stability), distinct
  placements (different meshes) compile distinct executables.
* ``prepare(params, cfg, placement)`` with a mesh performs ALL device
  placement up front: a traced sharded sequence/decode call contains no
  ``device_put`` of weight arrays (jaxpr inspection, multidev test).
* With a calibration file, ``backend="auto"`` selects per shape (two
  shapes whose measured costs invert the static preference order pick
  different backends); with a missing/corrupt file, selection degrades
  to the static table — identical to the pre-CostModel executor.
* ``plan()``/``ExecPlan`` warn once and are bitwise-equal to
  ``compile()``/``GRUExecutable`` across the dispatch matrix.
"""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.params import init_params

TOL = dict(rtol=3e-5, atol=3e-6)


@pytest.fixture(autouse=True)
def _cost_isolation():
    """Restore the suite's hermetic (empty -> static) cost model after any
    test that installs its own calibration."""
    yield
    runtime.set_cost_model(runtime.CostModel({}, source="<tests: static>"))


def _cfg(depth=3, hetero=False, backend="auto", **kw):
    if hetero:
        return GRUConfig(input_dim=5, layer_dims=(16, 8, 12)[:depth],
                         backend=backend, **kw)
    return GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth,
                     backend=backend, **kw)


def _data(cfg, B=2, T=6, key=1):
    xs = jax.random.normal(jax.random.key(key), (B, T, cfg.input_dim))
    return xs, gru.stack_h0(cfg, B)


def _calib(depth, H, costs_by_backend, batch=1, op="decode"):
    return [{"backend": b, "op": op, "depth": depth, "batch": batch,
             "hidden_dim": H, "p50_us": us}
            for b, us in costs_by_backend.items()]


# ---------------------------------------------------------------------------
# executable cache keying
# ---------------------------------------------------------------------------

def test_recompile_identical_key_returns_same_object():
    cfg = _cfg(2)
    a = runtime.compile(cfg, batch=4, seq=8, mode="serve")
    b = runtime.compile(cfg, batch=4, seq=8, mode="serve")
    assert a is b and a.sequence is b.sequence and a.decode is b.decode
    # any key component changes the executable
    assert runtime.compile(cfg, batch=8, seq=8, mode="serve") is not a
    assert runtime.compile(cfg, batch=4, seq=8, mask=True,
                           mode="serve") is not a


def test_distinct_placements_compile_distinct_executables():
    """Host vs mesh, and two meshes differing only in axis naming, all
    key separately; re-compiling each key hits its memoized object."""
    from jax.sharding import Mesh
    cfg = _cfg(2)
    dev = np.array(jax.devices()[:1])
    pa = runtime.Placement(mesh=Mesh(dev, ("model",)))
    pb = runtime.Placement(mesh=Mesh(dev, ("row",)), axis="row")
    host = runtime.compile(cfg, batch=2, seq=6, mode="prefill")
    ea = runtime.compile(cfg, batch=2, seq=6, placement=pa, mode="prefill")
    eb = runtime.compile(cfg, batch=2, seq=6, placement=pb, mode="prefill")
    assert len({id(host), id(ea), id(eb)}) == 3
    assert ea is runtime.compile(cfg, batch=2, seq=6, placement=pa,
                                 mode="prefill")
    assert ea.sequence_backend == "pallas_sharded"      # mesh: kernel-fused
    assert host.sequence_backend not in ("sharded", "pallas_sharded")
    # the 1-device mesh placements execute correctly, axis naming included
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    xs, h0s = _data(cfg)
    ref, _ = gru.gru_stack_reference(params, h0s, xs)
    for exe in (ea, eb):
        finals, _ = exe.sequence(exe.prepare(params), h0s, xs)
        for a, b in zip(finals, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


def test_cost_epoch_invalidates_memoized_executables():
    """Installing a calibration must not resurrect executables planned
    under the old costs (the epoch is part of the cache key)."""
    cfg = _cfg(3)
    before = runtime.compile(cfg, batch=1, mode="decode")
    assert before.decode_backend == "pallas_fused"       # static order
    runtime.set_cost_model(runtime.CostModel.from_entries(_calib(
        3, 16, {"xla": 10.0, "pallas_fused": 90.0, "pallas_chain": 95.0})))
    after = runtime.compile(cfg, batch=1, mode="decode")
    assert after is not before
    assert after.decode_backend == "xla" and after.cost_source == "measured"


# ---------------------------------------------------------------------------
# cost model: measured per-shape selection, static fallback
# ---------------------------------------------------------------------------

def test_calibration_selects_per_shape_inverting_static_order():
    """The acceptance case: two shapes whose measured costs invert the
    static preference order (pallas_fused=10 < xla=30) pick DIFFERENT
    backends under one calibration."""
    cfg = _cfg(3)
    entries = (_calib(3, 16, {"xla": 40.0, "pallas_fused": 200.0,
                              "pallas_chain": 250.0}, batch=1)
               + _calib(3, 16, {"xla": 400.0, "pallas_fused": 80.0,
                                "pallas_chain": 90.0}, batch=8))
    runtime.set_cost_model(runtime.CostModel.from_entries(entries))
    e1 = runtime.compile(cfg, batch=1, mode="decode")
    e8 = runtime.compile(cfg, batch=8, mode="decode")
    assert e1.decode_backend == "xla"            # inverts the static order
    assert e8.decode_backend == "pallas_fused"
    assert e1.cost_source == e8.cost_source == "measured"
    # an uncalibrated shape (different depth) degrades to static per call
    e_other = runtime.compile(_cfg(2), batch=1, mode="decode")
    assert e_other.cost_source == "static"
    assert e_other.decode_backend == "pallas_fused"


def test_sequence_calibration_flips_prefill_choice_per_shape():
    """The sequence half of the calibration (op="sequence" rows, emitted
    by decode_latency.py --emit-costs): two shapes whose measured SEQUENCE
    costs invert the static order pick different prefill backends, while
    decode selection is untouched (stays static: no decode rows here)."""
    cfg = _cfg(3)
    entries = (_calib(3, 16, {"xla": 40.0, "pallas_fused": 200.0,
                              "pallas_chain": 250.0}, batch=1, op="sequence")
               + _calib(3, 16, {"xla": 400.0, "pallas_fused": 80.0,
                                "pallas_chain": 90.0}, batch=8,
                        op="sequence"))
    runtime.set_cost_model(runtime.CostModel.from_entries(entries))
    e1 = runtime.compile(cfg, batch=1, seq=12, mode="prefill")
    e8 = runtime.compile(cfg, batch=8, seq=12, mode="prefill")
    assert e1.sequence_backend == "xla"          # inverts the static order
    assert e8.sequence_backend == "pallas_fused"
    assert e1.cost_source == e8.cost_source == "measured"
    # decode at the same shapes has no measured rows -> static order
    ed = runtime.compile(cfg, batch=1, mode="decode")
    assert ed.cost_source == "static"
    assert ed.decode_backend == "pallas_fused"


def test_decode_only_calibration_degrades_sequence_to_static_only():
    """A calibration that covers decode but NOT sequence must degrade to
    the static order for sequence selection ONLY — decode keeps its
    measured choice (per-op fallback, not global)."""
    cfg = _cfg(3)
    runtime.set_cost_model(runtime.CostModel.from_entries(_calib(
        3, 16, {"xla": 1.0, "pallas_fused": 50.0, "pallas_chain": 60.0},
        batch=1, op="decode")))
    es = runtime.compile(cfg, batch=1, seq=8, mode="prefill")
    assert es.cost_source == "static"            # sequence: no coverage
    assert es.sequence_backend == "pallas_fused"     # the static winner
    ed = runtime.compile(cfg, batch=1, mode="decode")
    assert ed.cost_source == "measured"          # decode: fully covered
    assert ed.decode_backend == "xla"            # inverts the static order
    # one executable carrying both ops keeps the per-op split
    eb = runtime.compile(cfg, batch=1, seq=8, mode="serve")
    assert eb.sequence_backend == "pallas_fused"
    assert eb.decode_backend == "xla"


def test_calibration_interpolates_and_clamps_batch():
    m = runtime.CostModel.from_entries(
        _calib(1, 16, {"xla": 100.0}, batch=2)
        + _calib(1, 16, {"xla": 300.0}, batch=6))
    lk = lambda b: m.lookup("xla", "decode", depth=1, batch=b, hidden=16)
    assert lk(2) == 100.0 and lk(6) == 300.0
    assert lk(4) == 200.0                        # linear between points
    assert lk(1) == 100.0 and lk(64) == 300.0    # clamped to the edges
    assert lk(2) is not None
    assert m.lookup("xla", "decode", depth=2, batch=2, hidden=16) is None
    assert m.lookup("pallas_fused", "decode", depth=1, batch=2,
                    hidden=16) is None


def test_partial_calibration_falls_back_to_static():
    """µs and static ints are not comparable: if ANY legal candidate is
    uncovered, the whole selection uses the static table."""
    cfg = _cfg(3)
    runtime.set_cost_model(runtime.CostModel.from_entries(_calib(
        3, 16, {"xla": 1.0, "pallas_fused": 2.0})))   # chain missing
    exe = runtime.compile(cfg, batch=1, mode="decode")
    assert exe.cost_source == "static"
    assert exe.decode_backend == "pallas_fused"


def test_missing_and_corrupt_calibration_resolve_to_static(tmp_path):
    missing = runtime.CostModel.load(tmp_path / "nope.json")
    assert len(missing) == 0 and missing.error is not None
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    corrupt = runtime.load_cost_model(bad)
    assert len(corrupt) == 0 and corrupt.error is not None
    exe = runtime.compile(_cfg(3), batch=1, mode="decode")
    assert exe.cost_source == "static"
    assert exe.decode_backend == "pallas_fused"      # unchanged from PR 3
    schema_mismatch = tmp_path / "other.json"
    schema_mismatch.write_text(json.dumps({"bench": "something_else",
                                           "entries": []}))
    assert len(runtime.CostModel.load(schema_mismatch)) == 0


def test_default_calibration_loads_from_env(tmp_path, monkeypatch):
    """The lazy default load honors $REPRO_GRU_COSTS (the CI artifact
    path), and a benchmark-emitted file round-trips through CostModel."""
    path = tmp_path / "BENCH_backend_costs.json"
    path.write_text(json.dumps({
        "bench": "gru_backend_costs", "schema": 1, "device": "cpu",
        "entries": _calib(3, 16, {"xla": 5.0, "pallas_fused": 50.0,
                                  "pallas_chain": 60.0})}))
    monkeypatch.setenv("REPRO_GRU_COSTS", str(path))
    runtime.set_cost_model(None)                 # re-arm the lazy load
    exe = runtime.compile(_cfg(3), batch=1, mode="decode")
    assert exe.cost_source == "measured" and exe.decode_backend == "xla"
    assert runtime.cost_model().source == str(path)


def test_emit_costs_schema_loads():
    """benchmarks/decode_latency.py --emit-costs writes exactly what
    CostModel.load expects (schema lockstep, no benchmark run needed)."""
    import importlib.util, pathlib
    spec = importlib.util.spec_from_file_location(
        "decode_latency", pathlib.Path(__file__).resolve().parents[1]
        / "benchmarks" / "decode_latency.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    rows = [{"via": "runtime", "backend": "xla", "depth": 1, "batch": 1,
             "hidden_dim": 32, "p50_us": 12.5},       # no op field: decode
            {"via": "runtime", "backend": "pallas_fused", "depth": 1,
             "batch": 1, "hidden_dim": 32, "p50_us": 8.0, "op": "decode"},
            {"via": "runtime", "backend": "xla", "depth": 1, "batch": 1,
             "hidden_dim": 32, "p50_us": 95.0, "op": "sequence",
             "seq_len": 16},                          # same key, other op
            {"via": "direct", "backend": "fused", "depth": 1, "batch": 8,
             "hidden_dim": 32, "p50_us": 9.0}]      # non-runtime: dropped
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "BENCH_backend_costs.json")
        out = mod.emit_costs(rows, path, csv=False)
        assert len(out["entries"]) == 3
        m = runtime.CostModel.load(path)
    assert len(m) == 3
    assert m.lookup("xla", "decode", depth=1, batch=1, hidden=32) == 12.5
    assert m.lookup("xla", "sequence", depth=1, batch=1, hidden=32) == 95.0
    assert m.lookup("pallas_fused", "sequence", depth=1, batch=1,
                    hidden=32) is None
    assert m.lookup("fused", "decode", depth=1, batch=8, hidden=32) is None


# ---------------------------------------------------------------------------
# legacy shims: plan() / ExecPlan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth,hetero", [(1, False), (3, False), (3, True)])
def test_plan_shim_bitwise_equals_compile(depth, hetero):
    """plan() returns the SAME memoized executable compile() builds, and
    running through either surface is bitwise-identical."""
    cfg = _cfg(depth, hetero)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    xs, h0s = _data(cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        p = runtime.plan(cfg, batch=2, seq=6, mode="serve")
    c = runtime.compile(cfg, batch=2, seq=6, mode="serve")
    assert p is c
    f_p, _ = p.sequence(params, h0s, xs)
    f_c, _ = c.sequence(params, h0s, xs)
    for a, b in zip(f_p, f_c):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(p.decode(params, h0s, xs[:, 0]),
                    c.decode(params, h0s, xs[:, 0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_and_execplan_warn_once():
    gru._DEPRECATION_WARNED.discard("runtime.plan")
    gru._DEPRECATION_WARNED.discard("runtime.ExecPlan")
    cfg = _cfg(2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        runtime.plan(cfg, batch=2, seq=6, mode="serve")
        runtime.plan(cfg, batch=2, seq=6, mode="serve")     # no second warn
        assert runtime.ExecPlan is runtime.GRUExecutable
        runtime.ExecPlan                                     # no second warn
    deps = [str(x.message) for x in w
            if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 2, deps
    assert any("runtime.plan" in m for m in deps)
    assert any("runtime.ExecPlan" in m for m in deps)
    assert all("compile" in m for m in deps)
    assert isinstance(runtime.compile(cfg, mode="serve"), runtime.ExecPlan)


# ---------------------------------------------------------------------------
# prepare(): placement-resident params
# ---------------------------------------------------------------------------

def test_prepare_params_dict_carries_placed_views():
    """gru_lm.prepare_params under a mesh ctx attaches pre-placed views
    that runtime.prepare reuses verbatim — the engine's params round-trip
    never re-places weights."""
    from jax.sharding import Mesh
    from repro.configs.base import get_smoke_config
    from repro.distributed.sharding import ShardCtx
    from repro.models import gru_lm
    from repro.models import api as mapi
    cfg = get_smoke_config("gru-jet-deep")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    prepared = gru_lm.prepare_params(params, cfg, ShardCtx(mesh=mesh))
    assert "placed_cells" in prepared and "stacked_cells" in prepared
    sp = runtime.prepare(prepared, cfg.gru, runtime.Placement(mesh=mesh))
    assert sp.placed is prepared["placed_cells"]
    # host ctx: no placed views, stacked only (the PR 3 behavior)
    host = gru_lm.prepare_params(params, cfg, ShardCtx())
    assert "placed_cells" not in host and "stacked_cells" in host


def test_prepare_replaces_stale_placed_views_from_another_mesh():
    """A dict prepared for mesh A must not leak its placed views into a
    prepare for mesh B: the guard re-places instead of feeding arrays
    committed elsewhere into the new mesh's shard_map."""
    from jax.sharding import Mesh, NamedSharding
    cfg = _cfg(2)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    dev = np.array(jax.devices()[:1])
    pa = runtime.Placement(mesh=Mesh(dev, ("model",)))
    pb = runtime.Placement(mesh=Mesh(dev, ("row",)), axis="row")
    sp_a = runtime.prepare(params, cfg, pa)
    carrier = {"cells": sp_a.cells, "placed_cells": sp_a.placed}
    sp_b = runtime.prepare(carrier, cfg, pb)
    assert sp_b.placed is not sp_a.placed            # stale views dropped
    arr = next(iter(sp_b.placed[0].values()))
    assert isinstance(arr.sharding, NamedSharding)
    assert arr.sharding.mesh == pb.mesh
    # matching mesh: reused verbatim
    sp_a2 = runtime.prepare(carrier, cfg, pa)
    assert sp_a2.placed is sp_a.placed


def test_executable_prepare_builds_only_what_its_backends_read():
    cfg = _cfg(2, backend="xla")
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    exe = runtime.compile(cfg, batch=2, seq=6, mode="serve")
    sp = exe.prepare(params)
    assert sp.stacked is None and sp.placed is None    # xla reads cells
    cfg_p = _cfg(2, backend="pallas")
    exe_p = runtime.compile(cfg_p, batch=2, seq=6, mode="serve")
    sp_p = exe_p.prepare(params)
    assert sp_p.stacked is not None                    # fused kernel views


def test_compile_mesh_placement_resident(multidev):
    """Acceptance: prepare(params, cfg, placement) with a mesh performs
    ALL device placement up front — the traced sharded sequence AND decode
    calls contain no device_put of weight arrays (jaxpr inspection); the
    raw-params path DOES trace device_puts (the assertion bites); distinct
    meshes compile distinct executables; prepared and raw execution agree
    bitwise."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.params import init_params

def prim_names(fn, *args):
    names = set()
    def walk(j):
        for e in j.eqns:
            names.add(e.primitive.name)
            for v in e.params.values():
                if hasattr(v, "jaxpr"):
                    walk(v.jaxpr)
    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return names

mesh = jax.make_mesh((4,), ("model",))
placement = runtime.Placement(mesh=mesh)
cfg = GRUConfig(input_dim=6, layer_dims=(16, 16), backend="auto",
                layer_matvec_modes=("rowwise", "cascade"))
params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
xs = jax.random.normal(jax.random.key(1), (2, 7, 6))
h0s = gru.stack_h0(cfg, 2)
exe = runtime.compile(cfg, batch=2, seq=7, placement=placement,
                      mode="prefill")
assert exe.sequence_backend == "pallas_sharded"
sp = exe.prepare(params)
assert sp.placed is not None
for arr in sp.placed[0].values():      # placement happened eagerly
    assert isinstance(arr.sharding, jax.sharding.NamedSharding), arr.sharding
n_prep = prim_names(lambda p, h, x: exe.sequence(p, h, x), sp, h0s, xs)
n_raw = prim_names(lambda p, h, x: exe.sequence(p, h, x), params, h0s, xs)
assert "device_put" not in n_prep, sorted(n_prep)
assert "device_put" in n_raw
# distinct meshes (same shapes) compile distinct executables; the same
# key hits the memoized object (checked BEFORE the calibration install
# below — installing a cost model bumps the epoch on purpose)
mesh2 = jax.make_mesh((2,), ("model",))
e2 = runtime.compile(cfg, batch=2, seq=7,
                     placement=runtime.Placement(mesh=mesh2),
                     mode="prefill")
assert e2 is not exe
assert exe is runtime.compile(cfg, batch=2, seq=7, placement=placement,
                              mode="prefill")
# decode: force the sharded step via calibration, same assertions
runtime.set_cost_model(runtime.CostModel.from_entries(
    [{"backend": b, "op": "decode", "depth": 2, "batch": 2,
      "hidden_dim": 16, "p50_us": 5.0 if b == "sharded_decode" else 50.0}
     for b in ("xla", "pallas_fused", "pallas_chain", "sharded_decode",
               "pallas_sharded")]))
ed = runtime.compile(cfg, batch=2, placement=placement, mode="decode")
assert ed.decode_backend == "sharded_decode"
nd_prep = prim_names(lambda p, h, x: ed.decode(p, h, x), sp, h0s, xs[:, 0])
nd_raw = prim_names(lambda p, h, x: ed.decode(p, h, x), params, h0s,
                    xs[:, 0])
assert "device_put" not in nd_prep, sorted(nd_prep)
assert "device_put" in nd_raw
# prepared == raw, bitwise (placement moves work, not numerics)
f_prep, _ = exe.sequence(sp, h0s, xs)
f_raw, _ = exe.sequence(params, h0s, xs)
for a, b in zip(f_prep, f_raw):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
for a, b in zip(ed.decode(sp, h0s, xs[:, 0]),
                ed.decode(params, h0s, xs[:, 0])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("PASS")
""", timeout=560)