"""Shared q8 oracle helpers: executor-level quantize-dequantize references.

Each helper mirrors what the NAMED q8 backend computes — the fused
backend quantizes deep-layer input projections in-kernel, the chain
backend keeps them f32 — so tests compare each backend against its own
exact twin (the oracles accumulate the kernels' int32 sums exactly in
f32 at test sizes; see ``repro.kernels.gru_cell.ref._q8_act_ref``).
"""
import jax.numpy as jnp

from repro.core.params import quantize_gru_cells
from repro.kernels.gru_cell.ref import gru_step_q8_ref
from repro.kernels.gru_sequence import ref as sref


def q8_stack_finals(backend: str, cells: tuple, h0s, xs, cfg):
    """Per-layer final states of a whole-sequence run on ``backend``."""
    q = quantize_gru_cells(cells)
    if backend == "pallas_fused_q8":
        st = q.stacked
        xp_t = jnp.moveaxis(xs @ cells[0]["w"], -2, 0)
        _, hT = sref.gru_stack_sequence_q8_ref(
            jnp.stack(tuple(h0s)), xp_t, st["u_q"], st["u_eff"],
            st["wd_q"], st["wd_eff"], st["b"], cfg.variant)
        return tuple(hT[l] for l in range(len(cells)))
    assert backend == "pallas_chain_q8", backend
    finals, cur = [], xs
    for l, c in enumerate(cells):
        xp_t = jnp.moveaxis(cur @ c["w"], -2, 0)
        hs = sref.gru_sequence_q8_ref(h0s[l], xp_t, q.cells[l]["u_q"],
                                      q.cells[l]["u_eff"], c["b"],
                                      cfg.variant)
        finals.append(hs[-1])
        cur = jnp.moveaxis(hs, 0, -2)            # f32 inter-layer sequence
    return tuple(finals)


def q8_stack_decode(backend: str, cells: tuple, hs, x, cfg):
    """Per-layer new states of ONE decode step on ``backend``."""
    q = quantize_gru_cells(cells)
    if backend == "pallas_fused_q8":
        st = q.stacked
        h2 = sref.gru_stack_decode_q8_ref(
            jnp.stack(tuple(hs)), x @ cells[0]["w"], st["u_q"],
            st["u_eff"], st["wd_q"], st["wd_eff"], st["b"], cfg.variant)
        return tuple(h2[l] for l in range(len(cells)))
    assert backend == "pallas_chain_q8", backend
    out, cur = [], x
    for l, c in enumerate(cells):
        h2 = gru_step_q8_ref(hs[l], cur @ c["w"], q.cells[l]["u_q"],
                             q.cells[l]["u_eff"], c["b"],
                             variant=cfg.variant)
        out.append(h2)
        cur = h2                                  # f32 inter-layer hand-off
    return tuple(out)
