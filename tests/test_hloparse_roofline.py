"""HLO analyzer: exact flops, trip weighting, slice-aware bytes; roofline
term arithmetic."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency import V5E, RooflineTerms, gru_step_model, roofline
from repro.launch.hloparse import analyze


def test_dot_flops_exact():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    a = analyze(jax.jit(lambda x, w: x @ w).lower(x, w).compile().as_text())
    assert abs(a.flops - 2 * 128 ** 3) < 1


def test_while_trip_weighting():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=9)
        return out
    a1 = analyze(jax.jit(lambda x, w: x @ w).lower(x, w).compile().as_text())
    a9 = analyze(jax.jit(scanned).lower(x, w).compile().as_text())
    assert 8.5 <= a9.flops / a1.flops <= 9.5


def test_slice_aware_bytes():
    """Reading one row per loop step from a big stacked tensor must count
    slices, not the whole tensor per step."""
    big = jax.ShapeDtypeStruct((64, 256, 256), jnp.float32)

    def f(big):
        def body(c, i):
            sl = jax.lax.dynamic_index_in_dim(big, i, 0, keepdims=False)
            return c + sl.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), jnp.arange(64))
        return out
    a = analyze(jax.jit(f).lower(big).compile().as_text())
    full = 64 * 256 * 256 * 4
    # traffic should be O(few x total), not O(64 x total)
    assert a.hbm_bytes < 8 * full, (a.hbm_bytes, full)


def test_collectives_counted(multidev):
    multidev("""
import jax, jax.numpy as jnp
from repro import compat
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hloparse import analyze
mesh = compat.make_mesh((4,), ("model",))
x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
def f(x, w):
    def body(c, _):
        return c @ w, None
    out, _ = jax.lax.scan(body, x, None, length=7)
    return out
t = jax.jit(f, in_shardings=(NamedSharding(mesh, P()), NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P())).lower(x, w).compile().as_text()
a = analyze(t)
assert a.total_coll_bytes > 0
assert sum(a.coll_counts.values()) >= 7   # one reduce per scan step
print("PASS")
""")


def test_roofline_terms():
    t = roofline(flops=197e12, hbm_bytes=819e9, collective_bytes=50e9, chips=1)
    assert abs(t.compute_s - 1.0) < 1e-6
    assert abs(t.memory_s - 1.0) < 1e-6
    assert abs(t.collective_s - 1.0) < 1e-6
    t2 = roofline(1e15, 1e9, 0, chips=256)
    assert t2.bound == "compute"


def test_gru_step_model_scaling():
    """The analytical model reproduces the paper's qualitative findings."""
    base = gru_step_model(32, 32, row_shards=1)
    dec = gru_step_model(32, 256, decoupled_wx=True)
    inl = gru_step_model(32, 256, decoupled_wx=False)
    # decoupling removes the X dependence from the critical path (plateau)
    assert dec.compute_s < inl.compute_s
    # v3 has fewer launch phases than unfused
    v3 = gru_step_model(32, 32, variant="v3")
    unf = gru_step_model(32, 32, fused_gates=False)
    assert v3.compute_s < unf.compute_s
    # sharding rows adds an aggregation (collective) cost
    sh = gru_step_model(32, 32, row_shards=4)
    assert sh.collective_s > base.collective_s
