"""The serving fleet's failure matrix, exercised deterministically.

Every test drives the FleetRouter with a ManualClock and a seeded/explicit
FaultInjector schedule: kill mid-wave, kill before prefill, straggler
hedging, queue overflow, deadline shedding, recovery. No wall-clock sleeps
anywhere — all timing flows through the injected Clock, so the suite runs
in tier-1 at full speed and every failure path is reproducible.
"""
import numpy as np
import pytest

from repro.configs.base import GRUConfig, get_smoke_config
from repro.core.params import init_params
from repro.distributed.fault_tolerance import ManualClock
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import (FaultEvent, FaultInjector, FleetConfig,
                               FleetRejected, FleetRouter)


def _setup(hidden=12, num_layers=1):
    cfg = get_smoke_config("gru-jet").replace(
        gru=GRUConfig(input_dim=5, hidden_dim=hidden, num_classes=5,
                      seq_len=20, num_layers=num_layers))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), __import__("jax").random.key(0),
                         cfg.param_dtype)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=4, vary=True):
    rng = np.random.default_rng(seed)
    X = cfg.gru.input_dim
    return [Request(prompt=rng.normal(size=(3 + (i % 4 if vary else 0), X))
                    .astype(np.float32), max_new_tokens=max_new)
            for i in range(n)]


def _fleet(cfg, params, *, replicas=2, injector=None, clock=None,
           config=None, max_batch=2):
    return FleetRouter(cfg, params, replicas=replicas, max_batch=max_batch,
                       clock=clock or ManualClock(),
                       config=config or FleetConfig(
                           heartbeat_timeout_s=0.05, backoff_base_s=0.02,
                           tick_s=0.01),
                       injector=injector)


def _reference_outs(cfg, params, requests):
    """Fault-free single-engine oracle for the same prompts."""
    solo = ServeEngine(cfg, params, ShardCtx(), max_batch=1)
    outs = []
    for r in requests:
        ref = Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                      eos_id=r.eos_id, stream=r.stream)
        solo.generate([ref])
        outs.append(ref.out)
    return outs


# ---------------------------------------------------------------------------
# baseline: the one-call fleet surface, no faults
# ---------------------------------------------------------------------------

def test_fleet_serves_and_matches_single_engine():
    cfg, params = _setup()
    reqs = _requests(cfg, 6, seed=1)
    router = _fleet(cfg, params)
    done = router.generate(reqs)
    assert all(r.done for r in done)
    assert [r.out for r in done] == _reference_outs(cfg, params, reqs)
    s = router.stats()
    assert s["submitted"] == s["completed"] == 6
    assert s["failed"] == 0 and s["shed"] == {}
    # both replicas actually served (depth routing spreads the load)
    assert all(v["steps"] > 0 for v in s["replicas"].values())


def test_fleet_depth_routing_prefers_idle_replica():
    """With replica0 loaded, a depth-aware route sends the next request to
    the idle replica; static round-robin alternates regardless."""
    cfg, params = _setup()
    router = _fleet(cfg, params, config=FleetConfig(
        heartbeat_timeout_s=0.05, tick_s=0.01, bucket_penalty_s=0.0))
    r0 = router.replicas[0]
    # load replica0 directly with a long request
    heavy = _requests(cfg, 1, seed=2, max_new=32)[0]
    t_heavy = router.submit(heavy)
    router.tick()                        # dispatched somewhere
    loaded = router._by_name[t_heavy.replicas[0]]
    other = next(r for r in router.replicas if r is not loaded)
    t2 = router.submit(_requests(cfg, 1, seed=3)[0])
    router.tick()
    assert t2.replicas[0] == other.name
    router.run_until_done()
    assert heavy.done and t2.request.done
    assert r0.engine.latency_stats()["requests"] >= 0  # stats surface exists


# ---------------------------------------------------------------------------
# failure matrix
# ---------------------------------------------------------------------------

def test_fleet_replica_kill_mid_wave_completes_all():
    """Kill a replica while it is mid-decode: heartbeat timeout detects it,
    its in-flight requests retry on the survivor, 100% of admitted
    requests complete, and every token stream equals the fault-free run
    of the same seeds."""
    cfg, params = _setup()
    reqs = _requests(cfg, 6, seed=4, max_new=6)
    # kill replica0 at t=0.06 (several ticks after dispatch -> mid-wave),
    # never restore: the survivor must absorb everything
    inj = FaultInjector([FaultEvent(t=0.06, kind="kill", replica="replica0")])
    router = _fleet(cfg, params, injector=inj)
    done = router.generate(reqs)
    s = router.stats()
    assert s["kills"] == 1
    assert s["completed"] == s["submitted"] == 6
    assert s["failed"] == 0
    assert all(r.done for r in done)
    assert s["retries"] >= 1             # something was really in flight
    assert [r.out for r in done] == _reference_outs(cfg, params, reqs)


def test_fleet_kill_during_prefill_retries():
    """Kill a replica that has admitted requests but has not yet run their
    prefill (its first step is deferred by a slow window): the requests
    are requeued and complete elsewhere, streams unchanged."""
    cfg, params = _setup()
    reqs = _requests(cfg, 4, seed=5, max_new=4)
    inj = FaultInjector([
        # slow from t=0: replica0's first step (the cohort prefill) is
        # deferred past the kill, so it dies holding un-prefilled work
        FaultEvent(t=0.0, kind="slow", replica="replica0", factor=50.0),
        FaultEvent(t=0.03, kind="kill", replica="replica0"),
    ])
    router = _fleet(cfg, params, config=FleetConfig(
        heartbeat_timeout_s=0.05, backoff_base_s=0.02, tick_s=0.01,
        hedge=False), injector=inj)
    done = router.generate(reqs)
    s = router.stats()
    assert s["kills"] == 1 and s["failed"] == 0
    assert s["completed"] == 4                              # all admitted
    assert all(r.done for r in done)
    # the killed replica never produced a prefill for its victims
    killed = router._by_name["replica0"]
    assert killed.alive is False
    assert [r.out for r in done] == _reference_outs(cfg, params, reqs)


def test_fleet_straggler_hedged_first_wins():
    """A slow replica's in-flight requests get a duplicate dispatch on the
    fast replica; the duplicate finishes first, the straggler's lane is
    cancelled, and the result is returned exactly once."""
    cfg, params = _setup()
    reqs = _requests(cfg, 4, seed=6, max_new=8)
    inj = FaultInjector([
        FaultEvent(t=0.0, kind="slow", replica="replica0", factor=10.0)])
    # 3 replicas: the straggler monitor compares against the fleet MEDIAN
    # step time, which needs a majority of fast peers to be meaningful
    router = _fleet(cfg, params, replicas=3, injector=inj,
                    config=FleetConfig(
                        heartbeat_timeout_s=0.5,   # slow != dead
                        straggler_factor=3.0, tick_s=0.01))
    done = router.generate(reqs)
    s = router.stats()
    assert s["completed"] == 4 and s["failed"] == 0
    assert s["hedges"] >= 1, s
    assert s["hedges_cancelled"] >= 1, s
    # returned once: each request's stream has exactly max_new tokens (a
    # double-resolve would append twice) and matches the oracle
    assert all(len(r.out) == 8 for r in done)
    assert [r.out for r in done] == _reference_outs(cfg, params, reqs)
    # the hedged tickets really raced two replicas
    hedged = [t for t in router.tickets if t.hedged]
    assert hedged and all(len(t.replicas) >= 2 for t in hedged)


def test_fleet_queue_overflow_sheds_typed():
    cfg, params = _setup()
    router = _fleet(cfg, params, config=FleetConfig(
        queue_limit=2, heartbeat_timeout_s=0.05, tick_s=0.01))
    reqs = _requests(cfg, 3, seed=7)
    router.submit(reqs[0])
    router.submit(reqs[1])
    with pytest.raises(FleetRejected) as ei:
        router.submit(reqs[2])
    assert ei.value.reason == "queue_full"
    assert router.stats()["shed"] == {"queue_full": 1}
    router.run_until_done()              # the two admitted still complete
    assert reqs[0].done and reqs[1].done and not reqs[2].done


def test_fleet_deadline_shedding():
    """An infeasible deadline rejects at submit; a feasible one that
    lapses while queued sheds with reason='deadline'."""
    cfg, params = _setup()
    router = _fleet(cfg, params, config=FleetConfig(
        queue_limit=64, heartbeat_timeout_s=10.0, tick_s=0.01,
        nominal_step_s=0.01))
    with pytest.raises(FleetRejected) as ei:
        router.submit(_requests(cfg, 1, max_new=100)[0], deadline_s=1e-9)
    assert ei.value.reason == "deadline_infeasible"
    # admit while healthy, then kill everything before the first dispatch
    # tick: the queued request cannot dispatch and its deadline lapses
    inj = FaultInjector([
        FaultEvent(t=0.0, kind="kill", replica="replica0"),
        FaultEvent(t=0.0, kind="kill", replica="replica1"),
        FaultEvent(t=0.5, kind="restore", replica="replica0"),
    ])
    clock = ManualClock()
    router2 = _fleet(cfg, params, injector=inj, clock=clock,
                     config=FleetConfig(heartbeat_timeout_s=0.05,
                                        tick_s=0.01, nominal_step_s=1e-4))
    t = router2.submit(_requests(cfg, 1, max_new=2)[0], deadline_s=0.1)
    router2.run_until_done()
    assert t.status == "shed" and t.reason == "deadline"
    assert router2.stats()["shed"]["deadline"] == 1


def test_fleet_recovered_replica_serves_again_warm():
    """Kill -> restore: the replica re-enters the rotation (restart reruns
    the engine's prepare()) and serves later requests."""
    cfg, params = _setup()
    inj = FaultInjector([
        FaultEvent(t=0.02, kind="kill", replica="replica0"),
        FaultEvent(t=0.10, kind="restore", replica="replica0"),
    ])
    # zero the cold-bucket penalty: a freshly restarted replica starts with
    # empty jit caches, and this test wants routing to use it again
    router = _fleet(cfg, params, injector=inj, config=FleetConfig(
        heartbeat_timeout_s=0.05, backoff_base_s=0.02, tick_s=0.01,
        bucket_penalty_s=0.0))
    first = _requests(cfg, 4, seed=8, max_new=4)
    done = router.generate(first)
    assert all(r.done for r in done)
    rep0 = router._by_name["replica0"]
    assert rep0.restarts == 1 and rep0.alive
    # restart rebuilt the engine: serving prep (prepare()) ran against the
    # replica's placement, so the rebuilt params carry the stacked views
    assert "stacked_cells" in rep0.engine.params
    steps_before = rep0.steps
    second = _requests(cfg, 4, seed=9, max_new=4)
    done2 = router.generate(second)
    assert all(r.done for r in done2)
    assert rep0.steps > steps_before     # it really served again
    assert [r.out for r in done2] == _reference_outs(cfg, params, second)


def test_fleet_seeded_schedule_zero_drops_and_stream_parity():
    """Acceptance: under a seeded kill+restore schedule mid-load, the
    fleet completes 100% of admitted requests and the token streams are
    identical to a fault-free run of the same request seeds."""
    cfg, params = _setup(hidden=10)
    inj = FaultInjector.seeded(11, ["replica0", "replica1", "replica2"],
                               horizon_s=0.6, kill_prob=0.7, slow_prob=0.5)
    assert len(inj) > 0                  # the seed really scheduled faults
    reqs_f = _requests(cfg, 10, seed=12, max_new=5)
    router = _fleet(cfg, params, replicas=3, injector=inj)
    done_f = router.generate(reqs_f)
    s = router.stats()
    assert s["completed"] == s["submitted"] == 10
    assert s["failed"] == 0 and s["shed"] == {}
    # fault-free fleet run, same seeds
    reqs_c = _requests(cfg, 10, seed=12, max_new=5)
    clean = _fleet(cfg, params, replicas=3)
    done_c = clean.generate(reqs_c)
    assert [r.out for r in done_f] == [r.out for r in done_c]


def test_fleet_static_vs_depth_routing_ab():
    """Both routing arms complete the same work (the benchmark's A/B);
    static round-robin alternates replicas by construction."""
    cfg, params = _setup()
    for routing in ("depth", "static"):
        reqs = _requests(cfg, 5, seed=13)
        router = _fleet(cfg, params, config=FleetConfig(
            routing=routing, heartbeat_timeout_s=0.05, tick_s=0.01))
        done = router.generate(reqs)
        assert all(r.done for r in done)
        assert router.stats()["routing"] == routing
        if routing == "static":
            first_two = [t.replicas[0] for t in router.tickets[:2]]
            assert first_two == ["replica0", "replica1"]


# ---------------------------------------------------------------------------
# engine satellites: stepwise wave API + queue-wait/e2e stats
# ---------------------------------------------------------------------------

def test_engine_stepwise_wave_matches_generate():
    """Driving the wave one step at a time (the router's surface) produces
    exactly what the closed-loop generate() produces."""
    cfg, params = _setup()
    reqs_a = _requests(cfg, 5, seed=14, max_new=3)
    reqs_b = _requests(cfg, 5, seed=14, max_new=3)
    e1 = ServeEngine(cfg, params, ShardCtx(), max_batch=2)
    e1.generate(reqs_a)
    e2 = ServeEngine(cfg, params, ShardCtx(), max_batch=2)
    e2.gru_wave_begin(reqs_b)
    n = 0
    while e2.gru_wave_active():
        e2.gru_wave_step()
        n += 1
        assert n < 1000
    assert [r.out for r in reqs_a] == [r.out for r in reqs_b]
    assert all(r.done for r in reqs_b)


def test_engine_wave_cancel_frees_lane():
    cfg, params = _setup()
    reqs = _requests(cfg, 3, seed=15, max_new=50)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2)
    engine.gru_wave_begin(reqs)
    engine.gru_wave_step()
    assert engine.gru_wave_active() == 3          # 2 live + 1 pending
    assert engine.gru_wave_cancel(reqs[0])        # live lane
    assert engine.gru_wave_cancel(reqs[2])        # still pending
    assert not engine.gru_wave_cancel(reqs[0])    # already gone
    engine.gru_wave_step()
    assert engine.gru_wave_active() == 1
    assert not reqs[0].done and len(reqs[1].out) >= 1


def test_engine_latency_stats_queue_wait_and_e2e():
    """latency_stats reports per-request queue-wait and admit->finish e2e
    latency (the router's routing signal and the benchmark's honest p99),
    and e2e >= queue-wait for every request."""
    cfg, params = _setup()
    reqs = _requests(cfg, 5, seed=16, max_new=3)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2)
    engine.generate(reqs)
    s = engine.latency_stats()
    assert s["requests"] == 5
    assert len(engine.queue_waits) == 5 and len(engine.e2e_times) == 5
    assert all(q >= 0 for q in engine.queue_waits)
    assert s["e2e_p99_s"] >= s["e2e_p50_s"] >= 0.0
    assert s["queue_wait_p99_s"] >= s["queue_wait_p50_s"] >= 0.0
    # queued requests (beyond the 2 slots) waited longer than the cohort
    assert max(engine.queue_waits) >= min(engine.queue_waits)
    for r in reqs:
        assert r.t_submit is not None and r.t_finish is not None
        assert r.t_admit is not None
        assert r.t_finish - r.t_submit >= r.t_admit - r.t_submit >= 0.0


# ---------------------------------------------------------------------------
# client-disconnect cancellation (FleetRouter.cancel)
# ---------------------------------------------------------------------------

def test_fleet_cancel_queued_and_inflight():
    """cancel() drops an outstanding request everywhere it lives: a
    queued ticket (by integer id) leaves the bounded queue without ever
    dispatching; an inflight ticket (by FleetTicket) frees its replica
    wave lane mid-decode; a finished ticket is a no-op returning False.
    The survivors complete with streams matching the fault-free oracle."""
    cfg, params = _setup()
    reqs = _requests(cfg, 5, seed=20, max_new=6)
    router = _fleet(cfg, params)
    tickets = [router.submit(r) for r in reqs]
    # queued cancel, before any dispatch tick — by id (the async handle a
    # disconnecting client holds)
    assert router.cancel(tickets[4].id) is True
    assert tickets[4].status == "cancelled"
    assert tickets[4].reason == "client_disconnect"
    # tombstone semantics: the deque entry is only skipped lazily, the
    # O(1) cancel just flips status — the next dispatch pass drops it
    assert tickets[4] in router._queue
    router.tick()
    assert tickets[4] not in router._queue
    assert tickets[4].replicas == []                   # never dispatched
    assert router.cancel(tickets[4]) is False          # already cancelled
    # dispatch and get mid-decode, then cancel an inflight ticket
    while not tickets[0].flights:
        router.tick()
    fl = tickets[0].flights[0]
    lane_req = fl.clone
    rep = fl.replica
    assert router.cancel(tickets[0]) is True
    assert tickets[0].status == "cancelled" and not tickets[0].flights
    assert fl not in rep.flights
    # the wave lane really freed: a second engine-level cancel misses
    assert rep.engine.gru_wave_cancel(lane_req) is False
    router.run_until_done()
    s = router.stats()
    assert s["cancelled"] == 2
    assert s["completed"] == 3 and s["failed"] == 0
    assert not reqs[0].done and not reqs[4].done
    done = [reqs[1], reqs[2], reqs[3]]
    assert all(r.done for r in done)
    assert [r.out for r in done] == _reference_outs(cfg, params, done)
    # disconnect after completion: no-op, result already landed
    done_ticket = next(t for t in tickets if t.status == "done")
    assert router.cancel(done_ticket) is False
    assert router.cancel(done_ticket.request) is False
    assert router.stats()["cancelled"] == 2            # unchanged


def test_fleet_cancel_unknown_handle_is_noop():
    cfg, params = _setup()
    router = _fleet(cfg, params)
    assert router.cancel(12345) is False               # unknown id
    assert router.cancel(Request(prompt=np.zeros((3, 5), np.float32))) \
        is False                                       # never-submitted
    assert router.stats()["cancelled"] == 0


def test_fleet_cancel_kills_hedged_duplicate_under_faults():
    """A ticket hedged onto a second replica (straggler duplicate) has
    TWO live lanes; client disconnect must cancel both — the straggler's
    and the duplicate's — so neither replica keeps decoding for a client
    that went away. Deterministic via FaultInjector slow + ManualClock."""
    cfg, params = _setup()
    reqs = _requests(cfg, 4, seed=21, max_new=8)
    inj = FaultInjector([
        FaultEvent(t=0.0, kind="slow", replica="replica0", factor=10.0)])
    router = _fleet(cfg, params, replicas=3, injector=inj,
                    config=FleetConfig(
                        heartbeat_timeout_s=0.5,       # slow != dead
                        straggler_factor=3.0, tick_s=0.01))
    tickets = [router.submit(r) for r in reqs]
    # pump until the straggler monitor hedges some ticket: 2 live flights
    n = 0
    while not any(len(t.flights) >= 2 for t in tickets):
        router.tick()
        n += 1
        assert n < 10_000, "straggler hedge never fired"
    t = next(t for t in tickets if len(t.flights) >= 2)
    lanes = [(fl.replica, fl.clone) for fl in t.flights]
    assert any(fl.hedge for fl in t.flights)
    before = router.stats()["hedges_cancelled"]
    assert router.cancel(t) is True
    assert t.status == "cancelled" and not t.flights
    assert router.stats()["hedges_cancelled"] == before + 1
    # BOTH lanes freed — straggler and duplicate alike
    for rep, clone in lanes:
        assert all(fl.clone is not clone for fl in rep.flights)
        assert rep.engine.gru_wave_cancel(clone) is False
    router.run_until_done()
    s = router.stats()
    assert s["cancelled"] == 1 and s["failed"] == 0
    assert s["completed"] == 3
    assert not t.request.done
    others = [r for r in reqs if r is not t.request]
    assert all(r.done for r in others)
    assert [r.out for r in others] == _reference_outs(cfg, params, others)


# ---------------------------------------------------------------------------
# fleet autotuning: per-replica tuners, A/B vs static
# ---------------------------------------------------------------------------

def test_fleet_autotune_per_replica_tuners_ab_parity():
    """autotune=True attaches one AutoTuner per replica: each tunes its
    bucket ladder to its OWN observed traffic at wave boundaries. Under a
    plain ManualClock every measured step dt is 0.0, so recalibration
    stays inert (the shared CostModel is never touched) — and the tuned
    fleet's streams stay bitwise-identical to the static fleet's (the
    benchmark A/B's correctness leg)."""
    from repro.core import runtime
    from repro.serve.autotune import AutoTuneConfig
    cfg, params = _setup()
    model_before = runtime.cost_model()
    tuned = FleetRouter(cfg, params, replicas=2, max_batch=2,
                        clock=ManualClock(),
                        config=FleetConfig(heartbeat_timeout_s=0.05,
                                           backoff_base_s=0.02, tick_s=0.01),
                        autotune=True,
                        tuner_config=AutoTuneConfig(ladder_min_prompts=4))
    reqs_t = _requests(cfg, 12, seed=22, max_new=4)
    done_t = tuned.generate(reqs_t)
    assert all(r.done for r in done_t)
    s = tuned.stats()
    assert s["autotune"] is True
    assert s["completed"] == 12 and s["failed"] == 0
    # at least one replica saw enough prompts to install a quantile ladder
    tuned_reps = [v for v in s["replicas"].values()
                  if v["bucket_ladder"] is not None]
    assert tuned_reps and all(v["retunes"] >= 1 for v in tuned_reps)
    # recalibration stayed inert at dt == 0: shared model untouched
    assert runtime.cost_model() is model_before
    # full decision records (with measurements) live on each engine
    for rep in tuned.replicas:
        at = rep.engine.latency_stats()["autotune"]
        assert at["enabled"] is True
        for d in at["decisions"]:
            assert d["measurement"] and "rule" in d["measurement"]
    # A/B: the static fleet serves the same seeds to identical streams
    static = _fleet(cfg, params)
    reqs_s = _requests(cfg, 12, seed=22, max_new=4)
    static.generate(reqs_s)
    assert static.stats()["autotune"] is False
    assert [r.out for r in reqs_t] == [r.out for r in reqs_s]


def test_engine_wave_enqueue_into_live_wave():
    """Requests can join a running wave (the fleet dispatch path) and are
    admitted into freed slots with the usual single-prefill batching."""
    cfg, params = _setup()
    first = _requests(cfg, 2, seed=17, max_new=3)
    late = _requests(cfg, 2, seed=18, max_new=2)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2)
    engine.gru_wave_begin(first)
    engine.gru_wave_step()
    engine.gru_wave_enqueue(late)
    n = 0
    while engine.gru_wave_active():
        engine.gru_wave_step()
        n += 1
        assert n < 100
    assert all(r.done for r in first + late)
    assert [len(r.out) for r in first + late] == [3, 3, 2, 2]


# ---------------------------------------------------------------------------
# scheduler bugfix regressions: virtual time, deadlines, stats, tombstones
# ---------------------------------------------------------------------------

def test_tick_advance_time_false_freezes_virtual_time():
    """tick(advance_time=False) runs a full scheduler round (dispatch,
    decode steps) without consuming ManualClock time, and reports how
    many decode steps it performed; the default tick still advances
    tick_s per round."""
    cfg, params = _setup()
    clock = ManualClock()
    router = _fleet(cfg, params, clock=clock)
    router.submit(_requests(cfg, 1, seed=30)[0])
    stepped = router.tick(advance_time=False)
    assert clock.now() == 0.0                # waiting is not service time
    assert stepped > 0                       # ...but the fleet did work
    router.tick()
    assert clock.now() == pytest.approx(router.config.tick_s)
    router.run_until_done()
    assert router.stats()["completed"] == 1


def test_generate_admission_pump_does_not_age_virtual_time():
    """Regression: generate()'s backpressure pump used to run normal
    ticks, advancing virtual time per pumped round while merely waiting
    for a queue slot — spuriously aging queued tickets' deadlines and
    expiring retry backoffs. Pump ticks now run with advance_time=False,
    so far fewer virtual seconds elapse than scheduler rounds ran, and a
    deadline that per-request service comfortably meets is never shed
    just because the caller submitted under backpressure."""
    cfg, params = _setup()
    clock = ManualClock()
    small = FleetConfig(heartbeat_timeout_s=10.0, backoff_base_s=0.02,
                        tick_s=0.01, queue_limit=2)
    router = _fleet(cfg, params, clock=clock, config=small)
    reqs = _requests(cfg, 8, seed=31, max_new=6)
    done = router.generate(reqs, deadline_s=0.5)
    assert all(r.done for r in done)
    s = router.stats()
    assert s["completed"] == 8 and s["shed"] == {}
    # the old pump made now() == ticks * tick_s exactly; admission waits
    # no longer consume virtual time
    assert clock.now() < router.ticks * router.config.tick_s
    assert [r.out for r in done] == _reference_outs(cfg, params, reqs)


def test_generate_pump_advances_time_when_fleet_cannot_step():
    """Liveness of the frozen-time pump: with every replica dead and the
    queue full, a pump round performs zero decode steps — the clock must
    then advance manually so the scheduled restore can fire, instead of
    spinning forever at a frozen now()."""
    cfg, params = _setup()
    clock = ManualClock()
    inj = FaultInjector([
        FaultEvent(t=0.0, kind="kill", replica="replica0"),
        FaultEvent(t=0.0, kind="kill", replica="replica1"),
        FaultEvent(t=0.06, kind="restore", replica="replica0")])
    small = FleetConfig(heartbeat_timeout_s=10.0, backoff_base_s=0.02,
                        tick_s=0.01, queue_limit=2)
    router = _fleet(cfg, params, injector=inj, clock=clock, config=small)
    reqs = _requests(cfg, 4, seed=32, max_new=4)
    done = router.generate(reqs)
    assert all(r.done for r in done)
    s = router.stats()
    assert s["kills"] == 2 and s["restores"] == 1
    assert s["completed"] == 4 and s["failed"] == 0
    assert clock.now() >= 0.06               # time DID move to the restore


def test_deadline_sheds_inflight_ticket_and_frees_lane():
    """End-to-end deadline enforcement: an IN-FLIGHT ticket past its
    deadline is shed mid-decode — wave lane cancelled so no replica keeps
    spending steps on a request that can only be returned late — instead
    of the old queued-only check letting it run to completion."""
    cfg, params = _setup()
    clock = ManualClock()
    router = _fleet(cfg, params, clock=clock)
    long_req = _requests(cfg, 1, seed=33, max_new=60)[0]
    shorts = _requests(cfg, 3, seed=34, max_new=4)
    t_long = router.submit(long_req, deadline_s=0.2)
    for r in shorts:
        router.submit(r)
    while not t_long.flights:                # definitely dispatched
        router.tick()
    router.run_until_done()
    assert t_long.status == "shed" and t_long.reason == "deadline"
    assert router.sheds["deadline"] == 1
    assert t_long.flights == []              # lane freed fleet-wide
    assert not long_req.done                 # never returned late
    assert t_long.t_first_dispatch is not None   # it WAS in flight
    # shed at the first round past the deadline, not at completion
    assert t_long.t_done - t_long.t_submit <= 0.2 + 2 * router.config.tick_s
    # bystanders unharmed
    assert all(r.done for r in shorts)
    assert [r.out for r in shorts] == _reference_outs(cfg, params, shorts)


def test_empty_history_stats_are_nan_not_zero():
    """Regression: a fleet/engine that served nothing used to report
    0.0 percentiles — a fake-perfect p99 that silently passes CI's
    `tuned p99 <= 1.1x static` gate. Empty histories now report NaN,
    which fails any <=/>= comparison."""
    cfg, params = _setup()
    router = _fleet(cfg, params)
    s = router.stats()
    for k in ("e2e_mean_s", "e2e_p50_s", "e2e_p99_s",
              "queue_wait_p50_s", "queue_wait_p99_s"):
        assert np.isnan(s[k]), k
    assert not (s["e2e_p99_s"] <= 1.1 * 0.005)   # the gate cannot pass
    ls = router.replicas[0].engine.latency_stats()
    for k in ("mean_s", "p50_s", "p90_s", "p99_s", "max_s",
              "prefill_mean_s", "queue_wait_p99_s", "e2e_p50_s"):
        assert np.isnan(ls[k]), k
    # after real traffic the numbers come back
    router.generate(_requests(cfg, 2, seed=35))
    s2 = router.stats()
    assert s2["e2e_p99_s"] > 0.0 and not np.isnan(s2["e2e_mean_s"])


def test_cancelled_queue_entries_tombstoned_and_never_dispatch():
    """O(1) cancel: a queued cancel only flips status (no deque scan);
    the stale entry is lazily dropped by the next dispatch pass and the
    ticket never reaches a replica. Survivors complete bitwise-clean."""
    cfg, params = _setup()
    router = _fleet(cfg, params)
    reqs = _requests(cfg, 10, seed=36, max_new=4)
    tickets = [router.submit(r) for r in reqs]
    for t in tickets[::2]:
        assert router.cancel(t) is True
    assert len(router._queue) == 10          # tombstones still in deque
    router.tick()                            # ...dropped lazily here
    assert all(t.status == "cancelled" and t.replicas == []
               for t in tickets[::2])
    router.run_until_done()
    s = router.stats()
    assert s["cancelled"] == 5 and s["completed"] == 5
    live = [r for i, r in enumerate(reqs) if i % 2 == 1]
    assert all(r.done for r in live)
    assert [r.out for r in live] == _reference_outs(cfg, params, live)
