"""MoE: dispatch correctness, capacity behavior, EP path vs oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.base import get_smoke_config
from repro.core.params import init_params
from repro.distributed.sharding import ShardCtx
from repro.models import moe as moe_mod

CFG = get_smoke_config("qwen2-moe-a2.7b").replace(dtype="float32",
                                                  param_dtype="float32")


def _setup(capacity_factor=8.0, key=0):
    cfg = CFG.replace(moe=dataclasses.replace(CFG.moe,
                                              capacity_factor=capacity_factor))
    params = init_params(moe_mod.moe_specs(cfg), jax.random.key(key), "float32")
    x = jax.random.normal(jax.random.key(key + 1), (2, 8, cfg.d_model))
    return cfg, params, x


def test_local_dispatch_matches_ref():
    cfg, params, x = _setup()
    out, aux = moe_mod.moe_apply(params, cfg, x, ctx=ShardCtx())
    ref = moe_mod.moe_ref(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0.0


def test_capacity_drops_reduce_output():
    """With capacity 1 token/expert some contributions are dropped; output
    must stay finite and differ from the no-drop reference."""
    cfg, params, x = _setup(capacity_factor=0.1)
    out, _ = moe_mod.moe_apply(params, cfg, x, ctx=ShardCtx())
    assert np.isfinite(np.asarray(out)).all()
    ref = moe_mod.moe_ref(params, cfg, x)
    assert np.abs(np.asarray(out) - np.asarray(ref)).max() > 1e-5


def test_padded_experts_never_routed():
    cfg, params, x = _setup()
    E = moe_mod.padded_experts(cfg.moe)
    assert E == 16  # 8 -> padded to 16
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ params["router"]
    masked = jnp.where(jnp.arange(E)[None] < cfg.moe.num_experts, logits,
                       moe_mod.NEG_INF)
    _, top_i = jax.lax.top_k(jax.nn.softmax(masked, -1), cfg.moe.top_k)
    assert int(top_i.max()) < cfg.moe.num_experts


def test_ep_shard_map_matches_local(multidev):
    multidev("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import get_smoke_config
from repro.core.params import init_params
from repro.distributed.sharding import ShardCtx
from repro.models import moe as moe_mod
cfg = get_smoke_config("qwen2-moe-a2.7b").replace(dtype="float32", param_dtype="float32")
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
params = init_params(moe_mod.moe_specs(cfg), jax.random.key(0), "float32")
x = jax.random.normal(jax.random.key(1), (4, 8, cfg.d_model))
mesh = compat.make_mesh((2, 2), ("data", "model"))
out_ep, _ = jax.jit(lambda p, x: moe_mod.moe_apply(p, cfg, x, ctx=ShardCtx(mesh=mesh)))(params, x)
ref = moe_mod.moe_ref(params, cfg, x)
np.testing.assert_allclose(np.asarray(out_ep), np.asarray(ref), rtol=3e-4, atol=3e-4)
print("PASS")
""")


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_combine_weights_sum(seed):
    """Renormalized top-k routing weights sum to 1 per token."""
    cfg, params, _ = _setup(key=seed % 7)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, norm_topk_prob=True))
    x = jax.random.normal(jax.random.key(seed), (1, 6, cfg.d_model))
    xf = x.reshape(-1, cfg.d_model)
    E = moe_mod.padded_experts(cfg.moe)
    logits = xf @ params["router"]
    logits = jnp.where(jnp.arange(E)[None] < cfg.moe.num_experts, logits,
                       moe_mod.NEG_INF)
    probs = jax.nn.softmax(logits, -1)
    top_p, _ = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(top_p.sum(-1)), 1.0, rtol=1e-5)


def test_moe_grads_flow_through_router():
    cfg, params, x = _setup()

    def loss(p):
        out, aux = moe_mod.moe_apply(p, cfg, x, ctx=ShardCtx())
        return (out ** 2).mean() + aux
    g = jax.grad(loss)(params)
    assert np.abs(np.asarray(g["router"])).sum() > 0
    assert np.abs(np.asarray(g["wg"])).sum() > 0
