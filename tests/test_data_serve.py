"""Data pipeline determinism + serving engine behavior."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_smoke_config
from repro.core.params import init_params
from repro.data.pipeline import PipelineConfig, Prefetcher, SyntheticStream, shard_batch
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi
from repro.serve.engine import Request, ServeEngine


def test_stream_deterministic_and_seekable():
    cfg = get_smoke_config("qwen3-0.6b")
    s1 = SyntheticStream(cfg, ShapeConfig("t", 16, 4, "train"))
    s2 = SyntheticStream(cfg, ShapeConfig("t", 16, 4, "train"))
    b_a = s1.batch_at(7)
    b_b = s2.batch_at(7)          # fresh object, same (seed, step)
    for k in b_a:
        np.testing.assert_array_equal(b_a[k], b_b[k])
    # different steps differ
    assert not np.array_equal(s1.batch_at(8)["tokens"], b_a["tokens"])
    # targets are next-token shifted view of the same underlying sequence
    assert b_a["targets"].shape == b_a["tokens"].shape


def test_stream_has_learnable_structure():
    cfg = get_smoke_config("qwen3-0.6b")
    s = SyntheticStream(cfg, ShapeConfig("t", 256, 2, "train"),
                        PipelineConfig(bigram_eps=0.25))
    b = s.batch_at(0)
    nxt = (b["tokens"] * s._a + s._c) % cfg.vocab_size
    frac = (nxt == b["targets"]).mean()
    assert frac > 0.6, frac        # ~75% deterministic bigram


def test_prefetcher_order_and_seek():
    cfg = get_smoke_config("gru-jet")
    s = SyntheticStream(cfg, ShapeConfig("t", cfg.gru.seq_len, 2, "train"))
    shardings = jax.tree_util.tree_map(lambda _: None, s.batch_at(0))
    pf = Prefetcher(s, shardings, start_step=3, depth=2)
    b3 = pf.next()
    np.testing.assert_allclose(np.asarray(b3["features"]),
                               s.batch_at(3)["features"])
    pf.seek(10)
    b10 = pf.next()
    np.testing.assert_allclose(np.asarray(b10["features"]),
                               s.batch_at(10)["features"])


def test_serve_engine_generates():
    cfg = get_smoke_config("qwen3-0.6b")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=3)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
                    max_new_tokens=n) for n in (3, 5, 2)]
    done = engine.generate(reqs)
    assert [len(r.out) for r in done] == [3, 5, 2]
    assert all(r.done for r in done)
    stats = engine.latency_stats()
    assert stats["steps"] >= 1


def test_serve_engine_gru_wave_depth2():
    """Feature-vector wave serving through a depth-2 GRU stack: per-step
    decode latency is measured (the paper's figure of merit)."""
    from repro.configs.base import GRUConfig
    cfg = get_smoke_config("gru-jet").replace(
        gru=GRUConfig(input_dim=5, hidden_dim=16, num_classes=5, seq_len=20,
                      num_layers=2))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=3)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.normal(size=(s, 5)).astype(np.float32),
                    max_new_tokens=n) for s, n in ((6, 3), (4, 5), (6, 2))]
    done = engine.generate(reqs)
    assert [len(r.out) for r in done] == [3, 5, 2]
    assert all(r.done for r in done)
    assert all(0 <= t < 5 for r in done for t in r.out)
    stats = engine.latency_stats()
    assert stats["steps"] >= 1
    # streamed decode features are honored
    engine2 = ServeEngine(cfg, params, ShardCtx(), max_batch=1)
    stream = rng.normal(size=(4, 5)).astype(np.float32)
    done2 = engine2.generate([Request(prompt=stream[:2], max_new_tokens=4,
                                      stream=stream)])
    assert len(done2[0].out) == 4


def test_serve_engine_gru_matches_model_api():
    """Engine prefill+decode == direct model-API calls (deep config)."""
    import jax.numpy as jnp
    cfg = get_smoke_config("gru-jet-deep")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    rng = np.random.default_rng(1)
    feats = rng.normal(size=(3, 5)).astype(np.float32)
    logits, cache = A.prefill(params, cfg, {"features": jnp.asarray(feats[None])},
                              ShardCtx())
    logits2, _ = A.decode_step(params, cfg, cache,
                               jnp.asarray(feats[-1][None]), ShardCtx())
    expect = int(np.argmax(np.asarray(logits2)[0]))
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=1)
    done = engine.generate([Request(prompt=feats, max_new_tokens=1)])
    assert done[0].out[0] == expect


def test_serve_engine_no_retrace_same_bucket():
    """Two GRU waves with DIFFERENT prompt lengths in the same power-of-two
    bucket share one prefill jit entry and trace it exactly once; the
    decode step compiles once for the engine lifetime (fixed slots)."""
    cfg = get_smoke_config("gru-jet-deep")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2, bucket_min=8)
    rng = np.random.default_rng(0)

    def wave(S):
        return [Request(prompt=rng.normal(size=(S, 5)).astype(np.float32),
                        max_new_tokens=2) for _ in range(2)]

    engine.generate(wave(5))                     # warmup: bucket 8
    n_prefill = len(engine._prefill_jit)
    n_decode = len(engine._decode_jit)
    traces = {k: f._cache_size() for k, f in engine._prefill_jit.items()}
    engine.generate(wave(7))                     # different S, same bucket
    assert len(engine._prefill_jit) == n_prefill == 1
    assert len(engine._decode_jit) == n_decode == 1
    for k, f in engine._prefill_jit.items():
        assert f._cache_size() == traces[k] == 1, (k, f._cache_size())
    for f in engine._decode_jit.values():
        assert f._cache_size() == 1
    # a longer prompt opens exactly one NEW bucket
    engine.generate(wave(11))                    # bucket 16
    assert len(engine._prefill_jit) == 2
    assert len(engine._decode_jit) == 1


def test_serve_engine_decode_cache_keyed_by_batch():
    """Regression: the decode jit cache is keyed by batch shape, so waves
    of different sizes get their own donated-cache jit instead of silently
    retracing one unkeyed entry."""
    cfg = get_smoke_config("qwen3-0.6b")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=3)
    rng = np.random.default_rng(0)

    def wave(B):
        return [Request(prompt=rng.integers(0, cfg.vocab_size, size=5)
                        .astype(np.int32), max_new_tokens=2)
                for _ in range(B)]

    engine.generate(wave(2))
    engine.generate(wave(1))
    assert set(engine._decode_jit) == {(2,), (1,)}
    for f in engine._decode_jit.values():
        assert f._cache_size() == 1              # each traced exactly once
    done = engine.generate(wave(2))              # reuses the (2,) entry
    assert engine._decode_jit[(2,)]._cache_size() == 1
    assert [len(r.out) for r in done] == [2, 2]


def test_serve_engine_gru_continuous_batching():
    """More requests than slots: finished streams retire mid-wave and
    queued requests are admitted into the freed slots — everyone is served
    with correct lengths and only ONE prefill bucket is compiled. Admits
    that land on the same step are BATCHED into one prefill."""
    cfg = get_smoke_config("gru-jet-deep")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2, bucket_min=8)
    rng = np.random.default_rng(0)
    budgets = [2, 5, 3, 4, 1]
    reqs = [Request(prompt=rng.normal(size=(3 + i % 4, 5)).astype(np.float32),
                    max_new_tokens=n) for i, n in enumerate(budgets)]
    done = engine.generate(reqs)
    assert [len(r.out) for r in done] == budgets
    assert all(r.done for r in done)
    assert all(0 <= t < 5 for r in done for t in r.out)
    # 5 requests through 2 slots: 1 cohort prefill + 1 single admit (req2
    # into req0's slot) + ONE batched admit (req1 and req2 finish on the
    # same step, so req3+req4 share a single prefill), all through the
    # SAME bucket jit (prompts 3..6 all bucket to 8)
    stats = engine.latency_stats()
    assert stats["prefills"] == 3
    assert len(engine._prefill_jit) == 1
    for f in engine._prefill_jit.values():
        assert f._cache_size() == 1
    # mid-wave admission really overlapped: total decode steps is less than
    # a serial 2-slot schedule would need (bounded by the longest lane sum)
    assert stats["steps"] >= max(budgets)


def test_serve_engine_decode_backend_attribution():
    """latency_stats attributes every recorded decode step to the backend
    that actually ran it: attribution is keyed by the decode jit the step
    ran under (frozen at that jit's trace time — the trace embeds the
    backend) instead of trusting a wave-start snapshot, and
    ``decode_backends`` stays aligned with ``step_times`` across
    continuous-batching admits."""
    cfg = get_smoke_config("gru-jet-deep")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2, bucket_min=8)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.normal(size=(3, 5)).astype(np.float32),
                    max_new_tokens=n) for n in (2, 5, 3, 4)]
    done = engine.generate(reqs)
    assert all(r.done for r in done)
    stats = engine.latency_stats()
    # one attribution per recorded step, consistent with the executor's
    # resolved decode backend for the (fixed-slot) batch shape
    assert len(engine.decode_backends) == stats["steps"]
    from repro.models import gru_lm
    expect = gru_lm.serve_executable(cfg, batch=2,
                                     mode="decode").decode_backend
    assert engine.decode_backend == expect
    assert set(engine.decode_backends) == {expect}
    assert stats["decode_backend_steps"] == {expect: stats["steps"]}


def test_serve_engine_gru_batched_admits():
    """When several slots free on the SAME decode step, the engine runs
    ONE bucketed prefill for all admitted requests (ROADMAP item): equal
    budgets retire the whole cohort at once, so 6 requests through 3
    slots cost exactly 2 prefills — and every request still gets the
    answer a solo engine gives it."""
    cfg = get_smoke_config("gru-jet-deep")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    rng = np.random.default_rng(3)
    prompts = [rng.normal(size=(3 + i % 3, 5)).astype(np.float32)
               for i in range(6)]
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=3, bucket_min=8)
    done = engine.generate([Request(prompt=p, max_new_tokens=2)
                            for p in prompts])
    assert engine.latency_stats()["prefills"] == 2     # cohort + ONE batched
    assert all(len(r.out) == 2 for r in done)
    # the batched-admit rows were scattered into the right slots: each
    # request's outputs match a single-request engine (one engine reused
    # across prompts — its jits are cached, so this stays cheap)
    solo = ServeEngine(cfg, params, ShardCtx(), max_batch=1, bucket_min=8)
    for p, r in zip(prompts, done):
        ref = solo.generate([Request(prompt=p, max_new_tokens=2)])[0]
        assert r.out == ref.out


def test_serve_engine_gru_bucketed_prefill_exact():
    """Bucket padding must not change results: a batch-1 engine answer
    equals the direct model-API answer on the UNPADDED prompt, even though
    the engine pads the prompt up to the bucket length (mask exactness)."""
    cfg = get_smoke_config("gru-jet-deep")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    rng = np.random.default_rng(1)
    feats = rng.normal(size=(3, 5)).astype(np.float32)   # S=3 -> bucket 8
    logits, cache = A.prefill(params, cfg,
                              {"features": jnp.asarray(feats[None])},
                              ShardCtx())
    logits2, _ = A.decode_step(params, cfg, cache,
                               jnp.asarray(feats[-1][None]), ShardCtx())
    expect = int(np.argmax(np.asarray(logits2)[0]))
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=1, bucket_min=8)
    done = engine.generate([Request(prompt=feats, max_new_tokens=1)])
    assert done[0].out[0] == expect


def test_serve_engine_gru_pallas_backend():
    """The fused decode path serves end-to-end (backend="pallas"): same
    class predictions as the XLA engine on the same wave."""
    import dataclasses
    cfg = get_smoke_config("gru-jet-deep")
    cfg_p = cfg.replace(gru=dataclasses.replace(cfg.gru, backend="pallas"))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    rng = np.random.default_rng(2)
    prompts = [rng.normal(size=(4, 5)).astype(np.float32) for _ in range(2)]
    outs = []
    for c in (cfg, cfg_p):
        engine = ServeEngine(c, params, ShardCtx(), max_batch=2)
        # serving prep attaches the pre-stacked decode weights exactly once
        assert "stacked_cells" in engine.params
        done = engine.generate([Request(prompt=p, max_new_tokens=3)
                                for p in prompts])
        outs.append([r.out for r in done])
    assert outs[0] == outs[1]


def test_serve_engine_masked_prefill_runs_pallas():
    """Acceptance: a ServeEngine prefill with a NON-TRIVIAL length mask
    (ragged prompts inside one bucket) executes the fused Pallas sequence
    kernel — asserted via the executor plan the engine recorded, not
    inferred — and the masked, bucketed results equal the direct
    model-API answers on the UNPADDED prompts (mask exactness end to
    end)."""
    import dataclasses
    cfg = get_smoke_config("gru-jet-deep")
    cfg = cfg.replace(gru=dataclasses.replace(cfg.gru, backend="pallas"))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    rng = np.random.default_rng(5)
    # ragged lengths 3 and 6 -> both left-padded into the 8-bucket: the
    # mask rows are genuinely non-trivial (and differ per row)
    prompts = [rng.normal(size=(s, 5)).astype(np.float32) for s in (3, 6)]
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2, bucket_min=8)
    done = engine.generate([Request(prompt=p, max_new_tokens=1)
                            for p in prompts])
    assert engine.prefill_backends == ["pallas_fused"], engine.prefill_backends
    assert engine.decode_backend == "pallas_fused"
    for p, r in zip(prompts, done):
        logits, cache = A.prefill(params, cfg,
                                  {"features": jnp.asarray(p[None])},
                                  ShardCtx())
        logits2, _ = A.decode_step(params, cfg, cache,
                                   jnp.asarray(p[-1][None]), ShardCtx())
        assert r.out[0] == int(np.argmax(np.asarray(logits2)[0]))


def test_serve_engine_greedy_matches_model():
    """Engine's first generated token == argmax of the model prefill."""
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32",
                                                 param_dtype="float32")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), "float32")
    prompt = np.arange(5, dtype=np.int32)
    logits, _ = A.prefill(params, cfg, {"tokens": jnp.asarray(prompt[None])},
                          ShardCtx())
    expect = int(np.argmax(np.asarray(logits)[0]))
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=1)
    done = engine.generate([Request(prompt=prompt, max_new_tokens=1)])
    assert done[0].out[0] == expect
