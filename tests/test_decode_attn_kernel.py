"""Flash-decode Pallas kernel vs oracle: shape/dtype sweeps, ring masks,
sliding windows, and end-to-end through the transformer decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attn.kernel import flash_decode
from repro.kernels.decode_attn.ops import decode_attend_pallas
from repro.kernels.decode_attn.ref import flash_decode_ref


@pytest.mark.parametrize("B,Hkv,G,C,D,bc", [
    (1, 2, 4, 64, 16, 16), (2, 1, 1, 128, 32, 64), (2, 4, 2, 96, 16, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(B, Hkv, G, C, D, bc, dtype):
    ks = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(ks[0], (B, Hkv, G, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, C, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, C, D)).astype(dtype)
    mask = (jax.random.uniform(ks[3], (C,)) > 0.3)
    out = flash_decode(q, k, v, mask, block_c=bc, interpret=True)
    ref = flash_decode_ref(q, k, v, mask)
    tol = dict(rtol=4e-2, atol=4e-2) if dtype == jnp.bfloat16 else dict(
        rtol=3e-4, atol=3e-5)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               **tol)


def test_ops_ring_and_window_mask():
    B, Hkv, G, C, D = 1, 2, 2, 32, 16
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, D))
    k = jax.random.normal(ks[1], (B, Hkv, C, D))
    v = jax.random.normal(ks[2], (B, Hkv, C, D))
    slot_pos = jnp.concatenate([jnp.arange(20), jnp.full((12,), -1)]).astype(jnp.int32)
    pos = jnp.array(19, jnp.int32)
    out = decode_attend_pallas(q, k, v, slot_pos, pos, window=8)
    valid = (slot_pos >= 0) & (slot_pos > pos - 8) & (slot_pos <= pos)
    ref = flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-5)


def test_transformer_decode_with_pallas_kernel():
    """attn_impl=pallas decode == default einsum decode."""
    from repro.configs.base import get_smoke_config
    from repro.core.params import init_params
    from repro.distributed.sharding import ShardCtx
    from repro.models import api as mapi
    CTX = ShardCtx()
    cfg = get_smoke_config("qwen3-0.6b").replace(dtype="float32",
                                                 param_dtype="float32")
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), "float32")
    toks = jax.random.randint(jax.random.key(1), (2, 9), 0, cfg.vocab_size)
    _, cache = A.prefill(params, cfg, {"tokens": toks}, CTX)
    nt = jnp.zeros((2,), jnp.int32)
    l_x, _ = A.decode_step(params, cfg, cache, nt, CTX)
    cfg_p = cfg.replace(attn_impl="pallas")
    l_p, _ = mapi.get_api(cfg_p).decode_step(params, cfg_p, cache, nt, CTX)
    np.testing.assert_allclose(np.asarray(l_p), np.asarray(l_x),
                               rtol=3e-4, atol=3e-4)
