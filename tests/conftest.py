"""Shared test fixtures. NOTE: no XLA_FLAGS here — single-process tests see
1 device; multi-device tests run their bodies in a subprocess (see
``run_multidev``)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_multidev(body: str, n_devices: int = 4, timeout: int = 420) -> str:
    """Run ``body`` in a fresh python with n host devices; returns stdout.
    The body must print 'PASS' on success."""
    script = ("import os\n"
              f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n_devices}'\n"
              + textwrap.dedent(body))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    assert "PASS" in proc.stdout, f"stdout:\n{proc.stdout[-2000:]}\nstderr:\n{proc.stderr[-2000:]}"
    return proc.stdout


@pytest.fixture(scope="session")
def multidev():
    return run_multidev


@pytest.fixture(scope="session", autouse=True)
def _hermetic_gru_costs():
    """Pin the GRU executor to the STATIC cost table for the whole suite:
    a stray BENCH_backend_costs.json in the cwd (e.g. from a local
    benchmark run) must not flip backend choices under test. Tests that
    exercise calibration install their own model via set_cost_model."""
    from repro.core import runtime
    runtime.set_cost_model(runtime.CostModel({}, source="<tests: static>"))
    yield


@pytest.fixture(scope="session", autouse=True)
def _hermetic_quant_gate():
    """Pin the q8 accuracy gate CLOSED for the whole suite: a stray
    BENCH_quant_accuracy.json in the cwd (e.g. from a local harness run)
    must not make the q8 backends auto-eligible under test. Exact-name
    pins bypass the gate, so the q8 parity tests are unaffected; gating
    tests install their own report via set_quant_accuracy."""
    from repro.core import runtime
    runtime.set_quant_accuracy(runtime.QuantAccuracy(
        {}, source="<tests: closed>"))
    yield
