"""Deep GRU stacks: xla/pallas/sharded paths vs the dense stack oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GRUConfig
from repro.core import gru
from repro.core.params import init_params

TOL = dict(rtol=3e-5, atol=3e-6)


def _stack(cfg, key=0):
    return init_params(gru.gru_stack_specs(cfg), jax.random.key(key))


def _data(cfg, B=2, T=9, key=1):
    xs = jax.random.normal(jax.random.key(key), (B, T, cfg.input_dim))
    return xs, gru.stack_h0(cfg, B)


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("mode", ["dense", "rowwise", "cascade"])
def test_stack_xla_matches_reference(depth, mode):
    cfg = GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth,
                    matvec_mode=mode)
    params = _stack(cfg)
    xs, h0s = _data(cfg)
    ref_f, ref_all = gru.gru_stack_reference(params, h0s, xs, return_all=True)
    finals, alls = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg,
                                          return_all=True)
    assert len(finals) == depth
    for got, want in zip(finals, ref_f):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    np.testing.assert_allclose(np.asarray(alls), np.asarray(ref_all), **TOL)


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_stack_pallas_kernel_parity(depth, variant):
    """Fused multi-layer kernel (interpret mode) vs the step-by-step oracle
    on raw arrays."""
    from repro.kernels.gru_sequence import ref as gs_ref
    from repro.kernels.gru_sequence.kernel import gru_stack_sequence_kernel
    T, B, H, L = 7, 2, 16, depth
    ks = jax.random.split(jax.random.key(3), 5)
    h0 = jax.random.normal(ks[0], (L, B, H))
    xp = jax.random.normal(ks[1], (T, B, 3 * H))
    u = jax.random.normal(ks[2], (L, H, 3 * H)) / np.sqrt(H)
    wd = jax.random.normal(ks[3], (max(L - 1, 1), H, 3 * H)) / np.sqrt(H)
    b = jax.random.normal(ks[4], (L, 3 * H)) * 0.1
    ref_hs, ref_hT = gs_ref.gru_stack_sequence_ref(h0, xp, u, wd, b,
                                                   variant=variant)
    hs, hT = gru_stack_sequence_kernel(h0, xp, u, wd, b, variant=variant,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref_hs),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(ref_hT),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_stack_pallas_backend_matches_xla(depth, variant):
    cfg_x = GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth,
                      variant=variant)
    cfg_p = GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth,
                      variant=variant, backend="pallas")
    params = _stack(cfg_x)
    xs, h0s = _data(cfg_x)
    fx, ax = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg_x,
                                    return_all=True)
    fp, ap = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg_p,
                                    return_all=True)
    for a, b in zip(fx, fp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
    np.testing.assert_allclose(np.asarray(ax), np.asarray(ap), **TOL)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_depth1_identical_to_single_layer(backend):
    """A depth-1 stack IS the original single-layer path (same ops)."""
    cfg = GRUConfig(input_dim=5, hidden_dim=20, num_layers=1, backend=backend)
    params = _stack(cfg)
    xs, h0s = _data(cfg)
    single, _ = gru.gru_sequence(params[0], h0s[0], xs, cfg=cfg)
    stack, _ = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(single), np.asarray(stack[0]))


def test_stack_mixed_modes_hetero_dims():
    cfg = GRUConfig(input_dim=5, layer_dims=(16, 8, 12),
                    layer_matvec_modes=("rowwise", "cascade", "dense"))
    params = _stack(cfg)
    xs, h0s = _data(cfg)
    ref_f, _ = gru.gru_stack_reference(params, h0s, xs)
    finals, _ = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg)
    for got, want in zip(finals, ref_f):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_stack_decode_step_matches_sequence():
    """T decode steps through the stack == the sequence path's finals."""
    cfg = GRUConfig(input_dim=4, hidden_dim=12, num_layers=2)
    params = _stack(cfg)
    xs, h0s = _data(cfg, B=1, T=6)
    finals, _ = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg)
    hs = h0s
    for t in range(xs.shape[1]):
        hs = gru.gru_stack_decode_step(params, hs, xs[:, t], cfg=cfg)
    for got, want in zip(hs, finals):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_depth1_layer_dims_override_consistent():
    """A one-element layer_dims must size cell AND head from the override."""
    cfg = GRUConfig(input_dim=5, hidden_dim=20, layer_dims=(24,))
    params = init_params(gru.gru_classifier_specs(cfg), jax.random.key(0))
    assert params["cell"]["u"].shape == (24, 72)
    assert params["head"]["w"].shape == (24, 5)
    xs = jax.random.normal(jax.random.key(1), (2, 7, 5))
    assert gru.gru_classify(params, xs, cfg=cfg).shape == (2, 5)


def test_deep_classifier_shapes_and_grads():
    from repro.configs.gru_jet_deep import CONFIG
    params = init_params(gru.gru_classifier_specs(CONFIG.gru),
                         jax.random.key(0))
    assert "cells" in params and len(params["cells"]) == 3
    xs = jax.random.normal(jax.random.key(1), (4, 20, 5))
    logits = gru.gru_classify(params, xs, cfg=CONFIG.gru)
    assert logits.shape == (4, 5)

    def loss(p):
        return gru.gru_classify(p, xs, cfg=CONFIG.gru).sum()
    g = jax.grad(loss)(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_stack_sharded_all_modes(multidev):
    """Row-wise and cascade stacks on a 4-device mesh match the oracle for
    depths 1..3; mixed per-layer modes too (the collective-reuse path)."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import GRUConfig
from repro.core import gru, rowparallel
from repro.core.params import init_params
mesh = jax.make_mesh((4,), ("model",))
X, B, T = 6, 2, 7
xs = jax.random.normal(jax.random.key(1), (B, T, X))
for L in (1, 2, 3):
    for mode in ("rowwise", "cascade"):
        cfg = GRUConfig(input_dim=X, hidden_dim=16, num_layers=L, matvec_mode=mode)
        params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
        h0s = gru.stack_h0(cfg, B)
        outs = rowparallel.gru_stack_sequence_sharded(params, h0s, xs, mesh=mesh, cfg=cfg)
        ref, _ = gru.gru_stack_reference(params, h0s, xs)
        for a, b in zip(outs, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-6)
# v3 cross-scheme consistency at depth 2
o3 = []
for mode in ("rowwise", "cascade"):
    cfg = GRUConfig(input_dim=X, hidden_dim=16, num_layers=2, matvec_mode=mode, variant="v3")
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    h0s = gru.stack_h0(cfg, B)
    o3.append(rowparallel.gru_stack_sequence_sharded(params, h0s, xs, mesh=mesh, cfg=cfg))
for a, b in zip(*o3):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-6)
# mixed modes, heterogeneous dims
cfg = GRUConfig(input_dim=X, layer_dims=(16, 8, 12),
                layer_matvec_modes=("rowwise", "cascade", "rowwise"))
params = init_params(gru.gru_stack_specs(cfg), jax.random.key(2))
h0s = gru.stack_h0(cfg, B)
outs = rowparallel.gru_stack_sequence_sharded(params, h0s, xs, mesh=mesh, cfg=cfg)
ref, _ = gru.gru_stack_reference(params, h0s, xs)
for a, b in zip(outs, ref):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-6)
print("PASS")
""", timeout=560)


def test_stack_sharded_rowwise_has_no_reduce(multidev):
    """Collective reuse, verified in HLO: an all-rowwise DEEP stack still
    aggregates exclusively with gathers — stacking adds no reductions and
    no extra broadcast collectives."""
    multidev("""
import jax, jax.numpy as jnp
from repro.configs.base import GRUConfig
from repro.core import gru, rowparallel
from repro.core.params import init_params
from repro.launch.hloparse import analyze
mesh = jax.make_mesh((4,), ("model",))
X, B, T = 6, 1, 4
xs = jnp.ones((B, T, X))
def hlo(L):
    cfg = GRUConfig(input_dim=X, hidden_dim=16, num_layers=L, matvec_mode="rowwise")
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    h0s = gru.stack_h0(cfg, B)
    f = jax.jit(lambda p, h, x: rowparallel.gru_stack_sequence_sharded(
        p, h, x, mesh=mesh, cfg=cfg))
    return analyze(f.lower(params, h0s, xs).compile().as_text())
a1, a2 = hlo(1), hlo(2)
assert a1.coll_counts.get("all-reduce", 0) == 0, a1.coll_counts
assert a2.coll_counts.get("all-reduce", 0) == 0, a2.coll_counts
# per-layer gather count does not grow at layer boundaries: depth 2 uses
# exactly 2x the gathers of depth 1 (two per step per layer, v1), nothing extra
g1 = a1.coll_counts.get("all-gather", 0)
g2 = a2.coll_counts.get("all-gather", 0)
assert g1 > 0 and g2 <= 2 * g1, (g1, g2)
print("PASS")
""", timeout=560)
