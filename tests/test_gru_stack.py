"""Deep GRU stacks: xla/pallas/sharded paths vs the dense stack oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GRUConfig
from repro.core import gru
from repro.core.params import init_params

TOL = dict(rtol=3e-5, atol=3e-6)


def _stack(cfg, key=0):
    return init_params(gru.gru_stack_specs(cfg), jax.random.key(key))


def _data(cfg, B=2, T=9, key=1):
    xs = jax.random.normal(jax.random.key(key), (B, T, cfg.input_dim))
    return xs, gru.stack_h0(cfg, B)


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("mode", ["dense", "rowwise", "cascade"])
def test_stack_xla_matches_reference(depth, mode):
    cfg = GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth,
                    matvec_mode=mode)
    params = _stack(cfg)
    xs, h0s = _data(cfg)
    ref_f, ref_all = gru.gru_stack_reference(params, h0s, xs, return_all=True)
    finals, alls = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg,
                                          return_all=True)
    assert len(finals) == depth
    for got, want in zip(finals, ref_f):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    np.testing.assert_allclose(np.asarray(alls), np.asarray(ref_all), **TOL)


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_stack_pallas_kernel_parity(depth, variant):
    """Fused multi-layer kernel (interpret mode) vs the step-by-step oracle
    on raw arrays."""
    from repro.kernels.gru_sequence import ref as gs_ref
    from repro.kernels.gru_sequence.kernel import gru_stack_sequence_kernel
    T, B, H, L = 7, 2, 16, depth
    ks = jax.random.split(jax.random.key(3), 5)
    h0 = jax.random.normal(ks[0], (L, B, H))
    xp = jax.random.normal(ks[1], (T, B, 3 * H))
    u = jax.random.normal(ks[2], (L, H, 3 * H)) / np.sqrt(H)
    wd = jax.random.normal(ks[3], (max(L - 1, 1), H, 3 * H)) / np.sqrt(H)
    b = jax.random.normal(ks[4], (L, 3 * H)) * 0.1
    ref_hs, ref_hT = gs_ref.gru_stack_sequence_ref(h0, xp, u, wd, b,
                                                   variant=variant)
    hs, hT = gru_stack_sequence_kernel(h0, xp, u, wd, b, variant=variant,
                                       interpret=True)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref_hs),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(ref_hT),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_stack_pallas_backend_matches_xla(depth, variant):
    cfg_x = GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth,
                      variant=variant)
    cfg_p = GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth,
                      variant=variant, backend="pallas")
    params = _stack(cfg_x)
    xs, h0s = _data(cfg_x)
    fx, ax = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg_x,
                                    return_all=True)
    fp, ap = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg_p,
                                    return_all=True)
    for a, b in zip(fx, fp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
    np.testing.assert_allclose(np.asarray(ax), np.asarray(ap), **TOL)


@pytest.mark.parametrize("depth", [1, 2, 3])
@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_stack_decode_kernel_parity(depth, variant):
    """Fused decode-step kernel (interpret mode) vs the raw-array oracle,
    including the batch-tiled grid path."""
    from repro.kernels.gru_sequence import ref as gs_ref
    from repro.kernels.gru_sequence.kernel import gru_stack_decode_kernel
    B, H, L = 4, 16, depth
    ks = jax.random.split(jax.random.key(11 + depth), 5)
    h = jax.random.normal(ks[0], (L, B, H))
    xp = jax.random.normal(ks[1], (B, 3 * H))
    u = jax.random.normal(ks[2], (L, H, 3 * H)) / np.sqrt(H)
    wd = jax.random.normal(ks[3], (max(L - 1, 1), H, 3 * H)) / np.sqrt(H)
    b = jax.random.normal(ks[4], (L, 3 * H)) * 0.1
    ref = gs_ref.gru_stack_decode_ref(h, xp, u, wd, b, variant=variant)
    got = gru_stack_decode_kernel(h, xp, u, wd, b, variant=variant,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # batch-tiled grid (2 tiles) computes the same wave
    tiled = gru_stack_decode_kernel(h, xp, u, wd, b, variant=variant,
                                    batch_block=2, interpret=True)
    np.testing.assert_allclose(np.asarray(tiled), np.asarray(got),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_stack_decode_kernel_megacore_tiles_bitwise(variant):
    """The decode grid's batch-tile axis is declared
    ``dimension_semantics=("parallel",)`` (megacore): tiles are mutually
    independent, so each tile of a multi-tile wave must compute BITWISE
    the same rows as a standalone one-tile launch at the tile shape (same
    GEMM shapes -> bitwise is a fair bar; cross-shape comparisons are
    only held to tolerance elsewhere)."""
    from repro.kernels.gru_sequence.kernel import gru_stack_decode_kernel
    B, H, L, Bt = 8, 16, 2, 2
    ks = jax.random.split(jax.random.key(23), 5)
    h = jax.random.normal(ks[0], (L, B, H))
    xp = jax.random.normal(ks[1], (B, 3 * H))
    u = jax.random.normal(ks[2], (L, H, 3 * H)) / np.sqrt(H)
    wd = jax.random.normal(ks[3], (L - 1, H, 3 * H)) / np.sqrt(H)
    b = jax.random.normal(ks[4], (L, 3 * H)) * 0.1
    wave = gru_stack_decode_kernel(h, xp, u, wd, b, variant=variant,
                                   batch_block=Bt, interpret=True)
    for i in range(B // Bt):
        sl = slice(i * Bt, (i + 1) * Bt)
        solo = gru_stack_decode_kernel(h[:, sl], xp[sl], u, wd, b,
                                       variant=variant, interpret=True)
        np.testing.assert_array_equal(np.asarray(wave[:, sl]),
                                      np.asarray(solo))


@pytest.mark.parametrize("variant", ["v1", "v3"])
def test_decode_kernel_depth1_bitwise_single_layer(variant):
    """The depth-1 fused decode kernel IS one step of the single-layer
    sequence kernel (same gate math, same dtypes -> bitwise)."""
    from repro.kernels.gru_sequence.kernel import (gru_sequence_kernel,
                                                   gru_stack_decode_kernel)
    B, H = 2, 16
    ks = jax.random.split(jax.random.key(9), 4)
    h0 = jax.random.normal(ks[0], (B, H))
    xp = jax.random.normal(ks[1], (B, 3 * H))
    u = jax.random.normal(ks[2], (H, 3 * H)) / np.sqrt(H)
    b = jax.random.normal(ks[3], (3 * H,)) * 0.1
    seq = gru_sequence_kernel(h0, xp[None], u, b, variant=variant,
                              interpret=True)[0]
    dec = gru_stack_decode_kernel(h0[None], xp, u[None],
                                  jnp.zeros((1, 1, 3 * H)), b[None],
                                  variant=variant, interpret=True)[0]
    np.testing.assert_array_equal(np.asarray(seq), np.asarray(dec))


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_stack_decode_pallas_impl_matches_reference(depth):
    """T fused decode steps (impl="pallas") reproduce the dense stack
    oracle's per-layer finals — the serving fast path is numerically the
    paper's recurrence."""
    cfg = GRUConfig(input_dim=5, hidden_dim=16, num_layers=depth)
    params = _stack(cfg)
    xs, h0s = _data(cfg, B=2, T=6)
    ref_finals, _ = gru.gru_stack_reference(params, h0s, xs)
    hs = h0s
    for t in range(xs.shape[1]):
        hs = gru.gru_stack_decode_step(params, hs, xs[:, t], cfg=cfg,
                                       impl="pallas")
    for got, want in zip(hs, ref_finals):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
    # and agrees with the layer-by-layer XLA impl on a single step
    a = gru.gru_stack_decode_step(params, h0s, xs[:, 0], cfg=cfg, impl="xla")
    p = gru.gru_stack_decode_step(params, h0s, xs[:, 0], cfg=cfg,
                                  impl="pallas")
    for ai, pi in zip(a, p):
        np.testing.assert_allclose(np.asarray(ai), np.asarray(pi), **TOL)


def test_stack_masked_prefill_matches_unpadded():
    """Left-padding + mask == the unpadded prompt, bitwise (the bucketed
    prefill exactness contract), including ragged per-row lengths."""
    cfg = GRUConfig(input_dim=5, hidden_dim=16, num_layers=3)
    params = _stack(cfg)
    xs, h0s = _data(cfg, B=2, T=5)
    f_un, _ = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg)
    P = 3
    xs_pad = jnp.pad(xs, ((0, 0), (P, 0), (0, 0)))
    mask = jnp.broadcast_to(jnp.arange(5 + P)[None, :] >= P, (2, 5 + P))
    f_pd, _ = gru.gru_stack_sequence(params, h0s, xs_pad, cfg=cfg, mask=mask)
    for a, b in zip(f_un, f_pd):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ragged: row 1 has a shorter prompt, left-aligned into the same batch
    lens = np.array([5, 3])
    xs_r = np.zeros((2, 5, 5), np.float32)
    xs_r[0] = np.asarray(xs[0])
    xs_r[1, 2:] = np.asarray(xs[1, :3])
    mask_r = jnp.asarray(np.arange(5)[None, :] >= (5 - lens)[:, None])
    f_r, _ = gru.gru_stack_sequence(params, h0s, jnp.asarray(xs_r), cfg=cfg,
                                    mask=mask_r)
    f_solo, _ = gru.gru_stack_sequence(params,
                                       tuple(h[1:2] for h in h0s),
                                       xs[1:2, :3], cfg=cfg)
    np.testing.assert_allclose(np.asarray(f_r[-1][1]),
                               np.asarray(f_solo[-1][0]),
                               rtol=1e-6, atol=1e-7)
    # oracle agrees with the masked path
    ref_r, _ = gru.gru_stack_reference(params, h0s, jnp.asarray(xs_r),
                                       mask=mask_r)
    for a, b in zip(f_r, ref_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_depth1_identical_to_single_layer(backend):
    """A depth-1 stack IS the original single-layer path (same ops)."""
    cfg = GRUConfig(input_dim=5, hidden_dim=20, num_layers=1, backend=backend)
    params = _stack(cfg)
    xs, h0s = _data(cfg)
    single, _ = gru.gru_sequence(params[0], h0s[0], xs, cfg=cfg)
    stack, _ = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg)
    np.testing.assert_array_equal(np.asarray(single), np.asarray(stack[0]))


def test_stack_mixed_modes_hetero_dims():
    cfg = GRUConfig(input_dim=5, layer_dims=(16, 8, 12),
                    layer_matvec_modes=("rowwise", "cascade", "dense"))
    params = _stack(cfg)
    xs, h0s = _data(cfg)
    ref_f, _ = gru.gru_stack_reference(params, h0s, xs)
    finals, _ = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg)
    for got, want in zip(finals, ref_f):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_stack_decode_step_matches_sequence():
    """T decode steps through the stack == the sequence path's finals."""
    cfg = GRUConfig(input_dim=4, hidden_dim=12, num_layers=2)
    params = _stack(cfg)
    xs, h0s = _data(cfg, B=1, T=6)
    finals, _ = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg)
    hs = h0s
    for t in range(xs.shape[1]):
        hs = gru.gru_stack_decode_step(params, hs, xs[:, t], cfg=cfg)
    for got, want in zip(hs, finals):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_depth1_layer_dims_override_consistent():
    """A one-element layer_dims must size cell AND head from the override."""
    cfg = GRUConfig(input_dim=5, hidden_dim=20, layer_dims=(24,))
    params = init_params(gru.gru_classifier_specs(cfg), jax.random.key(0))
    assert params["cell"]["u"].shape == (24, 72)
    assert params["head"]["w"].shape == (24, 5)
    xs = jax.random.normal(jax.random.key(1), (2, 7, 5))
    assert gru.gru_classify(params, xs, cfg=cfg).shape == (2, 5)


def test_deep_classifier_shapes_and_grads():
    from repro.configs.gru_jet_deep import CONFIG
    params = init_params(gru.gru_classifier_specs(CONFIG.gru),
                         jax.random.key(0))
    assert "cells" in params and len(params["cells"]) == 3
    xs = jax.random.normal(jax.random.key(1), (4, 20, 5))
    logits = gru.gru_classify(params, xs, cfg=CONFIG.gru)
    assert logits.shape == (4, 5)

    def loss(p):
        return gru.gru_classify(p, xs, cfg=CONFIG.gru).sum()
    g = jax.grad(loss)(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


def test_stack_sharded_all_modes(multidev):
    """Row-wise and cascade stacks on a 4-device mesh match the oracle for
    depths 1..3; mixed per-layer modes too (the collective-reuse path)."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import GRUConfig
from repro.core import gru, rowparallel
from repro.core.params import init_params
mesh = jax.make_mesh((4,), ("model",))
X, B, T = 6, 2, 7
xs = jax.random.normal(jax.random.key(1), (B, T, X))
for L in (1, 2, 3):
    for mode in ("rowwise", "cascade"):
        cfg = GRUConfig(input_dim=X, hidden_dim=16, num_layers=L, matvec_mode=mode)
        params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
        h0s = gru.stack_h0(cfg, B)
        outs = rowparallel.gru_stack_sequence_sharded(params, h0s, xs, mesh=mesh, cfg=cfg)
        ref, _ = gru.gru_stack_reference(params, h0s, xs)
        for a, b in zip(outs, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-6)
# v3 cross-scheme consistency at depth 2
o3 = []
for mode in ("rowwise", "cascade"):
    cfg = GRUConfig(input_dim=X, hidden_dim=16, num_layers=2, matvec_mode=mode, variant="v3")
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    h0s = gru.stack_h0(cfg, B)
    o3.append(rowparallel.gru_stack_sequence_sharded(params, h0s, xs, mesh=mesh, cfg=cfg))
for a, b in zip(*o3):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-6)
# mixed modes, heterogeneous dims
cfg = GRUConfig(input_dim=X, layer_dims=(16, 8, 12),
                layer_matvec_modes=("rowwise", "cascade", "rowwise"))
params = init_params(gru.gru_stack_specs(cfg), jax.random.key(2))
h0s = gru.stack_h0(cfg, B)
outs = rowparallel.gru_stack_sequence_sharded(params, h0s, xs, mesh=mesh, cfg=cfg)
ref, _ = gru.gru_stack_reference(params, h0s, xs)
for a, b in zip(outs, ref):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-6)
print("PASS")
""", timeout=560)


def test_stack_sharded_return_all(multidev):
    """Sharded prefill emits the full last-layer sequence in the SAME pass
    (ROADMAP item): parity vs gru_stack_sequence for both last-layer
    schemes, with unchanged finals."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import GRUConfig
from repro.core import gru, rowparallel
from repro.core.params import init_params
mesh = jax.make_mesh((4,), ("model",))
X, B, T = 6, 2, 7
xs = jax.random.normal(jax.random.key(1), (B, T, X))
for modes in (("rowwise", "rowwise"), ("rowwise", "cascade")):
    cfg = GRUConfig(input_dim=X, hidden_dim=16, num_layers=2,
                    layer_matvec_modes=modes)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    h0s = gru.stack_h0(cfg, B)
    finals, states = rowparallel.gru_stack_sequence_sharded(
        params, h0s, xs, mesh=mesh, cfg=cfg, return_all=True)
    ref_f, ref_all = gru.gru_stack_sequence(params, h0s, xs, cfg=cfg,
                                            return_all=True)
    assert states.shape == (B, T, 16), states.shape
    for a, b in zip(finals, ref_f):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(np.asarray(states), np.asarray(ref_all),
                               rtol=3e-5, atol=3e-6)
    # return_all=False keeps the legacy finals-only contract
    only = rowparallel.gru_stack_sequence_sharded(params, h0s, xs,
                                                  mesh=mesh, cfg=cfg)
    for a, b in zip(only, ref_f):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-5, atol=3e-6)
print("PASS")
""", timeout=560)


def test_stack_sharded_rowwise_has_no_reduce(multidev):
    """Collective reuse, verified in HLO: an all-rowwise DEEP stack still
    aggregates exclusively with gathers — stacking adds no reductions and
    no extra broadcast collectives."""
    multidev("""
import jax, jax.numpy as jnp
from repro.configs.base import GRUConfig
from repro.core import gru, rowparallel
from repro.core.params import init_params
from repro.launch.hloparse import analyze
mesh = jax.make_mesh((4,), ("model",))
X, B, T = 6, 1, 4
xs = jnp.ones((B, T, X))
def hlo(L):
    cfg = GRUConfig(input_dim=X, hidden_dim=16, num_layers=L, matvec_mode="rowwise")
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    h0s = gru.stack_h0(cfg, B)
    f = jax.jit(lambda p, h, x: rowparallel.gru_stack_sequence_sharded(
        p, h, x, mesh=mesh, cfg=cfg))
    return analyze(f.lower(params, h0s, xs).compile().as_text())
a1, a2 = hlo(1), hlo(2)
assert a1.coll_counts.get("all-reduce", 0) == 0, a1.coll_counts
assert a2.coll_counts.get("all-reduce", 0) == 0, a2.coll_counts
# per-layer gather count does not grow at layer boundaries: depth 2 uses
# exactly 2x the gathers of depth 1 (two per step per layer, v1), nothing extra
g1 = a1.coll_counts.get("all-gather", 0)
g2 = a2.coll_counts.get("all-gather", 0)
assert g1 > 0 and g2 <= 2 * g1, (g1, g2)
print("PASS")
""", timeout=560)
