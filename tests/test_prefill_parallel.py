"""§Perf H1 regression tests: parallel prefill must equal the sequential
baseline / teacher-forced forward, and banded SWA must equal flash-SWA."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core.params import init_params
from repro.distributed.sharding import ShardCtx
from repro.models.attention import _banded_attention, _xla_flash

CTX = ShardCtx()


def test_banded_equals_flash_swa():
    B, S, Hq, Hkv, D, W = 2, 64, 4, 2, 16, 16
    q = jax.random.normal(jax.random.key(0), (B, S, Hq, D))
    k = jax.random.normal(jax.random.key(1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.key(2), (B, S, Hkv, D))
    band = _banded_attention(q, k, v, W)
    ref = _xla_flash(q, k, v, causal=True, window=W, chunk=32)
    np.testing.assert_allclose(np.asarray(band), np.asarray(ref),
                               rtol=3e-4, atol=3e-5)


def test_xlstm_parallel_prefill_equals_sequential():
    from repro.models import xlstm
    cfg = get_smoke_config("xlstm-125m").replace(dtype="float32",
                                                 param_dtype="float32")
    params = init_params(xlstm.lm_specs(cfg), jax.random.key(0), "float32")
    toks = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg.vocab_size)
    lg_par, c_par = xlstm.prefill(params, cfg, toks, ctx=CTX)
    lg_seq, c_seq = xlstm.prefill_sequential(params, cfg, toks, ctx=CTX)
    np.testing.assert_allclose(np.asarray(lg_par), np.asarray(lg_seq),
                               rtol=3e-4, atol=3e-4)
    nt = jnp.zeros((2,), jnp.int32)
    l1, _ = xlstm.decode_step(params, cfg, c_par, nt, ctx=CTX)
    l2, _ = xlstm.decode_step(params, cfg, c_seq, nt, ctx=CTX)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=3e-4, atol=3e-4)


def test_hymba_parallel_prefill_matches_teacher_forced():
    """Ground truth is the full forward (the sequential baseline's global
    layers wrap their ring at capacity=S, which the parallel cache fixes)."""
    from repro.models import hymba
    cfg = get_smoke_config("hymba-1.5b").replace(dtype="float32",
                                                 param_dtype="float32")
    params = init_params(hymba.lm_specs(cfg), jax.random.key(0), "float32")
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    nxt = jnp.ones((B, 2), jnp.int32)
    full = hymba.forward(params, cfg, jnp.concatenate([toks, nxt], 1), ctx=CTX)
    lg, cache = hymba.prefill(params, cfg, toks, ctx=CTX)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 1]),
                               rtol=5e-4, atol=5e-4)
    for t in range(2):
        lg, cache = hymba.decode_step(params, cfg, cache, nxt[:, t], ctx=CTX)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S + t]),
                                   rtol=5e-4, atol=5e-4)
