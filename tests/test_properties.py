"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.configs.base import TrainConfig
from repro.models.layers import softmax_xent
from repro.optim import adamw


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.integers(1, 8), st.integers(0, 10_000))
def test_softmax_xent_matches_naive(V, B, seed):
    logits = jax.random.normal(jax.random.key(seed), (B, V)) * 3
    targets = jax.random.randint(jax.random.key(seed + 1), (B,), 0, V)
    got = float(softmax_xent(logits, targets))
    p = jax.nn.softmax(logits, -1)
    want = float(-jnp.log(jnp.take_along_axis(
        p, targets[:, None], axis=-1))[..., 0].mean())
    np.testing.assert_allclose(got, want, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_xent_lower_bounded_by_zero(seed):
    logits = jax.random.normal(jax.random.key(seed), (4, 16)) * 5
    targets = jnp.argmax(logits, -1)   # best case
    assert float(softmax_xent(logits, targets)) >= 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.floats(1e-4, 1e-1))
def test_adamw_zero_grad_only_decays(seed, wd):
    cfg = TrainConfig(learning_rate=1e-2, warmup_steps=0, weight_decay=wd,
                      grad_clip=1e9)
    w0 = jax.random.normal(jax.random.key(seed), (8,))
    p = {"w": w0}
    opt = adamw.init_opt_state(p)
    p2, _, _ = adamw.adamw_update(p, {"w": jnp.zeros(8)}, opt, jnp.array(0), cfg)
    lr = float(adamw.lr_schedule(jnp.array(0), cfg))
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(w0) * (1 - lr * wd), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_clip_idempotent(seed):
    g = {"a": jax.random.normal(jax.random.key(seed), (16,)) * 100}
    c1, _ = adamw.clip_by_global_norm(g, 1.0)
    c2, _ = adamw.clip_by_global_norm(c1, 1.0)
    np.testing.assert_allclose(np.asarray(c1["a"]), np.asarray(c2["a"]),
                               rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(0, 10_000))
def test_int8_quant_error_bounded(n, seed):
    """One int8 quantization step: |err| <= scale/2 elementwise."""
    g = jax.random.normal(jax.random.key(seed), (n,)) * 10 ** (seed % 4 - 2)
    scale = jnp.maximum(jnp.abs(g).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    err = np.abs(np.asarray(g - q.astype(jnp.float32) * scale))
    assert (err <= float(scale) / 2 + 1e-9).all()


@settings(max_examples=12, deadline=None)
@given(st.integers(2, 40), st.integers(1, 10), st.integers(0, 1000))
def test_rowwise_matvec_property(N, K, seed):
    from repro.core.gru import matvec
    x = jax.random.normal(jax.random.key(seed), (3, K))
    w = jax.random.normal(jax.random.key(seed + 1), (K, N))
    ref = np.asarray(x @ w)
    for mode in ("rowwise", "cascade"):
        np.testing.assert_allclose(np.asarray(matvec(x, w, mode)), ref,
                                   rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_checkpoint_roundtrip_property(tmp_seed):
    import tempfile
    from repro.checkpoint.manager import CheckpointManager
    rng = np.random.default_rng(tmp_seed)
    state = {"a": jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32)),
             "n": {"b": jnp.asarray(rng.integers(0, 9, size=(4,)))}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=1)
        mgr.save(state, 1)
        out = mgr.restore(state, step=1)
        for x, y in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
