"""The online autotuner: measured timings -> engine configuration.

Covers the feedback loop's three dimensions (wave size from the measured
batch-latency curve, quantile bucket ladder from observed prompt lengths,
online CostModel recalibration with epoch bumps), the wave-boundary-only
retune invariant (zero mid-wave retraces, jit-count asserted), the
post-retune compile-step exclusion in latency_stats, and the
recalibration safety properties (legal candidate set, pin immunity,
old-epoch cache eviction — property-fuzzed via tests/_hyp).

Everything runs under deterministic clocks: a plain ManualClock measures
dt == 0 (which the tuner must IGNORE), and an auto-advancing subclass
produces nonzero deterministic timings for the recalibration paths. No
sleeps anywhere.
"""
import jax
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs.base import GRUConfig, get_smoke_config
from repro.core import runtime
from repro.core.params import init_params
from repro.distributed.fault_tolerance import ManualClock
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi
from repro.serve.autotune import AutoTuneConfig, AutoTuner
from repro.serve.engine import Request, ServeEngine, bucket_len


def _setup(hidden=12, num_layers=1, backend="xla"):
    cfg = get_smoke_config("gru-jet").replace(
        gru=GRUConfig(input_dim=5, hidden_dim=hidden, num_classes=5,
                      seq_len=20, num_layers=num_layers, backend=backend))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), jax.random.key(0), cfg.param_dtype)
    return cfg, params


def _requests(cfg, lens, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    X = cfg.gru.input_dim
    return [Request(prompt=rng.normal(size=(int(L), X)).astype(np.float32),
                    max_new_tokens=max_new) for L in lens]


class _AutoClock(ManualClock):
    """ManualClock that advances a fixed dt per now() call: step timings
    measured as now() deltas come out nonzero AND deterministic."""

    def __init__(self, dt_s: float = 1e-4):
        super().__init__()
        self._dt_s = dt_s

    def now(self) -> float:
        t = super().now()
        self.advance(self._dt_s)
        return t


def _install_curve(backend, points, *, depth=1, hidden=12, op="decode"):
    """Install a synthetic measured batch-latency curve for one backend
    (callers restore the prior model via try/finally)."""
    entries = [{"family": "gru", "backend": backend, "op": op,
                "depth": depth, "hidden_dim": hidden, "batch": b,
                "p50_us": us} for b, us in points]
    runtime.set_cost_model(runtime.CostModel.from_entries(
        entries, source="<test curve>"))


# ---------------------------------------------------------------------------
# CostModel.merged / batch_points (the runtime half of the loop)
# ---------------------------------------------------------------------------

def test_cost_model_merged_replaces_and_extends():
    base = runtime.CostModel.from_entries([
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 1, "p50_us": 100.0},
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 8, "p50_us": 200.0}])
    out = base.merged([
        # replaces the batch=1 point
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 1, "p50_us": 50.0},
        # extends the curve at a new batch
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 4, "p50_us": 120.0}])
    assert out.batch_points("xla", "decode", depth=1, hidden=12) == \
        [(1, 50.0), (4, 120.0), (8, 200.0)]
    # pure: the base model is untouched
    assert base.lookup("xla", "decode", depth=1, batch=1, hidden=12) == 100.0
    assert base.batch_points("xla", "decode", depth=1, hidden=12) == \
        [(1, 100.0), (8, 200.0)]


def test_cost_model_merged_skips_malformed_rows():
    base = runtime.CostModel.from_entries([
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 2, "p50_us": 10.0}])
    out = base.merged([
        {"backend": "xla"},                                   # missing keys
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 0, "p50_us": 5.0},                          # batch < 1
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 2, "p50_us": 0.0},                          # ManualClock dt
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 2, "p50_us": float("nan")},
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 2, "p50_us": float("inf")},
        {"backend": "xla", "op": "decode", "depth": 1, "hidden_dim": 12,
         "batch": 2, "p50_us": -3.0}])
    # every row was bad: the measured point survives unchanged
    assert out.batch_points("xla", "decode", depth=1, hidden=12) == \
        [(2, 10.0)]


# ---------------------------------------------------------------------------
# dimension 1: wave size from the measured batch-latency curve
# ---------------------------------------------------------------------------

def test_wave_size_follows_marginal_cost_rule():
    cfg, params = _setup()
    snap = runtime.cost_model()
    try:
        # step(1)=10us; adding slots is ~free until B=3, then the curve
        # kinks: marginal cap = 0.5 x 10 = 5us, step(4)-step(3) = 18 > 5
        _install_curve("xla", [(1, 10.0), (2, 11.0), (3, 12.0),
                               (4, 30.0), (8, 100.0)])
        tuner = AutoTuner(AutoTuneConfig(tune_buckets=False,
                                         recalibrate=False,
                                         marginal_frac=0.5, wave_cap=8))
        engine = ServeEngine(cfg, params, ShardCtx(), max_batch=8,
                             clock=ManualClock(), tuner=tuner)
        engine.gru_wave_begin(())        # a wave boundary: retune runs
        assert engine.max_batch == 3
        (d,) = tuner.decisions
        assert d["kind"] == "wave_size" and d["from"] == 8 and d["to"] == 3
        m = d["measurement"]
        assert m["backend"] == "xla" and m["solo_us"] == 10.0
        assert [1, 10.0] in m["curve_us"]
        # idempotent: the same curve produces no second decision
        engine.gru_wave_begin(())
        assert len(tuner.decisions) == 1
    finally:
        runtime.set_cost_model(snap)


def test_wave_size_needs_a_measured_curve():
    """With < 2 measured batch points there is no curve: the operator's
    static wave size stands and no decision is recorded."""
    cfg, params = _setup()
    snap = runtime.cost_model()
    try:
        _install_curve("xla", [(1, 10.0)])
        tuner = AutoTuner(AutoTuneConfig(tune_buckets=False,
                                         recalibrate=False))
        engine = ServeEngine(cfg, params, ShardCtx(), max_batch=4,
                             clock=ManualClock(), tuner=tuner)
        engine.gru_wave_begin(())
        assert engine.max_batch == 4 and tuner.decisions == []
    finally:
        runtime.set_cost_model(snap)


def test_wave_size_respects_step_budget():
    cfg, params = _setup()
    snap = runtime.cost_model()
    try:
        # smooth marginals everywhere, but an absolute per-step deadline
        # of 12us caps the wave at the largest batch under budget
        _install_curve("xla", [(1, 10.0), (2, 11.0), (3, 12.0), (4, 13.0),
                               (8, 17.0)])
        tuner = AutoTuner(AutoTuneConfig(tune_buckets=False,
                                         recalibrate=False, wave_cap=8,
                                         marginal_frac=1.0,
                                         step_budget_us=12.0))
        engine = ServeEngine(cfg, params, ShardCtx(), max_batch=8,
                             clock=ManualClock(), tuner=tuner)
        engine.gru_wave_begin(())
        assert engine.max_batch == 3
    finally:
        runtime.set_cost_model(snap)


# ---------------------------------------------------------------------------
# dimension 2: bucket ladder from the observed prompt-length distribution
# ---------------------------------------------------------------------------

def test_bucket_ladder_from_skewed_prompt_distribution():
    cfg, params = _setup()
    tuner = AutoTuner(AutoTuneConfig(tune_wave_size=False,
                                     recalibrate=False,
                                     ladder_min_prompts=8))
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2,
                         clock=ManualClock(), tuner=tuner)
    # heavily skewed: most prompts are tiny, a few are long — the static
    # pow2 ladder would pad everything short up to 8
    for L in [3] * 51 + [5] * 30 + [9] * 15 + [16] * 5:
        tuner.observe_prompt(L)
    engine.gru_wave_begin(())
    assert engine.bucket_ladder == (3, 5, 9, 16)
    (d,) = tuner.decisions
    assert d["kind"] == "bucket_ladder" and d["to"] == [3, 5, 9, 16]
    assert d["measurement"]["prompts"] == 101
    # the tuned ladder really differs from the static pow2 buckets
    assert engine._bucket_for(3) == 3 != bucket_len(3, engine.bucket_min)
    assert engine._bucket_for(4) == 5
    assert engine._bucket_for(16) == 16
    # beyond the top rung: doubles from it (a bounded jit-key space)
    assert engine._bucket_for(17) == 32
    # too few observations -> no decision
    t2 = AutoTuner(AutoTuneConfig(ladder_min_prompts=8))
    e2 = ServeEngine(cfg, params, ShardCtx(), clock=ManualClock(), tuner=t2)
    for L in (3, 4, 5):
        t2.observe_prompt(L)
    e2.gru_wave_begin(())
    assert e2.bucket_ladder is None and t2.decisions == []


# ---------------------------------------------------------------------------
# dimension 3: online recalibration (epoch bump, no needless retrace)
# ---------------------------------------------------------------------------

def test_recalibration_folds_steps_and_bumps_epoch_without_retrace():
    """Served warm-step timings become fresh CostModel rows (epoch bump);
    when the refreshed table does NOT change the resolved backend, the
    live jits survive untouched (zero retraces)."""
    cfg, params = _setup()                   # backend="xla": pinned family
    snap = runtime.cost_model()
    try:
        tuner = AutoTuner(AutoTuneConfig(tune_wave_size=False,
                                         tune_buckets=False,
                                         recal_min_steps=4))
        engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2,
                             clock=_AutoClock(1e-4), tuner=tuner)
        engine.generate(_requests(cfg, [3, 3], max_new=6))
        epoch0 = runtime.cost_epoch()
        gen0 = engine._jit_gen
        decode_jits0 = dict(engine._decode_jit)
        # the drain boundary inside generate() already ran maybe_retune;
        # warm steps (>= 4 of them at 2 slots x 6 tokens) were folded
        recs = [d for d in tuner.decisions if d["kind"] == "recalibrate"]
        if not recs:                         # fold on the next boundary
            engine.generate(_requests(cfg, [3, 3], max_new=6))
            recs = [d for d in tuner.decisions
                    if d["kind"] == "recalibrate"]
        assert recs, tuner.decisions
        d = recs[0]
        assert d["to"] > d["from"]           # the epoch really bumped
        assert d["rebuilt_jits"] is False    # same resolution: no retrace
        assert engine._jit_gen == gen0
        for k, v in decode_jits0.items():    # the SAME jit objects live on
            assert engine._decode_jit.get(k) is v
        assert runtime.cost_epoch() > epoch0 or d["to"] <= epoch0
        # the folded rows are real measured rows at the served shape
        entries = d["measurement"]["entries"]
        assert entries and all(e["backend"] == "xla" and e["p50_us"] > 0
                               for e in entries)
        assert runtime.cost_model().batch_points(
            "xla", "decode", depth=1, hidden=12)
    finally:
        runtime.set_cost_model(snap)


def test_recalibration_ignores_manualclock_zero_timings():
    """Under a plain ManualClock every measured dt is 0.0 — the tuner
    must never fold 'free' rows into the table."""
    cfg, params = _setup()
    snap = runtime.cost_model()
    try:
        tuner = AutoTuner(AutoTuneConfig(tune_wave_size=False,
                                         tune_buckets=False,
                                         recal_min_steps=1))
        engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2,
                             clock=ManualClock(), tuner=tuner)
        engine.generate(_requests(cfg, [3, 3], max_new=6))
        engine.generate(_requests(cfg, [3, 3], max_new=6))
        assert [d for d in tuner.decisions
                if d["kind"] == "recalibrate"] == []
        assert runtime.cost_model() is snap  # never touched
    finally:
        runtime.set_cost_model(snap)


# ---------------------------------------------------------------------------
# satellite: post-retune compile-step exclusion in latency_stats
# ---------------------------------------------------------------------------

def test_post_retune_prefill_jit_first_call_excluded():
    """A bucket jit created AFTER a retune compiles mid-serve; its first
    call is excluded from prefill percentiles — while first-EVER bucket
    compiles (before any retune) stay included, and the second use of a
    post-retune bucket records normally."""
    cfg, params = _setup()
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2,
                         clock=ManualClock())
    engine.generate(_requests(cfg, [3, 3], max_new=2))
    assert len(engine.prefill_times) == 1    # gen-0 compile: included
    # a ladder retune between waves: prompts of length 3 now land in a
    # NEW bucket (3), whose jit does not exist yet
    engine.apply_bucket_ladder((3, 16))
    engine.generate(_requests(cfg, [3, 3], max_new=2))
    assert len(engine.prefill_times) == 1    # post-retune compile: excluded
    engine.generate(_requests(cfg, [3, 3], max_new=2))
    assert len(engine.prefill_times) == 2    # warm reuse: recorded


def test_post_retune_decode_jit_first_step_excluded_again():
    """After an invalidating retune (e.g. a recalibration that changed a
    resolved backend), the re-created decode jit's first step is a
    compile again and must be excluded — same per-jit rule as its first
    life, even though the key is unchanged."""
    cfg, params = _setup()
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2,
                         clock=ManualClock())
    engine.generate(_requests(cfg, [3, 3], max_new=3))
    n0 = len(engine.step_times)
    assert n0 == 3 - 1                       # first step excluded per key
    engine._invalidate_jits()                # what a backend-change does
    assert engine._decode_jit == {} and engine._decode_warm == set()
    engine.generate(_requests(cfg, [3, 3], max_new=3))
    # the re-created jit recorded one step fewer than it ran
    assert len(engine.step_times) == n0 + 3 - 1
    # prefill side of the same invalidation: bucket 8's jit was dropped
    # too, so its post-retune re-compile is excluded...
    assert len(engine.prefill_times) == 1
    engine.generate(_requests(cfg, [3, 3], max_new=3))
    # ...while its warm reuse records normally again
    assert len(engine.prefill_times) == 2


# ---------------------------------------------------------------------------
# acceptance: the full loop on a skewed workload, boundary-only retuning
# ---------------------------------------------------------------------------

def test_autotuned_engine_acceptance_skewed_workload():
    """End-to-end under deterministic virtual time: an autotuned engine
    on a skewed prompt-length workload ends with a bucket ladder AND wave
    size that differ from the static defaults; every decision carries its
    justifying measurement; streams are bitwise-identical to an untuned
    engine; and no retune ever fires mid-wave (asserted on every mutate)
    nor does any jit silently retrace (jax cache size == 1 per jit)."""
    cfg, params = _setup()
    lens = [3, 3, 3, 5, 3, 3, 5, 9, 3, 5, 3, 16, 3, 5, 3, 3]
    snap = runtime.cost_model()
    try:
        _install_curve("xla", [(1, 10.0), (2, 11.0), (4, 40.0), (8, 90.0)])
        # recalibration off: the auto-advancing clock's synthetic step
        # timings would overwrite the installed curve mid-test and make
        # the expected wave size depend on fold timing; the recal
        # dimension has its own end-to-end tests above
        tuner = AutoTuner(AutoTuneConfig(ladder_min_prompts=8,
                                         recalibrate=False,
                                         marginal_frac=0.5, wave_cap=8))
        engine = ServeEngine(cfg, params, ShardCtx(), max_batch=4,
                             clock=_AutoClock(1e-4), tuner=tuner)

        # spy: every tuner-driven mutation must happen at a wave boundary
        boundary_violations = []
        real_retune = tuner.maybe_retune

        def guarded(eng):
            if eng._wave is not None and eng.gru_wave_active() > 0:
                boundary_violations.append(eng.gru_wave_active())
            return real_retune(eng)

        tuner.maybe_retune = guarded
        outs_tuned = []
        for i in range(0, len(lens), 4):
            reqs = _requests(cfg, lens[i:i + 4], seed=i, max_new=4)
            engine.generate(reqs)
            outs_tuned.extend(r.out for r in reqs)

        assert boundary_violations == []
        # tuned shape differs from the static defaults on BOTH dimensions
        assert engine.max_batch == 2 != 4          # curve kinks after B=2
        assert engine.bucket_ladder is not None
        assert set(engine.bucket_ladder) != {
            bucket_len(L, 8) for L in lens}        # not the pow2 ladder
        stats = engine.latency_stats()
        at = stats["autotune"]
        assert at["enabled"] and at["wave_size"] == 2
        assert at["bucket_ladder"] == list(engine.bucket_ladder)
        kinds = {d["kind"] for d in at["decisions"]}
        assert {"wave_size", "bucket_ladder"} <= kinds
        for d in at["decisions"]:                  # measurement-justified
            assert d["measurement"] and "rule" in d["measurement"]
            assert "from" in d and "to" in d and d["t"] >= 0.0
        # no silent retraces: every live jit traced exactly one shape
        for jit_fn in (list(engine._decode_jit.values())
                       + list(engine._prefill_jit.values())):
            cache_size = getattr(jit_fn, "_cache_size", None)
            if cache_size is not None:
                assert cache_size() == 1
        # stream parity vs an untuned engine on the identical workload
        untuned = ServeEngine(cfg, params, ShardCtx(), max_batch=4,
                              clock=_AutoClock(1e-4))
        outs_ref = []
        for i in range(0, len(lens), 4):
            reqs = _requests(cfg, lens[i:i + 4], seed=i, max_new=4)
            untuned.generate(reqs)
            outs_ref.extend(r.out for r in reqs)
        assert outs_tuned == outs_ref
    finally:
        runtime.set_cost_model(snap)


def test_untuned_engine_reports_autotune_disabled():
    cfg, params = _setup()
    engine = ServeEngine(cfg, params, ShardCtx(), max_batch=2)
    engine.generate(_requests(cfg, [3], max_new=2))
    at = engine.latency_stats()["autotune"]
    assert at == {"enabled": False, "wave_size": 2, "bucket_ladder": None}


# ---------------------------------------------------------------------------
# satellite: recalibration safety properties (via tests/_hyp)
# ---------------------------------------------------------------------------

_BACKENDS = ["xla", "pallas_fused", "pallas_chain", "bogus_backend",
             "sharded_decode", "pallas_fused_q8"]


def _legal_decode_set(cfg):
    """The legal candidate set for a host decode call of this config —
    computed from the registry the same way compile() filters."""
    from repro.core.runtime import _REGISTRY, _legal
    return {name for (fam, name), s in _REGISTRY.items()
            if fam == "gru" and _legal(s, op="decode", masked=False,
                                       hetero=False, mesh=None, cfg=cfg)}


@settings(max_examples=25, deadline=None, derandomize=True)
@given(entries=st.lists(st.fixed_dictionaries({
    "backend": st.sampled_from(_BACKENDS),
    "op": st.sampled_from(["decode", "sequence"]),
    "depth": st.integers(min_value=1, max_value=2),
    "hidden_dim": st.sampled_from([12, 32]),
    "batch": st.integers(min_value=-2, max_value=16),
    "p50_us": st.floats(min_value=-1e6, max_value=1e6,
                        allow_nan=True, allow_infinity=True, width=32),
}), max_size=12))
def test_prop_recalibration_never_escapes_legal_set(entries):
    """Folding ARBITRARY served-timing entries into the CostModel — junk
    backends, absurd batches, nan/inf/negative latencies — (1) never
    makes auto-dispatch select outside the legal candidate set, (2) never
    overrides an exact backend-name pin, (3) leaves older epochs
    unreachable in the executable cache."""
    snap = runtime.cost_model()
    auto_cfg = GRUConfig(input_dim=5, hidden_dim=12, num_layers=1,
                         backend="auto")
    pin_cfg = GRUConfig(input_dim=5, hidden_dim=12, num_layers=1,
                        backend="pallas_chain")
    try:
        merged = runtime.cost_model().merged(entries, source="<prop>")
        runtime.set_cost_model(merged)
        assert runtime._EXEC_CACHE == {}     # the bump evicted everything
        epoch = runtime.cost_epoch()
        exe = runtime.compile(auto_cfg, batch=2, mode="decode")
        assert exe.decode_backend in _legal_decode_set(auto_cfg)
        assert exe.decode_backend != "bogus_backend"
        # quant gate closed (conftest): q8 must not be selectable by cost
        assert not exe.decode_backend.endswith("_q8")
        # exact-name pins bypass cost selection entirely
        pin = runtime.compile(pin_cfg, batch=2, mode="decode")
        assert pin.decode_backend == "pallas_chain"
        # every cached executable belongs to the CURRENT epoch
        assert runtime._EXEC_CACHE
        assert all(k[-1] == epoch for k in runtime._EXEC_CACHE)
    finally:
        runtime.set_cost_model(snap)


def test_recalibration_epoch_evicts_stale_executables():
    """The non-fuzzed core of the property: an executable compiled under
    epoch N is unreachable after a fold installs epoch N+1 — compile()
    returns a FRESH object keyed to the new epoch."""
    snap = runtime.cost_model()
    cfg = GRUConfig(input_dim=5, hidden_dim=12, num_layers=1,
                    backend="auto")
    try:
        exe_old = runtime.compile(cfg, batch=1, mode="decode")
        runtime.set_cost_model(runtime.cost_model().merged(
            [{"backend": "xla", "op": "decode", "depth": 1,
              "hidden_dim": 12, "batch": 1, "p50_us": 7.0}]))
        assert exe_old not in runtime._EXEC_CACHE.values()
        exe_new = runtime.compile(cfg, batch=1, mode="decode")
        assert exe_new is not exe_old
        assert runtime.compile(cfg, batch=1, mode="decode") is exe_new
    finally:
        runtime.set_cost_model(snap)
