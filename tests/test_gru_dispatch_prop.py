"""Property-based fuzz of the recurrent executor's dispatch matrix.

Random draws over the FULL request space — CELL FAMILY (gru/slstm: the
``(family, backend)`` registry namespaces), depth 1-4, uniform/hetero
``layer_dims``, rowwise/cascade mode mixes, mask on/off, mesh/none,
backend pin vs auto, prefill vs decode — must always:

* resolve (``compile()`` never raises: ``xla`` is universally legal, so
  an illegal preference falls through instead of erroring),
* resolve LEGALLY (the chosen backend's declared ``Capabilities`` cover
  the request — the silent-capability-gap failure mode the executor
  exists to eliminate),
* run correctly (``allclose`` vs the family's registered reference — the
  oracle is drawn with the family, never hardcoded to GRU), and
* honor the bitwise mask contract wherever the executable CLAIMS
  ``mask_exact`` (padded+masked == unpadded at identical batch shapes).

Runs under the optional-``hypothesis`` shim (``tests/_hyp.py``): with
hypothesis installed (CI) the draws are derandomized — a fixed seed
profile, so CI is deterministic; without it the property tests skip and
the pinned ``test_dispatch_case_pinned`` corners still run.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from _q8 import q8_stack_decode, q8_stack_finals
from repro.configs.base import GRUConfig
from repro.core import cells, gru, runtime
from repro.core.params import init_params

TOL = dict(rtol=3e-5, atol=3e-6)
DEC_TOL = dict(rtol=1e-4, atol=1e-5)
# q8 draws compare against the quantize-dequantize twin oracle, which
# accumulates the kernels' int32 sums exactly at these sizes — so the
# q8 tolerance is TIGHTER than the f32 one, not looser.
Q8_TOL = dict(rtol=1e-6, atol=1e-6)
B, T, X, PAD = 2, 5, 5, 3
DIM_POOL = (8, 12, 16)
BACKENDS = ("auto", "xla", "pallas", "pallas_fused", "pallas_chain",
            "sharded", "pallas_sharded", "sharded_decode",
            "pallas_fused_q8", "pallas_chain_q8")
# per-family backend pools: the sLSTM namespace registers xla +
# pallas_fused; pins on GRU-only names still belong in its pool — they
# must FALL THROUGH to a legal (slstm, ·) backend, never resolve across
# the family boundary or error
FAMILY_BACKENDS = {
    "gru": BACKENDS,
    "slstm": ("auto", "xla", "pallas", "pallas_fused", "pallas_chain",
              "pallas_fused_q8"),
}


@functools.lru_cache(maxsize=None)
def _mesh_placement():
    """One shared single-device mesh: a stable Placement so executables
    memoize across examples (multi-device dispatch runs in the multidev
    suites; the capability/dispatch logic is device-count-agnostic)."""
    from jax.sharding import Mesh
    return runtime.Placement(mesh=Mesh(np.array(jax.devices()[:1]),
                                       ("model",)))


@functools.lru_cache(maxsize=None)
def _case_params(dims: tuple, modes: tuple, backend: str,
                 family: str = "gru"):
    cfg = GRUConfig(input_dim=X, layer_dims=dims, backend=backend,
                    layer_matvec_modes=modes, family=family)
    if family == "gru":
        specs = gru.gru_stack_specs(cfg)
    else:
        specs = {"cells": cells.get_family(family).stack_specs(cfg)}
    params = init_params(specs, jax.random.key(0))
    return cfg, params


@functools.lru_cache(maxsize=None)
def _data():
    xs = jax.random.normal(jax.random.key(1), (B, T, X))
    xs_pad = jnp.pad(xs, ((0, 0), (PAD, 0), (0, 0)))
    mask = jnp.broadcast_to(jnp.arange(T + PAD)[None, :] >= PAD,
                            (B, T + PAD))
    return xs, xs_pad, mask


def _assert_capabilities_cover(backend_name: str, *, op: str, masked: bool,
                               hetero: bool, mesh,
                               family: str = "gru") -> None:
    """The dispatch contract: the resolved backend's declared caps cover
    the request — looked up in the FAMILY's registry namespace (a name
    resolving outside it would be the cross-family dispatch bug)."""
    spec = runtime.backends(family)[backend_name]
    c = spec.caps
    if op == "decode":
        assert c.decode and spec.decode_fn is not None, backend_name
    else:
        assert c.sequence and spec.sequence_fn is not None, backend_name
        assert not masked or c.supports_mask, backend_name
    assert not hetero or c.supports_hetero_dims, backend_name
    # a mesh-REQUIRING backend must never resolve without a mesh
    assert not (c.supports_mesh and mesh is None), backend_name


def check_dispatch_case(depth: int, dims: tuple, modes: tuple, masked: bool,
                        mesh_on: bool, backend: str, mode: str,
                        family: str = "gru") -> None:
    """One cell of the dispatch matrix, end to end."""
    assert len(dims) == len(modes) == depth
    fam = cells.get_family(family)
    cfg, params = _case_params(dims, modes, backend, family)
    xs, xs_pad, mask = _data()
    h0s = fam.state0(cfg, B)
    cell_p = fam.normalize(params, cfg)
    hetero = any(d != dims[0] for d in dims)
    placement = _mesh_placement() if mesh_on else None
    mesh = placement.mesh if mesh_on else None
    ref, _ = fam.reference(cell_p, h0s, xs)

    # 1. always resolves, and resolves legally
    p = runtime.compile(cfg, batch=B, seq=T + PAD if masked else T,
                        placement=placement, mask=masked, mode=mode)
    if mode == "decode":
        assert p.decode_backend is not None
        _assert_capabilities_cover(p.decode_backend, op="decode",
                                   masked=False, hetero=hetero, mesh=mesh,
                                   family=family)
        tol = DEC_TOL
        if p.decode_backend.endswith("_q8"):
            # a q8 pin resolved to the int8 datapath: its oracle is the
            # backend's own quantize-dequantize twin, not the f32 stack
            ref = h0s
            for t in range(T):
                ref = q8_stack_decode(p.decode_backend, cell_p, ref,
                                      xs[:, t], cfg)
            tol = Q8_TOL
        hs = h0s
        for t in range(T):
            hs = p.decode(params, hs, xs[:, t])
        for a, b in zip(hs, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
        return
    assert p.sequence_backend is not None
    _assert_capabilities_cover(p.sequence_backend, op="sequence",
                               masked=masked, hetero=hetero, mesh=mesh,
                               family=family)
    tol = TOL
    if p.sequence_backend.endswith("_q8"):
        ref = q8_stack_finals(p.sequence_backend, cell_p, h0s, xs, cfg)
        tol = Q8_TOL

    # 2. runs correctly against the dense oracle
    if not masked:
        finals, _ = p.sequence(params, h0s, xs)
    else:
        finals, _ = p.sequence(params, h0s, xs_pad, mask=mask)
        if p.mask_exact:
            # 3. the claimed bitwise mask contract, held to bitwise
            un = runtime.compile(cfg, batch=B, seq=T, placement=placement,
                                 mode=mode)
            f_un, _ = un.sequence(params, h0s, xs)
            for a, b in zip(f_un, finals):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(finals, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)


# ---------------------------------------------------------------------------
# the property: random draws over the whole request space
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.data())
def test_dispatch_matrix_property(data):
    """Any (family, depth, dims, modes, mask, mesh, backend, mode) draw
    resolves legally (Capabilities coverage inside the family's registry
    namespace) and matches the family's reference oracle (bitwise where
    mask-exactness is claimed). ``derandomize=True`` pins the example
    sequence — the CI run is deterministic."""
    family = data.draw(st.sampled_from(sorted(FAMILY_BACKENDS)),
                       label="family")
    depth = data.draw(st.integers(min_value=1, max_value=4), label="depth")
    uniform = data.draw(st.booleans(), label="uniform")
    if uniform:
        h = data.draw(st.sampled_from(DIM_POOL), label="hidden")
        dims = (h,) * depth
    else:
        dims = tuple(data.draw(
            st.lists(st.sampled_from(DIM_POOL), min_size=depth,
                     max_size=depth), label="dims"))
    modes = tuple(data.draw(
        st.lists(st.sampled_from(("rowwise", "cascade")), min_size=depth,
                 max_size=depth), label="modes"))
    masked = data.draw(st.booleans(), label="masked")
    mesh_on = data.draw(st.booleans(), label="mesh")
    backend = data.draw(st.sampled_from(FAMILY_BACKENDS[family]),
                        label="backend")
    mode = data.draw(st.sampled_from(("prefill", "decode")), label="mode")
    check_dispatch_case(depth, dims, modes, masked, mesh_on, backend, mode,
                        family)


# ---------------------------------------------------------------------------
# pinned corners: run even without hypothesis (the shim skips the property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth,dims,modes,masked,mesh_on,backend,mode", [
    # the new backend family, pinned by exact name, with and without mesh
    (2, (16, 16), ("rowwise", "cascade"), False, True, "pallas_sharded",
     "prefill"),
    (2, (16, 8), ("cascade", "rowwise"), True, True, "pallas_sharded",
     "prefill"),
    (3, (16, 8, 12), ("rowwise", "cascade", "rowwise"), False, True,
     "pallas_sharded", "decode"),
    (1, (16,), ("rowwise",), False, False, "pallas_sharded", "prefill"),
    # mesh-requiring pins without a mesh fall through, never error
    (2, (12, 12), ("cascade", "cascade"), True, False, "sharded", "prefill"),
    (2, (12, 12), ("rowwise", "rowwise"), False, False, "sharded_decode",
     "decode"),
    # hetero + pallas family falls to the chain; depth-4 uniform + mesh
    (3, (16, 8, 12), ("rowwise", "rowwise", "cascade"), True, False,
     "pallas", "prefill"),
    (4, (8, 8, 8, 8), ("rowwise", "cascade", "rowwise", "cascade"), True,
     True, "auto", "prefill"),
    (4, (8, 12, 16, 8), ("cascade",) * 4, False, True, "auto", "decode"),
    # q8 exact-name pins (bypass the accuracy gate): uniform fused —
    # plain, masked prefill (bitwise contract), decode; hetero chain
    (2, (12, 12), ("rowwise", "rowwise"), False, False, "pallas_fused_q8",
     "prefill"),
    (2, (12, 12), ("rowwise", "rowwise"), True, False, "pallas_fused_q8",
     "prefill"),
    (1, (16,), ("rowwise",), False, False, "pallas_fused_q8", "decode"),
    (2, (16, 8), ("rowwise", "rowwise"), False, False, "pallas_chain_q8",
     "decode"),
    # a fused_q8 pin on a hetero stack is illegal for the pinned backend:
    # it must fall through to a legal f32 backend, never error
    (2, (16, 8), ("rowwise", "rowwise"), False, False, "pallas_fused_q8",
     "prefill"),
])
def test_dispatch_case_pinned(depth, dims, modes, masked, mesh_on, backend,
                              mode):
    check_dispatch_case(depth, dims, modes, masked, mesh_on, backend, mode)


@pytest.mark.parametrize("depth,dims,modes,masked,mesh_on,backend,mode", [
    # the second family's fused kernel: plain, masked-bitwise, decode
    (1, (16,), ("rowwise",), False, False, "pallas_fused", "prefill"),
    (2, (16, 16), ("rowwise", "rowwise"), True, False, "pallas_fused",
     "prefill"),
    (3, (8, 8, 8), ("rowwise",) * 3, False, False, "pallas", "decode"),
    # hetero dims: fused is illegal in the slstm namespace too -> xla
    (2, (16, 8), ("rowwise", "rowwise"), True, False, "auto", "prefill"),
    # GRU-only names pinned under slstm fall through inside the family
    # namespace (never resolve a (gru, ·) backend, never error)
    (2, (16, 16), ("rowwise", "rowwise"), False, False, "pallas_chain",
     "decode"),
    (1, (16,), ("rowwise",), False, False, "pallas_fused_q8", "prefill"),
    # a mesh without any (slstm, ·) mesh backend resolves replicated
    (1, (16,), ("rowwise",), False, True, "auto", "prefill"),
])
def test_dispatch_case_pinned_slstm(depth, dims, modes, masked, mesh_on,
                                    backend, mode):
    check_dispatch_case(depth, dims, modes, masked, mesh_on, backend, mode,
                        family="slstm")
