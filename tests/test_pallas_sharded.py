"""The combined-axes backend (``pallas_sharded``): the fused Pallas shard
kernels running INSIDE the shard_map, stitched by the row-parallel /
cascade collectives.

Acceptance contract (ISSUE 5):

* ``pallas_sharded`` is a selectable ``supports_mesh`` candidate for
  sequence AND decode, statically preferred over ``sharded`` for sequence
  work and pinnable via ``cfg.backend="pallas_sharded"``.
* At identical shard shapes it is BITWISE-equal to the XLA shard bodies
  (``sharded`` for sequences — finals, ``return_all`` states and masked
  runs alike — and ``sharded_decode`` for decode steps).
* Its traced execute calls against prepared params contain no
  ``device_put`` of weight arrays (jaxpr inspection), like every other
  placement-resident backend.

All backends under comparison run interleaved in ONE subprocess (the
repo's benchmarking/bitwise-comparison convention: same process, same
shapes).
"""


def test_pallas_sharded_mesh_parity(multidev):
    multidev("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.params import init_params

mesh = jax.make_mesh((2,), ("model",))
placement = runtime.Placement(mesh=mesh)
X, B, T, P = 6, 2, 7, 3
xs = jax.random.normal(jax.random.key(1), (B, T, X))
xs_pad = jnp.pad(xs, ((0, 0), (P, 0), (0, 0)))
mask = jnp.broadcast_to(jnp.arange(T + P)[None, :] >= P, (B, T + P))

CASES = [((16, 16), ("rowwise", "cascade"), "v1"),
         ((16, 8), ("cascade", "rowwise"), "v1"),   # hetero dims
         ((16, 16), ("rowwise", "cascade"), "v3"),  # fused-U gate variant
         ((16,), ("rowwise",), "v1")]               # depth 1
for dims, modes, variant in CASES:
    cfg = GRUConfig(input_dim=X, layer_dims=dims, backend="auto",
                    layer_matvec_modes=modes, variant=variant)
    params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
    h0s = gru.stack_h0(cfg, B)
    uniform = all(d == dims[0] for d in dims)

    # auto under a mesh: the kernel-fused shard_map wins sequence work
    p = runtime.compile(cfg, batch=B, seq=T, placement=placement,
                        mode="prefill")
    assert p.sequence_backend == "pallas_sharded", p.sequence_backend
    assert p.mask_exact
    sp = p.prepare(params)
    finals, _ = p.sequence(sp, h0s, xs)
    fa, states = p.sequence(sp, h0s, xs, return_all=True)

    # bitwise vs the XLA shard bodies at the same shard shapes
    scfg = dataclasses.replace(cfg, backend="sharded")
    ps = runtime.compile(scfg, batch=B, seq=T, placement=placement,
                         mode="prefill")
    assert ps.sequence_backend == "sharded", ps.sequence_backend
    sps = ps.prepare(params)
    fs, _ = ps.sequence(sps, h0s, xs)
    _, states_s = ps.sequence(sps, h0s, xs, return_all=True)
    for a, b in zip(finals, fs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(states), np.asarray(states_s))

    # masked+padded == unpadded, bitwise (the mask_exact claim)
    pm = runtime.compile(cfg, batch=B, seq=T + P, placement=placement,
                         mask=True, mode="prefill")
    assert pm.sequence_backend == "pallas_sharded"
    fm, _ = pm.sequence(sp, h0s, xs_pad, mask=mask)
    for a, b in zip(finals, fm):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # v1 cases also match the dense oracle
    if variant == "v1":
        ref, _ = gru.gru_stack_reference(params, h0s, xs)
        for a, b in zip(finals, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-5, atol=3e-6)

    # replicated fused kernel (uniform dims): same numbers to fp tolerance
    if uniform:
        fcfg = dataclasses.replace(cfg, backend="pallas_fused")
        pf = runtime.compile(fcfg, batch=B, seq=T, mode="prefill")
        assert pf.sequence_backend == "pallas_fused"
        ff, _ = pf.sequence(pf.prepare(params), h0s, xs)
        for a, b in zip(finals, ff):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-5, atol=3e-6)

    # decode: auto stays replicated; the exact name pins the kernel-fused
    # shard step, bitwise-equal to sharded_decode at the same shapes
    pd_auto = runtime.compile(cfg, batch=B, placement=placement,
                              mode="decode")
    assert pd_auto.decode_backend in ("xla", "pallas_fused", "pallas_chain")
    dcfg = dataclasses.replace(cfg, backend="pallas_sharded")
    pd = runtime.compile(dcfg, batch=B, placement=placement, mode="decode")
    assert pd.decode_backend == "pallas_sharded", pd.decode_backend
    spd = pd.prepare(params)
    sd_spec = runtime.backends()["sharded_decode"]
    # jit both steps, as serving does: identical compilation contexts are
    # the bitwise contract (eager per-op dispatch may fuse differently)
    dec_p = jax.jit(lambda p, h, x: pd.decode(p, h, x))
    dec_s = jax.jit(lambda p, h, x: sd_spec.decode_fn(
        p, h, x, cfg=cfg, placement=placement))
    hs_p, hs_s = tuple(h0s), tuple(h0s)
    for t in range(T):
        hs_p = dec_p(spd, hs_p, xs[:, t])
        hs_s = dec_s(sps, hs_s, xs[:, t])
    for a, b in zip(hs_p, hs_s):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ... and the sequence pin serves the same executable family
    psq = runtime.compile(dcfg, batch=B, seq=T, placement=placement,
                          mode="prefill")
    assert psq.sequence_backend == "pallas_sharded"
print("PASS")
""", n_devices=2, timeout=560)


def test_pallas_sharded_placement_resident(multidev):
    """Acceptance: no weight ``device_put`` inside the traced
    ``pallas_sharded`` sequence or decode call against prepared params
    (the jaxpr assertion PR 4 established for the XLA shard bodies); raw
    params still trace their placement per call."""
    multidev("""
import dataclasses
import jax, numpy as np
from repro.configs.base import GRUConfig
from repro.core import gru, runtime
from repro.core.params import init_params

def prim_names(fn, *args):
    names = set()
    def walk(j):
        for e in j.eqns:
            names.add(e.primitive.name)
            for v in e.params.values():
                if hasattr(v, "jaxpr"):     # ClosedJaxpr
                    walk(v.jaxpr)
                elif hasattr(v, "eqns"):    # raw Jaxpr (shard_map body)
                    walk(v)
    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return names

mesh = jax.make_mesh((2,), ("model",))
placement = runtime.Placement(mesh=mesh)
cfg = GRUConfig(input_dim=6, layer_dims=(16, 16),
                backend="pallas_sharded",
                layer_matvec_modes=("rowwise", "cascade"))
params = init_params(gru.gru_stack_specs(cfg), jax.random.key(0))
xs = jax.random.normal(jax.random.key(1), (2, 7, 6))
h0s = gru.stack_h0(cfg, 2)
exe = runtime.compile(cfg, batch=2, seq=7, placement=placement,
                      mode="serve")
assert exe.sequence_backend == "pallas_sharded"
assert exe.decode_backend == "pallas_sharded"
sp = exe.prepare(params)
assert sp.placed is not None
n_seq = prim_names(lambda p, h, x: exe.sequence(p, h, x), sp, h0s, xs)
n_dec = prim_names(lambda p, h, x: exe.decode(p, h, x), sp, h0s, xs[:, 0])
assert "device_put" not in n_seq, sorted(n_seq)
assert "device_put" not in n_dec, sorted(n_dec)
# the kernels actually appear in the traced program
assert "pallas_call" in n_seq and "pallas_call" in n_dec
n_raw = prim_names(lambda p, h, x: exe.sequence(p, h, x), params, h0s, xs)
assert "device_put" in n_raw
print("PASS")
""", n_devices=2, timeout=560)
