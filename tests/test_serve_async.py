"""The asyncio front-end, exercised deterministically.

Every test drives the same ManualClock'd FleetRouter as the synchronous
fleet suite — ``asyncio.run`` hosts the event loop, but no wall-clock
timing leaks in: under a ManualClock the scheduler task ticks
back-to-back with ``asyncio.sleep(0)`` yields only (zero sleeps, tier-1
safe), so every interleaving of N client coroutines is reproducible.

Covers the tentpole contracts: concurrent clients' token streams are
bitwise-identical to the synchronous path, a mid-stream client
disconnect propagates to ``FleetRouter.cancel`` (queue entry, wave lane,
hedges) without stalling other clients, queue-full admission becomes
async backpressure, and the deterministic kill/restore fault matrix
completes under the async loop with zero drops.
"""
import asyncio

import numpy as np
import pytest

from repro.configs.base import GRUConfig, get_smoke_config
from repro.core.params import init_params
from repro.distributed.fault_tolerance import ManualClock
from repro.distributed.sharding import ShardCtx
from repro.models import api as mapi
from repro.serve.async_frontend import AsyncFleetClient, run_clients
from repro.serve.engine import Request, ServeEngine
from repro.serve.fleet import (FaultEvent, FaultInjector, FleetConfig,
                               FleetRejected, FleetRouter)


def _setup(hidden=12, num_layers=1):
    cfg = get_smoke_config("gru-jet").replace(
        gru=GRUConfig(input_dim=5, hidden_dim=hidden, num_classes=5,
                      seq_len=20, num_layers=num_layers))
    A = mapi.get_api(cfg)
    params = init_params(A.specs(cfg), __import__("jax").random.key(0),
                         cfg.param_dtype)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=4):
    rng = np.random.default_rng(seed)
    X = cfg.gru.input_dim
    return [Request(prompt=rng.normal(size=(3 + i % 4, X))
                    .astype(np.float32), max_new_tokens=max_new)
            for i in range(n)]


def _fleet(cfg, params, *, replicas=2, injector=None, config=None,
           max_batch=2):
    return FleetRouter(cfg, params, replicas=replicas, max_batch=max_batch,
                       clock=ManualClock(),
                       config=config or FleetConfig(
                           heartbeat_timeout_s=0.05, backoff_base_s=0.02,
                           tick_s=0.01),
                       injector=injector)


def _reference_outs(cfg, params, requests):
    """Fault-free single-engine oracle for the same prompts."""
    solo = ServeEngine(cfg, params, ShardCtx(), max_batch=1)
    outs = []
    for r in requests:
        ref = Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                      eos_id=r.eos_id, stream=r.stream)
        solo.generate([ref])
        outs.append(ref.out)
    return outs


# ---------------------------------------------------------------------------
# bitwise parity: N concurrent client coroutines vs the synchronous path
# ---------------------------------------------------------------------------

def test_async_streams_bitwise_match_sync_path():
    """8 concurrent client coroutines each stream their tokens through
    ``async for``; every stream must be bitwise-equal to the synchronous
    fleet path AND the fault-free single-engine oracle — admission
    interleaving must not leak into greedy decode."""
    cfg, params = _setup()
    reqs = _requests(cfg, 8, seed=50, max_new=6)
    streamed = {}

    async def client_coro(client, i, req):
        handle = await client.submit(req)
        toks = []
        async for tok in handle:
            toks.append(tok)
        streamed[i] = toks

    async def main():
        router = _fleet(cfg, params)
        async with AsyncFleetClient(router) as client:
            await asyncio.gather(*(client_coro(client, i, r)
                                   for i, r in enumerate(reqs)))
        return router

    router = asyncio.run(main())
    # same prompts through the synchronous one-call surface
    sync_reqs = _requests(cfg, 8, seed=50, max_new=6)
    _fleet(cfg, params).generate(sync_reqs)
    oracle = _reference_outs(cfg, params, reqs)
    for i, r in enumerate(reqs):
        assert r.done
        assert streamed[i] == r.out          # stream == final result
        assert r.out == sync_reqs[i].out     # async == sync path
        assert r.out == oracle[i]            # == fault-free oracle
    s = router.stats()
    assert s["completed"] == s["submitted"] == 8
    assert s["failed"] == 0 and s["cancelled"] == 0


def test_async_stream_yields_tokens_mid_flight():
    """Per-token streaming is real streaming: tokens arrive while the
    ticket is still in flight, not in one burst at completion."""
    cfg, params = _setup()
    req = _requests(cfg, 1, seed=51, max_new=16)[0]
    statuses = []

    async def main():
        router = _fleet(cfg, params)
        async with AsyncFleetClient(router) as client:
            handle = await client.submit(req)
            async for _ in handle:
                statuses.append(handle.status)
        return router

    asyncio.run(main())
    assert req.done and len(req.out) == len(statuses)
    assert "inflight" in statuses            # tokens seen mid-decode
    assert statuses[-1] == "done"


# ---------------------------------------------------------------------------
# client disconnect -> FleetRouter.cancel propagation
# ---------------------------------------------------------------------------

def test_async_disconnect_cancels_without_stalling_others():
    """Cancelling a consuming task mid-stream propagates into
    ``FleetRouter.cancel``: the ticket's wave lane frees, ``cancelled``
    counts it, its request never completes — and the other concurrent
    clients finish with oracle-equal streams."""
    cfg, params = _setup()
    reqs = _requests(cfg, 4, seed=52, max_new=10)

    async def consumer(client, req, first_token):
        handle = await client.submit(req)
        async for _ in handle:
            first_token.set()
        return handle

    async def main():
        router = _fleet(cfg, params)
        async with AsyncFleetClient(router) as client:
            first_token = asyncio.Event()
            victim = asyncio.create_task(consumer(client, reqs[0],
                                                  first_token))
            others = [asyncio.create_task(client.generate(r))
                      for r in reqs[1:]]
            await first_token.wait()         # victim is mid-stream
            victim.cancel()
            res = await asyncio.gather(victim, *others,
                                       return_exceptions=True)
            assert isinstance(res[0], asyncio.CancelledError)
        return router

    router = asyncio.run(main())
    s = router.stats()
    assert s["cancelled"] == 1
    assert s["completed"] == 3 and s["failed"] == 0
    t = router.tickets[0]
    assert t.status == "cancelled" and t.reason == "client_disconnect"
    assert t.flights == []                   # lane freed, nothing racing
    assert not reqs[0].done
    survivors = reqs[1:]
    assert all(r.done for r in survivors)
    assert [r.out for r in survivors] == _reference_outs(cfg, params,
                                                         survivors)


def test_async_explicit_cancel_ends_stream():
    """client.cancel(handle) is the programmatic disconnect: the stream
    ends early (status says why) instead of raising into the consumer."""
    cfg, params = _setup()
    req = _requests(cfg, 1, seed=53, max_new=32)[0]

    async def main():
        router = _fleet(cfg, params)
        async with AsyncFleetClient(router) as client:
            handle = await client.submit(req)
            toks = []
            async for tok in handle:
                toks.append(tok)
                if len(toks) == 2:
                    assert await client.cancel(handle) is True
            assert handle.status == "cancelled"
            assert len(toks) < req.max_new_tokens
        return router

    router = asyncio.run(main())
    assert router.stats()["cancelled"] == 1 and not req.done


def test_async_cancel_during_admission_leaves_no_ghost():
    """A client task cancelled while submit() is still on the executor
    must not leave a ghost request serving with no consumer: whichever
    side of the admission race the cancel lands on, the ticket ends
    cancelled and the fleet keeps serving everyone else."""
    cfg, params = _setup()
    reqs = _requests(cfg, 2, seed=57, max_new=6)

    async def main():
        router = _fleet(cfg, params)
        async with AsyncFleetClient(router) as client:
            task = asyncio.create_task(client.generate(reqs[0]))
            await asyncio.sleep(0)           # task is inside submit()
            task.cancel()
            res = await asyncio.gather(task, return_exceptions=True)
            assert isinstance(res[0], asyncio.CancelledError)
            await client.generate(reqs[1])   # fleet unaffected
        return router

    router = asyncio.run(main())
    s = router.stats()
    assert s["cancelled"] == 1 and not reqs[0].done
    assert s["completed"] == 1 and reqs[1].done


# ---------------------------------------------------------------------------
# admission: typed rejection + async backpressure
# ---------------------------------------------------------------------------

def test_async_queue_full_backpressure_and_reject():
    """With wait=False a full queue raises the same typed FleetRejected
    as the sync surface; with the default wait=True the submit coroutine
    parks until a slot frees and every client completes."""
    cfg, params = _setup()
    small = FleetConfig(heartbeat_timeout_s=10.0, backoff_base_s=0.02,
                        tick_s=0.01, queue_limit=2)
    reqs = _requests(cfg, 6, seed=54, max_new=4)

    async def main():
        router = _fleet(cfg, params, config=small)
        async with AsyncFleetClient(router) as client:
            h0 = await client.submit(reqs[0])
            h1 = await client.submit(reqs[1])
            with pytest.raises(FleetRejected) as ei:
                await client.submit(reqs[2], wait=False)
            assert ei.value.reason == "queue_full"
            # backpressured path: all remaining clients park + complete
            await asyncio.gather(
                h0.result(), h1.result(),
                *(client.generate(r) for r in reqs[2:]))
        return router

    router = asyncio.run(main())
    assert all(r.done for r in reqs)
    assert router.stats()["completed"] == 6
    assert [r.out for r in reqs] == _reference_outs(cfg, params, reqs)


# ---------------------------------------------------------------------------
# the deterministic fault matrix under the async loop
# ---------------------------------------------------------------------------

def test_async_kill_restore_schedule_zero_drops():
    """The PR-7 failure matrix headline, now under asyncio: kill a
    replica mid-wave, restore it later — 100% of admitted requests
    complete with oracle-equal streams, zero drops, and the run_clients
    convenience drives one coroutine per request."""
    cfg, params = _setup()
    reqs = _requests(cfg, 8, seed=55, max_new=6)
    inj = FaultInjector([
        FaultEvent(t=0.05, kind="kill", replica="replica0"),
        FaultEvent(t=0.15, kind="restore", replica="replica0")])
    router = _fleet(cfg, params, injector=inj)
    done = run_clients(router, reqs)
    s = router.stats()
    assert s["kills"] == 1 and s["restores"] == 1
    assert s["completed"] == s["submitted"] == 8
    assert s["failed"] == 0 and s["cancelled"] == 0 and s["shed"] == {}
    assert all(r.done for r in done)
    assert [r.out for r in done] == _reference_outs(cfg, params, reqs)


# ---------------------------------------------------------------------------
# lifecycle: drain semantics, shutdown, reuse guards
# ---------------------------------------------------------------------------

def test_async_drain_and_close_semantics():
    cfg, params = _setup()
    reqs = _requests(cfg, 3, seed=56, max_new=4)

    async def main():
        router = _fleet(cfg, params)
        client = AsyncFleetClient(router)
        await client.start()
        handles = [await client.submit(r) for r in reqs]
        await client.drain()                 # barrier: everything served
        assert router._outstanding == 0
        assert all(h.status == "done" for h in handles)
        # streams still consumable after the work finished
        for h, r in zip(handles, reqs):
            assert [t async for t in h] == r.out
        await client.aclose()
        with pytest.raises(RuntimeError):
            await client.submit(reqs[0])     # closed clients refuse work
        return router

    router = asyncio.run(main())
    assert router.stats()["completed"] == 3
